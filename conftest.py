"""Repo-root pytest shim: make `python/` importable so the suite can run
as `pytest python/tests/` from the repository root (the Makefile's
`make test` cds into python/ instead; both work)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
