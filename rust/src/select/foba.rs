//! Adaptive forward–backward greedy selection (FoBa; paper §5, ref \[31\]
//! — Zhang 2009).
//!
//! The paper's discussion: "\[31\] considered a modification of the forward
//! selection for least-squares, which performs corrective steps instead
//! of greedily adding a new feature in each iteration ... shown to have
//! approximately the same computational complexity ... but better
//! performance than greedy forward selection or backward elimination."
//!
//! FoBa's rule: after each forward step, delete any selected feature
//! whose removal increases the criterion by less than ν times the gain
//! of the forward step that would re-add something (here: the standard
//! ν-threshold variant — delete while the cheapest deletion costs less
//! than ν × the last forward gain). Criterion: the same LOO loss used by
//! greedy RLS, so the selector composes with the rest of the framework
//! and inherits its equivalence tests in the ν→∞ (never-delete) limit.
//!
//! [`DroppingFoba`] is the group-drop variant (arXiv 1910.08007): the
//! backward pass ranks every deletion in **one** scan and drops the
//! whole set of ν-qualifying weak features at once (shrinking the group
//! from its costliest member until the joint drop fits the threshold),
//! instead of re-scanning after every single deletion. On data where no
//! deletion qualifies the two selectors take identical trajectories —
//! the cross-selector equivalence suite pins that.

use anyhow::ensure;

use super::session::{
    CoreStep, PolicySession, Session, SessionCore, SessionSelector,
};
use super::{argmin, Round, SelectionConfig, SelectionResult, Selector};
use crate::linalg::Matrix;
use crate::metrics::Loss;
use crate::rls;

/// FoBa selector with deletion threshold `nu ∈ (0, 1]`.
#[derive(Clone, Copy, Debug)]
pub struct Foba {
    /// Deletion threshold: a backward step fires when the cheapest
    /// deletion's criterion increase is < `nu` × the last forward gain.
    pub nu: f64,
    /// Enable the swap phase at |S| = k (overshoot + forced deletion,
    /// accepted only when it strictly improves the criterion).
    pub swap: bool,
    /// Step budget guard.
    pub max_steps: usize,
}

impl Default for Foba {
    fn default() -> Self {
        Foba { nu: 0.5, swap: true, max_steps: 10_000 }
    }
}

/// Round-by-round engine. One session round is either one **grow** step
/// (forward addition + its ν-thresholded corrective deletions) or one
/// **swap** step at |S| = k (overshoot + forced deletion); the swap phase
/// ends when no improving swap exists (`stable`).
struct FobaCore<'a> {
    x: &'a Matrix,
    y: &'a [f64],
    lambda: f64,
    loss: Loss,
    k: usize,
    nu: f64,
    swap: bool,
    max_steps: usize,
    threads: usize,
    /// Group-drop backward pass ([`DroppingFoba`]) instead of the
    /// one-at-a-time deletion loop.
    drop_group: bool,
    s: Vec<usize>,
    rounds: Vec<Round>,
    steps: usize,
    cur: f64,
    stable: bool,
}

impl FobaCore<'_> {
    fn criterion(&self, s: &[usize]) -> f64 {
        if s.is_empty() {
            // empty-model LOO: predict 0 for everything
            return self.y.iter().map(|&yv| self.loss.eval(yv, 0.0)).sum();
        }
        rls::loo_subset_criterion(self.x, s, self.y, self.lambda, self.loss)
    }

    fn forward_scores(&self) -> Vec<f64> {
        // each candidate set retrains independently — deterministic
        // parallel scan
        super::scan_candidates(
            self.x.rows(),
            self.threads,
            |i| !self.s.contains(&i),
            |i| {
                let mut t = self.s.clone();
                t.push(i);
                self.criterion(&t)
            },
        )
    }

    fn deletion_scores(&self) -> Vec<f64> {
        super::scan_ops::add(self.s.len() as u64);
        crate::parallel::par_map(self.threads, self.s.len(), |pos| {
            let mut t = self.s.clone();
            t.remove(pos);
            self.criterion(&t)
        })
    }

    /// LOO criterion of `S ∪ {i}` — candidates are independent, so a
    /// forced round scores only its own candidate.
    fn forward_score_one(&self, i: usize) -> f64 {
        let mut t = self.s.clone();
        t.push(i);
        self.criterion(&t)
    }

    /// Grow step: forward addition + ν-thresholded corrective deletions.
    fn grow_round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let n = self.x.rows();
        self.steps += 1;
        let (b, score_b) = match forced {
            Some(b) => {
                ensure!(b < n, "feature {b} out of range (n={n})");
                ensure!(!self.s.contains(&b), "feature {b} already selected");
                (b, self.forward_score_one(b))
            }
            None => {
                let scores = self.forward_scores();
                match argmin(&scores) {
                    Some(b) => (b, scores[b]),
                    None => return Ok(CoreStep::Exhausted),
                }
            }
        };
        let fwd_gain = self.cur - score_b;
        self.s.push(b);
        self.cur = score_b;
        let round = Round { feature: b, criterion: self.cur };
        self.rounds.push(round.clone());
        if fwd_gain > 0.0 && self.drop_group {
            self.group_drop(b, fwd_gain);
        } else if fwd_gain > 0.0 {
            // delete while cheap relative to the forward gain
            while self.s.len() > 1 && self.steps < self.max_steps {
                self.steps += 1;
                let del = self.deletion_scores();
                let pos = argmin(&del).unwrap();
                if del[pos] - self.cur < self.nu * fwd_gain {
                    self.s.remove(pos);
                    self.cur = del[pos];
                } else {
                    break;
                }
            }
        }
        Ok(CoreStep::Committed(round))
    }

    /// Group-drop backward pass (arXiv 1910.08007): one ranked deletion
    /// scan per forward step; every previously selected feature whose
    /// *individual* removal costs < ν × the forward gain joins the drop
    /// group (cheapest first, position ties low — deterministic). The
    /// joint drop is then verified against the same threshold on the
    /// recomputed criterion, shedding the group's costliest member and
    /// retrying until it fits (each recompute bills one step). `b` — the
    /// feature the forward step just added — never drops, and at least
    /// one feature always remains.
    fn group_drop(&mut self, b: usize, fwd_gain: f64) {
        if self.s.len() <= 1 || self.steps >= self.max_steps {
            return;
        }
        self.steps += 1;
        let del = self.deletion_scores();
        let thresh = self.nu * fwd_gain;
        let mut group: Vec<usize> = (0..self.s.len())
            .filter(|&pos| self.s[pos] != b && del[pos] - self.cur < thresh)
            .collect();
        group.sort_by(|&p, &q| del[p].total_cmp(&del[q]).then(p.cmp(&q)));
        group.truncate(self.s.len() - 1);
        while !group.is_empty() && self.steps < self.max_steps {
            self.steps += 1;
            let keep: Vec<usize> = (0..self.s.len())
                .filter(|pos| !group.contains(pos))
                .map(|pos| self.s[pos])
                .collect();
            let c = self.criterion(&keep);
            if c - self.cur < thresh {
                self.s = keep;
                self.cur = c;
                return;
            }
            // the group jointly costs too much — shed its most
            // expensive member and retry
            group.pop();
        }
    }

    /// Swap step at |S| = k: overshoot to k+1 with the best addition,
    /// then force the cheapest deletion back to k. A net swap strictly
    /// decreases the criterion (guaranteeing termination); when the
    /// forced deletion would just remove the overshoot feature, the set
    /// is swap-stable and the session is done.
    fn swap_round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let n = self.x.rows();
        self.steps += 1;
        // the overshoot feature's own score is never recorded — only the
        // argmin needs the scan, so a forced swap skips it entirely
        let b = match forced {
            Some(b) => {
                ensure!(b < n, "feature {b} out of range (n={n})");
                ensure!(!self.s.contains(&b), "feature {b} already selected");
                b
            }
            None => {
                let scores = self.forward_scores();
                match argmin(&scores) {
                    Some(b) => b,
                    None => {
                        self.stable = true;
                        return Ok(CoreStep::Exhausted);
                    }
                }
            }
        };
        self.s.push(b);
        let del = self.deletion_scores();
        let pos = argmin(&del).unwrap();
        if self.s[pos] == b || del[pos] >= self.cur {
            self.s.pop(); // no improving swap exists — stable
            self.stable = true;
            return Ok(CoreStep::Exhausted);
        }
        self.s.remove(pos);
        self.cur = del[pos];
        let round = Round { feature: b, criterion: self.cur };
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }
}

impl SessionCore for FobaCore<'_> {
    fn target_reached(&self) -> bool {
        // complete once k features stand AND the swap phase (if enabled)
        // has converged
        self.s.len() >= self.k
            && (!self.swap || self.k >= self.x.rows() || self.stable)
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        if self.s.len() < self.k {
            if self.steps >= self.max_steps {
                return Ok(CoreStep::Exhausted);
            }
            self.grow_round(forced)
        } else if self.swap && self.k < self.x.rows() && !self.stable {
            if self.steps >= self.max_steps {
                return Ok(CoreStep::Exhausted);
            }
            self.swap_round(forced)
        } else {
            Ok(CoreStep::Exhausted)
        }
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.s.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        if self.s.is_empty() {
            return Ok(Vec::new());
        }
        let xs = self.x.select_rows(&self.s);
        Ok(rls::train(&xs, self.y, self.lambda))
    }
}

/// Shared `begin` body of [`Foba`] and [`DroppingFoba`] — identical
/// validation and core wiring, differing only in the backward pass.
#[allow(clippy::too_many_arguments)]
fn begin_foba<'a>(
    x: &'a Matrix,
    y: &'a [f64],
    cfg: &SelectionConfig,
    name: &str,
    nu: f64,
    swap: bool,
    max_steps: usize,
    drop_group: bool,
) -> anyhow::Result<Box<dyn Session + 'a>> {
    let n = x.rows();
    ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
    ensure!(cfg.lambda > 0.0, "λ must be positive");
    ensure!(nu > 0.0, "ν must be positive");
    ensure!(x.cols() == y.len(), "shape mismatch");
    super::require_f64(cfg, name)?;
    super::require_no_preselect(cfg, name)?;
    let mut core = FobaCore {
        x,
        y,
        lambda: cfg.lambda,
        loss: cfg.loss,
        k: cfg.k,
        nu,
        swap,
        max_steps,
        threads: crate::parallel::resolve(cfg.threads),
        drop_group,
        s: Vec::new(),
        rounds: Vec::new(),
        steps: 0,
        cur: 0.0,
        stable: false,
    };
    core.cur = core.criterion(&[]);
    Ok(Box::new(PolicySession::new(core, cfg)?))
}

impl SessionSelector for Foba {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        begin_foba(
            x, y, cfg, "foba", self.nu, self.swap, self.max_steps, false,
        )
    }
}

impl Selector for Foba {
    fn name(&self) -> &'static str {
        "foba"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        super::run_to_completion(self.begin(x, y, cfg)?)
    }
}

/// Dropping Forward-Backward selection (arXiv 1910.08007): [`Foba`]
/// whose backward pass drops the whole group of ν-qualifying weak
/// features per forward step in one ranked deletion scan — see
/// [`FobaCore::group_drop`]. Same criterion, stop policies, threading,
/// and session surface as `foba`.
#[derive(Clone, Copy, Debug)]
pub struct DroppingFoba {
    /// Deletion threshold ν ∈ (0, 1] shared with [`Foba::nu`]; here it
    /// gates both group membership and the joint-drop verification.
    pub nu: f64,
    /// Enable the swap phase at |S| = k (identical to [`Foba::swap`]).
    pub swap: bool,
    /// Step budget guard.
    pub max_steps: usize,
}

impl Default for DroppingFoba {
    fn default() -> Self {
        DroppingFoba { nu: 0.5, swap: true, max_steps: 10_000 }
    }
}

impl SessionSelector for DroppingFoba {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        begin_foba(
            x,
            y,
            cfg,
            "dropping-foba",
            self.nu,
            self.swap,
            self.max_steps,
            true,
        )
    }
}

impl Selector for DroppingFoba {
    fn name(&self) -> &'static str {
        "dropping-foba"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        super::run_to_completion(self.begin(x, y, cfg)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;
    use crate::select::greedy::GreedyRls;

    #[test]
    fn reaches_k_on_easy_data() {
        let (ds, mut support) =
            crate::data::synthetic::sparse_regression(200, 20, 4, 0.05, 31);
        let cfg = SelectionConfig { k: 4, lambda: 0.1, loss: Loss::Squared, ..Default::default() };
        let r = Foba::default().select(&ds.x, &ds.y, &cfg).unwrap();
        let mut sel = r.selected.clone();
        sel.sort_unstable();
        support.sort_unstable();
        assert_eq!(sel, support);
    }

    #[test]
    fn tiny_nu_never_deletes_matches_greedy() {
        // ν → 0⁺: deletions require near-zero cost; on generic data none
        // fire and FoBa == greedy forward selection with the same
        // criterion (wrapper-style), which == greedy RLS.
        let ds = crate::data::synthetic::two_gaussians(60, 12, 4, 1.2, 17);
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::Squared, ..Default::default() };
        let foba = Foba { nu: 1e-12, swap: false, max_steps: 10_000 };
        let rf = foba.select(&ds.x, &ds.y, &cfg).unwrap();
        let rg = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(rf.selected, rg.selected);
    }

    #[test]
    fn corrects_a_greedy_mistake() {
        // classic FoBa showcase: two features that jointly explain y
        // better than the single feature greedy grabs first.
        // y = x1 + x2; x3 = 0.9·(x1 + x2) + noise is the greedy bait.
        let mut rng = crate::rng::Pcg64::new(5, 301);
        let m = 120;
        let mut x = Matrix::zeros(3, m);
        let mut y = vec![0.0; m];
        for j in 0..m {
            let a = rng.normal();
            let b = rng.normal();
            x[(0, j)] = a;
            x[(1, j)] = b;
            x[(2, j)] = 0.9 * (a + b) + 0.30 * rng.normal();
            y[j] = a + b;
        }
        let cfg = SelectionConfig { k: 2, lambda: 1e-3, loss: Loss::Squared, ..Default::default() };
        let greedy = GreedyRls.select(&x, &y, &cfg).unwrap();
        assert_eq!(greedy.selected[0], 2, "bait feature should tempt greedy");
        let foba = Foba { nu: 0.9, swap: true, max_steps: 10_000 }
            .select(&x, &y, &cfg)
            .unwrap();
        let mut sel = foba.selected.clone();
        sel.sort_unstable();
        assert_eq!(sel, vec![0, 1], "FoBa must drop the bait: {sel:?}");
    }

    #[test]
    fn dropping_foba_also_drops_the_bait() {
        // the group-drop backward pass must shed the bait feature just
        // like the one-at-a-time pass does
        let mut rng = crate::rng::Pcg64::new(5, 301);
        let m = 120;
        let mut x = Matrix::zeros(3, m);
        let mut y = vec![0.0; m];
        for j in 0..m {
            let a = rng.normal();
            let b = rng.normal();
            x[(0, j)] = a;
            x[(1, j)] = b;
            x[(2, j)] = 0.9 * (a + b) + 0.30 * rng.normal();
            y[j] = a + b;
        }
        let cfg = SelectionConfig { k: 2, lambda: 1e-3, loss: Loss::Squared, ..Default::default() };
        let df = DroppingFoba { nu: 0.9, swap: true, max_steps: 10_000 }
            .select(&x, &y, &cfg)
            .unwrap();
        let mut sel = df.selected.clone();
        sel.sort_unstable();
        assert_eq!(sel, vec![0, 1], "group drop must shed the bait: {sel:?}");
    }

    #[test]
    fn foba_rejects_preselect() {
        let ds = crate::data::synthetic::two_gaussians(20, 5, 2, 1.0, 1);
        let cfg = SelectionConfig::builder()
            .k(2)
            .preselect(Some(crate::select::PreselectConfig {
                p: 3,
                sketch_dim: 0,
                seed: 0,
            }))
            .build();
        for (name, r) in [
            ("foba", Foba::default().select(&ds.x, &ds.y, &cfg)),
            ("dropping-foba", DroppingFoba::default().select(&ds.x, &ds.y, &cfg)),
        ] {
            let err = r.unwrap_err();
            assert!(err.to_string().contains(name), "{err}");
            assert!(err.to_string().contains("--preselect"), "{err}");
        }
    }

    #[test]
    fn rejects_bad_config() {
        let ds = crate::data::synthetic::two_gaussians(20, 5, 2, 1.0, 1);
        let cfg = SelectionConfig { k: 9, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        assert!(Foba::default().select(&ds.x, &ds.y, &cfg).is_err());
        let foba = Foba { nu: 0.0, swap: true, max_steps: 10 };
        let cfg = SelectionConfig { k: 2, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        assert!(foba.select(&ds.x, &ds.y, &cfg).is_err());
    }
}
