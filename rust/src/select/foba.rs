//! Adaptive forward–backward greedy selection (FoBa; paper §5, ref \[31\]
//! — Zhang 2009).
//!
//! The paper's discussion: "\[31\] considered a modification of the forward
//! selection for least-squares, which performs corrective steps instead
//! of greedily adding a new feature in each iteration ... shown to have
//! approximately the same computational complexity ... but better
//! performance than greedy forward selection or backward elimination."
//!
//! FoBa's rule: after each forward step, delete any selected feature
//! whose removal increases the criterion by less than ν times the gain
//! of the forward step that would re-add something (here: the standard
//! ν-threshold variant — delete while the cheapest deletion costs less
//! than ν × the last forward gain). Criterion: the same LOO loss used by
//! greedy RLS, so the selector composes with the rest of the framework
//! and inherits its equivalence tests in the ν→∞ (never-delete) limit.

use anyhow::ensure;

use super::{argmin, Round, SelectionConfig, SelectionResult, Selector, BIG};
use crate::linalg::Matrix;
use crate::rls;

/// FoBa selector with deletion threshold `nu ∈ (0, 1]`.
#[derive(Clone, Copy, Debug)]
pub struct Foba {
    /// Deletion threshold: a backward step fires when the cheapest
    /// deletion's criterion increase is < `nu` × the last forward gain.
    pub nu: f64,
    /// Enable the swap phase at |S| = k (overshoot + forced deletion,
    /// accepted only when it strictly improves the criterion).
    pub swap: bool,
    /// Step budget guard.
    pub max_steps: usize,
}

impl Default for Foba {
    fn default() -> Self {
        Foba { nu: 0.5, swap: true, max_steps: 10_000 }
    }
}

impl Foba {
    fn criterion(
        &self,
        x: &Matrix,
        s: &[usize],
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> f64 {
        if s.is_empty() {
            // empty-model LOO: predict 0 for everything
            return y
                .iter()
                .map(|&yv| cfg.loss.eval(yv, 0.0))
                .sum();
        }
        let xs = x.select_rows(s);
        let p = if xs.rows() <= xs.cols() {
            rls::loo_primal(&xs, y, cfg.lambda)
        } else {
            rls::loo_dual(&xs, y, cfg.lambda)
        };
        cfg.loss.total(y, &p)
    }
}

impl Selector for Foba {
    fn name(&self) -> &'static str {
        "foba"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        let n = x.rows();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(self.nu > 0.0, "ν must be positive");

        let mut s: Vec<usize> = Vec::new();
        let mut rounds = Vec::new();
        let mut steps = 0usize;
        let mut cur = self.criterion(x, &s, y, cfg);

        // phase helpers ----------------------------------------------------
        let forward_scores = |s: &[usize]| -> Vec<f64> {
            let mut scores = vec![BIG; n];
            for i in 0..n {
                if s.contains(&i) {
                    continue;
                }
                let mut t = s.to_vec();
                t.push(i);
                scores[i] = self.criterion(x, &t, y, cfg);
            }
            scores
        };
        let deletion_scores = |s: &[usize]| -> Vec<f64> {
            let mut del = vec![BIG; s.len()];
            for pos in 0..s.len() {
                let mut t = s.to_vec();
                t.remove(pos);
                del[pos] = self.criterion(x, &t, y, cfg);
            }
            del
        };

        // grow phase: forward steps with ν-thresholded corrective deletions
        while s.len() < cfg.k && steps < self.max_steps {
            steps += 1;
            let scores = forward_scores(&s);
            let Some(b) = argmin(&scores) else { break };
            let fwd_gain = cur - scores[b];
            s.push(b);
            cur = scores[b];
            rounds.push(Round { feature: b, criterion: cur });
            if fwd_gain <= 0.0 {
                continue; // no improvement; FoBa keeps growing toward k
            }
            // delete while cheap relative to the forward gain
            while s.len() > 1 && steps < self.max_steps {
                steps += 1;
                let del = deletion_scores(&s);
                let pos = argmin(&del).unwrap();
                if del[pos] - cur < self.nu * fwd_gain {
                    s.remove(pos);
                    cur = del[pos];
                } else {
                    break;
                }
            }
        }

        // swap phase at |S| = k: overshoot to k+1 with the best addition,
        // then force the cheapest deletion back to k. A net swap strictly
        // decreases the criterion (guaranteeing termination); when the
        // forced deletion would just remove the overshoot feature, the
        // set is swap-stable and we stop.
        while self.swap && s.len() == cfg.k && cfg.k < n && steps < self.max_steps {
            steps += 1;
            let scores = forward_scores(&s);
            let Some(b) = argmin(&scores) else { break };
            s.push(b);
            let del = deletion_scores(&s);
            let pos = argmin(&del).unwrap();
            if s[pos] == b || del[pos] >= cur {
                s.pop(); // no improving swap exists — stable
                break;
            }
            let removed = s.remove(pos);
            cur = del[pos];
            rounds.push(Round { feature: b, criterion: cur });
            let _ = removed;
        }

        let xs = x.select_rows(&s);
        let weights = rls::train(&xs, y, cfg.lambda);
        Ok(SelectionResult { selected: s, rounds, weights })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;
    use crate::select::greedy::GreedyRls;

    #[test]
    fn reaches_k_on_easy_data() {
        let (ds, mut support) =
            crate::data::synthetic::sparse_regression(200, 20, 4, 0.05, 31);
        let cfg = SelectionConfig { k: 4, lambda: 0.1, loss: Loss::Squared };
        let r = Foba::default().select(&ds.x, &ds.y, &cfg).unwrap();
        let mut sel = r.selected.clone();
        sel.sort_unstable();
        support.sort_unstable();
        assert_eq!(sel, support);
    }

    #[test]
    fn tiny_nu_never_deletes_matches_greedy() {
        // ν → 0⁺: deletions require near-zero cost; on generic data none
        // fire and FoBa == greedy forward selection with the same
        // criterion (wrapper-style), which == greedy RLS.
        let ds = crate::data::synthetic::two_gaussians(60, 12, 4, 1.2, 17);
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::Squared };
        let foba = Foba { nu: 1e-12, swap: false, max_steps: 10_000 };
        let rf = foba.select(&ds.x, &ds.y, &cfg).unwrap();
        let rg = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(rf.selected, rg.selected);
    }

    #[test]
    fn corrects_a_greedy_mistake() {
        // classic FoBa showcase: two features that jointly explain y
        // better than the single feature greedy grabs first.
        // y = x1 + x2; x3 = 0.9·(x1 + x2) + noise is the greedy bait.
        let mut rng = crate::rng::Pcg64::new(5, 301);
        let m = 120;
        let mut x = Matrix::zeros(3, m);
        let mut y = vec![0.0; m];
        for j in 0..m {
            let a = rng.normal();
            let b = rng.normal();
            x[(0, j)] = a;
            x[(1, j)] = b;
            x[(2, j)] = 0.9 * (a + b) + 0.30 * rng.normal();
            y[j] = a + b;
        }
        let cfg = SelectionConfig { k: 2, lambda: 1e-3, loss: Loss::Squared };
        let greedy = GreedyRls.select(&x, &y, &cfg).unwrap();
        assert_eq!(greedy.selected[0], 2, "bait feature should tempt greedy");
        let foba = Foba { nu: 0.9, swap: true, max_steps: 10_000 }
            .select(&x, &y, &cfg)
            .unwrap();
        let mut sel = foba.selected.clone();
        sel.sort_unstable();
        assert_eq!(sel, vec![0, 1], "FoBa must drop the bait: {sel:?}");
    }

    #[test]
    fn rejects_bad_config() {
        let ds = crate::data::synthetic::two_gaussians(20, 5, 2, 1.0, 1);
        let cfg = SelectionConfig { k: 9, lambda: 1.0, loss: Loss::ZeroOne };
        assert!(Foba::default().select(&ds.x, &ds.y, &cfg).is_err());
        let foba = Foba { nu: 0.0, swap: true, max_steps: 10 };
        let cfg = SelectionConfig { k: 2, lambda: 1.0, loss: Loss::ZeroOne };
        assert!(foba.select(&ds.x, &ds.y, &cfg).is_err());
    }
}
