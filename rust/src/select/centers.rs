//! Reduced-set / center selection for kernel RLS (paper §5).
//!
//! "Analogously to the feature selection methods, many approaches \[have\]
//! been developed also for so-called reduced set selection ... \[and\] for
//! selecting centers for radial basis function networks. ... we plan to
//! investigate how well approaches similar to our feature selection
//! algorithm could perform on the tasks of reduced set or center
//! selection."
//!
//! The investigation is direct: the kernel expansion
//! `f(x) = Σ_{i ∈ S} w_i k(x_i, x)` over a center subset S is a linear
//! model whose "features" are the **columns of the kernel matrix**. So
//! greedy RLS (Algorithm 3) applies verbatim with `X := K` — each
//! candidate center is one kernel column, the LOO criterion and the
//! O(m) per-candidate shortcut carry over unchanged, and selecting k
//! centers costs O(k m²) after the O(m²·dim) kernel assembly (here
//! n = m candidates of length m).

use std::borrow::Cow;

use anyhow::ensure;

use super::greedy::GreedyCore;
use super::session::{
    run_to_completion, PolicySession, Session, SessionSelector,
};
use super::{SelectionConfig, SelectionResult, Selector};
use crate::linalg::Matrix;
use crate::rls::kernel::Kernel;

/// A sparse kernel-expansion model over selected centers.
#[derive(Clone, Debug)]
pub struct ReducedSetModel {
    /// Kernel used.
    pub kernel: Kernel,
    /// Indices of the selected centers (into the training set).
    pub centers: Vec<usize>,
    /// Expansion weights aligned with `centers`.
    pub weights: Vec<f64>,
    /// Center example vectors (feature-major, one column per center).
    pub center_x: Matrix,
}

impl ReducedSetModel {
    /// Predict every column of a feature-major test matrix: O(k·dim) per
    /// example — the reduced-set payoff versus O(m·dim) for full kernel
    /// RLS.
    pub fn predict(&self, x_test: &Matrix) -> Vec<f64> {
        let kt = self.kernel.matrix(x_test, &self.center_x); // (mt × k)
        kt.matvec(&self.weights)
    }
}

/// Greedy center selection: greedy RLS over kernel columns.
#[derive(Clone, Copy, Debug)]
pub struct CenterSelector {
    /// Kernel defining the expansion.
    pub kernel: Kernel,
}

impl SessionSelector for CenterSelector {
    /// Begin a center-selection session: the greedy-RLS engine over the
    /// kernel gram matrix (one candidate per training example), which the
    /// session owns. The session's `x` argument is the raw feature-major
    /// training data; the gram assembly happens here. The O(m²)-per-round
    /// scan and downdate inherit the greedy engine's deterministic
    /// multi-threading via `cfg.threads` (bit-identical centers at any
    /// thread count).
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        ensure!(x.cols() == y.len(), "shape mismatch");
        ensure!(cfg.k <= x.cols(), "k={} > m={}", cfg.k, x.cols());
        super::require_f64(cfg, "greedy-centers")?;
        super::require_no_preselect(cfg, "greedy-centers")?;
        // candidate "feature" matrix: kernel gram, one row per center
        // (rows are candidates exactly like features in Algorithm 3;
        // K is symmetric so rows == columns)
        let gram = self.kernel.gram(x);
        let core = GreedyCore::new(Cow::Owned(gram), Cow::Borrowed(y), cfg)?;
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for CenterSelector {
    fn name(&self) -> &'static str {
        "greedy-centers"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        run_to_completion(self.begin(x, y, cfg)?)
    }
}

impl CenterSelector {
    /// Select `cfg.k` centers from the training set and fit the sparse
    /// expansion. Returns the model and the underlying selection log.
    pub fn fit(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<(ReducedSetModel, SelectionResult)> {
        let r = self.select(x, y, cfg)?;
        let center_x = {
            let mut c = Matrix::zeros(x.rows(), r.selected.len());
            for (j, &idx) in r.selected.iter().enumerate() {
                let col = x.col(idx);
                for (i, &v) in col.iter().enumerate() {
                    c[(i, j)] = v;
                }
            }
            c
        };
        let model = ReducedSetModel {
            kernel: self.kernel,
            centers: r.selected.clone(),
            weights: r.weights.clone(),
            center_x,
        };
        Ok((model, r))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{accuracy, Loss};
    use crate::rls::kernel::KernelRls;
    use crate::select::greedy::GreedyRls;

    fn ring_dataset(seed: u64) -> crate::data::Dataset {
        // radially separable: class = sign(‖x‖ − r): linear models fail,
        // RBF centers succeed — the canonical reduced-set motivation
        let mut rng = crate::rng::Pcg64::new(seed, 201);
        let m = 160;
        let mut x = Matrix::zeros(2, m);
        let mut y = vec![0.0; m];
        for j in 0..m {
            let (a, b) = (rng.normal(), rng.normal());
            x[(0, j)] = a;
            x[(1, j)] = b;
            y[j] = if (a * a + b * b).sqrt() > 1.1 { 1.0 } else { -1.0 };
        }
        crate::data::Dataset::new("ring", x, y)
    }

    #[test]
    fn selects_k_distinct_centers() {
        let ds = ring_dataset(1);
        let sel = CenterSelector { kernel: Kernel::Rbf { gamma: 1.0 } };
        let cfg = SelectionConfig { k: 12, lambda: 0.5, loss: Loss::ZeroOne, ..Default::default() };
        let (model, r) = sel.fit(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(model.centers.len(), 12);
        let mut u = model.centers.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 12);
        assert_eq!(r.selected, model.centers);
    }

    #[test]
    fn reduced_set_approaches_full_kernel_rls() {
        let ds = ring_dataset(2);
        let kernel = Kernel::Rbf { gamma: 1.0 };
        let full = KernelRls::fit(&ds.x, &ds.y, kernel, 0.5);
        let acc_full = accuracy(&ds.y, &full.predict(&ds.x));

        let sel = CenterSelector { kernel };
        let cfg = SelectionConfig { k: 20, lambda: 0.5, loss: Loss::ZeroOne, ..Default::default() };
        let (model, _) = sel.fit(&ds.x, &ds.y, &cfg).unwrap();
        let acc_sparse = accuracy(&ds.y, &model.predict(&ds.x));
        // 20 of 160 centers should recover most of the full model
        assert!(
            acc_sparse >= acc_full - 0.08,
            "sparse {acc_sparse} vs full {acc_full}"
        );
        assert!(acc_sparse > 0.85, "ring should be solvable: {acc_sparse}");
    }

    #[test]
    fn rbf_centers_beat_linear_model_on_ring() {
        let ds = ring_dataset(3);
        let cfg = SelectionConfig { k: 2, lambda: 0.5, loss: Loss::ZeroOne, ..Default::default() };
        // best 2-feature *linear* model on raw coordinates: near chance
        let lin = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        let acc_lin = accuracy(&ds.y, &lin.predictor().predict_matrix(&ds.x));
        // 12 RBF centers: solves it
        let sel = CenterSelector { kernel: Kernel::Rbf { gamma: 1.0 } };
        let cfg12 = SelectionConfig {
            k: 12,
            lambda: 0.5,
            loss: Loss::ZeroOne,
            ..Default::default()
        };
        let (model, _) = sel.fit(&ds.x, &ds.y, &cfg12).unwrap();
        let acc_rbf = accuracy(&ds.y, &model.predict(&ds.x));
        assert!(
            acc_rbf > acc_lin + 0.15,
            "rbf {acc_rbf} vs linear {acc_lin}"
        );
    }

    #[test]
    fn prediction_uses_only_selected_centers() {
        let ds = ring_dataset(4);
        let sel = CenterSelector { kernel: Kernel::Rbf { gamma: 0.7 } };
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let (model, _) = sel.fit(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(model.center_x.cols(), 5);
        // manual expansion must match predict()
        let p = model.predict(&ds.x);
        for j in [0usize, 17, 42] {
            let xj = ds.x.col(j);
            let manual: f64 = model
                .centers
                .iter()
                .zip(&model.weights)
                .map(|(&ci, &w)| {
                    let c = ds.x.col(ci);
                    w * model.kernel.eval(&xj, &c)
                })
                .sum();
            assert!((p[j] - manual).abs() < 1e-10);
        }
    }
}
