//! **Greedy RLS** — the paper's Algorithm 3, native Rust engine.
//!
//! O(kmn) time, O(mn) space. State per selection run:
//!
//! * `ct` — the cache matrix C = G Xᵀ stored **transposed** (n rows of
//!   length m, so `ct[i]` is the contiguous column C[:, i] that candidate
//!   i streams — the layout is the hot-path optimization, see
//!   EXPERIMENTS.md §Perf);
//! * `a = G y` — dual variables;
//! * `d = diag(G)`.
//!
//! Per round: score all candidates (eqs. 14/15/17 + the dual LOO shortcut
//! eq. 8, O(m) each), pick the argmin, commit it with the SMW rank-1
//! downdate (O(mn)).
//!
//! Both O(mn) passes run on the deterministic thread layer
//! ([`crate::parallel`], sized by `SelectionConfig::threads`): the scan is
//! sharded over quad blocks of the active list and the downdate over the
//! n independent cache rows, so results stay bit-identical to the serial
//! engine at any thread count (see EXPERIMENTS.md §Perf for the
//! serial-vs-parallel measurement protocol).
//!
//! The inner loops themselves live in the [`crate::kernel`] tier: this
//! module owns the *shape* of the passes (sharding, quad grouping,
//! staging, the active list) and dispatches the arithmetic once per
//! session by [`KernelKind`] (scalar reference vs opt-in SIMD — bit-
//! identical) and [`Precision`] (f64 reference vs the f32-cache
//! mixed-precision representation — tolerance-gated).
//!
//! The same state type backs the PJRT engine's numerical cross-checks and
//! the microbenchmarks, so `GreedyState` is public.

use std::borrow::Cow;

use anyhow::ensure;

use super::session::{
    CoreStep, PolicySession, Session, SessionCore, SessionSelector,
};
use super::{argmin, Round, SelectionConfig, SelectionResult, Selector, BIG};
use crate::data::storage::{MatrixStore, StorageOptions};
use crate::kernel::{self, KernelKind, Precision};
use crate::linalg::{dot, Matrix};
use crate::metrics::Loss;

/// Mutable selection-state of Algorithm 3 (native engine).
pub struct GreedyState {
    /// m — number of training examples.
    pub m: usize,
    /// n — number of candidate features.
    pub n: usize,
    /// λ.
    pub lambda: f64,
    /// Cᵀ, row i = C[:, i] (n × m, row-major). **Empty when
    /// `precision == F32c`** — the cache then lives in the private f32
    /// buffer and is only reachable through the scoring/commit API.
    pub ct: Vec<f64>,
    /// Dual variables a = G y.
    pub a: Vec<f64>,
    /// diag(G).
    pub d: Vec<f64>,
    /// 1.0 for evaluable candidates, 0.0 for selected ones. **Read-only
    /// reflection** of the selection state for the PJRT cross-checks and
    /// benches: it is maintained by [`GreedyState::commit`] alongside the
    /// internal active list that the scans actually iterate, so mutating
    /// it by hand does not mask a candidate — use `commit` to retire one.
    pub cand_mask: Vec<f64>,
    /// Selected features in order.
    pub selected: Vec<usize>,
    /// Resolved worker-thread count for the O(mn) passes (≥ 1); set via
    /// [`GreedyState::with_threads`], 1 after [`GreedyState::init`].
    pub threads: usize,
    /// Column-tile width for the LLC-tiled scan/commit kernels; `0`
    /// (the default) runs the untiled kernels. Set via
    /// [`GreedyState::with_tile_cols`], which normalizes the width to a
    /// multiple of 8 ≥ 8 (or 0). **Every value yields bit-identical
    /// scores, caches, and selections** — the tiled kernels carry their
    /// accumulators across tiles, so each candidate sees the serial
    /// operation sequence exactly; tiling only localizes memory traffic.
    pub tile_cols: usize,
    /// Which f64 kernel implementation scores and commits run
    /// ([`KernelKind::active`] after [`GreedyState::init`]; override via
    /// [`GreedyState::with_kernel`]). Every kind is bit-identical —
    /// this exists so equivalence tests can force the scalar reference
    /// inside a `--features simd` build.
    pub kernel: KernelKind,
    /// Cache representation ([`Precision::F64`] after
    /// [`GreedyState::init`]; switch via
    /// [`GreedyState::with_precision`]). Read-only reflection — flip it
    /// only through the builder, which converts the cache.
    pub precision: Precision,
    /// The f32 cache (row i = C[:, i]) when `precision == F32c`; empty
    /// otherwise.
    ct32: Vec<f32>,
    /// Ascending active-candidate list, maintained incrementally by
    /// [`GreedyState::commit`] (never rebuilt from `cand_mask` — the
    /// rebuild was an O(n) per-call allocation on the hot path).
    active: Vec<usize>,
    /// Reusable commit scratch: copy of the committed column C[:, b].
    scratch_cb: Vec<f64>,
    /// Reusable commit scratch: the SMW update vector u = c_b / denom.
    scratch_u: Vec<f64>,
}

impl GreedyState {
    /// Initialize caches for the empty feature set:
    /// C = Xᵀ/λ, a = y/λ, d = 1/λ (Algorithm 3, lines 1–4).
    pub fn init(x: &Matrix, y: &[f64], lambda: f64) -> GreedyState {
        let n = x.rows();
        let m = x.cols();
        assert_eq!(m, y.len());
        assert!(lambda > 0.0, "λ must be positive");
        let inv = 1.0 / lambda;
        let mut ct = vec![0.0; n * m];
        for i in 0..n {
            let src = x.row(i);
            let dst = &mut ct[i * m..(i + 1) * m];
            for (d_, &s) in dst.iter_mut().zip(src) {
                *d_ = s * inv;
            }
        }
        GreedyState {
            m,
            n,
            lambda,
            ct,
            a: y.iter().map(|&v| v * inv).collect(),
            d: vec![inv; m],
            cand_mask: vec![1.0; n],
            selected: Vec::new(),
            threads: 1,
            tile_cols: 0,
            kernel: KernelKind::active(),
            precision: Precision::F64,
            ct32: Vec::new(),
            active: (0..n).collect(),
            scratch_cb: Vec::with_capacity(m),
            scratch_u: Vec::with_capacity(m),
        }
    }

    /// Set the worker-thread count for [`GreedyState::score_all`] and
    /// [`GreedyState::commit`] (`0` = available parallelism; the resolved
    /// count is stored). Results are bit-identical at any value — see
    /// [`crate::parallel`].
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = crate::parallel::resolve(threads);
        self
    }

    /// Set the column-tile width for the scan and commit kernels. `0`
    /// keeps the untiled kernels; any other value is rounded **down** to
    /// a multiple of 8 (floor 8), and widths that cover the whole of `m`
    /// fall back to 0 because a single tile is the untiled walk. Scores,
    /// caches, and selections are bit-identical for every setting (the
    /// tiled kernels carry their accumulators across tiles), so this is
    /// purely a memory-locality knob — see ARCHITECTURE.md §Data
    /// backends for the geometry.
    pub fn with_tile_cols(mut self, tile_cols: usize) -> Self {
        self.tile_cols = normalize_tile(tile_cols, self.m);
        self
    }

    /// Pin the f64 kernel implementation (default:
    /// [`KernelKind::active`], i.e. SIMD in a `--features simd` build).
    /// Every kind yields bit-identical scores, caches, and selections —
    /// the lane kernels mirror the scalar accumulators exactly — so
    /// this is a test/bench knob, not a semantic one.
    pub fn with_kernel(mut self, kernel: KernelKind) -> Self {
        self.kernel = kernel;
        self
    }

    /// Select the cache representation. [`Precision::F64`] is a no-op;
    /// [`Precision::F32c`] demotes the cache to f32 **now** (one
    /// rounding per element) and routes every subsequent scan/commit
    /// through the compensated mixed-precision kernels
    /// ([`crate::kernel::f32c`]). Call this once, immediately after
    /// [`GreedyState::init`], before any rounds — converting a
    /// mid-session cache would compound rounding with downdate history.
    /// There is no way back to f64: the dropped bits are gone.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        if precision == Precision::F32c && self.precision == Precision::F64 {
            self.ct32 = kernel::f32c::demote(&self.ct);
            self.ct = Vec::new();
        }
        self.precision = precision;
        self
    }

    /// Restrict the candidate set to `survivors` (ascending, in-range)
    /// before any rounds — the sketched-preselection entry point
    /// ([`super::sketch`]). Non-survivors are masked exactly the way
    /// [`GreedyState::commit`] retires selected features (mask zeroed,
    /// dropped from the active list), so scans skip them, commits and
    /// forced rounds reject them, and every downstream path — sessions,
    /// checkpoints, warm starts, the PJRT mask reflection — works
    /// unchanged.
    ///
    /// Panics if any round already ran: restriction is a pre-round
    /// configuration step, like [`GreedyState::with_precision`].
    pub fn restrict_to(mut self, survivors: &[usize]) -> Self {
        assert!(
            self.selected.is_empty(),
            "candidate restriction must precede the first round"
        );
        for v in self.cand_mask.iter_mut() {
            *v = 0.0;
        }
        for &i in survivors {
            assert!(i < self.n, "survivor {i} out of range (n={})", self.n);
            self.cand_mask[i] = 1.0;
        }
        self.active = survivors.to_vec();
        self
    }

    /// LOO criterion of S ∪ {i} for every candidate i (Algorithm 3 lines
    /// 8–17, all candidates). Selected/masked candidates score [`BIG`].
    ///
    /// Candidates are processed in blocks of 4 so the shared `a`, `d`,
    /// `y` streams are read once per block instead of once per candidate
    /// — the register-blocking step of the §Perf log (the per-candidate
    /// arrays `v_i`, `c_i` are unavoidable traffic either way).
    ///
    /// With `threads > 1` the active list is sharded across scoped
    /// workers **at quad boundaries** ([`crate::parallel::quad_ranges`]),
    /// so every worker's blocks-of-4 grouping — and hence the exact
    /// per-candidate operation order — matches the serial scan: the
    /// scores are bit-identical at any thread count, and to
    /// [`GreedyState::score_of`].
    pub fn score_all(&self, x: &Matrix, y: &[f64], loss: Loss) -> Vec<f64> {
        let m = self.m;
        super::scan_ops::add(self.active.len() as u64);
        let mut scores = vec![BIG; self.n];
        let active = &self.active;
        let ranges = crate::parallel::quad_ranges(active.len(), self.threads);
        let per_range = crate::parallel::map_ranges(&ranges, |r| {
            let slice = &active[r];
            let mut out = Vec::with_capacity(slice.len());
            if self.precision == Precision::F32c {
                // Mixed precision: every candidate is scored by one
                // independent sequential pass (no quad coupling), so
                // shard boundaries can't shift any result bit.
                let vrows: Vec<&[f64]> =
                    slice.iter().map(|&i| x.row(i)).collect();
                let crows: Vec<&[f32]> = slice
                    .iter()
                    .map(|&i| &self.ct32[i * m..(i + 1) * m])
                    .collect();
                kernel::f32c::score_rows(
                    &vrows, &crows, &self.a, &self.d, y, loss, &mut out,
                );
                return out;
            }
            if self.tile_cols > 0 {
                let vrows: Vec<&[f64]> =
                    slice.iter().map(|&i| x.row(i)).collect();
                let crows: Vec<&[f64]> = slice
                    .iter()
                    .map(|&i| &self.ct[i * m..(i + 1) * m])
                    .collect();
                kernel::score_rows_tiled(
                    self.kernel,
                    &vrows,
                    &crows,
                    &self.a,
                    &self.d,
                    y,
                    loss,
                    self.tile_cols,
                    &mut out,
                );
                return out;
            }
            let mut chunks = slice.chunks_exact(4);
            for quad in &mut chunks {
                let [i0, i1, i2, i3] = [quad[0], quad[1], quad[2], quad[3]];
                let e = kernel::score_quad(
                    self.kernel,
                    [x.row(i0), x.row(i1), x.row(i2), x.row(i3)],
                    [
                        &self.ct[i0 * m..(i0 + 1) * m],
                        &self.ct[i1 * m..(i1 + 1) * m],
                        &self.ct[i2 * m..(i2 + 1) * m],
                        &self.ct[i3 * m..(i3 + 1) * m],
                    ],
                    &self.a,
                    &self.d,
                    y,
                    loss,
                );
                out.extend_from_slice(&e);
            }
            for &i in chunks.remainder() {
                let v = x.row(i);
                let c = &self.ct[i * m..(i + 1) * m];
                out.push(kernel::score_one(
                    self.kernel,
                    v,
                    c,
                    &self.a,
                    &self.d,
                    y,
                    loss,
                ));
            }
            out
        });
        for (r, vals) in ranges.iter().zip(per_range) {
            for (&i, v) in active[r.clone()].iter().zip(vals) {
                scores[i] = v;
            }
        }
        scores
    }

    /// Score a single candidate `b`, bit-identical to the value
    /// [`GreedyState::score_all`] would report for it, in O(m) instead of
    /// O(mn). `score_all` processes the active candidates in blocks of 4,
    /// so the exact arithmetic for `b` depends on its position in the
    /// active list: this recomputes just `b`'s quad (or its scalar
    /// remainder slot). Forced session rounds (warm-start replay, the
    /// fixed-order CV baseline) use this so replays stay cheap while
    /// remaining bit-identical to a greedy run's recorded criterion.
    ///
    /// Panics if `b` is not an active candidate (already selected or out
    /// of range) — the same contract as [`GreedyState::commit`].
    pub fn score_of(
        &self,
        x: &Matrix,
        y: &[f64],
        loss: Loss,
        b: usize,
    ) -> f64 {
        let m = self.m;
        super::scan_ops::add(1);
        let active = &self.active;
        let pos = active
            .binary_search(&b)
            // xtask-allow: no-panic-hot-path -- documented panic contract:
            // callers only pass candidates drawn from the active set.
            .expect("candidate must be active");
        if self.precision == Precision::F32c {
            // f32c scores are per-candidate sequential passes — no quad
            // coupling, so the single-candidate call IS the score_all
            // arithmetic for `b`.
            let v = x.row(b);
            let c = &self.ct32[b * m..(b + 1) * m];
            return kernel::f32c::score_one(v, c, &self.a, &self.d, y, loss);
        }
        let quad_start = pos - pos % 4;
        if quad_start + 4 <= active.len() {
            let [i0, i1, i2, i3] = [
                active[quad_start],
                active[quad_start + 1],
                active[quad_start + 2],
                active[quad_start + 3],
            ];
            let e = kernel::score_quad(
                self.kernel,
                [x.row(i0), x.row(i1), x.row(i2), x.row(i3)],
                [
                    &self.ct[i0 * m..(i0 + 1) * m],
                    &self.ct[i1 * m..(i1 + 1) * m],
                    &self.ct[i2 * m..(i2 + 1) * m],
                    &self.ct[i3 * m..(i3 + 1) * m],
                ],
                &self.a,
                &self.d,
                y,
                loss,
            );
            e[pos - quad_start]
        } else {
            let v = x.row(b);
            let c = &self.ct[b * m..(b + 1) * m];
            kernel::score_one(self.kernel, v, c, &self.a, &self.d, y, loss)
        }
    }

    /// Commit feature `b` (Algorithm 3 lines 23–30): update a, d, and the
    /// whole cache C ← C − u (vᵀ C) in O(mn).
    ///
    /// The n cache-row downdates are independent, so they are sharded
    /// across `threads` workers ([`crate::parallel::rank1_row_update`]);
    /// each row receives the identical fused serial update, keeping the
    /// caches bit-identical at any thread count. The O(m) `c_b`/`u`
    /// staging buffers are reusable scratch on the state — commit
    /// allocates nothing after the first round.
    pub fn commit(&mut self, x: &Matrix, b: usize) {
        assert!(self.cand_mask[b] != 0.0, "feature {b} already selected");
        let m = self.m;
        let v = x.row(b);
        let mut cb = std::mem::take(&mut self.scratch_cb);
        let (denom, va) = if self.precision == Precision::F32c {
            // Stage c_b promoted to f64 once; the f32-sourced dots run
            // the compensated accumulator like the scan.
            kernel::f32c::promote_into(&self.ct32[b * m..(b + 1) * m], &mut cb);
            (
                1.0 + kernel::f32c::neumaier_dot(v, &cb),
                kernel::f32c::neumaier_dot(v, &self.a),
            )
        } else {
            cb.clear();
            cb.extend_from_slice(&self.ct[b * m..(b + 1) * m]);
            (
                1.0 + kernel::dot(self.kernel, v, &cb),
                kernel::dot(self.kernel, v, &self.a),
            )
        };
        let mut u = std::mem::take(&mut self.scratch_u);
        u.clear();
        u.extend(cb.iter().map(|&c| c / denom));

        // a ← a − u (vᵀ a);  d ← d − u ∘ c_b (fused, serial — the O(m)
        // epilogue stays on the scalar kernel for every kind/precision)
        kernel::update_ad(&mut self.a, &mut self.d, &u, &cb, va, -1.0);

        // C ← C − u (vᵀ C): per candidate row i of Cᵀ, w_i = v·C[:,i],
        // then ct[i] ← ct[i] − w_i · u. One fused pass per row, rows
        // sharded across workers; tile_cols = 0 dispatches to the
        // untiled update, any other width is bit-identical to it.
        if self.precision == Precision::F32c {
            crate::parallel::rank1_row_update_f32c(
                self.threads,
                &mut self.ct32,
                m,
                v,
                &u,
                -1.0,
            );
        } else {
            crate::parallel::rank1_row_update_tiled(
                self.kernel,
                self.threads,
                &mut self.ct,
                m,
                v,
                &u,
                -1.0,
                self.tile_cols,
            );
        }

        self.cand_mask[b] = 0.0;
        let pos = self
            .active
            .binary_search(&b)
            // xtask-allow: no-panic-hot-path -- documented panic contract:
            // commit is only called with the feature chosen from `active`.
            .expect("feature must be active");
        self.active.remove(pos);
        self.selected.push(b);
        self.scratch_cb = cb;
        self.scratch_u = u;
    }

    /// Final weights w = X_S a over the selected features (Algorithm 3
    /// line 32), in selection order.
    pub fn weights(&self, x: &Matrix) -> Vec<f64> {
        self.selected
            .iter()
            .map(|&i| dot(x.row(i), &self.a))
            .collect()
    }
}

/// Normalize a requested tile width against row length `m`: `0` stays 0
/// (untiled); anything else is floored to a multiple of 8 (minimum 8);
/// widths covering all of `m` collapse back to 0 because one tile is
/// exactly the untiled walk. Multiples of 8 keep tile starts even (the
/// scalar kernel pairs elements) and quad-aligned (the dot kernel runs
/// 4-wide), which is what makes every width bit-identical.
fn normalize_tile(tile_cols: usize, m: usize) -> usize {
    if tile_cols == 0 {
        return 0;
    }
    let t = tile_cols.max(8);
    let t = t - t % 8;
    if t >= m {
        0
    } else {
        t
    }
}

/// Out-of-core twin of [`GreedyState`]: `X` and the cache matrix Cᵀ live
/// in [`MatrixStore`]s (RAM or mmap-backed scratch), and the two O(mn)
/// passes stream them through bounded row windows with the LLC-tiled
/// kernels. Every floating-point operation lands in the same order as
/// the in-RAM engine's, so selections, criteria, and weights are
/// **bit-identical** to [`GreedyState`] at any thread count, window
/// size, or tile width — the backend-equivalence tests pin this.
///
/// Bookkeeping errors surface as `Result`s instead of panics: this type
/// fronts multi-gigabyte runs where an abort loses hours.
pub(crate) struct StoredGreedyState {
    m: usize,
    n: usize,
    ct: MatrixStore,
    a: Vec<f64>,
    d: Vec<f64>,
    cand_mask: Vec<f64>,
    selected: Vec<usize>,
    threads: usize,
    /// Always ≥ 8 and a multiple of 8: the stored engine runs the tiled
    /// kernels unconditionally (they are bit-identical to the untiled
    /// ones, and windows make untiled walks pointless).
    tile_cols: usize,
    /// f64 kernel dispatch, fixed at init ([`KernelKind::active`]);
    /// every kind is bit-identical, so the stored engine matches the
    /// in-RAM engine whatever the build features. The stored engine is
    /// f64-only — [`StoredGreedyCore::new`] rejects `F32c`.
    kernel: KernelKind,
    active: Vec<usize>,
    scratch_v: Vec<f64>,
    scratch_cb: Vec<f64>,
    scratch_u: Vec<f64>,
}

/// Default tile width for the stored engine when `opts.tile_cols` is 0:
/// size the ~11 concurrent f64 streams of a scan quad (4 `v`, 4 `c`,
/// plus `a`, `d`, `y`) to a 2 MiB LLC slice, floored to a multiple of 8.
/// ≈ 23 824 columns — see EXPERIMENTS.md §Out-of-core for the roofline
/// arithmetic behind the 11-stream count.
const STORED_TILE_AUTO: usize = {
    let t = (2 << 20) / (8 * 11);
    t - t % 8
};

impl StoredGreedyState {
    /// Algorithm 3 lines 1–4 against stored data: Cᵀ is created as a new
    /// store with `opts` (so `--backend mmap` keeps the cache out of RAM
    /// too) and filled window-by-window with `X/λ`.
    fn init(
        x: &MatrixStore,
        y: &[f64],
        lambda: f64,
        opts: &StorageOptions,
    ) -> anyhow::Result<StoredGreedyState> {
        let n = x.rows();
        let m = x.row_len();
        ensure!(m == y.len(), "shape mismatch");
        ensure!(lambda > 0.0, "λ must be positive");
        let inv = 1.0 / lambda;
        let mut ct = MatrixStore::zeros(n, m, opts)?;
        let step = x.window_rows().min(ct.window_rows()).max(1);
        let mut r0 = 0;
        while r0 < n {
            let r1 = (r0 + step).min(n);
            x.read_rows(r0..r1, |xs| {
                ct.write_rows(r0..r1, |cs| {
                    for (c_, &s) in cs.iter_mut().zip(xs) {
                        *c_ = s * inv;
                    }
                })
            })??;
            r0 = r1;
        }
        let tile = if opts.tile_cols > 0 {
            let t = opts.tile_cols.max(8);
            t - t % 8
        } else {
            STORED_TILE_AUTO
        };
        Ok(StoredGreedyState {
            m,
            n,
            ct,
            a: y.iter().map(|&v| v * inv).collect(),
            d: vec![inv; m],
            cand_mask: vec![1.0; n],
            selected: Vec::new(),
            threads: 1,
            tile_cols: tile,
            kernel: KernelKind::active(),
            active: (0..n).collect(),
            scratch_v: Vec::with_capacity(m),
            scratch_cb: Vec::with_capacity(m),
            scratch_u: Vec::with_capacity(m),
        })
    }

    fn with_threads(mut self, threads: usize) -> Self {
        self.threads = crate::parallel::resolve(threads);
        self
    }

    /// Stored twin of [`GreedyState::restrict_to`] — same invariants,
    /// surfaced as a `Result` like the rest of this engine.
    fn restrict_to(mut self, survivors: &[usize]) -> anyhow::Result<Self> {
        ensure!(
            self.selected.is_empty(),
            "candidate restriction must precede the first round"
        );
        for v in self.cand_mask.iter_mut() {
            *v = 0.0;
        }
        for &i in survivors {
            ensure!(i < self.n, "survivor {i} out of range (n={})", self.n);
            self.cand_mask[i] = 1.0;
        }
        self.active = survivors.to_vec();
        Ok(self)
    }

    /// Windowed, tiled scan — the stored twin of
    /// [`GreedyState::score_all`]. The active list is sharded at quad
    /// boundaries exactly like the in-RAM scan; within a shard,
    /// consecutive quads are greedily grouped while their candidate-row
    /// span fits one read window of both `X` and Cᵀ, each group is
    /// scored from the mapped slices, and a quad whose own span exceeds
    /// the window (sparse active list, tiny window) falls back to
    /// staging its ≤ 4 rows through per-row copies. Group boundaries
    /// never change the blocks-of-4 grouping, so scores stay
    /// bit-identical to the in-RAM engine.
    fn score_all(
        &self,
        x: &MatrixStore,
        y: &[f64],
        loss: Loss,
    ) -> anyhow::Result<Vec<f64>> {
        let m = self.m;
        let tile = self.tile_cols;
        super::scan_ops::add(self.active.len() as u64);
        let mut scores = vec![BIG; self.n];
        let active = &self.active;
        let wrows = x.window_rows().min(self.ct.window_rows()).max(1);
        let ranges = crate::parallel::quad_ranges(active.len(), self.threads);
        let per_range = crate::parallel::map_ranges(&ranges, |r| {
            let slice = &active[r];
            let mut out = Vec::with_capacity(slice.len());
            let mut stage_v: Vec<Vec<f64>> = vec![Vec::new(); 4];
            let mut stage_c: Vec<Vec<f64>> = vec![Vec::new(); 4];
            let mut pos = 0;
            while pos < slice.len() {
                let unit = 4.min(slice.len() - pos);
                let lo = slice[pos];
                if slice[pos + unit - 1] + 1 - lo > wrows {
                    // Window too small for even one quad's span: stage
                    // the rows through per-row copies (correct for any
                    // window size; only hit with sparse active lists).
                    for t in 0..unit {
                        x.read_row_into(slice[pos + t], &mut stage_v[t])?;
                        self.ct
                            .read_row_into(slice[pos + t], &mut stage_c[t])?;
                    }
                    let vrows: Vec<&[f64]> =
                        stage_v[..unit].iter().map(|v| v.as_slice()).collect();
                    let crows: Vec<&[f64]> =
                        stage_c[..unit].iter().map(|c| c.as_slice()).collect();
                    kernel::score_rows_tiled(
                        self.kernel, &vrows, &crows, &self.a, &self.d, y,
                        loss, tile, &mut out,
                    );
                    // xtask-allow: serial-float-reduction -- usize quad cursor, not a float accumulator
                    pos += unit;
                    continue;
                }
                // Grow the group by whole quads while the row span fits
                // one window.
                let mut end = pos + unit;
                loop {
                    let next = 4.min(slice.len() - end);
                    if next == 0 || slice[end + next - 1] + 1 - lo > wrows {
                        break;
                    }
                    // xtask-allow: serial-float-reduction -- usize quad cursor, not a float accumulator
                    end += next;
                }
                let row0 = lo;
                let row1 = slice[end - 1] + 1;
                x.read_rows(row0..row1, |xs| {
                    self.ct.read_rows(row0..row1, |cs| {
                        let vrows: Vec<&[f64]> = slice[pos..end]
                            .iter()
                            .map(|&i| &xs[(i - row0) * m..(i - row0 + 1) * m])
                            .collect();
                        let crows: Vec<&[f64]> = slice[pos..end]
                            .iter()
                            .map(|&i| &cs[(i - row0) * m..(i - row0 + 1) * m])
                            .collect();
                        kernel::score_rows_tiled(
                            self.kernel, &vrows, &crows, &self.a, &self.d,
                            y, loss, tile, &mut out,
                        );
                    })
                })??;
                pos = end;
            }
            Ok(out)
        });
        for (r, vals) in ranges.iter().zip(per_range) {
            let vals: Vec<f64> = vals?;
            for (&i, v) in active[r.clone()].iter().zip(vals) {
                scores[i] = v;
            }
        }
        Ok(scores)
    }

    /// Stored twin of [`GreedyState::score_of`]: recompute candidate
    /// `b`'s quad (or scalar remainder slot) from per-row staged copies.
    /// O(m) reads; used only for forced rounds (warm-start replay).
    fn score_of(
        &self,
        x: &MatrixStore,
        y: &[f64],
        loss: Loss,
        b: usize,
    ) -> anyhow::Result<f64> {
        super::scan_ops::add(1);
        let active = &self.active;
        let pos = active
            .binary_search(&b)
            .map_err(|_| anyhow::anyhow!("candidate {b} is not active"))?;
        let quad_start = pos - pos % 4;
        let unit = 4.min(active.len() - quad_start);
        let mut stage_v: Vec<Vec<f64>> = vec![Vec::new(); unit];
        let mut stage_c: Vec<Vec<f64>> = vec![Vec::new(); unit];
        for t in 0..unit {
            x.read_row_into(active[quad_start + t], &mut stage_v[t])?;
            self.ct.read_row_into(active[quad_start + t], &mut stage_c[t])?;
        }
        if unit == 4 {
            let e = kernel::score_quad(
                self.kernel,
                [&stage_v[0], &stage_v[1], &stage_v[2], &stage_v[3]],
                [&stage_c[0], &stage_c[1], &stage_c[2], &stage_c[3]],
                &self.a,
                &self.d,
                y,
                loss,
            );
            Ok(e[pos - quad_start])
        } else {
            let t = pos - quad_start;
            Ok(kernel::score_one(
                self.kernel,
                &stage_v[t],
                &stage_c[t],
                &self.a,
                &self.d,
                y,
                loss,
            ))
        }
    }

    /// Stored twin of [`GreedyState::commit`]: the serial a/d downdate
    /// runs on staged copies of `x_b` and C[:, b] (bit-identical — `dot`
    /// over a copy is `dot` over the row), and the O(mn) cache downdate
    /// streams Cᵀ through writable windows sharded across workers.
    fn commit(&mut self, x: &MatrixStore, b: usize) -> anyhow::Result<()> {
        ensure!(
            self.cand_mask.get(b).copied().unwrap_or(0.0) != 0.0,
            "feature {b} already selected or out of range"
        );
        let m = self.m;
        let mut v = std::mem::take(&mut self.scratch_v);
        x.read_row_into(b, &mut v)?;
        let mut cb = std::mem::take(&mut self.scratch_cb);
        self.ct.read_row_into(b, &mut cb)?;
        let denom = 1.0 + kernel::dot(self.kernel, &v, &cb);
        let mut u = std::mem::take(&mut self.scratch_u);
        u.clear();
        u.extend(cb.iter().map(|&c| c / denom));

        let va = kernel::dot(self.kernel, &v, &self.a);
        kernel::update_ad(&mut self.a, &mut self.d, &u, &cb, va, -1.0);

        let tile = self.tile_cols;
        let kind = self.kernel;
        self.ct.par_update_row_blocks(self.threads, |_, slab| {
            crate::parallel::rank1_block_update(
                kind, slab, m, &v, &u, -1.0, tile,
            );
        })?;

        self.cand_mask[b] = 0.0;
        let pos = self
            .active
            .binary_search(&b)
            .map_err(|_| anyhow::anyhow!("feature {b} is not active"))?;
        self.active.remove(pos);
        self.selected.push(b);
        self.scratch_v = v;
        self.scratch_cb = cb;
        self.scratch_u = u;
        Ok(())
    }

    /// Final weights w = X_S a, one streamed row read per selected
    /// feature.
    fn weights(&self, x: &MatrixStore) -> anyhow::Result<Vec<f64>> {
        let mut buf = Vec::with_capacity(self.m);
        let mut w = Vec::with_capacity(self.selected.len());
        for &i in &self.selected {
            x.read_row_into(i, &mut buf)?;
            w.push(dot(&buf, &self.a));
        }
        Ok(w)
    }
}

/// Round-by-round engine over stored (possibly out-of-core) data: owns
/// its [`MatrixStore`] and labels, mirrors [`GreedyCore`]'s round logic
/// verbatim. Backs [`GreedyRls::begin_stored`].
pub(crate) struct StoredGreedyCore {
    x: MatrixStore,
    y: Vec<f64>,
    loss: Loss,
    k: usize,
    st: StoredGreedyState,
    rounds: Vec<Round>,
}

impl StoredGreedyCore {
    pub(crate) fn new(
        x: MatrixStore,
        y: Vec<f64>,
        cfg: &SelectionConfig,
        opts: &StorageOptions,
    ) -> anyhow::Result<Self> {
        ensure!(cfg.k <= x.rows(), "k={} > n={}", cfg.k, x.rows());
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(x.row_len() == y.len(), "shape mismatch");
        ensure!(
            cfg.precision == Precision::F64,
            "--precision f32c runs on the in-RAM backend only (the stored \
             cache streams f64 windows)"
        );
        // Streamed finiteness check — same contract and message as the
        // in-RAM validation, one window at a time.
        let step = x.window_rows().max(1);
        let mut r0 = 0;
        while r0 < x.rows() {
            let r1 = (r0 + step).min(x.rows());
            let ok =
                x.read_rows(r0..r1, |rows| rows.iter().all(|v| v.is_finite()))?;
            ensure!(ok, "X contains non-finite values");
            r0 = r1;
        }
        ensure!(
            y.iter().all(|v| v.is_finite()),
            "y contains non-finite values"
        );
        let mut st = StoredGreedyState::init(&x, &y, cfg.lambda, opts)?
            .with_threads(cfg.threads);
        if let Some(keep) = super::sketch::survivors_stored(&x, cfg)? {
            ensure!(
                cfg.k <= keep.len(),
                "k={} exceeds the preselect survivor count p={}",
                cfg.k,
                keep.len()
            );
            st = st.restrict_to(&keep)?;
        }
        Ok(StoredGreedyCore {
            loss: cfg.loss,
            k: cfg.k,
            st,
            rounds: Vec::new(),
            x,
            y,
        })
    }
}

impl SessionCore for StoredGreedyCore {
    fn target_reached(&self) -> bool {
        self.st.selected.len() >= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let (b, criterion) = match forced {
            Some(b) => {
                ensure!(
                    b < self.st.n,
                    "feature {b} out of range (n={})",
                    self.st.n
                );
                ensure!(
                    self.st.cand_mask[b] != 0.0,
                    "feature {b} already selected"
                );
                (b, self.st.score_of(&self.x, &self.y, self.loss, b)?)
            }
            None => {
                let scores =
                    self.st.score_all(&self.x, &self.y, self.loss)?;
                let b = argmin(&scores)
                    .ok_or_else(|| anyhow::anyhow!("no candidate left"))?;
                (b, scores[b])
            }
        };
        let round = Round { feature: b, criterion };
        self.st.commit(&self.x, b)?;
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.st.selected.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        self.st.weights(&self.x)
    }
}

impl GreedyRls {
    /// Begin a greedy session over **stored** data (the out-of-core
    /// path): takes ownership of the [`MatrixStore`] and labels, builds
    /// the Cᵀ cache as a second store with the same `opts`, and returns
    /// a [`Session`] whose rounds, criteria, and weights are
    /// bit-identical to [`SessionSelector::begin`] on the same data in
    /// RAM — at any backend, window size, tile width, or thread count.
    pub fn begin_stored(
        &self,
        x: MatrixStore,
        y: Vec<f64>,
        cfg: &SelectionConfig,
        opts: &StorageOptions,
    ) -> anyhow::Result<Box<dyn Session + 'static>> {
        let core = StoredGreedyCore::new(x, y, cfg, opts)?;
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }

    /// [`GreedyRls::begin_stored`] warm-started from an already-selected
    /// prefix: each feature is replayed as a forced round (criteria
    /// recomputed bit-identically via the O(m) single-candidate path)
    /// and the stop clock restarts after the replay — the stored twin of
    /// [`SessionSelector::begin_from`].
    pub fn begin_stored_from(
        &self,
        x: MatrixStore,
        y: Vec<f64>,
        cfg: &SelectionConfig,
        opts: &StorageOptions,
        selected: &[usize],
    ) -> anyhow::Result<Box<dyn Session + 'static>> {
        let mut s = self.begin_stored(x, y, cfg, opts)?;
        for &f in selected {
            s.force(f)?;
        }
        s.reset_clock();
        Ok(s)
    }
}

/// Round-by-round engine of Algorithm 3: [`GreedyState`] plus the round
/// log. Owns or borrows its data (`Cow`) so the same core backs both
/// feature selection (borrowed `X`) and kernel-center selection (owned
/// gram matrix, see [`super::centers`]).
pub(crate) struct GreedyCore<'a> {
    x: Cow<'a, Matrix>,
    y: Cow<'a, [f64]>,
    loss: Loss,
    k: usize,
    st: GreedyState,
    rounds: Vec<Round>,
}

impl<'a> GreedyCore<'a> {
    pub(crate) fn new(
        x: Cow<'a, Matrix>,
        y: Cow<'a, [f64]>,
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Self> {
        ensure!(cfg.k <= x.rows(), "k={} > n={}", cfg.k, x.rows());
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(x.cols() == y.len(), "shape mismatch");
        ensure!(
            x.as_slice().iter().all(|v| v.is_finite()),
            "X contains non-finite values"
        );
        ensure!(
            y.iter().all(|v| v.is_finite()),
            "y contains non-finite values"
        );
        let mut st = GreedyState::init(&x, &y, cfg.lambda)
            .with_threads(cfg.threads)
            .with_tile_cols(cfg.tile_cols)
            .with_precision(cfg.precision);
        if let Some(keep) = super::sketch::survivors(&x, cfg)? {
            ensure!(
                cfg.k <= keep.len(),
                "k={} exceeds the preselect survivor count p={}",
                cfg.k,
                keep.len()
            );
            st = st.restrict_to(&keep);
        }
        Ok(GreedyCore {
            loss: cfg.loss,
            k: cfg.k,
            st,
            rounds: Vec::new(),
            x,
            y,
        })
    }
}

impl SessionCore for GreedyCore<'_> {
    fn target_reached(&self) -> bool {
        self.st.selected.len() >= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let (b, criterion) = match forced {
            Some(b) => {
                ensure!(
                    b < self.st.n,
                    "feature {b} out of range (n={})",
                    self.st.n
                );
                ensure!(
                    self.st.cand_mask[b] != 0.0,
                    "feature {b} already selected"
                );
                // O(m) single-candidate path, bit-identical to score_all
                (b, self.st.score_of(&self.x, &self.y, self.loss, b))
            }
            None => {
                let scores = self.st.score_all(&self.x, &self.y, self.loss);
                let b = argmin(&scores)
                    .ok_or_else(|| anyhow::anyhow!("no candidate left"))?;
                (b, scores[b])
            }
        };
        let round = Round { feature: b, criterion };
        self.st.commit(&self.x, b);
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.st.selected.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        Ok(self.st.weights(&self.x))
    }
}

/// The paper's algorithm as a [`Selector`] / [`SessionSelector`].
#[derive(Clone, Copy, Debug, Default)]
pub struct GreedyRls;

impl SessionSelector for GreedyRls {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let core = GreedyCore::new(Cow::Borrowed(x), Cow::Borrowed(y), cfg)?;
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for GreedyRls {
    fn name(&self) -> &'static str {
        "greedy-rls"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        super::run_to_completion(self.begin(x, y, cfg)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::spd_inverse;
    use crate::proptest::{assert_close, forall_seeds, Gen};

    /// C, a, d tracked incrementally must equal the explicit G-based
    /// quantities after every commit (the SMW identity chain).
    #[test]
    fn caches_track_explicit_inverse() {
        forall_seeds(20, |seed| {
            let mut g = Gen::new(seed + 10);
            let n = g.size(3, 10);
            let m = g.size(3, 10);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.labels(m);
            let mut st = GreedyState::init(&x, &y, lam);
            let steps = 3.min(n);
            for step in 0..steps {
                st.commit(&x, step);
                // explicit: G = (X_Sᵀ X_S + λI)⁻¹
                let xs = x.select_rows(&st.selected);
                let mut k = xs.gram_t();
                k.add_diag(lam);
                let gmat = spd_inverse(&k).unwrap();
                let a_ref = gmat.matvec(&y);
                assert_close(&st.a, &a_ref, 1e-7, "a");
                let d_ref: Vec<f64> = (0..m).map(|j| gmat[(j, j)]).collect();
                assert_close(&st.d, &d_ref, 1e-7, "d");
                // C = G Xᵀ — check one random candidate column
                let i = (seed as usize) % n;
                let xi = x.row(i);
                let c_ref = gmat.matvec(xi);
                assert_close(
                    &st.ct[i * m..(i + 1) * m],
                    &c_ref,
                    1e-7,
                    "C column",
                );
            }
        });
    }

    /// The score of each candidate equals the dual LOO shortcut computed
    /// from an explicitly retrained model on S ∪ {i}.
    #[test]
    fn scores_equal_explicit_loo() {
        forall_seeds(15, |seed| {
            let mut g = Gen::new(seed + 99);
            let n = g.size(2, 8);
            let m = g.size(3, 10);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.targets(m);
            let mut st = GreedyState::init(&x, &y, lam);
            if n > 2 {
                st.commit(&x, 0);
            }
            let scores = st.score_all(&x, &y, Loss::Squared);
            for i in 0..n {
                if st.cand_mask[i] == 0.0 {
                    assert!(scores[i] >= BIG);
                    continue;
                }
                let mut s = st.selected.clone();
                s.push(i);
                let xs = x.select_rows(&s);
                let p = crate::rls::loo_dual(&xs, &y, lam);
                let want: f64 =
                    y.iter().zip(&p).map(|(&yv, &pv)| (yv - pv).powi(2)).sum();
                assert!(
                    (scores[i] - want).abs() <= 1e-6 * want.abs().max(1.0),
                    "cand {i}: {} vs {}",
                    scores[i],
                    want
                );
            }
        });
    }

    #[test]
    fn quad_scoring_matches_scalar_scoring() {
        forall_seeds(10, |seed| {
            let mut g = Gen::new(seed + 7777);
            let n = 4 + g.size(0, 5); // ≥ 4 so a quad exists
            let m = g.size(3, 17);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.labels(m);
            let st = GreedyState::init(&x, &y, lam);
            for loss in [Loss::Squared, Loss::ZeroOne] {
                let fast = st.score_all(&x, &y, loss);
                // scalar reference: score every candidate individually
                let mut slow = vec![BIG; n];
                for i in 0..n {
                    let v = x.row(i);
                    let c = &st.ct[i * m..(i + 1) * m];
                    slow[i] = crate::kernel::scalar::score_one(
                        v, c, &st.a, &st.d, &y, loss,
                    );
                }
                assert_close(&fast, &slow, 1e-12, "quad vs scalar");
            }
        });
    }

    /// The O(m) single-candidate path must reproduce score_all exactly
    /// (bit-for-bit), for every quad/remainder position of the active
    /// list — warm-start bit-identity depends on this.
    #[test]
    fn score_of_is_bit_identical_to_score_all() {
        forall_seeds(10, |seed| {
            let mut g = Gen::new(seed + 881);
            let n = g.size(3, 13);
            let m = g.size(3, 11);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.labels(m);
            let mut st = GreedyState::init(&x, &y, lam);
            if n > 2 {
                st.commit(&x, 1); // make the active list non-contiguous
            }
            for loss in [Loss::Squared, Loss::ZeroOne] {
                let all = st.score_all(&x, &y, loss);
                for i in 0..n {
                    if st.cand_mask[i] == 0.0 {
                        continue;
                    }
                    let one = st.score_of(&x, &y, loss, i);
                    assert_eq!(
                        one.to_bits(),
                        all[i].to_bits(),
                        "cand {i}: {one} vs {}",
                        all[i]
                    );
                }
            }
        });
    }

    /// Quad-sharded parallel scoring must be bit-identical to the serial
    /// scan for every thread count, including uneven active-list splits
    /// (lengths with partial quads, holes from prior commits).
    #[test]
    fn parallel_score_all_is_bit_identical_for_uneven_splits() {
        forall_seeds(8, |seed| {
            let mut g = Gen::new(seed + 4242);
            // lengths straddling quad boundaries: 4q, 4q+1..4q+3
            let n = 5 + g.size(0, 14);
            let m = g.size(3, 12);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.labels(m);
            let mut st = GreedyState::init(&x, &y, lam);
            // punch holes so the active list is non-contiguous and its
            // length is decoupled from n
            st.commit(&x, 1);
            st.commit(&x, n - 1);
            for loss in [Loss::Squared, Loss::ZeroOne] {
                let serial = st.score_all(&x, &y, loss);
                for threads in [2usize, 3, 4, 7] {
                    let mut stp =
                        GreedyState::init(&x, &y, lam).with_threads(threads);
                    stp.commit(&x, 1);
                    stp.commit(&x, n - 1);
                    let par = stp.score_all(&x, &y, loss);
                    for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "cand {i} threads={threads}: {a} vs {b}"
                        );
                    }
                }
            }
        });
    }

    /// Row-sharded parallel commit must leave every cache (C, a, d)
    /// bit-identical to the serial downdate.
    #[test]
    fn parallel_commit_is_bit_identical() {
        forall_seeds(8, |seed| {
            let mut g = Gen::new(seed + 555);
            let n = g.size(4, 13);
            let m = g.size(3, 11);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.labels(m);
            let steps = 3.min(n);
            let mut serial = GreedyState::init(&x, &y, lam);
            for step in 0..steps {
                serial.commit(&x, step);
            }
            for threads in [2usize, 4] {
                let mut par =
                    GreedyState::init(&x, &y, lam).with_threads(threads);
                for step in 0..steps {
                    par.commit(&x, step);
                }
                let eq_bits = |a: &[f64], b: &[f64]| {
                    a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
                };
                assert!(eq_bits(&serial.ct, &par.ct), "ct threads={threads}");
                assert!(eq_bits(&serial.a, &par.a), "a threads={threads}");
                assert!(eq_bits(&serial.d, &par.d), "d threads={threads}");
            }
        });
    }

    /// The incrementally maintained active list must match a rebuild
    /// from the candidate mask after every commit.
    #[test]
    fn active_list_tracks_cand_mask() {
        let mut g = Gen::new(99);
        let n = 9;
        let m = 7;
        let x = g.matrix(n, m);
        let y = g.labels(m);
        let mut st = GreedyState::init(&x, &y, 1.0);
        for b in [3usize, 0, 8, 5] {
            st.commit(&x, b);
            let rebuilt: Vec<usize> = (0..n)
                .filter(|&i| st.cand_mask[i] != 0.0)
                .collect();
            assert_eq!(st.active, rebuilt);
        }
    }

    #[test]
    fn selects_planted_features_first() {
        let (ds, support) =
            crate::data::synthetic::sparse_regression(300, 25, 3, 0.05, 11);
        let cfg = SelectionConfig { k: 3, lambda: 0.1, loss: Loss::Squared, ..Default::default() };
        let r = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        let mut sel = r.selected.clone();
        sel.sort_unstable();
        let mut sup = support.clone();
        sup.sort_unstable();
        assert_eq!(sel, sup, "greedy should find the planted support");
    }

    #[test]
    fn criterion_decreases_weakly_on_regression() {
        // adding a feature cannot worsen the best achievable LOO much;
        // on easy data the curve should be monotone decreasing
        let (ds, _) =
            crate::data::synthetic::sparse_regression(200, 20, 5, 0.1, 3);
        let cfg = SelectionConfig { k: 5, lambda: 0.5, loss: Loss::Squared, ..Default::default() };
        let r = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        let curve = r.criterion_curve();
        for w in curve.windows(2) {
            assert!(w[1] <= w[0] * 1.05, "curve {curve:?}");
        }
    }

    #[test]
    fn no_feature_selected_twice() {
        let ds = crate::data::synthetic::two_gaussians(60, 15, 5, 1.0, 5);
        let cfg =
            SelectionConfig { k: 15, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let r = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        let mut sel = r.selected.clone();
        sel.sort_unstable();
        sel.dedup();
        assert_eq!(sel.len(), 15);
    }

    #[test]
    fn k_too_large_errors() {
        let ds = crate::data::synthetic::two_gaussians(20, 5, 2, 1.0, 6);
        let cfg = SelectionConfig { k: 6, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        assert!(GreedyRls.select(&ds.x, &ds.y, &cfg).is_err());
    }

    #[test]
    fn non_finite_inputs_rejected() {
        let mut ds = crate::data::synthetic::two_gaussians(20, 5, 2, 1.0, 6);
        let cfg = SelectionConfig { k: 2, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        ds.x[(1, 3)] = f64::NAN;
        let err = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
        let ds = crate::data::synthetic::two_gaussians(20, 5, 2, 1.0, 6);
        let mut y = ds.y.clone();
        y[0] = f64::INFINITY;
        assert!(GreedyRls.select(&ds.x, &y, &cfg).is_err());
    }

    #[test]
    fn weights_match_retrained_rls() {
        let ds = crate::data::synthetic::two_gaussians(80, 12, 4, 1.5, 7);
        let cfg = SelectionConfig { k: 4, lambda: 0.7, loss: Loss::ZeroOne, ..Default::default() };
        let r = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        let xs = ds.x.select_rows(&r.selected);
        let w_direct = crate::rls::train(&xs, &ds.y, cfg.lambda);
        assert_close(&r.weights, &w_direct, 1e-7, "final weights");
    }

    #[test]
    fn tile_normalization() {
        assert_eq!(normalize_tile(0, 100), 0);
        assert_eq!(normalize_tile(7, 100), 8);
        assert_eq!(normalize_tile(9, 100), 8);
        assert_eq!(normalize_tile(64, 100), 64);
        assert_eq!(normalize_tile(64, 50), 0); // covers m: untiled walk
        assert_eq!(normalize_tile(1, 4), 0);
    }

    /// Tiled scoring must be bit-identical to the untiled scan for every
    /// tile width, loss, thread count, and active-list shape — the whole
    /// tiling contract rests on this.
    #[test]
    fn tiled_score_all_is_bit_identical_to_untiled() {
        forall_seeds(8, |seed| {
            let mut g = Gen::new(seed + 31_000);
            let n = 5 + g.size(0, 12);
            let m = g.size(9, 40);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.labels(m);
            let mut plain = GreedyState::init(&x, &y, lam);
            plain.commit(&x, 1); // non-contiguous active list
            for loss in [Loss::Squared, Loss::ZeroOne] {
                let want = plain.score_all(&x, &y, loss);
                for tile in [8usize, 16, 40] {
                    for threads in [1usize, 3] {
                        let mut st = GreedyState::init(&x, &y, lam)
                            .with_threads(threads)
                            .with_tile_cols(tile);
                        st.commit(&x, 1);
                        let got = st.score_all(&x, &y, loss);
                        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "cand {i} tile={tile} threads={threads}"
                            );
                        }
                    }
                }
            }
        });
    }

    /// Tiled commits must leave every cache (C, a, d) bit-identical to
    /// the untiled downdate sequence.
    #[test]
    fn tiled_commit_is_bit_identical_to_untiled() {
        forall_seeds(8, |seed| {
            let mut g = Gen::new(seed + 32_000);
            let n = g.size(4, 12);
            let m = g.size(9, 40);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.labels(m);
            let steps = 3.min(n);
            let mut plain = GreedyState::init(&x, &y, lam);
            for step in 0..steps {
                plain.commit(&x, step);
            }
            for tile in [8usize, 16, 40] {
                for threads in [1usize, 2] {
                    let mut st = GreedyState::init(&x, &y, lam)
                        .with_threads(threads)
                        .with_tile_cols(tile);
                    for step in 0..steps {
                        st.commit(&x, step);
                    }
                    let eq = |a: &[f64], b: &[f64]| {
                        a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
                    };
                    assert!(eq(&plain.ct, &st.ct), "ct tile={tile}");
                    assert!(eq(&plain.a, &st.a), "a tile={tile}");
                    assert!(eq(&plain.d, &st.d), "d tile={tile}");
                }
            }
        });
    }

    /// End-to-end selection with a tiled config must reproduce the
    /// untiled run bit-for-bit (the CLI `--tile-cols` contract).
    #[test]
    fn tiled_selection_result_is_bit_identical() {
        let ds = crate::data::synthetic::two_gaussians(57, 14, 5, 1.2, 21);
        let base = SelectionConfig::builder()
            .k(6)
            .lambda(0.8)
            .loss(Loss::ZeroOne)
            .build();
        let want = GreedyRls.select(&ds.x, &ds.y, &base).unwrap();
        for tile in [8usize, 16] {
            let cfg = base.with().tile_cols(tile).build();
            let got = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
            assert_results_bit_identical(&want, &got, &format!("tile {tile}"));
        }
    }

    // ---- stored (out-of-core) engine ------------------------------------

    fn assert_results_bit_identical(
        a: &SelectionResult,
        b: &SelectionResult,
        what: &str,
    ) {
        assert_eq!(a.selected, b.selected, "{what}: selected sets differ");
        assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round counts");
        for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(ra.feature, rb.feature, "{what}: feature");
            assert_eq!(
                ra.criterion.to_bits(),
                rb.criterion.to_bits(),
                "{what}: criterion {} vs {}",
                ra.criterion,
                rb.criterion
            );
        }
        assert_eq!(a.weights.len(), b.weights.len(), "{what}: weight counts");
        for (wa, wb) in a.weights.iter().zip(&b.weights) {
            assert_eq!(
                wa.to_bits(),
                wb.to_bits(),
                "{what}: weights {wa} vs {wb}"
            );
        }
    }

    fn run_stored(
        ds: &crate::data::Dataset,
        cfg: &SelectionConfig,
        opts: &crate::data::storage::StorageOptions,
    ) -> SelectionResult {
        let store = MatrixStore::from_matrix(&ds.x, opts).unwrap();
        let s = GreedyRls
            .begin_stored(store, ds.y.clone(), cfg, opts)
            .unwrap();
        super::super::run_to_completion(s).unwrap()
    }

    /// The stored engine on the RAM backend must be bit-identical to the
    /// in-RAM engine for every thread count and tile width (runs on all
    /// platforms; the mmap twin below adds the Linux-only backend).
    #[test]
    fn stored_engine_matches_ram_engine_bitwise() {
        let ds = crate::data::synthetic::two_gaussians(41, 13, 5, 1.4, 33);
        let cfg = SelectionConfig::builder()
            .k(5)
            .lambda(0.9)
            .loss(Loss::ZeroOne)
            .build();
        let want = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        for tile in [0usize, 8, 16] {
            for threads in [1usize, 2, 4] {
                let cfg = cfg.with().threads(threads).build();
                let opts =
                    crate::data::storage::StorageOptions::default()
                        .tile_cols(tile);
                let got = run_stored(&ds, &cfg, &opts);
                assert_results_bit_identical(
                    &want,
                    &got,
                    &format!("ram-backend tile={tile} threads={threads}"),
                );
            }
        }
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn stored_engine_on_mmap_matches_ram_engine_bitwise() {
        use crate::data::storage::{Backend, StorageOptions};
        let ds = crate::data::synthetic::two_gaussians(41, 13, 5, 1.4, 33);
        let cfg = SelectionConfig::builder()
            .k(5)
            .lambda(0.9)
            .loss(Loss::Squared)
            .build();
        let want = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        for threads in [1usize, 2, 4] {
            let cfg = cfg.with().threads(threads).build();
            let opts = StorageOptions::default()
                .backend(Backend::Mmap)
                .tile_cols(8);
            let got = run_stored(&ds, &cfg, &opts);
            assert_results_bit_identical(
                &want,
                &got,
                &format!("mmap-backend threads={threads}"),
            );
        }
    }

    /// Force genuinely windowed scans: with a 1 MiB window and 16 Ki
    /// examples a window holds 8 rows, so the grouped scan walks several
    /// windows per shard — results must not move by a bit.
    #[cfg(target_os = "linux")]
    #[test]
    fn stored_windowed_scan_matches_ram_engine_bitwise() {
        use crate::data::storage::{Backend, StorageOptions};
        let ds = crate::data::synthetic::two_gaussians(16_384, 12, 4, 1.0, 9);
        let cfg = SelectionConfig::builder()
            .k(4)
            .lambda(1.0)
            .loss(Loss::ZeroOne)
            .threads(2)
            .build();
        let want = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        let opts = StorageOptions::default()
            .backend(Backend::Mmap)
            .window_bytes(1 << 20);
        let got = run_stored(&ds, &cfg, &opts);
        assert_results_bit_identical(&want, &got, "windowed mmap scan");
    }

    /// Degenerate window (one row per window): every quad takes the
    /// staged per-row path. Still bit-identical.
    #[cfg(target_os = "linux")]
    #[test]
    fn stored_single_row_window_matches_ram_engine_bitwise() {
        use crate::data::storage::{Backend, StorageOptions};
        let ds =
            crate::data::synthetic::two_gaussians(131_072, 5, 2, 1.0, 15);
        let cfg = SelectionConfig::builder()
            .k(2)
            .lambda(1.0)
            .loss(Loss::Squared)
            .threads(2)
            .build();
        let want = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        let opts = StorageOptions::default()
            .backend(Backend::Mmap)
            .window_bytes(1 << 20);
        let got = run_stored(&ds, &cfg, &opts);
        assert_results_bit_identical(&want, &got, "single-row windows");
    }

    /// Warm-start replay through the stored engine: forced rounds must
    /// recompute the same criteria the fresh run logged, on both
    /// engines.
    #[test]
    fn stored_warm_start_replay_is_bit_identical() {
        let ds = crate::data::synthetic::two_gaussians(37, 11, 4, 1.3, 27);
        let cfg = SelectionConfig::builder()
            .k(5)
            .lambda(0.7)
            .loss(Loss::ZeroOne)
            .build();
        let fresh = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        let prefix = &fresh.selected[..2];

        let ram = super::super::run_to_completion(
            GreedyRls.begin_from(&ds.x, &ds.y, &cfg, prefix).unwrap(),
        )
        .unwrap();
        assert_results_bit_identical(&fresh, &ram, "ram warm start");

        let opts = crate::data::storage::StorageOptions::default();
        let store = MatrixStore::from_matrix(&ds.x, &opts).unwrap();
        let stored = super::super::run_to_completion(
            GreedyRls
                .begin_stored_from(store, ds.y.clone(), &cfg, &opts, prefix)
                .unwrap(),
        )
        .unwrap();
        assert_results_bit_identical(&fresh, &stored, "stored warm start");
    }

    /// The stored core applies the same validation as the in-RAM core,
    /// including the streamed finiteness check.
    #[test]
    fn stored_core_rejects_bad_inputs() {
        let mut ds = crate::data::synthetic::two_gaussians(20, 5, 2, 1.0, 6);
        let cfg = SelectionConfig::builder()
            .k(2)
            .lambda(1.0)
            .loss(Loss::ZeroOne)
            .build();
        let opts = crate::data::storage::StorageOptions::default();
        ds.x[(1, 3)] = f64::NAN;
        let store = MatrixStore::from_matrix(&ds.x, &opts).unwrap();
        let err = GreedyRls
            .begin_stored(store, ds.y.clone(), &cfg, &opts)
            .unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");

        let ds = crate::data::synthetic::two_gaussians(20, 5, 2, 1.0, 6);
        let store = MatrixStore::from_matrix(&ds.x, &opts).unwrap();
        let cfg = cfg.with().k(6).build();
        assert!(GreedyRls
            .begin_stored(store, ds.y.clone(), &cfg, &opts)
            .is_err());
    }

    /// Forcing the scalar kernel must not change anything: in a default
    /// build it IS the dispatch target, and in a `--features simd` build
    /// the lane kernels are pinned bit-identical to it.
    #[test]
    fn forced_scalar_kernel_matches_active_kernel_bitwise() {
        let ds = crate::data::synthetic::two_gaussians(60, 14, 4, 1.0, 11);
        for loss in [Loss::Squared, Loss::ZeroOne] {
            let mut st_a = GreedyState::init(&ds.x, &ds.y, 0.5);
            let mut st_s = GreedyState::init(&ds.x, &ds.y, 0.5)
                .with_kernel(KernelKind::Scalar);
            for _ in 0..4 {
                let sa = st_a.score_all(&ds.x, &ds.y, loss);
                let ss = st_s.score_all(&ds.x, &ds.y, loss);
                for (p, q) in sa.iter().zip(&ss) {
                    assert_eq!(p.to_bits(), q.to_bits());
                }
                let b = argmin(&sa).unwrap();
                st_a.commit(&ds.x, b);
                st_s.commit(&ds.x, b);
                for (p, q) in st_a.ct.iter().zip(&st_s.ct) {
                    assert_eq!(p.to_bits(), q.to_bits());
                }
            }
        }
    }

    /// The f32c engine's own determinism contract: scores and commits
    /// are bit-identical across thread counts, and `score_of` equals
    /// `score_all` for every candidate (there is no quad coupling to
    /// recompute).
    #[test]
    fn f32c_is_bit_deterministic_across_threads_and_score_of() {
        let ds = crate::data::synthetic::two_gaussians(50, 13, 4, 1.0, 23);
        for loss in [Loss::Squared, Loss::ZeroOne] {
            let mut base = GreedyState::init(&ds.x, &ds.y, 1.0)
                .with_precision(Precision::F32c);
            assert!(base.ct.is_empty(), "f64 cache must be dropped");
            for _ in 0..3 {
                let s1 = base.score_all(&ds.x, &ds.y, loss);
                for t in [2usize, 4] {
                    let mut st = GreedyState::init(&ds.x, &ds.y, 1.0)
                        .with_precision(Precision::F32c)
                        .with_threads(t);
                    for &f in &base.selected {
                        st.commit(&ds.x, f);
                    }
                    let s2 = st.score_all(&ds.x, &ds.y, loss);
                    for (i, (p, q)) in s1.iter().zip(&s2).enumerate() {
                        assert_eq!(p.to_bits(), q.to_bits(), "t={t} i={i}");
                    }
                }
                for i in 0..base.n {
                    if base.cand_mask[i] == 0.0 {
                        continue;
                    }
                    let one = base.score_of(&ds.x, &ds.y, loss, i);
                    assert_eq!(one.to_bits(), s1[i].to_bits(), "cand {i}");
                }
                let b = argmin(&s1).unwrap();
                base.commit(&ds.x, b);
            }
        }
    }

    /// f32c vs f64 criterion trajectories on a well-conditioned problem:
    /// same features selected, criteria within the documented tolerance
    /// (EXPERIMENTS.md §Mixed precision).
    #[test]
    fn f32c_trajectory_tracks_f64_within_tolerance() {
        let ds = crate::data::synthetic::two_gaussians(80, 16, 5, 1.0, 7);
        let mut st64 = GreedyState::init(&ds.x, &ds.y, 1.0);
        let mut st32 = GreedyState::init(&ds.x, &ds.y, 1.0)
            .with_precision(Precision::F32c);
        for round in 0..5 {
            let s64 = st64.score_all(&ds.x, &ds.y, Loss::Squared);
            let s32 = st32.score_all(&ds.x, &ds.y, Loss::Squared);
            let b64 = argmin(&s64).unwrap();
            let b32 = argmin(&s32).unwrap();
            assert_eq!(b64, b32, "round {round}: selection diverged");
            let rel = (s64[b64] - s32[b32]).abs()
                / s64[b64].abs().max(1.0);
            assert!(
                rel <= 1e-4,
                "round {round}: criterion rel err {rel} above gate"
            );
            st64.commit(&ds.x, b64);
            st32.commit(&ds.x, b32);
        }
    }

    #[test]
    fn stored_engine_rejects_f32c() {
        let ds = crate::data::synthetic::two_gaussians(20, 5, 2, 1.0, 6);
        let opts = crate::data::storage::StorageOptions::default();
        let store = MatrixStore::from_matrix(&ds.x, &opts).unwrap();
        let cfg = SelectionConfig::builder()
            .k(2)
            .precision(Precision::F32c)
            .build();
        let err = GreedyRls
            .begin_stored(store, ds.y.clone(), &cfg, &opts)
            .unwrap_err();
        assert!(err.to_string().contains("f32c"), "{err}");
    }
}
