//! Random-k baseline (paper §4.2).
//!
//! "Thus we consider as a baseline an approach which chooses k features at
//! random. This is a good sanity-check, since training RLS with this
//! approach requires only O(min(k²m, km²)) time that is even less than
//! the time required by greedy RLS." Figures 4–9 plot greedy RLS against
//! this selector.

use anyhow::ensure;

use super::{Round, SelectionConfig, SelectionResult, Selector};
use crate::linalg::Matrix;
use crate::rls;
use crate::rng::Pcg64;

/// Uniformly random feature subset + RLS fit on it.
#[derive(Clone, Copy, Debug)]
pub struct RandomSelector {
    /// RNG seed (deterministic baseline runs).
    pub seed: u64,
}

impl Default for RandomSelector {
    fn default() -> Self {
        RandomSelector { seed: 0x5eed }
    }
}

impl Selector for RandomSelector {
    fn name(&self) -> &'static str {
        "random"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        let n = x.rows();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        let mut rng = Pcg64::new(self.seed, 31);
        let selected = rng.choose_distinct(n, cfg.k);
        // criterion logged for parity with other selectors: LOO of the
        // growing random prefix (cheap: one shortcut evaluation per round)
        let mut rounds = Vec::with_capacity(cfg.k);
        for r in 1..=cfg.k {
            let xs = x.select_rows(&selected[..r]);
            let p = if xs.rows() <= xs.cols() {
                rls::loo_primal(&xs, y, cfg.lambda)
            } else {
                rls::loo_dual(&xs, y, cfg.lambda)
            };
            rounds.push(Round {
                feature: selected[r - 1],
                criterion: cfg.loss.total(y, &p),
            });
        }
        let xs = x.select_rows(&selected);
        let weights = rls::train(&xs, y, cfg.lambda);
        Ok(SelectionResult { selected, rounds, weights })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;

    #[test]
    fn selects_k_distinct() {
        let ds = crate::data::synthetic::two_gaussians(50, 20, 5, 1.0, 3);
        let cfg = SelectionConfig { k: 8, lambda: 1.0, loss: Loss::ZeroOne };
        let r = RandomSelector::default().select(&ds.x, &ds.y, &cfg).unwrap();
        let mut s = r.selected.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
        assert_eq!(r.weights.len(), 8);
        assert_eq!(r.rounds.len(), 8);
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = crate::data::synthetic::two_gaussians(30, 15, 5, 1.0, 4);
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne };
        let a = RandomSelector { seed: 9 }.select(&ds.x, &ds.y, &cfg).unwrap();
        let b = RandomSelector { seed: 9 }.select(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(a.selected, b.selected);
        let c = RandomSelector { seed: 10 }.select(&ds.x, &ds.y, &cfg).unwrap();
        assert_ne!(a.selected, c.selected); // overwhelmingly likely
    }

    #[test]
    fn weights_are_rls_fit_on_subset() {
        let ds = crate::data::synthetic::two_gaussians(40, 10, 3, 1.5, 5);
        let cfg = SelectionConfig { k: 4, lambda: 0.8, loss: Loss::ZeroOne };
        let r = RandomSelector::default().select(&ds.x, &ds.y, &cfg).unwrap();
        let xs = ds.x.select_rows(&r.selected);
        let w = crate::rls::train(&xs, &ds.y, cfg.lambda);
        for (a, b) in r.weights.iter().zip(&w) {
            assert!((a - b).abs() < 1e-10);
        }
    }
}
