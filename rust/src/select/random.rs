//! Random-k baseline (paper §4.2).
//!
//! "Thus we consider as a baseline an approach which chooses k features at
//! random. This is a good sanity-check, since training RLS with this
//! approach requires only O(min(k²m, km²)) time that is even less than
//! the time required by greedy RLS." Figures 4–9 plot greedy RLS against
//! this selector.

use anyhow::ensure;

use super::session::{
    CoreStep, PolicySession, Session, SessionCore, SessionSelector,
};
use super::{Round, SelectionConfig, SelectionResult, Selector};
use crate::linalg::Matrix;
use crate::metrics::Loss;
use crate::rls;
use crate::rng::Pcg64;

/// Uniformly random feature subset + RLS fit on it.
#[derive(Clone, Copy, Debug)]
pub struct RandomSelector {
    /// RNG seed (deterministic baseline runs).
    pub seed: u64,
}

impl Default for RandomSelector {
    fn default() -> Self {
        RandomSelector { seed: 0x5eed }
    }
}

/// Round-by-round engine: the random order is drawn once at `begin`
/// (seed-deterministic); each round commits the next unused feature of
/// that order. The logged criterion is the LOO of the growing prefix
/// (one shortcut evaluation per round), for parity with the informed
/// selectors. A forced round (warm start / fixed-order replay) may
/// commit any feature; the predetermined order then skips it.
struct RandomCore<'a> {
    x: &'a Matrix,
    y: &'a [f64],
    lambda: f64,
    loss: Loss,
    k: usize,
    order: Vec<usize>,
    selected: Vec<usize>,
    in_s: Vec<bool>,
    rounds: Vec<Round>,
}

impl RandomCore<'_> {
    /// LOO criterion of the current prefix.
    fn prefix_criterion(&self) -> f64 {
        rls::loo_subset_criterion(
            self.x,
            &self.selected,
            self.y,
            self.lambda,
            self.loss,
        )
    }
}

impl SessionCore for RandomCore<'_> {
    fn target_reached(&self) -> bool {
        self.selected.len() >= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let n = self.x.rows();
        let b = match forced {
            Some(b) => {
                ensure!(b < n, "feature {b} out of range (n={n})");
                ensure!(!self.in_s[b], "feature {b} already selected");
                b
            }
            None => {
                match self.order.iter().copied().find(|&i| !self.in_s[i]) {
                    Some(b) => b,
                    None => return Ok(CoreStep::Exhausted),
                }
            }
        };
        self.in_s[b] = true;
        self.selected.push(b);
        let round = Round { feature: b, criterion: self.prefix_criterion() };
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.selected.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        if self.selected.is_empty() {
            return Ok(Vec::new());
        }
        let xs = self.x.select_rows(&self.selected);
        Ok(rls::train(&xs, self.y, self.lambda))
    }
}

impl SessionSelector for RandomSelector {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let n = x.rows();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(x.cols() == y.len(), "shape mismatch");
        super::require_f64(cfg, "random")?;
        super::require_no_preselect(cfg, "random")?;
        let mut rng = Pcg64::new(self.seed, 31);
        let order = rng.choose_distinct(n, cfg.k);
        let core = RandomCore {
            x,
            y,
            lambda: cfg.lambda,
            loss: cfg.loss,
            k: cfg.k,
            order,
            selected: Vec::new(),
            in_s: vec![false; n],
            rounds: Vec::new(),
        };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for RandomSelector {
    fn name(&self) -> &'static str {
        "random"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        super::run_to_completion(self.begin(x, y, cfg)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;

    #[test]
    fn selects_k_distinct() {
        let ds = crate::data::synthetic::two_gaussians(50, 20, 5, 1.0, 3);
        let cfg = SelectionConfig { k: 8, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let r = RandomSelector::default().select(&ds.x, &ds.y, &cfg).unwrap();
        let mut s = r.selected.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
        assert_eq!(r.weights.len(), 8);
        assert_eq!(r.rounds.len(), 8);
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = crate::data::synthetic::two_gaussians(30, 15, 5, 1.0, 4);
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let a = RandomSelector { seed: 9 }.select(&ds.x, &ds.y, &cfg).unwrap();
        let b = RandomSelector { seed: 9 }.select(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(a.selected, b.selected);
        let c = RandomSelector { seed: 10 }.select(&ds.x, &ds.y, &cfg).unwrap();
        assert_ne!(a.selected, c.selected); // overwhelmingly likely
    }

    #[test]
    fn weights_are_rls_fit_on_subset() {
        let ds = crate::data::synthetic::two_gaussians(40, 10, 3, 1.5, 5);
        let cfg = SelectionConfig { k: 4, lambda: 0.8, loss: Loss::ZeroOne, ..Default::default() };
        let r = RandomSelector::default().select(&ds.x, &ds.y, &cfg).unwrap();
        let xs = ds.x.select_rows(&r.selected);
        let w = crate::rls::train(&xs, &ds.y, cfg.lambda);
        for (a, b) in r.weights.iter().zip(&w) {
            assert!((a - b).abs() < 1e-10);
        }
    }
}
