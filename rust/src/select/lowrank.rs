//! **Low-rank updated LS-SVM** — the paper's Algorithm 2 (Ojeda, Suykens,
//! De Moor 2008), reimplemented as the O(km²n) baseline.
//!
//! Selects exactly the same features as greedy RLS (Algorithm 3) and the
//! wrapper (Algorithm 1) — it evaluates the same LOO criterion — but keeps
//! the full m × m matrix `G = (K + λI)⁻¹` in memory and refreshes it per
//! candidate with the Sherman–Morrison–Woodbury identity (eq. 10), which
//! costs O(m²) per candidate. Figures 1–2 of the paper are the runtime
//! comparison between this and Algorithm 3.

use anyhow::ensure;

use super::session::{
    CoreStep, PolicySession, Session, SessionCore, SessionSelector,
};
use super::{argmin, Round, SelectionConfig, SelectionResult, Selector, BIG};
use crate::linalg::{dot, Matrix};
use crate::metrics::Loss;

/// Round-by-round engine of Algorithm 2: the full m × m `G` is the state,
/// refreshed per candidate with the SMW identity (eq. 10).
struct LowRankCore<'a> {
    x: &'a Matrix,
    y: &'a [f64],
    loss: Loss,
    k: usize,
    /// G = (K + λI)⁻¹ for the current S.
    g: Matrix,
    selected: Vec<usize>,
    in_s: Vec<bool>,
    rounds: Vec<Round>,
}

impl LowRankCore<'_> {
    /// LOO criterion of `S ∪ {i}` via the SMW-refreshed G~ — candidates
    /// are independent, so a forced round scores only its own candidate.
    fn score_one(&self, i: usize) -> f64 {
        let m = self.x.cols();
        let v = self.x.row(i);
        // line 9: G~ = G − Gv (1 + vᵀGv)⁻¹ (vᵀG)  — O(m²)
        let gv = self.g.matvec(v);
        let denom = 1.0 + dot(v, &gv);
        // line 10: ã = G~ y — equivalently a − Gv (vᵀ a)/denom,
        // but Algorithm 2 recomputes it from G~; we form G~
        // explicitly to stay faithful to the O(m²) structure.
        let mut gt = self.g.clone();
        for r in 0..m {
            let f = gv[r] / denom;
            let row = gt.row_mut(r);
            for (c_, &gvc) in row.iter_mut().zip(&gv) {
                // xtask-allow: scan-via-kernel -- Algorithm 2's explicit
                // O(m²) G~ downdate, kept quadratic on purpose as the
                // paper-faithful baseline the linear engine is tested
                // against; deliberately not on the kernel tier
                *c_ -= f * gvc;
            }
        }
        let at = gt.matvec(self.y);
        // lines 12–15: LOO via eq. 8 on the diagonal of G~
        let mut e = 0.0;
        for j in 0..m {
            let p = self.y[j] - at[j] / gt[(j, j)];
            e += self.loss.eval(self.y[j], p);
        }
        e
    }
}

impl SessionCore for LowRankCore<'_> {
    fn target_reached(&self) -> bool {
        self.selected.len() >= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let n = self.x.rows();
        let m = self.x.cols();
        let (b, criterion) = match forced {
            Some(b) => {
                ensure!(b < n, "feature {b} out of range (n={n})");
                ensure!(!self.in_s[b], "feature {b} already selected");
                (b, self.score_one(b))
            }
            None => {
                let mut scores = vec![BIG; n];
                for i in 0..n {
                    if self.in_s[i] {
                        continue;
                    }
                    scores[i] = self.score_one(i);
                }
                let b = argmin(&scores)
                    .ok_or_else(|| anyhow::anyhow!("no candidate left"))?;
                (b, scores[b])
            }
        };
        let round = Round { feature: b, criterion };

        // lines 21–24: commit b into G (SMW), a implied by G y
        let v = self.x.row(b);
        let gv = self.g.matvec(v);
        let denom = 1.0 + dot(v, &gv);
        for r in 0..m {
            let f = gv[r] / denom;
            let row = self.g.row_mut(r);
            for (c_, &gvc) in row.iter_mut().zip(&gv) {
                // xtask-allow: scan-via-kernel -- quadratic SMW commit of
                // the same O(m²) baseline; see the downdate above
                *c_ -= f * gvc;
            }
        }
        self.in_s[b] = true;
        self.selected.push(b);
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.selected.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        // line 26: w = X_S a with a = G y
        let a = self.g.matvec(self.y);
        Ok(self
            .selected
            .iter()
            .map(|&i| dot(self.x.row(i), &a))
            .collect())
    }
}

/// Algorithm 2 as a [`Selector`] / [`SessionSelector`].
#[derive(Clone, Copy, Debug, Default)]
pub struct LowRankLsSvm;

impl SessionSelector for LowRankLsSvm {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let n = x.rows();
        let m = x.cols();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(m == y.len(), "shape mismatch");
        super::require_f64(cfg, "lowrank-lssvm")?;
        super::require_no_preselect(cfg, "lowrank-lssvm")?;

        // lines 1–3: S = ∅, a = λ⁻¹y, G = λ⁻¹I
        let inv = 1.0 / cfg.lambda;
        let mut g = Matrix::identity(m);
        for v in g.as_mut_slice().iter_mut() {
            *v *= inv;
        }
        let core = LowRankCore {
            x,
            y,
            loss: cfg.loss,
            k: cfg.k,
            g,
            selected: Vec::new(),
            in_s: vec![false; n],
            rounds: Vec::new(),
        };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for LowRankLsSvm {
    fn name(&self) -> &'static str {
        "lowrank-lssvm"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        super::run_to_completion(self.begin(x, y, cfg)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;
    use crate::proptest::{assert_close, forall_seeds, Gen};
    use crate::select::greedy::GreedyRls;

    /// The headline equivalence: Algorithm 2 == Algorithm 3 outputs.
    #[test]
    fn equivalent_to_greedy_rls() {
        forall_seeds(20, |seed| {
            let mut g = Gen::new(seed + 500);
            let n = g.size(3, 12);
            let m = g.size(3, 12);
            let k = 2.min(n);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.labels(m);
            for loss in [Loss::Squared, Loss::ZeroOne] {
                let cfg = SelectionConfig { k, lambda: lam, loss, ..Default::default() };
                let r2 = LowRankLsSvm.select(&x, &y, &cfg).unwrap();
                let r3 = GreedyRls.select(&x, &y, &cfg).unwrap();
                assert_eq!(r2.selected, r3.selected, "loss {loss:?}");
                assert_close(&r2.weights, &r3.weights, 1e-6, "weights");
                for (a, b) in r2.rounds.iter().zip(&r3.rounds) {
                    assert!(
                        (a.criterion - b.criterion).abs()
                            <= 1e-6 * a.criterion.abs().max(1.0),
                        "criterion {} vs {}",
                        a.criterion,
                        b.criterion
                    );
                }
            }
        });
    }

    #[test]
    fn rejects_bad_config() {
        let mut g = Gen::new(0);
        let x = g.matrix(4, 6);
        let y = g.labels(6);
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        assert!(LowRankLsSvm.select(&x, &y, &cfg).is_err());
        let cfg = SelectionConfig { k: 2, lambda: 0.0, loss: Loss::ZeroOne, ..Default::default() };
        assert!(LowRankLsSvm.select(&x, &y, &cfg).is_err());
    }

    #[test]
    fn selects_k_distinct_features() {
        let ds = crate::data::synthetic::two_gaussians(40, 10, 4, 1.0, 9);
        let cfg = SelectionConfig { k: 6, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let r = LowRankLsSvm.select(&ds.x, &ds.y, &cfg).unwrap();
        let mut s = r.selected.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 6);
    }
}
