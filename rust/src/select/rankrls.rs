//! Greedy forward feature selection for **RankRLS** (paper §5: "design
//! and implement similar feature selection algorithms for RankRLS").
//!
//! Same greedy skeleton as Algorithm 3, adapted to the pairwise ranking
//! objective of [`crate::rls::rank`]. The criterion is the regularized
//! pairwise risk of the model retrained on `S ∪ {i}`, evaluated
//! efficiently with a **bordering update**: the k×k primal matrix
//! `M_S = X_S L X_Sᵀ + λI` has a cached Cholesky factor; adding a
//! candidate row appends one bordered row/column whose Schur complement
//! is a scalar, so each candidate costs O(k² + km) instead of a fresh
//! O(k³ + k²m) solve — per round O(n(k² + km)), linear in m like the
//! classification algorithm.

use anyhow::{anyhow, ensure};

use super::session::{
    CoreStep, PolicySession, Session, SessionCore, SessionSelector,
};
use super::{argmin, Round, SelectionConfig, SelectionResult, Selector, BIG};
use crate::linalg::{dot, Cholesky, Matrix};
use crate::rls::rank::{laplacian_apply, pairwise_risk, train_rank};

/// Greedy RankRLS feature selector (pairwise-risk criterion).
#[derive(Clone, Copy, Debug, Default)]
pub struct GreedyRankRls;

/// Round-by-round engine: the L-products are precomputed once at `begin`;
/// each round refactors the k×k primal matrix and scores candidates with
/// the bordered solve.
struct RankRlsCore<'a> {
    x: &'a Matrix,
    y: &'a [f64],
    lambda: f64,
    k: usize,
    threads: usize,
    /// Lx_i per candidate row (never changes).
    lx: Vec<Vec<f64>>,
    /// x_i · (L y) per candidate (never changes).
    xly: Vec<f64>,
    selected: Vec<usize>,
    in_s: Vec<bool>,
    rounds: Vec<Round>,
}

impl RankRlsCore<'_> {
    /// Cholesky factor of M_S (k×k) and the solved base weights w_S,
    /// shared by every candidate of one round.
    fn base_solve(&self) -> anyhow::Result<(Cholesky, Vec<f64>)> {
        let k = self.selected.len();
        let mut mmat = Matrix::zeros(k, k);
        for (a, &ia) in self.selected.iter().enumerate() {
            for (b, &ib) in self.selected.iter().enumerate().skip(a) {
                let v = dot(&self.lx[ia], self.x.row(ib));
                mmat[(a, b)] = v;
                mmat[(b, a)] = v;
            }
        }
        mmat.add_diag(self.lambda);
        let chol = Cholesky::factor(&mmat)
            .ok_or_else(|| anyhow!("M_S not SPD"))?;
        let rhs: Vec<f64> =
            self.selected.iter().map(|&i| self.xly[i]).collect();
        let w_s = chol.solve(&rhs);
        Ok((chol, w_s))
    }

    /// Pairwise risk of the bordered model S ∪ {i} ([`BIG`] when the
    /// candidate is numerically collinear with S). Candidates are
    /// independent given the shared base solve, so forced session rounds
    /// score only their own candidate through this same code path.
    fn bordered_score(&self, chol: &Cholesky, w_s: &[f64], i: usize) -> f64 {
        let m = self.x.cols();
        let k = self.selected.len();
        // bordered solve for S ∪ {i}:
        //   [M_S  b ] [w ]   [rhs_S]
        //   [bᵀ   c ] [wi] = [xly_i]
        let b: Vec<f64> = self
            .selected
            .iter()
            .map(|&s| dot(&self.lx[s], self.x.row(i)))
            .collect();
        let c = dot(&self.lx[i], self.x.row(i)) + self.lambda;
        let (w_new, wi) = if k == 0 {
            (Vec::new(), self.xly[i] / c)
        } else {
            let minv_b = chol.solve(&b);
            let schur = c - dot(&b, &minv_b);
            if schur <= 1e-12 {
                return BIG; // numerically collinear candidate
            }
            let wi = (self.xly[i] - dot(&b, w_s)) / schur;
            let w_new: Vec<f64> = w_s
                .iter()
                .zip(&minv_b)
                .map(|(&ws, &mb)| ws - wi * mb)
                .collect();
            (w_new, wi)
        };
        // pairwise risk of the bordered model — O(km)
        let mut f = vec![0.0; m];
        for (t, &s_idx) in self.selected.iter().enumerate() {
            let row = self.x.row(s_idx);
            let wv = w_new[t];
            for (fj, &xv) in f.iter_mut().zip(row) {
                // xtask-allow: scan-via-kernel -- O(km) bordered-model
                // rescore faithful to the RankRLS paper; not a per-round
                // O(mn) hot path, stays off the kernel tier
                *fj += wv * xv;
            }
        }
        for (fj, &xv) in f.iter_mut().zip(self.x.row(i)) {
            // xtask-allow: scan-via-kernel -- same bordered-model
            // baseline as above; quadratic reference, not a hot path
            *fj += wi * xv;
        }
        pairwise_risk(self.y, &f)
    }
}

impl SessionCore for RankRlsCore<'_> {
    fn target_reached(&self) -> bool {
        self.selected.len() >= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let n = self.x.rows();
        let (chol, w_s) = self.base_solve()?;
        let (bsel, criterion) = match forced {
            Some(b) => {
                ensure!(b < n, "feature {b} out of range (n={n})");
                ensure!(!self.in_s[b], "feature {b} already selected");
                let s = self.bordered_score(&chol, &w_s, b);
                ensure!(
                    s < BIG,
                    "feature {b} is numerically collinear with the \
                     selected set"
                );
                (b, s)
            }
            None => {
                // the base solve is shared read-only state; each
                // bordered solve is independent — deterministic scan
                let scores = super::scan_candidates(
                    n,
                    self.threads,
                    |i| !self.in_s[i],
                    |i| self.bordered_score(&chol, &w_s, i),
                );
                let b = argmin(&scores)
                    .ok_or_else(|| anyhow!("no candidate left"))?;
                (b, scores[b])
            }
        };
        let round = Round { feature: bsel, criterion };
        self.in_s[bsel] = true;
        self.selected.push(bsel);
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.selected.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        if self.selected.is_empty() {
            return Ok(Vec::new());
        }
        let xs = self.x.select_rows(&self.selected);
        Ok(train_rank(&xs, self.y, self.lambda))
    }
}

impl SessionSelector for GreedyRankRls {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let n = x.rows();
        let m = x.cols();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(m == y.len(), "shape mismatch");
        super::require_f64(cfg, "greedy-rankrls")?;
        super::require_no_preselect(cfg, "greedy-rankrls")?;

        // precompute L-products that never change: Lx_i rows and Ly
        let lx: Vec<Vec<f64>> =
            (0..n).map(|i| laplacian_apply(x.row(i))).collect();
        let ly = laplacian_apply(y);
        let xly: Vec<f64> = (0..n).map(|i| dot(x.row(i), &ly)).collect();

        let core = RankRlsCore {
            x,
            y,
            lambda: cfg.lambda,
            k: cfg.k,
            threads: crate::parallel::resolve(cfg.threads),
            lx,
            xly,
            selected: Vec::new(),
            in_s: vec![false; n],
            rounds: Vec::new(),
        };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for GreedyRankRls {
    fn name(&self) -> &'static str {
        "greedy-rankrls"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        super::run_to_completion(self.begin(x, y, cfg)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;
    use crate::proptest::{forall_seeds, Gen};
    use crate::rls::rank::pairwise_accuracy;

    /// Bordered scoring must equal brute-force retraining on S ∪ {i}.
    #[test]
    fn bordered_criterion_equals_retraining() {
        forall_seeds(12, |seed| {
            let mut g = Gen::new(seed + 60);
            let n = g.size(3, 8);
            let m = g.size(4, 14);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.targets(m);
            let cfg = SelectionConfig {
                k: 2.min(n),
                lambda: lam,
                loss: Loss::Squared,
                ..Default::default()
            };
            let r = GreedyRankRls.select(&x, &y, &cfg).unwrap();
            // replay: at each round, the recorded criterion must equal
            // the pairwise risk of a freshly trained model on the prefix
            for (t, round) in r.rounds.iter().enumerate() {
                let s = &r.selected[..=t];
                let xs = x.select_rows(s);
                let w = train_rank(&xs, &y, lam);
                let f: Vec<f64> = (0..m)
                    .map(|j| {
                        let col = xs.col(j);
                        dot(&w, &col)
                    })
                    .collect();
                let want = pairwise_risk(&y, &f);
                assert!(
                    (round.criterion - want).abs()
                        <= 1e-7 * want.abs().max(1.0),
                    "round {t}: {} vs {want}",
                    round.criterion
                );
            }
        });
    }

    #[test]
    fn finds_the_ranking_feature() {
        let mut g = Gen::new(3);
        let m = 80;
        let mut x = g.matrix(10, m);
        let mut y = vec![0.0; m];
        for j in 0..m {
            y[j] = 2.0 * x[(4, j)] + 0.05 * g.rng.normal();
        }
        let _ = &mut x;
        let cfg =
            SelectionConfig { k: 1, lambda: 0.1, loss: Loss::Squared, ..Default::default() };
        let r = GreedyRankRls.select(&x, &y, &cfg).unwrap();
        assert_eq!(r.selected, vec![4]);
    }

    #[test]
    fn selected_model_ranks_well() {
        let mut g = Gen::new(4);
        let m = 100;
        let x = g.matrix(15, m);
        let y: Vec<f64> = (0..m)
            .map(|j| x[(1, j)] + 0.5 * x[(7, j)] + 0.05 * g.rng.normal())
            .collect();
        let cfg =
            SelectionConfig { k: 2, lambda: 0.1, loss: Loss::Squared, ..Default::default() };
        let r = GreedyRankRls.select(&x, &y, &cfg).unwrap();
        let mut s = r.selected.clone();
        s.sort_unstable();
        assert_eq!(s, vec![1, 7]);
        let xs = x.select_rows(&r.selected);
        let f: Vec<f64> = (0..m)
            .map(|j| {
                let col = xs.col(j);
                dot(&r.weights, &col)
            })
            .collect();
        assert!(pairwise_accuracy(&y, &f) > 0.95);
    }

    #[test]
    fn rejects_bad_config() {
        let mut g = Gen::new(5);
        let x = g.matrix(3, 6);
        let y = g.targets(6);
        let cfg = SelectionConfig { k: 4, lambda: 1.0, loss: Loss::Squared, ..Default::default() };
        assert!(GreedyRankRls.select(&x, &y, &cfg).is_err());
    }
}
