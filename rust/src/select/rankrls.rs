//! Greedy forward feature selection for **RankRLS** (paper §5: "design
//! and implement similar feature selection algorithms for RankRLS").
//!
//! Same greedy skeleton as Algorithm 3, adapted to the pairwise ranking
//! objective of [`crate::rls::rank`]. The criterion is the regularized
//! pairwise risk of the model retrained on `S ∪ {i}`, evaluated
//! efficiently with a **bordering update**: the k×k primal matrix
//! `M_S = X_S L X_Sᵀ + λI` has a cached Cholesky factor; adding a
//! candidate row appends one bordered row/column whose Schur complement
//! is a scalar, so each candidate costs O(k² + km) instead of a fresh
//! O(k³ + k²m) solve — per round O(n(k² + km)), linear in m like the
//! classification algorithm.

use anyhow::ensure;

use super::{argmin, Round, SelectionConfig, SelectionResult, Selector, BIG};
use crate::linalg::{dot, Cholesky, Matrix};
use crate::rls::rank::{laplacian_apply, pairwise_risk, train_rank};

/// Greedy RankRLS feature selector (pairwise-risk criterion).
#[derive(Clone, Copy, Debug, Default)]
pub struct GreedyRankRls;

impl Selector for GreedyRankRls {
    fn name(&self) -> &'static str {
        "greedy-rankrls"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        let n = x.rows();
        let m = x.cols();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(m == y.len(), "shape mismatch");

        // precompute L-products that never change: Lx_i rows and Ly
        let lx: Vec<Vec<f64>> =
            (0..n).map(|i| laplacian_apply(x.row(i))).collect();
        let ly = laplacian_apply(y);
        let xly: Vec<f64> = (0..n).map(|i| dot(x.row(i), &ly)).collect();

        let mut selected: Vec<usize> = Vec::new();
        let mut in_s = vec![false; n];
        let mut rounds = Vec::with_capacity(cfg.k);

        while selected.len() < cfg.k {
            let k = selected.len();
            // cached factor of M_S (k×k) and rhs X_S L y
            let (chol, rhs_s) = {
                let mut mmat = Matrix::zeros(k, k);
                for (a, &ia) in selected.iter().enumerate() {
                    for (b, &ib) in selected.iter().enumerate().skip(a) {
                        let v = dot(&lx[ia], x.row(ib));
                        mmat[(a, b)] = v;
                        mmat[(b, a)] = v;
                    }
                }
                mmat.add_diag(cfg.lambda);
                let rhs: Vec<f64> =
                    selected.iter().map(|&i| xly[i]).collect();
                (
                    Cholesky::factor(&mmat).expect("SPD"),
                    rhs,
                )
            };
            let w_s = chol.solve(&rhs_s); // reused by every candidate

            let mut scores = vec![BIG; n];
            for i in 0..n {
                if in_s[i] {
                    continue;
                }
                // bordered solve for S ∪ {i}:
                //   [M_S  b ] [w ]   [rhs_S]
                //   [bᵀ   c ] [wi] = [xly_i]
                let b: Vec<f64> = selected
                    .iter()
                    .map(|&s| dot(&lx[*&s], x.row(i)))
                    .collect();
                let c = dot(&lx[i], x.row(i)) + cfg.lambda;
                let (w_new, wi) = if k == 0 {
                    (Vec::new(), xly[i] / c)
                } else {
                    let minv_b = chol.solve(&b);
                    let schur = c - dot(&b, &minv_b);
                    if schur <= 1e-12 {
                        continue; // numerically collinear candidate
                    }
                    let wi = (xly[i] - dot(&b, &w_s)) / schur;
                    let w_new: Vec<f64> = w_s
                        .iter()
                        .zip(&minv_b)
                        .map(|(&ws, &mb)| ws - wi * mb)
                        .collect();
                    (w_new, wi)
                };
                // pairwise risk of the bordered model — O(km)
                let mut f = vec![0.0; m];
                for (t, &s_idx) in selected.iter().enumerate() {
                    let row = x.row(s_idx);
                    let wv = w_new[t];
                    for (fj, &xv) in f.iter_mut().zip(row) {
                        *fj += wv * xv;
                    }
                }
                for (fj, &xv) in f.iter_mut().zip(x.row(i)) {
                    *fj += wi * xv;
                }
                scores[i] = pairwise_risk(y, &f);
            }

            let bsel = argmin(&scores)
                .ok_or_else(|| anyhow::anyhow!("no candidate left"))?;
            rounds.push(Round { feature: bsel, criterion: scores[bsel] });
            in_s[bsel] = true;
            selected.push(bsel);
        }

        let xs = x.select_rows(&selected);
        let weights = train_rank(&xs, y, cfg.lambda);
        Ok(SelectionResult { selected, rounds, weights })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;
    use crate::proptest::{forall_seeds, Gen};
    use crate::rls::rank::pairwise_accuracy;

    /// Bordered scoring must equal brute-force retraining on S ∪ {i}.
    #[test]
    fn bordered_criterion_equals_retraining() {
        forall_seeds(12, |seed| {
            let mut g = Gen::new(seed + 60);
            let n = g.size(3, 8);
            let m = g.size(4, 14);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.targets(m);
            let cfg = SelectionConfig {
                k: 2.min(n),
                lambda: lam,
                loss: Loss::Squared,
            };
            let r = GreedyRankRls.select(&x, &y, &cfg).unwrap();
            // replay: at each round, the recorded criterion must equal
            // the pairwise risk of a freshly trained model on the prefix
            for (t, round) in r.rounds.iter().enumerate() {
                let s = &r.selected[..=t];
                let xs = x.select_rows(s);
                let w = train_rank(&xs, &y, lam);
                let f: Vec<f64> = (0..m)
                    .map(|j| {
                        let col = xs.col(j);
                        dot(&w, &col)
                    })
                    .collect();
                let want = pairwise_risk(&y, &f);
                assert!(
                    (round.criterion - want).abs()
                        <= 1e-7 * want.abs().max(1.0),
                    "round {t}: {} vs {want}",
                    round.criterion
                );
            }
        });
    }

    #[test]
    fn finds_the_ranking_feature() {
        let mut g = Gen::new(3);
        let m = 80;
        let mut x = g.matrix(10, m);
        let mut y = vec![0.0; m];
        for j in 0..m {
            y[j] = 2.0 * x[(4, j)] + 0.05 * g.rng.normal();
        }
        let _ = &mut x;
        let cfg =
            SelectionConfig { k: 1, lambda: 0.1, loss: Loss::Squared };
        let r = GreedyRankRls.select(&x, &y, &cfg).unwrap();
        assert_eq!(r.selected, vec![4]);
    }

    #[test]
    fn selected_model_ranks_well() {
        let mut g = Gen::new(4);
        let m = 100;
        let x = g.matrix(15, m);
        let y: Vec<f64> = (0..m)
            .map(|j| x[(1, j)] + 0.5 * x[(7, j)] + 0.05 * g.rng.normal())
            .collect();
        let cfg =
            SelectionConfig { k: 2, lambda: 0.1, loss: Loss::Squared };
        let r = GreedyRankRls.select(&x, &y, &cfg).unwrap();
        let mut s = r.selected.clone();
        s.sort_unstable();
        assert_eq!(s, vec![1, 7]);
        let xs = x.select_rows(&r.selected);
        let f: Vec<f64> = (0..m)
            .map(|j| {
                let col = xs.col(j);
                dot(&r.weights, &col)
            })
            .collect();
        assert!(pairwise_accuracy(&y, &f) > 0.95);
    }

    #[test]
    fn rejects_bad_config() {
        let mut g = Gen::new(5);
        let x = g.matrix(3, 6);
        let y = g.targets(6);
        let cfg = SelectionConfig { k: 4, lambda: 1.0, loss: Loss::Squared };
        assert!(GreedyRankRls.select(&x, &y, &cfg).is_err());
    }
}
