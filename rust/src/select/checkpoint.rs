//! Durable session checkpoints — kill-safe persistence for long selection
//! runs.
//!
//! The paper's greedy selection is a strictly incremental computation:
//! each round's LOO-shortcut state is a pure function of the selected
//! prefix, and [`SessionSelector::begin_from`] already rebuilds that state
//! bit-identically in-process. This module extends the guarantee across
//! process boundaries: a [`Checkpoint`] persists a session's trajectory
//! (replayable round log, current feature set and weights, cumulative
//! elapsed time for [`StopPolicy::TimeBudget`] re-arming, and a
//! config/data fingerprint), and [`resume_from_path`] turns it back into
//! a live session whose continuation is bit-identical to the run that was
//! killed — the invariant the CI kill/resume gauntlet enforces end to
//! end.
//!
//! **Format.** A versioned, self-describing text format (hand-rolled like
//! the model format in [`crate::coordinator`]; no new dependencies).
//! Criteria and weights are stored as `f64` bit patterns in hex so the
//! round-trip is exact, with a human-readable decimal alongside. The file
//! ends with an FNV-1a checksum line: a truncated or bit-flipped file is
//! rejected with a clear error instead of resuming a wrong trajectory.
//!
//! ```text
//! greedy-rls-checkpoint v1
//! config 9a…            config-hash: k, λ, loss, stop policy (not threads)
//! data 7f…              data-hash: shape + every f64 bit of X and y
//! elapsed_ns 12345      cumulative selection wall-clock, this + prior runs
//! stop -                or target|round-budget|time-budget|plateau|exhausted
//! rounds 2              replay log, in round order
//! r 17 bf… 4.1e1        feature, criterion bits, criterion (informative)
//! r 4 bf… 3.0e1
//! selected 2 17 4       current feature set (serving order)
//! weights 2
//! w 3fe… 7.1e-1         weight bits, weight (informative)
//! w bfc… -2.2e-1
//! end c0…               FNV-1a of every byte above this line
//! ```
//!
//! **Atomicity.** [`Checkpoint::save_atomic`] writes to a `.tmp` sibling,
//! fsyncs, then renames into place — on POSIX the rename is atomic, so a
//! kill mid-save leaves either the previous checkpoint or the new one,
//! never a torn file. Leftover `.tmp` files are ignored by
//! [`latest_in_dir`].
//!
//! **Autosave.** [`Autosaver`] is an [`Observer`] implementing the save
//! policy (every N rounds, and on stop — including a [`StopPolicy::Plateau`]
//! stop); [`drive_checkpointed`] drives a session with it, snapshotting
//! [`Session::state`] whenever the policy fires.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context};

use super::session::{
    drive_tapped, Observer, Session, SessionSelector, StateObserver,
    StopReason,
};
use super::{Round, SelectionConfig, StopPolicy};
use crate::data::fingerprint::{fingerprint_xy, Fnv64};
use crate::linalg::Matrix;
use crate::metrics::Loss;
use crate::rls::Predictor;

/// Checkpoint format version this build writes and reads.
pub const FORMAT_VERSION: u32 = 1;

const HEADER_TAG: &str = "greedy-rls-checkpoint";

// ---------------------------------------------------------------------------
// Fingerprints
// ---------------------------------------------------------------------------

/// Identity of a selection run: which configuration over which data.
///
/// Stored in every checkpoint; [`Checkpoint::verify`] refuses to resume
/// when either half differs, because the continuation would silently
/// diverge from the original trajectory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fingerprint {
    /// Hash of the [`SelectionConfig`] — see [`config_hash`].
    pub config: u64,
    /// Hash of the dataset — see [`crate::data::fingerprint::fingerprint_xy`].
    pub data: u64,
}

/// Hash the parts of a [`SelectionConfig`] that determine the selection
/// trajectory: `k`, `λ` (by bit pattern), the loss, and the stop policy.
///
/// `threads` is deliberately **excluded**: the parallel execution layer is
/// bit-deterministic (see [`crate::parallel`]), so a run checkpointed at
/// one thread count legitimately resumes at another — the CI gauntlet
/// exercises exactly that. `tile_cols` is excluded for the same reason.
/// `precision` **is included** (as a trailing marker, written only when
/// it differs from the f64 default so every pre-existing fingerprint is
/// unchanged): an f32c trajectory is deterministic but *different* from
/// the f64 one, so runs at different precisions must never silently
/// resume each other.
///
/// `preselect` **is included** the same way — a filtered trajectory is
/// deterministic but different, so its marker (`p`, `sketch_dim`,
/// `seed`) trails the hash when a filter is configured. This variant
/// hashes the config as declared; [`config_hash_for`] additionally
/// normalizes identity filters away when the candidate count is known.
pub fn config_hash(cfg: &SelectionConfig) -> u64 {
    config_hash_for(cfg, None)
}

/// [`config_hash`] with the candidate count `n` when the caller knows
/// it: a filter that keeps everything (`p >= n`) reproduces the exact
/// greedy trajectory bitwise, so its marker is **not** written — the
/// checkpoint is byte-identical to an unfiltered run's and the two
/// resume each other freely, which is what the p = n acceptance
/// gate checks. With `n = None` the marker is written for any
/// configured filter (the conservative choice for callers that never
/// see the data, e.g. cv sweep manifests).
pub fn config_hash_for(cfg: &SelectionConfig, n: Option<usize>) -> u64 {
    let mut h = Fnv64::new();
    h.write(b"greedy-rls-config-v1");
    h.write_usize(cfg.k);
    h.write_f64(cfg.lambda);
    h.write_u64(match cfg.loss {
        Loss::Squared => 0,
        Loss::ZeroOne => 1,
    });
    match cfg.stop {
        StopPolicy::KBudget(b) => {
            h.write_u64(0);
            h.write_usize(b);
        }
        StopPolicy::TimeBudget(d) => {
            h.write_u64(1);
            h.write_u64(d.as_nanos() as u64);
        }
        StopPolicy::Plateau { patience, min_rel_improvement } => {
            h.write_u64(2);
            h.write_usize(patience);
            h.write_f64(min_rel_improvement);
        }
    }
    if cfg.precision != crate::kernel::Precision::F64 {
        h.write(b"precision");
        h.write(cfg.precision.as_str().as_bytes());
    }
    if let Some(ps) = cfg.preselect {
        if n.map_or(true, |nn| ps.p < nn) {
            h.write(b"preselect");
            h.write_usize(ps.p);
            h.write_usize(ps.sketch_dim);
            h.write_u64(ps.seed);
        }
    }
    h.finish()
}

/// Fingerprint a selection problem (config + data). Knows the
/// candidate count, so identity preselect filters hash like no filter
/// at all — see [`config_hash_for`].
pub fn fingerprint(
    x: &Matrix,
    y: &[f64],
    cfg: &SelectionConfig,
) -> Fingerprint {
    Fingerprint {
        config: config_hash_for(cfg, Some(x.rows())),
        data: fingerprint_xy(x, y),
    }
}

// ---------------------------------------------------------------------------
// The checkpoint itself
// ---------------------------------------------------------------------------

/// A session trajectory frozen to disk (or to a string).
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Config/data identity of the run that wrote this.
    pub fingerprint: Fingerprint,
    /// Cumulative selection wall-clock (this process plus any prior ones)
    /// — re-armed into the resumed session via [`Session::bill_elapsed`].
    pub elapsed: Duration,
    /// Stop reason, if the session had stopped when this was written.
    pub stop_reason: Option<StopReason>,
    /// Per-round log in round order — the replay input for
    /// [`SessionSelector::begin_from`] (for backward elimination these are
    /// the *eliminated* features, exactly what `begin_from` expects).
    pub rounds: Vec<Round>,
    /// Current feature set (selection order for forward selectors,
    /// ascending survivors for backward elimination).
    pub selected: Vec<usize>,
    /// Model weights aligned with `selected` — lets `serve --follow` build
    /// a [`Predictor`] without replaying the trajectory.
    pub weights: Vec<f64>,
}

impl Checkpoint {
    /// Snapshot a live session under the given fingerprint.
    pub fn from_session(
        session: &(dyn Session + '_),
        fingerprint: Fingerprint,
    ) -> anyhow::Result<Checkpoint> {
        let st = session.state()?;
        Ok(Checkpoint {
            fingerprint,
            elapsed: session.elapsed(),
            stop_reason: st.stop_reason,
            rounds: st.rounds,
            selected: st.selected,
            weights: st.weights,
        })
    }

    /// The feature sequence to feed [`SessionSelector::begin_from`].
    pub fn replay_features(&self) -> Vec<usize> {
        self.rounds.iter().map(|r| r.feature).collect()
    }

    /// Criterion trajectory recorded so far (one value per round).
    pub fn criterion_curve(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.criterion).collect()
    }

    /// Package the checkpointed model for serving.
    pub fn predictor(&self) -> Predictor {
        Predictor {
            selected: self.selected.clone(),
            weights: self.weights.clone(),
        }
    }

    /// Refuse to resume under a different config or dataset.
    pub fn verify(&self, expect: &Fingerprint) -> anyhow::Result<()> {
        ensure!(
            self.fingerprint.config == expect.config,
            "checkpoint config hash {:016x} does not match this run's \
             {:016x}: k, lambda, loss, or stop policy differ (threads are \
             allowed to differ)",
            self.fingerprint.config,
            expect.config
        );
        ensure!(
            self.fingerprint.data == expect.data,
            "checkpoint data hash {:016x} does not match this dataset's \
             {:016x}: the checkpoint was written for different data",
            self.fingerprint.data,
            expect.data
        );
        Ok(())
    }

    /// Serialize to the versioned text format (see the module docs).
    pub fn to_text(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "{HEADER_TAG} v{FORMAT_VERSION}");
        let _ = writeln!(s, "config {:016x}", self.fingerprint.config);
        let _ = writeln!(s, "data {:016x}", self.fingerprint.data);
        let _ = writeln!(s, "elapsed_ns {}", self.elapsed.as_nanos());
        let _ = writeln!(s, "stop {}", stop_tag(self.stop_reason));
        let _ = writeln!(s, "rounds {}", self.rounds.len());
        for r in &self.rounds {
            let _ = writeln!(
                s,
                "r {} {:016x} {:.6e}",
                r.feature,
                r.criterion.to_bits(),
                r.criterion
            );
        }
        let _ = write!(s, "selected {}", self.selected.len());
        for &i in &self.selected {
            let _ = write!(s, " {i}");
        }
        s.push('\n');
        let _ = writeln!(s, "weights {}", self.weights.len());
        for &w in &self.weights {
            let _ = writeln!(s, "w {:016x} {:.17e}", w.to_bits(), w);
        }
        seal_with_checksum(s)
    }

    /// Parse the text format, rejecting truncation, corruption, and
    /// version mismatches with specific errors.
    pub fn from_text(text: &str) -> anyhow::Result<Checkpoint> {
        // 1. the integrity trailer: everything before the final `end`
        //    line must hash to the recorded checksum. A file cut short by
        //    a crash has no trailer at all.
        let body = checked_body(text)?;

        // 2. the body, line by line
        let mut lines = body.lines();
        let header = lines.next().unwrap_or("");
        let version = header.strip_prefix(HEADER_TAG).map(str::trim);
        let version = match version {
            Some(v) => v,
            None => bail!("not a greedy-rls checkpoint (header {header:?})"),
        };
        let vnum: u32 = version
            .strip_prefix('v')
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| {
                anyhow!("malformed checkpoint version tag {version:?}")
            })?;
        ensure!(
            vnum == FORMAT_VERSION,
            "unsupported checkpoint version v{vnum} (this build reads \
             v{FORMAT_VERSION})"
        );

        let config =
            parse_hex_u64(next_line(&mut lines, "config")?).context("config hash")?;
        let data =
            parse_hex_u64(next_line(&mut lines, "data")?).context("data hash")?;
        let elapsed_ns: u128 = next_line(&mut lines, "elapsed_ns")?
            .trim()
            .parse()
            .context("elapsed_ns")?;
        let stop_reason = parse_stop_tag(next_line(&mut lines, "stop")?.trim())?;

        let n_rounds: usize = next_line(&mut lines, "rounds")?
            .trim()
            .parse()
            .context("round count")?;
        let mut rounds = Vec::with_capacity(n_rounds);
        for _ in 0..n_rounds {
            let rest = next_line(&mut lines, "r")?;
            let mut tok = rest.split_whitespace();
            let feature: usize = tok
                .next()
                .ok_or_else(|| anyhow!("round line missing feature"))?
                .parse()
                .context("round feature")?;
            let criterion = f64::from_bits(
                parse_hex_u64(
                    tok.next()
                        .ok_or_else(|| anyhow!("round line missing criterion"))?,
                )
                .context("round criterion bits")?,
            );
            rounds.push(Round { feature, criterion });
        }

        let sel_line = next_line(&mut lines, "selected")?;
        let mut tok = sel_line.split_whitespace();
        let n_selected: usize = tok
            .next()
            .ok_or_else(|| anyhow!("selected line missing count"))?
            .parse()
            .context("selected count")?;
        let selected: Vec<usize> = tok
            .map(|t| t.parse().context("selected index"))
            .collect::<anyhow::Result<_>>()?;
        ensure!(
            selected.len() == n_selected,
            "selected line announces {n_selected} indices but carries {}",
            selected.len()
        );

        let n_weights: usize = next_line(&mut lines, "weights")?
            .trim()
            .parse()
            .context("weight count")?;
        ensure!(
            n_weights == n_selected,
            "checkpoint has {n_weights} weights for {n_selected} selected \
             features"
        );
        let mut weights = Vec::with_capacity(n_weights);
        for _ in 0..n_weights {
            let rest = next_line(&mut lines, "w")?;
            let bits = rest
                .split_whitespace()
                .next()
                .ok_or_else(|| anyhow!("weight line missing bits"))?;
            weights
                .push(f64::from_bits(parse_hex_u64(bits).context("weight bits")?));
        }

        Ok(Checkpoint {
            fingerprint: Fingerprint { config, data },
            elapsed: duration_from_nanos(elapsed_ns),
            stop_reason,
            rounds,
            selected,
            weights,
        })
    }

    /// Write atomically: serialize to a `.tmp` sibling, fsync, rename
    /// into place. A kill at any instant leaves either no file, a `.tmp`
    /// leftover (ignored by [`latest_in_dir`]), or the complete
    /// checkpoint — never a torn read for a concurrent `serve --follow`.
    pub fn save_atomic(&self, path: &Path) -> anyhow::Result<()> {
        write_atomic(path, &self.to_text())
    }

    /// Read and validate a checkpoint file.
    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Checkpoint::from_text(&text)
            .with_context(|| format!("parsing {}", path.display()))
    }
}

impl<'s> dyn Session + 's {
    /// Method form of [`Checkpoint::from_session`]:
    /// `session.checkpoint(fp)?` snapshots this session's trajectory for
    /// persistence.
    pub fn checkpoint(
        &self,
        fingerprint: Fingerprint,
    ) -> anyhow::Result<Checkpoint> {
        Checkpoint::from_session(self, fingerprint)
    }
}

// ---------------------------------------------------------------------------
// Shared persistence primitives (also used by coordinator::cv fold files)
// ---------------------------------------------------------------------------

/// Append the integrity trailer `end <fnv64>` to a serialized body.
pub(crate) fn seal_with_checksum(mut body: String) -> String {
    use std::fmt::Write as _;
    let mut h = Fnv64::new();
    h.write(body.as_bytes());
    let _ = writeln!(body, "end {:016x}", h.finish());
    body
}

/// Validate the trailer written by [`seal_with_checksum`] and return the
/// body (with its trailing newline). Distinguishes truncation (no
/// trailer at all — what a crash mid-write leaves) from corruption
/// (checksum mismatch).
pub(crate) fn checked_body(text: &str) -> anyhow::Result<&str> {
    let marker = text.rfind("\nend ").ok_or_else(|| {
        anyhow!(
            "file is truncated or not a checkpoint: missing \
             `end <checksum>` trailer"
        )
    })?;
    let body = &text[..marker + 1]; // includes the trailing newline
    let trailer = text[marker + 1..].trim_end();
    let recorded = trailer
        .strip_prefix("end ")
        .and_then(|h| u64::from_str_radix(h.trim(), 16).ok())
        .ok_or_else(|| {
            anyhow!("checkpoint trailer {trailer:?} is malformed")
        })?;
    let actual = {
        let mut h = Fnv64::new();
        h.write(body.as_bytes());
        h.finish()
    };
    ensure!(
        actual == recorded,
        "file is corrupt: checksum {actual:016x} does not match recorded \
         {recorded:016x}"
    );
    Ok(body)
}

/// Write `text` to `path` atomically: `.tmp` sibling, fsync, rename. The
/// durability half of every checkpoint-family format.
pub(crate) fn write_atomic(path: &Path, text: &str) -> anyhow::Result<()> {
    use std::io::Write as _;
    let file_name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| {
            anyhow!("checkpoint path {} has no file name", path.display())
        })?;
    let tmp = path.with_file_name(format!("{file_name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(text.as_bytes())
            .with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all()
            .with_context(|| format!("syncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", path.display()))
}

/// Consume one body line, which must be `<key>` or `<key> <rest>`;
/// returns `<rest>` (possibly empty).
fn next_line<'t>(
    lines: &mut std::str::Lines<'t>,
    key: &str,
) -> anyhow::Result<&'t str> {
    let line = lines
        .next()
        .ok_or_else(|| anyhow!("checkpoint ends before `{key}` line"))?;
    line.strip_prefix(key)
        .and_then(|rest| {
            // require a separating space (or an exactly-empty rest), so
            // `rounds …` can never satisfy the key `r`
            if rest.is_empty() {
                Some(rest)
            } else {
                rest.strip_prefix(' ')
            }
        })
        .ok_or_else(|| anyhow!("checkpoint line {line:?}: expected `{key} …`"))
}

fn parse_hex_u64(s: &str) -> anyhow::Result<u64> {
    u64::from_str_radix(s.trim(), 16)
        .map_err(|e| anyhow!("bad hex value {s:?}: {e}"))
}

fn duration_from_nanos(ns: u128) -> Duration {
    // Duration::from_nanos takes u64 (~584 years) — saturate above that.
    Duration::from_nanos(u64::try_from(ns).unwrap_or(u64::MAX))
}

fn stop_tag(reason: Option<StopReason>) -> &'static str {
    match reason {
        None => "-",
        Some(StopReason::TargetReached) => "target",
        Some(StopReason::RoundBudget) => "round-budget",
        Some(StopReason::TimeBudget) => "time-budget",
        Some(StopReason::Plateau) => "plateau",
        Some(StopReason::Exhausted) => "exhausted",
    }
}

fn parse_stop_tag(tag: &str) -> anyhow::Result<Option<StopReason>> {
    Ok(match tag {
        "-" => None,
        "target" => Some(StopReason::TargetReached),
        "round-budget" => Some(StopReason::RoundBudget),
        "time-budget" => Some(StopReason::TimeBudget),
        "plateau" => Some(StopReason::Plateau),
        "exhausted" => Some(StopReason::Exhausted),
        other => bail!("unknown stop tag {other:?}"),
    })
}

// ---------------------------------------------------------------------------
// Checkpoint directories
// ---------------------------------------------------------------------------

/// Canonical file name for a checkpoint taken after `rounds` rounds.
/// Zero-padded so lexicographic and numeric order agree.
pub fn checkpoint_file_name(rounds: usize) -> String {
    format!("ckpt-{rounds:08}.ckpt")
}

/// Canonical path for a checkpoint inside `dir`.
pub fn checkpoint_path(dir: &Path, rounds: usize) -> PathBuf {
    dir.join(checkpoint_file_name(rounds))
}

/// Round count encoded in a checkpoint file name, if it is one.
fn parse_round_count(name: &str) -> Option<usize> {
    name.strip_prefix("ckpt-")?
        .strip_suffix(".ckpt")?
        .parse()
        .ok()
}

/// Round count encoded in a checkpoint path's file name, if it follows
/// the [`checkpoint_file_name`] convention — lets a follower decide
/// whether a file is newer without reading it.
pub fn round_count_in_name(path: &Path) -> Option<usize> {
    path.file_name()?.to_str().and_then(parse_round_count)
}

/// The most advanced checkpoint in `dir` (highest round count), or `None`
/// if the directory is missing or holds none. Files that are not
/// `ckpt-<rounds>.ckpt` — crash-leftover `.tmp` files in particular — are
/// ignored.
pub fn latest_in_dir(dir: &Path) -> anyhow::Result<Option<PathBuf>> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(None)
        }
        Err(e) => {
            return Err(e)
                .with_context(|| format!("listing {}", dir.display()))
        }
    };
    let mut best: Option<(usize, PathBuf)> = None;
    for entry in entries {
        let entry =
            entry.with_context(|| format!("listing {}", dir.display()))?;
        let name = entry.file_name();
        let Some(rounds) = name.to_str().and_then(parse_round_count) else {
            continue;
        };
        if best.as_ref().is_none_or(|(r, _)| rounds > *r) {
            best = Some((rounds, entry.path()));
        }
    }
    Ok(best.map(|(_, p)| p))
}

// ---------------------------------------------------------------------------
// Resume
// ---------------------------------------------------------------------------

/// Rebuild a live session from a checkpoint file: verify the fingerprint,
/// replay the recorded rounds through [`SessionSelector::begin_from`]
/// (bit-identical cache reconstruction), and re-arm the time-budget clock
/// with the prior elapsed time. Returns the session together with the
/// checkpoint it was restored from.
pub fn resume_from_path<'a, S: SessionSelector + ?Sized>(
    sel: &S,
    x: &'a Matrix,
    y: &'a [f64],
    cfg: &SelectionConfig,
    path: &Path,
) -> anyhow::Result<(Box<dyn Session + 'a>, Checkpoint)> {
    let ckpt = Checkpoint::load(path)?;
    ckpt.verify(&fingerprint(x, y, cfg))?;
    let mut session = sel
        .begin_from(x, y, cfg, &ckpt.replay_features())
        .with_context(|| {
            format!(
                "replaying {} checkpointed rounds from {}",
                ckpt.rounds.len(),
                path.display()
            )
        })?;
    session.bill_elapsed(ckpt.elapsed);
    Ok((session, ckpt))
}

// ---------------------------------------------------------------------------
// Autosave
// ---------------------------------------------------------------------------

/// When the [`Autosaver`] writes.
#[derive(Clone, Copy, Debug)]
pub struct AutosavePolicy {
    /// Save after this many committed rounds since the last save
    /// (`0` = never periodically; only `on_stop`).
    pub every: usize,
    /// Also save when the session stops — whatever the reason, so a
    /// [`StopPolicy::Plateau`] stop leaves a final checkpoint behind.
    pub on_stop: bool,
}

impl Default for AutosavePolicy {
    fn default() -> Self {
        AutosavePolicy { every: 1, on_stop: true }
    }
}

/// The [`AutosavePolicy`] firing rule as a reusable counter state
/// machine: feed it rounds and the stop notification, ask whether the
/// action is due, and acknowledge when the action actually fired.
///
/// [`Autosaver`] runs one of these for checkpoint writes and the bus
/// [`crate::coordinator::stream::PublishObserver`] runs another for
/// model publishes — with equal policies the two fire in identical
/// flush cycles **by construction**, which is what makes the streaming
/// pipeline's publish-after-save ordering hold at any checkpoint
/// interval (and only then).
#[derive(Clone, Copy, Debug)]
pub struct PolicyTicker {
    policy: AutosavePolicy,
    since_fire: usize,
    due: bool,
}

impl PolicyTicker {
    /// An idle ticker for `policy`.
    pub fn new(policy: AutosavePolicy) -> PolicyTicker {
        PolicyTicker { policy, since_fire: 0, due: false }
    }

    /// The policy this ticker runs.
    pub fn policy(&self) -> AutosavePolicy {
        self.policy
    }

    /// Feed one committed round.
    pub fn on_round(&mut self) {
        self.since_fire += 1;
        if self.policy.every > 0 && self.since_fire >= self.policy.every {
            self.due = true;
        }
    }

    /// Feed the stop notification.
    pub fn on_stop(&mut self) {
        if self.policy.on_stop {
            self.due = true;
        }
    }

    /// Consume the due flag: `true` means the action should fire now.
    pub fn take_due(&mut self) -> bool {
        std::mem::take(&mut self.due)
    }

    /// Acknowledge that the action actually fired (restarts the
    /// interval counter).
    pub fn fired(&mut self) {
        self.since_fire = 0;
    }
}

/// [`Observer`]-driven autosave: the observer callbacks run the policy
/// state machine, and [`drive_checkpointed`] (which owns the session
/// borrow) snapshots and writes whenever the policy marks a save due.
pub struct Autosaver {
    dir: PathBuf,
    ticker: PolicyTicker,
    fingerprint: Fingerprint,
    /// Dedupe key of the last write: round count + stop reason. The stop
    /// reason is part of the key so the final on-stop save is *not*
    /// deduped against the same round's mid-run save — the trail's last
    /// checkpoint must record why the session stopped.
    last_saved: Option<(usize, Option<StopReason>)>,
    /// Checkpoints written so far (monotonic; exposed for logging/tests).
    pub saves: usize,
}

impl Autosaver {
    /// Create the checkpoint directory (if needed) and an idle saver.
    pub fn new(
        dir: impl Into<PathBuf>,
        policy: AutosavePolicy,
        fingerprint: Fingerprint,
    ) -> anyhow::Result<Autosaver> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        Ok(Autosaver {
            dir,
            ticker: PolicyTicker::new(policy),
            fingerprint,
            last_saved: None,
            saves: 0,
        })
    }

    /// The save policy this autosaver runs (read by
    /// [`crate::coordinator::stream::train_serve`] to give the bus
    /// publisher the identical policy).
    pub fn policy(&self) -> AutosavePolicy {
        self.ticker.policy()
    }

    /// Snapshot `session` and write `ckpt-<rounds>.ckpt` now (deduped: a
    /// (round count, stop reason) state already on disk is not
    /// rewritten; a stop re-saves the final round's file so it records
    /// the reason). Returns the path written, or `None` when deduped.
    pub fn save_now(
        &mut self,
        session: &(dyn Session + '_),
    ) -> anyhow::Result<Option<PathBuf>> {
        let key = (session.rounds_done(), session.stop_reason());
        if self.last_saved == Some(key) {
            return Ok(None);
        }
        let ckpt = Checkpoint::from_session(session, self.fingerprint)?;
        let path = checkpoint_path(&self.dir, key.0);
        ckpt.save_atomic(&path)?;
        self.last_saved = Some(key);
        self.ticker.fired();
        self.saves += 1;
        Ok(Some(path))
    }

    /// Write if the policy has marked a save due since the last write.
    pub fn flush_due(
        &mut self,
        session: &(dyn Session + '_),
    ) -> anyhow::Result<Option<PathBuf>> {
        if !self.ticker.take_due() {
            return Ok(None);
        }
        self.save_now(session)
    }
}

impl Observer for Autosaver {
    fn on_round(&mut self, _index: usize, _round: &Round, _elapsed: Duration) {
        self.ticker.on_round();
    }

    fn on_stop(&mut self, _reason: StopReason) {
        self.ticker.on_stop();
    }
}

impl StateObserver for Autosaver {
    /// Delegates to [`Autosaver::flush_due`] — write `ckpt-<rounds>.ckpt`
    /// if the policy marked a save due since the last write.
    fn flush(&mut self, session: &(dyn Session + '_)) -> anyhow::Result<()> {
        self.flush_due(session).map(|_| ())
    }
}

/// [`super::session::drive`] with autosaving: every committed round is
/// reported to `observer` *and* to the saver's policy; the saver then
/// writes a checkpoint whenever its policy fired (every N rounds, on
/// stop). Returns the stop reason; the final checkpoint — written for any
/// stop when the policy's `on_stop` is set — records it.
///
/// A thin wrapper over [`drive_tapped`] with the saver as the only tap;
/// to compose autosaving with other state taps (e.g. the model-publishing
/// [`crate::coordinator::stream::PublishObserver`]) call `drive_tapped`
/// directly — tap order is the publish-after-save contract.
pub fn drive_checkpointed(
    session: &mut (dyn Session + '_),
    observer: &mut dyn Observer,
    saver: &mut Autosaver,
) -> anyhow::Result<StopReason> {
    drive_tapped(session, observer, &mut [saver])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::greedy::GreedyRls;
    use crate::select::{NoopObserver, Selector, StepOutcome};

    fn dataset() -> crate::data::Dataset {
        crate::data::synthetic::two_gaussians(40, 12, 4, 1.5, 21)
    }

    fn cfg(k: usize) -> SelectionConfig {
        SelectionConfig::builder().k(k).lambda(0.8).build()
    }

    fn sample_checkpoint() -> Checkpoint {
        Checkpoint {
            fingerprint: Fingerprint { config: 0xdead_beef, data: 0x1234 },
            elapsed: Duration::from_nanos(987_654_321),
            stop_reason: Some(StopReason::Plateau),
            rounds: vec![
                Round { feature: 17, criterion: 41.25 },
                Round { feature: 4, criterion: -0.0 },
            ],
            selected: vec![17, 4],
            weights: vec![0.7071067811865476, -1.5e-300],
        }
    }

    fn assert_same(a: &Checkpoint, b: &Checkpoint) {
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.elapsed, b.elapsed);
        assert_eq!(a.stop_reason, b.stop_reason);
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.rounds.len(), b.rounds.len());
        for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(ra.feature, rb.feature);
            assert_eq!(ra.criterion.to_bits(), rb.criterion.to_bits());
        }
        assert_eq!(a.weights.len(), b.weights.len());
        for (wa, wb) in a.weights.iter().zip(&b.weights) {
            assert_eq!(wa.to_bits(), wb.to_bits());
        }
    }

    #[test]
    fn text_roundtrip_is_bit_exact() {
        let c = sample_checkpoint();
        let back = Checkpoint::from_text(&c.to_text()).unwrap();
        assert_same(&c, &back);
        // -0.0 and subnormal-ish weights survive exactly
        assert_eq!(back.rounds[1].criterion.to_bits(), (-0.0f64).to_bits());
    }

    #[test]
    fn truncated_text_is_rejected() {
        let text = sample_checkpoint().to_text();
        for cut in [text.len() / 4, text.len() / 2, text.len() - 2] {
            let err = Checkpoint::from_text(&text[..cut]).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("truncated") || msg.contains("corrupt"),
                "cut at {cut}: {msg}"
            );
        }
    }

    #[test]
    fn corrupted_byte_is_rejected() {
        let text = sample_checkpoint().to_text();
        // flip one digit inside the body (feature index 17 → 27)
        let bad = text.replacen("r 17 ", "r 27 ", 1);
        assert_ne!(bad, text);
        let err = Checkpoint::from_text(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("corrupt"), "{err:#}");
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let text = sample_checkpoint()
            .to_text()
            .replacen("checkpoint v1", "checkpoint v2", 1);
        // re-seal the checksum so only the version differs
        let marker = text.rfind("\nend ").unwrap();
        let body = &text[..marker + 1];
        let mut h = Fnv64::new();
        h.write(body.as_bytes());
        let resealed = format!("{body}end {:016x}\n", h.finish());
        let err = Checkpoint::from_text(&resealed).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported"), "{err:#}");
    }

    #[test]
    fn foreign_file_is_rejected() {
        assert!(Checkpoint::from_text("greedy-rls-model v1\n1 2.0\n").is_err());
        assert!(Checkpoint::from_text("").is_err());
    }

    #[test]
    fn weight_count_must_match_selected() {
        let mut c = sample_checkpoint();
        c.weights.pop();
        let err = Checkpoint::from_text(&c.to_text()).unwrap_err();
        assert!(format!("{err:#}").contains("weights"), "{err:#}");
    }

    #[test]
    fn verify_distinguishes_config_and_data_mismatch() {
        let c = sample_checkpoint();
        let fp = c.fingerprint;
        assert!(c.verify(&fp).is_ok());
        let err = c
            .verify(&Fingerprint { config: fp.config ^ 1, ..fp })
            .unwrap_err();
        assert!(format!("{err:#}").contains("config"), "{err:#}");
        let err =
            c.verify(&Fingerprint { data: fp.data ^ 1, ..fp }).unwrap_err();
        assert!(format!("{err:#}").contains("data"), "{err:#}");
    }

    #[test]
    fn config_hash_covers_policy_but_not_threads() {
        let base = cfg(4);
        assert_eq!(config_hash(&base), config_hash(&base));
        assert_eq!(
            config_hash(&base),
            config_hash(&SelectionConfig { threads: 7, ..base })
        );
        assert_ne!(
            config_hash(&base),
            config_hash(&SelectionConfig { k: 5, ..base })
        );
        assert_ne!(
            config_hash(&base),
            config_hash(&SelectionConfig { lambda: 0.9, ..base })
        );
        assert_ne!(
            config_hash(&base),
            config_hash(&SelectionConfig { loss: Loss::Squared, ..base })
        );
        assert_ne!(
            config_hash(&base),
            config_hash(&SelectionConfig {
                stop: StopPolicy::KBudget(3),
                ..base
            })
        );
    }

    /// f32c must fingerprint differently from f64 (so mixed-precision
    /// runs can never resume each other), while the f64 default keeps
    /// the legacy hash (the marker is written only when non-default).
    #[test]
    fn config_hash_separates_precisions_and_keeps_legacy_f64() {
        use crate::kernel::Precision;
        let base = cfg(4);
        assert_eq!(base.precision, Precision::F64);
        let mixed = SelectionConfig { precision: Precision::F32c, ..base };
        assert_ne!(config_hash(&base), config_hash(&mixed));
        assert_eq!(
            config_hash(&base),
            config_hash(&SelectionConfig {
                precision: Precision::F64,
                ..base
            })
        );
    }

    #[test]
    fn latest_in_dir_picks_max_and_ignores_leftovers() {
        let dir = std::env::temp_dir().join("greedy_rls_ckpt_latest_test");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(latest_in_dir(&dir).unwrap().is_none(), "missing dir");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(latest_in_dir(&dir).unwrap().is_none(), "empty dir");
        for rounds in [2usize, 10, 7] {
            std::fs::write(checkpoint_path(&dir, rounds), "x").unwrap();
        }
        // crash leftovers and unrelated files must be ignored
        std::fs::write(dir.join("ckpt-00000099.ckpt.tmp"), "x").unwrap();
        std::fs::write(dir.join("notes.txt"), "x").unwrap();
        let latest = latest_in_dir(&dir).unwrap().unwrap();
        assert_eq!(
            latest.file_name().unwrap().to_str().unwrap(),
            "ckpt-00000010.ckpt"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_atomic_leaves_no_tmp_behind() {
        let dir = std::env::temp_dir().join("greedy_rls_ckpt_atomic_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = checkpoint_path(&dir, 3);
        sample_checkpoint().save_atomic(&path).unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["ckpt-00000003.ckpt".to_string()]);
        assert_same(&Checkpoint::load(&path).unwrap(), &sample_checkpoint());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn autosave_then_resume_continues_bit_identically() {
        let dir = std::env::temp_dir().join("greedy_rls_ckpt_autosave_test");
        let _ = std::fs::remove_dir_all(&dir);
        let ds = dataset();
        let cfg = cfg(4);
        let fp = fingerprint(&ds.x, &ds.y, &cfg);

        let full = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();

        // drive with autosave every round, stop after 2 via a round budget
        let budget =
            SelectionConfig { stop: StopPolicy::KBudget(2), ..cfg };
        let fp_budget = fingerprint(&ds.x, &ds.y, &budget);
        let mut session = GreedyRls.begin(&ds.x, &ds.y, &budget).unwrap();
        let mut saver =
            Autosaver::new(&dir, AutosavePolicy::default(), fp_budget)
                .unwrap();
        let reason = drive_checkpointed(
            session.as_mut(),
            &mut NoopObserver,
            &mut saver,
        )
        .unwrap();
        assert_eq!(reason, StopReason::RoundBudget);
        // rounds 1 and 2, plus the on-stop re-save of round 2 that
        // records the stop reason in the final file
        assert_eq!(saver.saves, 3, "every-round policy writes each round");

        // resume the latest checkpoint under the *full* config (different
        // stop policy ⇒ different config hash ⇒ refusal)…
        let latest = latest_in_dir(&dir).unwrap().unwrap();
        assert_eq!(
            Checkpoint::load(&latest).unwrap().stop_reason,
            Some(StopReason::RoundBudget),
            "final checkpoint must record why the session stopped"
        );
        let err = resume_from_path(&GreedyRls, &ds.x, &ds.y, &cfg, &latest)
            .unwrap_err();
        assert!(format!("{err:#}").contains("config"), "{err:#}");

        // …so re-save under the full config's fingerprint and resume.
        let ckpt = Checkpoint::load(&latest).unwrap();
        let rewrapped = Checkpoint { fingerprint: fp, ..ckpt };
        rewrapped.save_atomic(&latest).unwrap();
        let (session, restored) =
            resume_from_path(&GreedyRls, &ds.x, &ds.y, &cfg, &latest)
                .unwrap();
        assert_eq!(restored.rounds.len(), 2);
        assert_eq!(session.rounds_done(), 2);
        let resumed = crate::select::run_to_completion(session).unwrap();
        assert_eq!(resumed.selected, full.selected);
        for (a, b) in resumed.rounds.iter().zip(&full.rounds) {
            assert_eq!(a.criterion.to_bits(), b.criterion.to_bits());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn session_checkpoint_records_weights_for_serving() {
        let ds = dataset();
        let cfg = cfg(3);
        let fp = fingerprint(&ds.x, &ds.y, &cfg);
        let mut session = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        while !matches!(session.step().unwrap(), StepOutcome::Done(_)) {}
        // the `session.checkpoint(fp)` method form
        let ckpt = session.checkpoint(fp).unwrap();
        let r = session.finish().unwrap();
        assert_eq!(ckpt.predictor().selected, r.selected);
        assert_eq!(ckpt.predictor().weights, r.weights);
        assert_eq!(ckpt.stop_reason, Some(StopReason::TargetReached));
        assert_eq!(ckpt.criterion_curve(), r.criterion_curve());
    }
}
