//! Feature-selection algorithms.
//!
//! The paper's three algorithmic tiers, equivalent in output, plus
//! baselines and the future-work extensions its §5 sketches:
//!
//! | module | algorithm | complexity |
//! |---|---|---|
//! | [`wrapper`] | Algorithm 1: black-box wrapper, LOO by retraining (or the eq. 7/8 shortcut) | O(min{k³m²n, k²m³n}) |
//! | [`lowrank`] | Algorithm 2: low-rank updated LS-SVM (Ojeda et al.) | O(km²n) |
//! | [`greedy`]  | **Algorithm 3: greedy RLS (the paper)** | **O(kmn)** |
//! | [`random`]  | random-k baseline (§4.2 sanity check) | O(min{k²m, km²}) |
//! | [`backward`] | backward elimination (§5) | O((n−k)mn) after O(m n²) init |
//! | [`floating`] | forward selection with floating backward steps (§5) | ≥ greedy |
//! | [`foba`] | adaptive forward–backward greedy (§5, ref \[31\]) | ≥ greedy |
//! | [`nfold`] | greedy forward with n-fold-CV criterion (§5) | O(kmn) |
//! | [`centers`] | reduced-set / RBF-center selection for kernel RLS (§5) | O(km²) |
//! | [`rankrls`] | greedy forward selection for RankRLS (§5, refs \[32, 33\]) | O(kn(k² + km)) |
//! | [`sketch`] | sketched preselection: leverage-score filter → exact greedy (Paul & Drineas) | O(dmn) once + O(kmp) |
//!
//! All selectors consume the same feature-major `X` (n × m) and return a
//! [`SelectionResult`]; equivalence across Algorithms 1–3 is enforced by
//! `rust/tests/equivalence.rs` property tests.
//!
//! Every selector also implements [`SessionSelector`] — the stepwise
//! [`session`] API with early stopping ([`StopPolicy`]), warm starts, and
//! per-round observation; [`Selector::select`] is its one-shot shim.
//! The selectors whose inner loop is the masked O(mn) scan — greedy,
//! wrapper (same trajectory), backward, FoBa, floating, and n-fold —
//! also run on the PJRT artifact engines in [`crate::runtime::engine`],
//! equivalence-tested against the native engines here.
//! Sessions persist across process boundaries via [`checkpoint`]: durable,
//! fingerprinted trajectory snapshots with bit-identical kill/resume
//! (atomic write-rename, autosave policies, checksum-guarded format).

pub mod backward;
pub mod centers;
pub mod checkpoint;
pub mod floating;
pub mod foba;
pub mod greedy;
pub mod lowrank;
pub mod nfold;
pub mod random;
pub mod rankrls;
pub mod session;
pub mod sketch;
pub mod wrapper;

pub use checkpoint::{
    drive_checkpointed, resume_from_path, AutosavePolicy, Autosaver,
    Checkpoint, Fingerprint, PolicyTicker,
};
pub use session::{
    drive, drive_tapped, run_to_completion, NoopObserver, Observer,
    Observers, Session, SessionSelector, SessionState, StateObserver,
    StepOutcome, StopPolicy, StopReason,
};

pub use sketch::{PreselectConfig, SketchedGreedy};

pub use crate::kernel::{KernelKind, Precision};

use crate::linalg::Matrix;
use crate::metrics::Loss;
use crate::rls::Predictor;

/// Sentinel score for unavailable candidates (mirrors the kernels' BIG).
pub const BIG: f64 = 1e30;

/// Configuration shared by every selector.
///
/// Construct with [`SelectionConfig::builder`]; derive a variant of an
/// existing config with [`SelectionConfig::with`]. Struct literals are
/// reserved for this module (enforced by `xtask analyze`) so new fields
/// can ship with validated defaults.
#[derive(Clone, Copy, Debug)]
pub struct SelectionConfig {
    /// Number of features to select (the session's natural target).
    pub k: usize,
    /// Regularization parameter λ > 0.
    pub lambda: f64,
    /// LOO loss used as the selection criterion.
    pub loss: Loss,
    /// Early-stopping policy for session-driven runs. The default
    /// (`StopPolicy::KBudget(usize::MAX)`) never fires, so the run goes
    /// to `k` — the pre-session behavior.
    pub stop: StopPolicy,
    /// Worker threads for the O(mn) per-round scans and cache updates
    /// (`0` = available parallelism, the default; `1` = fully serial).
    ///
    /// **Determinism guarantee:** selected sets, criterion curves, and
    /// weights are bit-identical at every thread count — work is sharded
    /// only at boundaries where the serial arithmetic is already
    /// independent (see [`crate::parallel`]), and all reductions run on
    /// the calling thread in serial order. Enforced by the equivalence
    /// test suite. The PJRT engine ignores this field (its parallelism
    /// lives in the compiled kernels).
    pub threads: usize,
    /// Column-tile width for the greedy engine's LLC-tiled scan/commit
    /// kernels: `0` (the default) means untiled on the RAM backend and
    /// auto-sized on the out-of-core backend; any explicit value is
    /// rounded down to a multiple of 8. **Tiling never changes results**
    /// — every tile width yields bit-identical selections (the tiled
    /// kernels carry their accumulators across tiles, performing the
    /// serial operation sequence exactly), so this field is excluded
    /// from checkpoint config fingerprints and checkpoints written at
    /// one tile width resume under another.
    pub tile_cols: usize,
    /// Numeric representation of the candidate cache
    /// ([`Precision::F64`], the default, or [`Precision::F32c`]).
    ///
    /// `F32c` halves the bytes the bandwidth-bound scan streams per
    /// round by storing Cᵀ in f32 while accumulating in compensated
    /// f64. It is deterministic per run (bit-identical across threads
    /// and tile widths) but follows a *different* trajectory from
    /// `F64`, so — unlike `threads`/`tile_cols` — it participates in
    /// checkpoint config fingerprints: runs at different precisions can
    /// never silently resume each other. Supported by the greedy
    /// selector on the in-RAM backend only; every other selector, the
    /// stored backend, and the PJRT engine reject it at `begin`.
    pub precision: Precision,
    /// Optional sketched preselection filter (see [`sketch`]): before
    /// round one, approximate ridge leverage scores rank all `n`
    /// candidates and only the top `p` survive, turning the per-round
    /// O(mn) scan into O(mp). `None` (the default) scans every
    /// candidate — the pre-sketch behavior.
    ///
    /// Supported by the greedy engine on both backends (the survivors
    /// become the engine's initial candidate mask, so checkpoints, warm
    /// starts, observers, threads, and precision work unchanged); every
    /// other selector and the PJRT engine reject it at `begin`. A
    /// filter that keeps everything (`p >= n`) is the identity — it
    /// consumes no RNG and reproduces the exact greedy trajectory
    /// bitwise, checkpoint bytes included. Participates in checkpoint
    /// config fingerprints via a trailing marker (legacy hashes are
    /// preserved when `None` or when `p >= n` normalizes the filter
    /// away).
    pub preselect: Option<PreselectConfig>,
}

impl Default for SelectionConfig {
    fn default() -> Self {
        SelectionConfig {
            k: 10,
            lambda: 1.0,
            loss: Loss::ZeroOne,
            stop: StopPolicy::default(),
            threads: 0,
            tile_cols: 0,
            precision: Precision::F64,
            preselect: None,
        }
    }
}

impl SelectionConfig {
    /// Fluent builder starting from [`SelectionConfig::default`].
    pub fn builder() -> SelectionConfigBuilder {
        SelectionConfigBuilder { cfg: SelectionConfig::default() }
    }

    /// Re-open this config as a builder to derive a variant:
    /// `base.with().lambda(0.5).build()`.
    pub fn with(self) -> SelectionConfigBuilder {
        SelectionConfigBuilder { cfg: self }
    }
}

/// Builder for [`SelectionConfig`]:
/// `SelectionConfig::builder().k(25).lambda(1.0).loss(Loss::Squared).build()`.
#[derive(Clone, Debug)]
pub struct SelectionConfigBuilder {
    cfg: SelectionConfig,
}

impl SelectionConfigBuilder {
    /// Number of features to select.
    pub fn k(mut self, k: usize) -> Self {
        self.cfg.k = k;
        self
    }

    /// Regularization parameter λ > 0.
    pub fn lambda(mut self, lambda: f64) -> Self {
        self.cfg.lambda = lambda;
        self
    }

    /// LOO loss used as the selection criterion.
    pub fn loss(mut self, loss: Loss) -> Self {
        self.cfg.loss = loss;
        self
    }

    /// Early-stopping policy.
    pub fn stop(mut self, stop: StopPolicy) -> Self {
        self.cfg.stop = stop;
        self
    }

    /// Shorthand for [`StopPolicy::Plateau`].
    pub fn plateau(self, patience: usize, min_rel_improvement: f64) -> Self {
        self.stop(StopPolicy::Plateau { patience, min_rel_improvement })
    }

    /// Shorthand for [`StopPolicy::TimeBudget`].
    pub fn time_budget(self, budget: std::time::Duration) -> Self {
        self.stop(StopPolicy::TimeBudget(budget))
    }

    /// Worker threads for the per-round scans (`0` = available
    /// parallelism, `1` = serial). Any value yields bit-identical
    /// selections — see [`SelectionConfig::threads`].
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Column-tile width for the LLC-tiled kernels (`0` = auto; any
    /// width yields bit-identical selections — see
    /// [`SelectionConfig::tile_cols`]).
    pub fn tile_cols(mut self, tile_cols: usize) -> Self {
        self.cfg.tile_cols = tile_cols;
        self
    }

    /// Numeric representation of the candidate cache — see
    /// [`SelectionConfig::precision`] for the determinism and support
    /// matrix.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.cfg.precision = precision;
        self
    }

    /// Sketched preselection filter (`None` disables) — see
    /// [`SelectionConfig::preselect`] for the support matrix and
    /// fingerprint semantics.
    pub fn preselect(mut self, preselect: Option<PreselectConfig>) -> Self {
        self.cfg.preselect = preselect;
        self
    }

    /// Finalize the configuration.
    pub fn build(self) -> SelectionConfig {
        self.cfg
    }
}

/// One selection round's record (figures 4–15 are drawn from these).
#[derive(Clone, Debug)]
pub struct Round {
    /// Chosen feature index.
    pub feature: usize,
    /// LOO criterion value of the chosen feature (summed loss).
    pub criterion: f64,
}

/// Output of a selection run.
#[derive(Clone, Debug)]
pub struct SelectionResult {
    /// Selected feature indices in selection order.
    pub selected: Vec<usize>,
    /// Per-round logs (criterion trajectory).
    pub rounds: Vec<Round>,
    /// Final RLS weights over `selected` (same order).
    pub weights: Vec<f64>,
}

impl SelectionResult {
    /// Package as a sparse [`Predictor`].
    pub fn predictor(&self) -> Predictor {
        Predictor {
            selected: self.selected.clone(),
            weights: self.weights.clone(),
        }
    }

    /// LOO criterion trajectory (one value per round).
    pub fn criterion_curve(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.criterion).collect()
    }
}

/// Common one-shot interface so the coordinator / benches can swap
/// algorithms. Every implementation in this crate is a thin shim over its
/// [`SessionSelector`] (`begin` + [`run_to_completion`]) — use the session
/// API directly for early stopping, warm starts, or progress observation.
pub trait Selector {
    /// Human-readable name for tables and logs.
    fn name(&self) -> &'static str;

    /// Select `cfg.k` features from feature-major `x` (n × m) with labels
    /// `y` (length m).
    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult>;
}

/// Shared parallel candidate scan: score every candidate `i in 0..n` with
/// `active(i)` true, on up to `threads` workers (`0` = auto); inactive
/// candidates keep [`BIG`]. Candidates are scored independently — no
/// cross-candidate state — so the assembled vector is bit-identical to
/// the serial loop at any thread count. This is the one scan body behind
/// the per-round O(mn) (or heavier) loops of the wrapper, FoBa, floating,
/// n-fold, backward, and RankRLS selectors.
pub(crate) fn scan_candidates<A, S>(
    n: usize,
    threads: usize,
    active: A,
    score: S,
) -> Vec<f64>
where
    A: Fn(usize) -> bool,
    S: Fn(usize) -> f64 + Sync,
{
    let idx: Vec<usize> = (0..n).filter(|&i| active(i)).collect();
    scan_ops::add(idx.len() as u64);
    let mut scores = vec![BIG; n];
    let t = crate::parallel::resolve(threads).min(idx.len());
    if t <= 1 {
        for &i in &idx {
            scores[i] = score(i);
        }
    } else {
        let ranges = crate::parallel::split_ranges(idx.len(), t);
        let idx_ref = &idx;
        let chunks = crate::parallel::map_ranges(&ranges, |r| {
            idx_ref[r].iter().map(|&i| score(i)).collect::<Vec<f64>>()
        });
        for (r, vals) in ranges.iter().zip(chunks) {
            for (&i, v) in idx[r.clone()].iter().zip(vals) {
                scores[i] = v;
            }
        }
    }
    scores
}

/// Guard for selectors whose engines run f64-only: every selector other
/// than in-RAM greedy RLS rejects `--precision f32c` at `begin` with a
/// uniform error, instead of silently computing in full precision under
/// a config that claims otherwise.
pub(crate) fn require_f64(
    cfg: &SelectionConfig,
    selector: &str,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        cfg.precision == Precision::F64,
        "--precision {} is not supported by the {selector} selector \
         (mixed precision runs on the in-RAM greedy-rls engine only)",
        cfg.precision,
    );
    Ok(())
}

/// Guard for engines that scan every candidate: every selector other
/// than the (sketched) greedy engine rejects `--preselect` at `begin`
/// with a uniform error, instead of silently ignoring the filter.
pub(crate) fn require_no_preselect(
    cfg: &SelectionConfig,
    selector: &str,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        cfg.preselect.is_none(),
        "--preselect is not supported by the {selector} selector \
         (sketched preselection runs on the greedy-rls engine only)",
    );
    Ok(())
}

/// Per-thread tally of candidate-scoring operations — the scan-work
/// column of the `compare` frontier table.
///
/// One "op" is one candidate scored: every per-round scan
/// ([`scan_candidates`], the greedy engines' `score_all`/`score_of`,
/// FoBa's deletion pass) adds its candidate count **on the calling
/// thread before dispatching workers**, so the counter is exact
/// whenever selection is driven from one thread (as `compare` does)
/// regardless of how many workers the scan itself fans out to.
pub mod scan_ops {
    use std::cell::Cell;

    thread_local! {
        static OPS: Cell<u64> = const { Cell::new(0) };
    }

    /// Zero this thread's tally (call before a measured run).
    pub fn reset() {
        OPS.with(|c| c.set(0));
    }

    /// This thread's tally since the last [`reset`].
    pub fn total() -> u64 {
        OPS.with(|c| c.get())
    }

    /// Record `n` candidate-scoring operations.
    pub(crate) fn add(n: u64) {
        OPS.with(|c| c.set(c.get() + n));
    }
}

/// Strict-argmin over candidate scores; ties break to the lowest index
/// (every implementation in the repo and the Python reference must agree
/// on this rule for the equivalence tests to be exact).
pub fn argmin(scores: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &s) in scores.iter().enumerate() {
        if s >= BIG || s.is_nan() {
            continue;
        }
        match best {
            Some((_, bs)) if s >= bs => {}
            _ => best = Some((i, s)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmin_basic() {
        assert_eq!(argmin(&[3.0, 1.0, 2.0]), Some(1));
    }

    #[test]
    fn argmin_tie_breaks_low_index() {
        assert_eq!(argmin(&[2.0, 1.0, 1.0]), Some(1));
    }

    #[test]
    fn argmin_skips_big_and_nan() {
        assert_eq!(argmin(&[BIG, f64::NAN, 5.0]), Some(2));
        assert_eq!(argmin(&[BIG, BIG]), None);
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn builder_sets_fields() {
        let cfg = SelectionConfig::builder()
            .k(25)
            .lambda(0.5)
            .loss(Loss::Squared)
            .threads(4)
            .tile_cols(64)
            .plateau(3, 1e-2)
            .build();
        assert_eq!(cfg.k, 25);
        assert_eq!(cfg.lambda, 0.5);
        assert_eq!(cfg.loss, Loss::Squared);
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.tile_cols, 64);
        assert_eq!(SelectionConfig::default().threads, 0);
        assert_eq!(SelectionConfig::default().tile_cols, 0);
        assert_eq!(
            cfg.stop,
            StopPolicy::Plateau { patience: 3, min_rel_improvement: 1e-2 }
        );
        let d = SelectionConfig::default();
        assert_eq!(d.stop, StopPolicy::KBudget(usize::MAX));
        let t = SelectionConfig::builder()
            .time_budget(std::time::Duration::from_secs(5))
            .build();
        assert_eq!(
            t.stop,
            StopPolicy::TimeBudget(std::time::Duration::from_secs(5))
        );
    }

    #[test]
    fn builder_sets_precision_and_guard_rejects_f32c() {
        assert_eq!(SelectionConfig::default().precision, Precision::F64);
        let cfg = SelectionConfig::builder()
            .precision(Precision::F32c)
            .build();
        assert_eq!(cfg.precision, Precision::F32c);
        assert!(require_f64(&SelectionConfig::default(), "x").is_ok());
        let err = require_f64(&cfg, "backward-elimination").unwrap_err();
        assert!(err.to_string().contains("backward-elimination"), "{err}");
        assert!(err.to_string().contains("f32c"), "{err}");
    }

    #[test]
    fn scan_candidates_matches_serial_at_any_thread_count() {
        let n = 23;
        let active = |i: usize| i % 3 != 0;
        let score = |i: usize| (i as f64).sqrt() + 1.0;
        let serial = scan_candidates(n, 1, active, score);
        for t in [0, 2, 4, 7] {
            let par = scan_candidates(n, t, active, score);
            assert_eq!(serial.len(), par.len());
            for (i, (a, b)) in serial.iter().zip(&par).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "i={i} threads={t}");
            }
        }
        for i in 0..n {
            if i % 3 == 0 {
                assert_eq!(serial[i], BIG);
            }
        }
    }

    #[test]
    fn result_predictor_roundtrip() {
        let r = SelectionResult {
            selected: vec![4, 2],
            rounds: vec![
                Round { feature: 4, criterion: 10.0 },
                Round { feature: 2, criterion: 6.0 },
            ],
            weights: vec![1.0, -1.0],
        };
        let p = r.predictor();
        assert_eq!(p.selected, vec![4, 2]);
        assert_eq!(r.criterion_curve(), vec![10.0, 6.0]);
    }
}
