//! Feature-selection algorithms.
//!
//! The paper's three algorithmic tiers, equivalent in output, plus
//! baselines and the future-work extensions its §5 sketches:
//!
//! | module | algorithm | complexity |
//! |---|---|---|
//! | [`wrapper`] | Algorithm 1: black-box wrapper, LOO by retraining (or the eq. 7/8 shortcut) | O(min{k³m²n, k²m³n}) |
//! | [`lowrank`] | Algorithm 2: low-rank updated LS-SVM (Ojeda et al.) | O(km²n) |
//! | [`greedy`]  | **Algorithm 3: greedy RLS (the paper)** | **O(kmn)** |
//! | [`random`]  | random-k baseline (§4.2 sanity check) | O(min{k²m, km²}) |
//! | [`backward`] | backward elimination (§5) | O((n−k)mn) after O(m n²) init |
//! | [`floating`] | forward selection with floating backward steps (§5) | ≥ greedy |
//! | [`foba`] | adaptive forward–backward greedy (§5, ref \[31\]) | ≥ greedy |
//! | [`nfold`] | greedy forward with n-fold-CV criterion (§5) | O(kmn) |
//! | [`centers`] | reduced-set / RBF-center selection for kernel RLS (§5) | O(km²) |
//! | [`rankrls`] | greedy forward selection for RankRLS (§5, refs \[32, 33\]) | O(kn(k² + km)) |
//!
//! All selectors consume the same feature-major `X` (n × m) and return a
//! [`SelectionResult`]; equivalence across Algorithms 1–3 is enforced by
//! `rust/tests/equivalence.rs` property tests.

pub mod backward;
pub mod centers;
pub mod floating;
pub mod foba;
pub mod greedy;
pub mod lowrank;
pub mod nfold;
pub mod random;
pub mod rankrls;
pub mod wrapper;

use crate::linalg::Matrix;
use crate::metrics::Loss;
use crate::rls::Predictor;

/// Sentinel score for unavailable candidates (mirrors the kernels' BIG).
pub const BIG: f64 = 1e30;

/// Configuration shared by every selector.
#[derive(Clone, Copy, Debug)]
pub struct SelectionConfig {
    /// Number of features to select.
    pub k: usize,
    /// Regularization parameter λ > 0.
    pub lambda: f64,
    /// LOO loss used as the selection criterion.
    pub loss: Loss,
}

impl Default for SelectionConfig {
    fn default() -> Self {
        SelectionConfig { k: 10, lambda: 1.0, loss: Loss::ZeroOne }
    }
}

/// One selection round's record (figures 4–15 are drawn from these).
#[derive(Clone, Debug)]
pub struct Round {
    /// Chosen feature index.
    pub feature: usize,
    /// LOO criterion value of the chosen feature (summed loss).
    pub criterion: f64,
}

/// Output of a selection run.
#[derive(Clone, Debug)]
pub struct SelectionResult {
    /// Selected feature indices in selection order.
    pub selected: Vec<usize>,
    /// Per-round logs (criterion trajectory).
    pub rounds: Vec<Round>,
    /// Final RLS weights over `selected` (same order).
    pub weights: Vec<f64>,
}

impl SelectionResult {
    /// Package as a sparse [`Predictor`].
    pub fn predictor(&self) -> Predictor {
        Predictor {
            selected: self.selected.clone(),
            weights: self.weights.clone(),
        }
    }

    /// LOO criterion trajectory (one value per round).
    pub fn criterion_curve(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.criterion).collect()
    }
}

/// Common interface so the coordinator / benches can swap algorithms.
pub trait Selector {
    /// Human-readable name for tables and logs.
    fn name(&self) -> &'static str;

    /// Select `cfg.k` features from feature-major `x` (n × m) with labels
    /// `y` (length m).
    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult>;
}

/// Strict-argmin over candidate scores; ties break to the lowest index
/// (every implementation in the repo and the Python reference must agree
/// on this rule for the equivalence tests to be exact).
pub fn argmin(scores: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &s) in scores.iter().enumerate() {
        if s >= BIG || s.is_nan() {
            continue;
        }
        match best {
            Some((_, bs)) if s >= bs => {}
            _ => best = Some((i, s)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmin_basic() {
        assert_eq!(argmin(&[3.0, 1.0, 2.0]), Some(1));
    }

    #[test]
    fn argmin_tie_breaks_low_index() {
        assert_eq!(argmin(&[2.0, 1.0, 1.0]), Some(1));
    }

    #[test]
    fn argmin_skips_big_and_nan() {
        assert_eq!(argmin(&[BIG, f64::NAN, 5.0]), Some(2));
        assert_eq!(argmin(&[BIG, BIG]), None);
        assert_eq!(argmin(&[]), None);
    }

    #[test]
    fn result_predictor_roundtrip() {
        let r = SelectionResult {
            selected: vec![4, 2],
            rounds: vec![
                Round { feature: 4, criterion: 10.0 },
                Round { feature: 2, criterion: 6.0 },
            ],
            weights: vec![1.0, -1.0],
        };
        let p = r.predictor();
        assert_eq!(p.selected, vec![4, 2]);
        assert_eq!(r.criterion_curve(), vec![10.0, 6.0]);
    }
}
