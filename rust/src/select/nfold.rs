//! Greedy forward selection with an **n-fold CV criterion** (paper §5).
//!
//! "Greedy RLS can quite straightforwardly be generalized to use different
//! types of cross-validation criteria, such as n-fold" — using the
//! hold-out shortcut of Pahikkala et al. (2006) / An et al. (2007): with
//! `G = (K + λI)⁻¹`, `a = G y`, the predictions for a held-out index block
//! `H` are
//!
//! ```text
//! p_H = y_H − (G_HH)⁻¹ a_H
//! ```
//!
//! (eq. 8 is the |H| = 1 special case). The greedy cache machinery extends
//! by additionally maintaining the fold-diagonal blocks `B_h = G[H_h, H_h]`
//! which under the SMW rank-1 update transform exactly like `d`:
//! `B̃_h = B_h − u_H (C[H,i])ᵀ`. Per-candidate cost is
//! O(m + Σ_h |H_h|³) — linear in m for fixed fold sizes, matching the
//! paper's claim that the generalization preserves efficiency.

use anyhow::ensure;

use super::session::{
    CoreStep, PolicySession, Session, SessionCore, SessionSelector,
};
use super::{argmin, Round, SelectionConfig, SelectionResult, Selector, BIG};
use crate::kernel::{self, KernelKind};
use crate::linalg::{dot, Cholesky, Matrix};
use crate::metrics::Loss;
use crate::rng::Pcg64;

/// Greedy forward selection scored by n-fold cross-validation.
#[derive(Clone, Copy, Debug)]
pub struct NFoldGreedy {
    /// Number of folds.
    pub folds: usize,
    /// Fold assignment seed.
    pub seed: u64,
}

impl Default for NFoldGreedy {
    fn default() -> Self {
        NFoldGreedy { folds: 10, seed: 7 }
    }
}

impl NFoldGreedy {
    /// The fold partition this selector scores against, for `m`
    /// examples. One code path shared by the native engine and the PJRT
    /// artifact engine ([`crate::runtime::engine::PjrtNFold`]) so both
    /// score identical partitions.
    pub fn fold_assignment(&self, m: usize) -> Vec<Vec<usize>> {
        let mut rng = Pcg64::new(self.seed, 47);
        let f = crate::data::folds::Folds::new(m, self.folds, &mut rng);
        (0..f.k()).map(|h| f.test_indices(h).to_vec()).collect()
    }
}

struct NFoldState {
    m: usize,
    n: usize,
    ct: Vec<f64>,
    a: Vec<f64>,
    /// fold → member indices
    folds: Vec<Vec<usize>>,
    /// fold → row-major |H|×|H| block of G
    blocks: Vec<Vec<f64>>,
    cand_mask: Vec<f64>,
    selected: Vec<usize>,
    /// Resolved worker-thread count for the per-round scans/downdates.
    threads: usize,
    /// Compute-kernel dispatch, fixed at construction
    /// ([`KernelKind::active`]).
    kernel: KernelKind,
}

impl NFoldState {
    fn init(x: &Matrix, y: &[f64], lambda: f64, folds: Vec<Vec<usize>>) -> Self {
        let n = x.rows();
        let m = x.cols();
        let inv = 1.0 / lambda;
        let mut ct = vec![0.0; n * m];
        for i in 0..n {
            for (dst, &src) in
                ct[i * m..(i + 1) * m].iter_mut().zip(x.row(i))
            {
                *dst = src * inv;
            }
        }
        // G = λ⁻¹ I ⇒ every fold block starts as λ⁻¹ I
        let blocks = folds
            .iter()
            .map(|h| {
                let s = h.len();
                let mut b = vec![0.0; s * s];
                for t in 0..s {
                    b[t * s + t] = inv;
                }
                b
            })
            .collect();
        NFoldState {
            m,
            n,
            ct,
            a: y.iter().map(|&v| v * inv).collect(),
            folds,
            blocks,
            cand_mask: vec![1.0; n],
            selected: Vec::new(),
            threads: 1,
            kernel: KernelKind::active(),
        }
    }

    /// CV criterion of S ∪ {i} for one candidate ([`BIG`] when a fold
    /// block fails to factor). Candidates are independent, so forced
    /// session rounds score only their own candidate through this same
    /// code path.
    fn score_one(&self, x: &Matrix, y: &[f64], loss: Loss, i: usize) -> f64 {
        let m = self.m;
        let v = x.row(i);
        let c = &self.ct[i * m..(i + 1) * m];
        let denom = 1.0 + kernel::dot(self.kernel, v, c);
        let va = kernel::dot(self.kernel, v, &self.a);
        let mut e = 0.0;
        for (h, block) in self.folds.iter().zip(&self.blocks) {
            let s = h.len();
            // B̃ = B − u_H c_Hᵀ,  ã_H = a_H − u_H·va
            let mut bt = vec![0.0; s * s];
            let mut at = vec![0.0; s];
            kernel::fold_tilde(
                c, &self.a, h, block, denom, va, &mut at, &mut bt,
            );
            // p_H = y_H − B̃⁻¹ ã_H
            let bmat = Matrix::from_vec(s, s, bt);
            let Some(ch) = Cholesky::factor(&bmat) else {
                return BIG;
            };
            let sol = ch.solve(&at);
            for (r, &jr) in h.iter().enumerate() {
                let p = y[jr] - sol[r];
                e += loss.eval(y[jr], p);
            }
        }
        e
    }

    /// CV criterion of S ∪ {i} for every candidate — one independent
    /// [`NFoldState::score_one`] per candidate, run on the shared
    /// deterministic parallel scan.
    fn score_all(&self, x: &Matrix, y: &[f64], loss: Loss) -> Vec<f64> {
        super::scan_candidates(
            self.n,
            self.threads,
            |i| self.cand_mask[i] != 0.0,
            |i| self.score_one(x, y, loss, i),
        )
    }

    fn commit(&mut self, x: &Matrix, b: usize) {
        let m = self.m;
        let v = x.row(b);
        let cb = self.ct[b * m..(b + 1) * m].to_vec();
        let denom = 1.0 + kernel::dot(self.kernel, v, &cb);
        let u: Vec<f64> = cb.iter().map(|&c| c / denom).collect();
        let va = kernel::dot(self.kernel, v, &self.a);
        kernel::update_a(&mut self.a, &u, va, -1.0);
        for (h, block) in self.folds.iter().zip(self.blocks.iter_mut()) {
            kernel::fold_block_downdate(block, h, &u, &cb);
        }
        // the O(mn) cache downdate: rows are independent, shard them
        crate::parallel::rank1_row_update(
            self.kernel,
            self.threads,
            &mut self.ct,
            m,
            v,
            &u,
            -1.0,
        );
        self.cand_mask[b] = 0.0;
        self.selected.push(b);
    }
}

/// Round-by-round engine: [`NFoldState`] plus the round log.
struct NFoldCore<'a> {
    x: &'a Matrix,
    y: &'a [f64],
    loss: Loss,
    k: usize,
    st: NFoldState,
    rounds: Vec<Round>,
}

impl SessionCore for NFoldCore<'_> {
    fn target_reached(&self) -> bool {
        self.st.selected.len() >= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let (b, criterion) = match forced {
            Some(b) => {
                ensure!(
                    b < self.st.n,
                    "feature {b} out of range (n={})",
                    self.st.n
                );
                ensure!(
                    self.st.cand_mask[b] != 0.0,
                    "feature {b} already selected"
                );
                let s = self.st.score_one(self.x, self.y, self.loss, b);
                ensure!(s < BIG, "feature {b} is not evaluable this round");
                (b, s)
            }
            None => {
                let scores = self.st.score_all(self.x, self.y, self.loss);
                let b = argmin(&scores)
                    .ok_or_else(|| anyhow::anyhow!("no candidate left"))?;
                (b, scores[b])
            }
        };
        let round = Round { feature: b, criterion };
        self.st.commit(self.x, b);
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.st.selected.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        Ok(self
            .st
            .selected
            .iter()
            .map(|&i| dot(self.x.row(i), &self.st.a))
            .collect())
    }
}

impl SessionSelector for NFoldGreedy {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let n = x.rows();
        let m = x.cols();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(self.folds >= 2 && self.folds <= m, "bad fold count");
        ensure!(m == y.len(), "shape mismatch");
        super::require_f64(cfg, "nfold-greedy")?;
        super::require_no_preselect(cfg, "nfold-greedy")?;

        let fold_vec = self.fold_assignment(m);
        let mut st = NFoldState::init(x, y, cfg.lambda, fold_vec);
        st.threads = crate::parallel::resolve(cfg.threads);
        let core = NFoldCore {
            x,
            y,
            loss: cfg.loss,
            k: cfg.k,
            st,
            rounds: Vec::new(),
        };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for NFoldGreedy {
    fn name(&self) -> &'static str {
        "nfold-greedy"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        super::run_to_completion(self.begin(x, y, cfg)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{forall_seeds, Gen};
    use crate::rls;

    /// With m folds (each of size 1) the criterion degenerates to LOO and
    /// the selector must match greedy RLS exactly.
    #[test]
    fn m_folds_reduces_to_loo() {
        forall_seeds(10, |seed| {
            let mut g = Gen::new(seed + 900);
            let n = g.size(3, 8);
            let m = g.size(4, 9);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.targets(m);
            let cfg = SelectionConfig {
                k: 2.min(n),
                lambda: lam,
                loss: Loss::Squared,
                ..Default::default()
            };
            let nf = NFoldGreedy { folds: m, seed: 1 };
            let r_nf = nf.select(&x, &y, &cfg).unwrap();
            let r_g =
                crate::select::greedy::GreedyRls.select(&x, &y, &cfg).unwrap();
            assert_eq!(r_nf.selected, r_g.selected);
        });
    }

    /// Fold-block predictions must equal explicit hold-out retraining.
    #[test]
    fn fold_scores_equal_explicit_holdout() {
        let mut g = Gen::new(4242);
        let n = 5;
        let m = 12;
        let lam = 1.3;
        let x = g.matrix(n, m);
        let y = g.targets(m);
        let nf = NFoldGreedy { folds: 3, seed: 5 };
        // reconstruct the same folds
        let mut rng = Pcg64::new(nf.seed, 47);
        let f = crate::data::folds::Folds::new(m, nf.folds, &mut rng);
        let folds: Vec<Vec<usize>> =
            (0..f.k()).map(|h| f.test_indices(h).to_vec()).collect();
        let st = NFoldState::init(&x, &y, lam, folds.clone());
        let scores = st.score_all(&x, &y, Loss::Squared);
        // explicit: for each candidate i, for each fold, retrain on the
        // complement and predict the fold
        for i in 0..n {
            let mut want = 0.0;
            for h in &folds {
                let train: Vec<usize> =
                    (0..m).filter(|j| !h.contains(j)).collect();
                let xs = x.select_rows(&[i]).select_cols(&train);
                let yl: Vec<f64> = train.iter().map(|&j| y[j]).collect();
                let w = rls::train(&xs, &yl, lam);
                for &j in h {
                    let p = w[0] * x[(i, j)];
                    want += (y[j] - p) * (y[j] - p);
                }
            }
            assert!(
                (scores[i] - want).abs() <= 1e-6 * want.max(1.0),
                "cand {i}: {} vs {want}",
                scores[i]
            );
        }
    }

    #[test]
    fn selects_k_distinct() {
        let ds = crate::data::synthetic::two_gaussians(60, 12, 4, 1.2, 6);
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let r = NFoldGreedy::default().select(&ds.x, &ds.y, &cfg).unwrap();
        let mut s = r.selected.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn rejects_bad_folds() {
        let mut g = Gen::new(1);
        let x = g.matrix(4, 6);
        let y = g.labels(6);
        let cfg = SelectionConfig { k: 2, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        assert!(NFoldGreedy { folds: 1, seed: 0 }
            .select(&x, &y, &cfg)
            .is_err());
        assert!(NFoldGreedy { folds: 7, seed: 0 }
            .select(&x, &y, &cfg)
            .is_err());
    }
}
