//! **Standard wrapper** — the paper's Algorithm 1.
//!
//! RLS is treated as a black box: for every candidate feature and every
//! LOO fold the model is retrained from scratch —
//! O(min{k³m²n, k²m³n}) total. A second mode replaces the literal
//! retraining with the eq. 7/8 LOO shortcut (the "immediate reduction"
//! the paper describes in §3.1), which drops the complexity to
//! O(min{k³mn, k²m²n}) while provably selecting the same features.
//!
//! Both modes exist because the ablation bench (`ablation_loo_shortcut`)
//! reproduces the paper's complexity narrative: wrapper ≪ wrapper+shortcut
//! ≪ low-rank ≪ greedy, with the crossovers the paper discusses.

use anyhow::ensure;

use super::{argmin, Round, SelectionConfig, SelectionResult, Selector, BIG};
use crate::linalg::Matrix;
use crate::rls;

/// How the wrapper evaluates LOO for a candidate feature set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LooMode {
    /// Retrain per held-out example (Algorithm 1 verbatim).
    BruteForce,
    /// Closed-form LOO via eq. (7)/(8) — same result, one training per
    /// candidate set.
    Shortcut,
}

/// Algorithm 1 as a [`Selector`].
#[derive(Clone, Copy, Debug)]
pub struct Wrapper {
    /// LOO evaluation mode.
    pub mode: LooMode,
}

impl Default for Wrapper {
    fn default() -> Self {
        Wrapper { mode: LooMode::Shortcut }
    }
}

impl Wrapper {
    /// LOO predictions for the feature set `s` (rows of `x`).
    fn loo(&self, x: &Matrix, s: &[usize], y: &[f64], lambda: f64) -> Vec<f64> {
        let xs = x.select_rows(s);
        match self.mode {
            LooMode::BruteForce => rls::loo_brute_force(&xs, y, lambda),
            LooMode::Shortcut => {
                // primal when |S| ≤ m, dual otherwise — mirrors training
                if xs.rows() <= xs.cols() {
                    rls::loo_primal(&xs, y, lambda)
                } else {
                    rls::loo_dual(&xs, y, lambda)
                }
            }
        }
    }
}

impl Selector for Wrapper {
    fn name(&self) -> &'static str {
        match self.mode {
            LooMode::BruteForce => "wrapper-bruteforce",
            LooMode::Shortcut => "wrapper-shortcut",
        }
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        let n = x.rows();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        let mut selected: Vec<usize> = Vec::new();
        let mut in_s = vec![false; n];
        let mut rounds = Vec::with_capacity(cfg.k);
        while selected.len() < cfg.k {
            let mut scores = vec![BIG; n];
            for i in 0..n {
                if in_s[i] {
                    continue;
                }
                let mut s = selected.clone();
                s.push(i);
                let p = self.loo(x, &s, y, cfg.lambda);
                scores[i] = cfg.loss.total(y, &p);
            }
            let b = argmin(&scores)
                .ok_or_else(|| anyhow::anyhow!("no candidate left"))?;
            rounds.push(Round { feature: b, criterion: scores[b] });
            in_s[b] = true;
            selected.push(b);
        }
        // line 21: final training on the chosen set
        let xs = x.select_rows(&selected);
        let weights = rls::train(&xs, y, cfg.lambda);
        Ok(SelectionResult { selected, rounds, weights })
    }
}

/// Convenience constructors.
impl Wrapper {
    pub fn brute_force() -> Self {
        Wrapper { mode: LooMode::BruteForce }
    }
    pub fn shortcut() -> Self {
        Wrapper { mode: LooMode::Shortcut }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;
    use crate::proptest::{assert_close, forall_seeds, Gen};
    use crate::select::greedy::GreedyRls;

    /// Central claim: the wrapper (both modes) selects exactly the same
    /// features as greedy RLS.
    #[test]
    fn equivalent_to_greedy_rls() {
        forall_seeds(12, |seed| {
            let mut g = Gen::new(seed + 300);
            let n = g.size(3, 8);
            let m = g.size(4, 9);
            let k = 2.min(n);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.labels(m);
            let cfg =
                SelectionConfig { k, lambda: lam, loss: Loss::Squared };
            let r3 = GreedyRls.select(&x, &y, &cfg).unwrap();
            for wrapper in [Wrapper::brute_force(), Wrapper::shortcut()] {
                let r1 = wrapper.select(&x, &y, &cfg).unwrap();
                assert_eq!(r1.selected, r3.selected, "{}", wrapper.name());
                assert_close(&r1.weights, &r3.weights, 1e-6, "weights");
            }
        });
    }

    #[test]
    fn shortcut_equals_bruteforce_criterion() {
        let mut g = Gen::new(77);
        let x = g.matrix(5, 8);
        let y = g.targets(8);
        let cfg =
            SelectionConfig { k: 3, lambda: 0.6, loss: Loss::Squared };
        let r_b = Wrapper::brute_force().select(&x, &y, &cfg).unwrap();
        let r_s = Wrapper::shortcut().select(&x, &y, &cfg).unwrap();
        assert_eq!(r_b.selected, r_s.selected);
        for (a, b) in r_b.rounds.iter().zip(&r_s.rounds) {
            assert!((a.criterion - b.criterion).abs() < 1e-6);
        }
    }

    #[test]
    fn names_distinguish_modes() {
        assert_ne!(Wrapper::brute_force().name(), Wrapper::shortcut().name());
    }

    #[test]
    fn rejects_bad_k() {
        let mut g = Gen::new(1);
        let x = g.matrix(3, 5);
        let y = g.labels(5);
        let cfg = SelectionConfig { k: 4, lambda: 1.0, loss: Loss::ZeroOne };
        assert!(Wrapper::shortcut().select(&x, &y, &cfg).is_err());
    }
}
