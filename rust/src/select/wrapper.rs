//! **Standard wrapper** — the paper's Algorithm 1.
//!
//! RLS is treated as a black box: for every candidate feature and every
//! LOO fold the model is retrained from scratch —
//! O(min{k³m²n, k²m³n}) total. A second mode replaces the literal
//! retraining with the eq. 7/8 LOO shortcut (the "immediate reduction"
//! the paper describes in §3.1), which drops the complexity to
//! O(min{k³mn, k²m²n}) while provably selecting the same features.
//!
//! Both modes exist because the ablation bench (`ablation_loo_shortcut`)
//! reproduces the paper's complexity narrative: wrapper ≪ wrapper+shortcut
//! ≪ low-rank ≪ greedy, with the crossovers the paper discusses.

use anyhow::ensure;

use super::session::{
    CoreStep, PolicySession, Session, SessionCore, SessionSelector,
};
use super::{argmin, Round, SelectionConfig, SelectionResult, Selector};
use crate::linalg::Matrix;
use crate::metrics::Loss;
use crate::rls;

/// How the wrapper evaluates LOO for a candidate feature set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LooMode {
    /// Retrain per held-out example (Algorithm 1 verbatim).
    BruteForce,
    /// Closed-form LOO via eq. (7)/(8) — same result, one training per
    /// candidate set.
    Shortcut,
}

/// Algorithm 1 as a [`Selector`].
#[derive(Clone, Copy, Debug)]
pub struct Wrapper {
    /// LOO evaluation mode.
    pub mode: LooMode,
}

impl Default for Wrapper {
    fn default() -> Self {
        Wrapper { mode: LooMode::Shortcut }
    }
}

impl Wrapper {
    /// LOO predictions for the feature set `s` (rows of `x`).
    fn loo(&self, x: &Matrix, s: &[usize], y: &[f64], lambda: f64) -> Vec<f64> {
        let xs = x.select_rows(s);
        match self.mode {
            LooMode::BruteForce => rls::loo_brute_force(&xs, y, lambda),
            LooMode::Shortcut => {
                // primal when |S| ≤ m, dual otherwise — mirrors training
                if xs.rows() <= xs.cols() {
                    rls::loo_primal(&xs, y, lambda)
                } else {
                    rls::loo_dual(&xs, y, lambda)
                }
            }
        }
    }
}

/// Round-by-round engine of Algorithm 1: score every candidate set
/// `S ∪ {i}` by retraining (or the eq. 7/8 shortcut), commit the argmin.
struct WrapperCore<'a> {
    x: &'a Matrix,
    y: &'a [f64],
    wrapper: Wrapper,
    lambda: f64,
    loss: Loss,
    k: usize,
    threads: usize,
    selected: Vec<usize>,
    in_s: Vec<bool>,
    rounds: Vec<Round>,
}

impl WrapperCore<'_> {
    /// LOO criterion of `S ∪ {i}` — candidates are independent, so a
    /// forced round scores only its own candidate.
    fn score_one(&self, i: usize) -> f64 {
        let mut s = self.selected.clone();
        s.push(i);
        let p = self.wrapper.loo(self.x, &s, self.y, self.lambda);
        self.loss.total(self.y, &p)
    }
}

impl SessionCore for WrapperCore<'_> {
    fn target_reached(&self) -> bool {
        self.selected.len() >= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let n = self.x.rows();
        let (b, criterion) = match forced {
            Some(b) => {
                ensure!(b < n, "feature {b} out of range (n={n})");
                ensure!(!self.in_s[b], "feature {b} already selected");
                (b, self.score_one(b))
            }
            None => {
                // each candidate set retrains independently — the
                // heaviest scan in the crate parallelizes the best
                let scores = super::scan_candidates(
                    n,
                    self.threads,
                    |i| !self.in_s[i],
                    |i| self.score_one(i),
                );
                let b = argmin(&scores)
                    .ok_or_else(|| anyhow::anyhow!("no candidate left"))?;
                (b, scores[b])
            }
        };
        let round = Round { feature: b, criterion };
        self.in_s[b] = true;
        self.selected.push(b);
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.selected.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        // line 21: final training on the chosen set
        if self.selected.is_empty() {
            return Ok(Vec::new());
        }
        let xs = self.x.select_rows(&self.selected);
        Ok(rls::train(&xs, self.y, self.lambda))
    }
}

impl SessionSelector for Wrapper {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let n = x.rows();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(x.cols() == y.len(), "shape mismatch");
        super::require_f64(cfg, "wrapper")?;
        super::require_no_preselect(cfg, "wrapper")?;
        let core = WrapperCore {
            x,
            y,
            wrapper: *self,
            lambda: cfg.lambda,
            loss: cfg.loss,
            k: cfg.k,
            threads: crate::parallel::resolve(cfg.threads),
            selected: Vec::new(),
            in_s: vec![false; n],
            rounds: Vec::new(),
        };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for Wrapper {
    fn name(&self) -> &'static str {
        match self.mode {
            LooMode::BruteForce => "wrapper-bruteforce",
            LooMode::Shortcut => "wrapper-shortcut",
        }
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        super::run_to_completion(self.begin(x, y, cfg)?)
    }
}

/// Convenience constructors.
impl Wrapper {
    /// Wrapper with LOO by literal retraining (the paper's slowest tier).
    pub fn brute_force() -> Self {
        Wrapper { mode: LooMode::BruteForce }
    }

    /// Wrapper with the eq. 7/8 LOO shortcut.
    pub fn shortcut() -> Self {
        Wrapper { mode: LooMode::Shortcut }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;
    use crate::proptest::{assert_close, forall_seeds, Gen};
    use crate::select::greedy::GreedyRls;

    /// Central claim: the wrapper (both modes) selects exactly the same
    /// features as greedy RLS.
    #[test]
    fn equivalent_to_greedy_rls() {
        forall_seeds(12, |seed| {
            let mut g = Gen::new(seed + 300);
            let n = g.size(3, 8);
            let m = g.size(4, 9);
            let k = 2.min(n);
            let lam = g.lambda(-1, 1);
            let x = g.matrix(n, m);
            let y = g.labels(m);
            let cfg =
                SelectionConfig { k, lambda: lam, loss: Loss::Squared, ..Default::default() };
            let r3 = GreedyRls.select(&x, &y, &cfg).unwrap();
            for wrapper in [Wrapper::brute_force(), Wrapper::shortcut()] {
                let r1 = wrapper.select(&x, &y, &cfg).unwrap();
                assert_eq!(r1.selected, r3.selected, "{}", wrapper.name());
                assert_close(&r1.weights, &r3.weights, 1e-6, "weights");
            }
        });
    }

    #[test]
    fn shortcut_equals_bruteforce_criterion() {
        let mut g = Gen::new(77);
        let x = g.matrix(5, 8);
        let y = g.targets(8);
        let cfg =
            SelectionConfig { k: 3, lambda: 0.6, loss: Loss::Squared, ..Default::default() };
        let r_b = Wrapper::brute_force().select(&x, &y, &cfg).unwrap();
        let r_s = Wrapper::shortcut().select(&x, &y, &cfg).unwrap();
        assert_eq!(r_b.selected, r_s.selected);
        for (a, b) in r_b.rounds.iter().zip(&r_s.rounds) {
            assert!((a.criterion - b.criterion).abs() < 1e-6);
        }
    }

    #[test]
    fn names_distinguish_modes() {
        assert_ne!(Wrapper::brute_force().name(), Wrapper::shortcut().name());
    }

    #[test]
    fn rejects_bad_k() {
        let mut g = Gen::new(1);
        let x = g.matrix(3, 5);
        let y = g.labels(5);
        let cfg = SelectionConfig { k: 4, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        assert!(Wrapper::shortcut().select(&x, &y, &cfg).is_err());
    }
}
