//! Sequential forward **floating** selection (paper §5 / Pudil et al. 1994).
//!
//! Forward greedy steps interleaved with conditional backward steps: after
//! each addition, repeatedly remove the selected feature whose removal
//! yields a LOO criterion strictly better than the best value previously
//! recorded for that subset size. This escapes some of plain greedy's
//! nesting traps at modest extra cost.
//!
//! Scoring reuses the eq. 7/8 LOO shortcut (wrapper machinery); this is an
//! extension, not the paper's headline, so clarity wins over the O(kmn)
//! cache engineering of [`super::greedy`].

use anyhow::ensure;

use super::session::{
    CoreStep, PolicySession, Session, SessionCore, SessionSelector,
};
use super::{argmin, Round, SelectionConfig, SelectionResult, Selector};
use crate::linalg::Matrix;
use crate::metrics::Loss;
use crate::rls;

/// SFFS-style selector with a step budget guard.
#[derive(Clone, Copy, Debug)]
pub struct FloatingForward {
    /// Hard cap on total (forward + backward) steps to guarantee
    /// termination; generous default.
    pub max_steps: usize,
}

impl Default for FloatingForward {
    fn default() -> Self {
        FloatingForward { max_steps: 10_000 }
    }
}

/// Round-by-round engine: one session round = one forward addition plus
/// its conditional floating removals (so the round log matches the
/// forward additions, as in the one-shot run).
struct FloatingCore<'a> {
    x: &'a Matrix,
    y: &'a [f64],
    lambda: f64,
    loss: Loss,
    k: usize,
    max_steps: usize,
    threads: usize,
    s: Vec<usize>,
    /// best criterion seen for each subset size (index = |S|)
    best_at: Vec<f64>,
    steps: usize,
    rounds: Vec<Round>,
}

impl FloatingCore<'_> {
    fn criterion(&self, s: &[usize]) -> f64 {
        rls::loo_subset_criterion(self.x, s, self.y, self.lambda, self.loss)
    }
}

impl SessionCore for FloatingCore<'_> {
    fn target_reached(&self) -> bool {
        self.s.len() >= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let n = self.x.rows();
        if self.steps >= self.max_steps {
            return Ok(CoreStep::Exhausted);
        }
        self.steps += 1;
        // forward step: best addition (a forced round scores only its own
        // candidate — candidates are independent, so the value is
        // identical to what the full scan would have recorded)
        let (b, cur) = match forced {
            Some(b) => {
                ensure!(b < n, "feature {b} out of range (n={n})");
                ensure!(!self.s.contains(&b), "feature {b} already selected");
                let mut t = self.s.clone();
                t.push(b);
                (b, self.criterion(&t))
            }
            None => {
                let scores = super::scan_candidates(
                    n,
                    self.threads,
                    |i| !self.s.contains(&i),
                    |i| {
                        let mut t = self.s.clone();
                        t.push(i);
                        self.criterion(&t)
                    },
                );
                let b = argmin(&scores)
                    .ok_or_else(|| anyhow::anyhow!("no candidate left"))?;
                (b, scores[b])
            }
        };
        self.s.push(b);
        self.best_at[self.s.len()] = self.best_at[self.s.len()].min(cur);
        let round = Round { feature: b, criterion: cur };
        self.rounds.push(round.clone());

        // conditional backward steps (never undo the just-added one
        // immediately into an empty improvement loop)
        while self.s.len() > 2 && self.steps < self.max_steps {
            self.steps += 1;
            let rem_scores =
                crate::parallel::par_map(self.threads, self.s.len(), |pos| {
                    let mut t = self.s.clone();
                    t.remove(pos);
                    self.criterion(&t)
                });
            let worst_pos = argmin(&rem_scores).unwrap();
            let smaller = self.s.len() - 1;
            if rem_scores[worst_pos] + 1e-12 < self.best_at[smaller] {
                // floating removal improves the smaller subset record
                self.best_at[smaller] = rem_scores[worst_pos];
                self.s.remove(worst_pos);
            } else {
                break;
            }
        }
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.s.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        if self.s.is_empty() {
            return Ok(Vec::new());
        }
        let xs = self.x.select_rows(&self.s);
        Ok(rls::train(&xs, self.y, self.lambda))
    }
}

impl SessionSelector for FloatingForward {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let n = x.rows();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(x.cols() == y.len(), "shape mismatch");
        super::require_f64(cfg, "floating-forward")?;
        super::require_no_preselect(cfg, "floating-forward")?;
        let core = FloatingCore {
            x,
            y,
            lambda: cfg.lambda,
            loss: cfg.loss,
            k: cfg.k,
            max_steps: self.max_steps,
            threads: crate::parallel::resolve(cfg.threads),
            s: Vec::new(),
            best_at: vec![f64::INFINITY; cfg.k + 1],
            steps: 0,
            rounds: Vec::new(),
        };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for FloatingForward {
    fn name(&self) -> &'static str {
        "floating-forward"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        super::run_to_completion(self.begin(x, y, cfg)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;
    use crate::select::greedy::GreedyRls;

    #[test]
    fn reaches_k_features() {
        let ds = crate::data::synthetic::two_gaussians(60, 15, 5, 1.2, 21);
        let cfg = SelectionConfig { k: 6, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let r = FloatingForward::default().select(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(r.selected.len(), 6);
        let mut u = r.selected.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 6);
    }

    fn loo_criterion(
        x: &Matrix,
        s: &[usize],
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> f64 {
        rls::loo_subset_criterion(x, s, y, cfg.lambda, cfg.loss)
    }

    #[test]
    fn never_worse_criterion_than_greedy_at_k() {
        // floating search explores a superset of greedy's trajectory, so
        // its final LOO criterion can't be (meaningfully) worse
        let (ds, _) =
            crate::data::synthetic::sparse_regression(120, 18, 6, 0.3, 33);
        let cfg = SelectionConfig { k: 6, lambda: 0.5, loss: Loss::Squared, ..Default::default() };
        let rg = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        let rf = FloatingForward::default().select(&ds.x, &ds.y, &cfg).unwrap();
        let fg = loo_criterion(&ds.x, &rg.selected, &ds.y, &cfg);
        let ff = loo_criterion(&ds.x, &rf.selected, &ds.y, &cfg);
        assert!(ff <= fg * 1.0 + 1e-9, "floating {ff} vs greedy {fg}");
    }

    #[test]
    fn step_budget_respected() {
        let ds = crate::data::synthetic::two_gaussians(30, 10, 3, 1.0, 2);
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let sel = FloatingForward { max_steps: 3 };
        let r = sel.select(&ds.x, &ds.y, &cfg).unwrap();
        assert!(r.selected.len() <= 5);
    }
}
