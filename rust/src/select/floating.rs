//! Sequential forward **floating** selection (paper §5 / Pudil et al. 1994).
//!
//! Forward greedy steps interleaved with conditional backward steps: after
//! each addition, repeatedly remove the selected feature whose removal
//! yields a LOO criterion strictly better than the best value previously
//! recorded for that subset size. This escapes some of plain greedy's
//! nesting traps at modest extra cost.
//!
//! Scoring reuses the eq. 7/8 LOO shortcut (wrapper machinery); this is an
//! extension, not the paper's headline, so clarity wins over the O(kmn)
//! cache engineering of [`super::greedy`].

use anyhow::ensure;

use super::{argmin, Round, SelectionConfig, SelectionResult, Selector, BIG};
use crate::linalg::Matrix;
use crate::rls;

/// SFFS-style selector with a step budget guard.
#[derive(Clone, Copy, Debug)]
pub struct FloatingForward {
    /// Hard cap on total (forward + backward) steps to guarantee
    /// termination; generous default.
    pub max_steps: usize,
}

impl Default for FloatingForward {
    fn default() -> Self {
        FloatingForward { max_steps: 10_000 }
    }
}

impl FloatingForward {
    fn criterion(
        &self,
        x: &Matrix,
        s: &[usize],
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> f64 {
        let xs = x.select_rows(s);
        let p = if xs.rows() <= xs.cols() {
            rls::loo_primal(&xs, y, cfg.lambda)
        } else {
            rls::loo_dual(&xs, y, cfg.lambda)
        };
        cfg.loss.total(y, &p)
    }
}

impl Selector for FloatingForward {
    fn name(&self) -> &'static str {
        "floating-forward"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        let n = x.rows();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");

        let mut s: Vec<usize> = Vec::new();
        // best criterion seen for each subset size (index = |S|)
        let mut best_at = vec![f64::INFINITY; cfg.k + 1];
        let mut rounds = Vec::new();
        let mut steps = 0usize;

        while s.len() < cfg.k && steps < self.max_steps {
            steps += 1;
            // forward step: best addition
            let mut scores = vec![BIG; n];
            for i in 0..n {
                if s.contains(&i) {
                    continue;
                }
                let mut t = s.clone();
                t.push(i);
                scores[i] = self.criterion(x, &t, y, cfg);
            }
            let b = argmin(&scores)
                .ok_or_else(|| anyhow::anyhow!("no candidate left"))?;
            s.push(b);
            let cur = scores[b];
            best_at[s.len()] = best_at[s.len()].min(cur);
            rounds.push(Round { feature: b, criterion: cur });

            // conditional backward steps (never undo the just-added one
            // immediately into an empty improvement loop)
            while s.len() > 2 && steps < self.max_steps {
                steps += 1;
                let mut rem_scores = vec![BIG; s.len()];
                for (pos, _) in s.iter().enumerate() {
                    let mut t = s.clone();
                    t.remove(pos);
                    rem_scores[pos] = self.criterion(x, &t, y, cfg);
                }
                let worst_pos = argmin(&rem_scores).unwrap();
                let smaller = s.len() - 1;
                if rem_scores[worst_pos] + 1e-12 < best_at[smaller] {
                    // floating removal improves the smaller subset record
                    best_at[smaller] = rem_scores[worst_pos];
                    s.remove(worst_pos);
                } else {
                    break;
                }
            }
        }

        let xs = x.select_rows(&s);
        let weights = rls::train(&xs, y, cfg.lambda);
        Ok(SelectionResult { selected: s, rounds, weights })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;
    use crate::select::greedy::GreedyRls;

    #[test]
    fn reaches_k_features() {
        let ds = crate::data::synthetic::two_gaussians(60, 15, 5, 1.2, 21);
        let cfg = SelectionConfig { k: 6, lambda: 1.0, loss: Loss::ZeroOne };
        let r = FloatingForward::default().select(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(r.selected.len(), 6);
        let mut u = r.selected.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 6);
    }

    #[test]
    fn never_worse_criterion_than_greedy_at_k() {
        // floating search explores a superset of greedy's trajectory, so
        // its final LOO criterion can't be (meaningfully) worse
        let (ds, _) =
            crate::data::synthetic::sparse_regression(120, 18, 6, 0.3, 33);
        let cfg = SelectionConfig { k: 6, lambda: 0.5, loss: Loss::Squared };
        let rg = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        let rf = FloatingForward::default().select(&ds.x, &ds.y, &cfg).unwrap();
        let fg = FloatingForward::default()
            .criterion(&ds.x, &rg.selected, &ds.y, &cfg);
        let ff = FloatingForward::default()
            .criterion(&ds.x, &rf.selected, &ds.y, &cfg);
        assert!(ff <= fg * 1.0 + 1e-9, "floating {ff} vs greedy {fg}");
    }

    #[test]
    fn step_budget_respected() {
        let ds = crate::data::synthetic::two_gaussians(30, 10, 3, 1.0, 2);
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne };
        let sel = FloatingForward { max_steps: 3 };
        let r = sel.select(&ds.x, &ds.y, &cfg).unwrap();
        assert!(r.selected.len() <= 5);
    }
}
