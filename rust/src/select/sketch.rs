//! Sketched preselection: a seeded leverage-score filter in front of
//! the exact greedy engine (ROADMAP "Sketched preselection"; Paul &
//! Drineas, arXiv 1506.05173).
//!
//! The paper's greedy scan is O(mn) per round. Ridge leverage scores
//! rank how much each feature row can matter to *any* regularized
//! least-squares fit, so computing them once and keeping only the top
//! `p` candidates turns every subsequent scan into O(mp) while the
//! exact LOO machinery — stop policies, checkpoints, warm starts,
//! observers, threads, precision, both data backends — runs unchanged
//! on the survivor set.
//!
//! Two score paths share one accumulation kernel:
//!
//! * **Exact** (`sketch_dim == 0`, or `>= n` where a projection could
//!   not compress anything): τ_i = x_iᵀ (XᵀX + λI)⁻¹ x_i — the ridge
//!   leverage score itself, and the reference oracle the property
//!   tests compare the projected path against.
//! * **Sketched** (`0 < sketch_dim < n`): a seeded Rademacher
//!   projection `B = ΠX` (d × m, signs ±1/√d from a dedicated
//!   [`Pcg64`] stream) stands in for `X`, and the Woodbury identity
//!   evaluates τ̃_i = x_iᵀ (BᵀB + λI)⁻¹ x_i as
//!   (‖x_i‖² − b_iᵀ (BBᵀ + λI)⁻¹ b_i) / λ with b_i = B x_i, keeping
//!   the whole pass linear in both n and m: O(nmd) total.
//!
//! **Determinism.** Projection signs are drawn feature-major from
//! `Pcg64::new(seed, SKETCH_STREAM)` in a serial build loop, so they
//! depend only on `seed`. The per-feature score pass goes through
//! [`scan_candidates`] (candidates are scored independently — the
//! assembled vector is bit-identical at every thread count), the
//! stored backend stages each row through `read_row_into` into the
//! same arithmetic, and every accumulation routes through the
//! [`kernel`] tier (`axpy`/`dot`, bit-identical across kinds). Hence
//! scores — and the survivor set — are bit-identical across threads,
//! tile widths, kernel kinds, and backends. A filter that keeps
//! everything (`p >= n`) is the identity: it consumes no RNG and the
//! run reproduces the exact greedy trajectory bitwise, checkpoint
//! bytes included (the config-fingerprint marker normalizes away with
//! it — see [`super::checkpoint`]).

use anyhow::{ensure, Context, Result};

use super::greedy::GreedyRls;
use super::{
    run_to_completion, scan_candidates, SelectionConfig, SelectionResult,
    Selector, Session, SessionSelector,
};
use crate::data::storage::MatrixStore;
use crate::kernel::{self, KernelKind};
use crate::linalg::{spd_inverse, Matrix};
use crate::rng::Pcg64;

/// Dedicated RNG stream for projection signs so the sketch never
/// entangles with data-generation or split streams sharing a seed.
const SKETCH_STREAM: u64 = 0x6c65_7665; // "leve"

/// Sketched-preselection parameters, carried by
/// [`SelectionConfig::preselect`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PreselectConfig {
    /// Survivor count: the top-`p` features by approximate leverage
    /// score pass the filter. `p >= n` keeps every candidate — the
    /// identity filter (no RNG consumed, exact greedy bitwise).
    pub p: usize,
    /// Rademacher projection rows `d`. `0` (the CLI default) means no
    /// projection: compute exact ridge leverage scores — O(nm²), the
    /// oracle path, right for small problems and tests. Values `>= n`
    /// also take the exact path (a projection that large compresses
    /// nothing).
    pub sketch_dim: usize,
    /// Seed of the sketch's own RNG stream (only the projected path
    /// consumes it).
    pub seed: u64,
}

/// Reject degenerate filters before any work happens.
pub fn validate(ps: &PreselectConfig) -> Result<()> {
    ensure!(
        ps.p >= 1,
        "--preselect must keep at least one candidate (got p = 0)"
    );
    Ok(())
}

/// Approximate ridge leverage scores of every feature row of the
/// in-RAM matrix `x` (n × m, feature-major), one per row. Exact when
/// `ps.sketch_dim` is `0` or `>= n`. Scores are clamped at zero (the
/// Woodbury subtraction can round a true zero a few ulp negative).
pub fn leverage_scores(
    x: &Matrix,
    lambda: f64,
    ps: &PreselectConfig,
    threads: usize,
    kind: KernelKind,
) -> Result<Vec<f64>> {
    let plan = SketchPlan::build(x.rows(), x.cols(), lambda, ps, kind, |i, out| {
        out.clear();
        out.extend_from_slice(x.row(i));
        Ok(())
    })?;
    Ok(scan_candidates(x.rows(), threads, |_| true, |i| {
        plan.score(x.row(i))
    }))
}

/// [`leverage_scores`] for the stored backend: rows are staged through
/// `read_row_into` into the identical arithmetic, so scores are
/// bit-identical to the in-RAM path on the same data. The score pass
/// is serial (row reads can fail, and the sketch build already
/// streamed the store once); it bills the same
/// [`super::scan_ops`] count as the parallel path.
pub fn leverage_scores_stored(
    x: &MatrixStore,
    lambda: f64,
    ps: &PreselectConfig,
    kind: KernelKind,
) -> Result<Vec<f64>> {
    let (n, m) = (x.rows(), x.row_len());
    let plan =
        SketchPlan::build(n, m, lambda, ps, kind, |i, out| x.read_row_into(i, out))?;
    super::scan_ops::add(n as u64);
    let mut buf = vec![0.0; m];
    let mut scores = Vec::with_capacity(n);
    for i in 0..n {
        x.read_row_into(i, &mut buf)?;
        scores.push(plan.score(&buf));
    }
    Ok(scores)
}

/// Indices of the top-`p` scores — descending by score, ties to the
/// lowest index (the repo-wide tie rule) — returned ascending, the
/// order the greedy engines keep their active sets in.
pub fn top_p(scores: &[f64], p: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]).then(a.cmp(&b)));
    idx.truncate(p);
    idx.sort_unstable();
    idx
}

/// Survivor set for `cfg` on the in-RAM backend: `None` when no filter
/// is configured or it is the identity (`p >= n`), otherwise the
/// ascending top-`p` candidate indices.
pub(crate) fn survivors(
    x: &Matrix,
    cfg: &SelectionConfig,
) -> Result<Option<Vec<usize>>> {
    let Some(ps) = cfg.preselect else {
        return Ok(None);
    };
    validate(&ps)?;
    if ps.p >= x.rows() {
        return Ok(None);
    }
    let scores =
        leverage_scores(x, cfg.lambda, &ps, cfg.threads, KernelKind::active())?;
    Ok(Some(top_p(&scores, ps.p)))
}

/// [`survivors`] for the stored backend — same decisions, same bits.
pub(crate) fn survivors_stored(
    x: &MatrixStore,
    cfg: &SelectionConfig,
) -> Result<Option<Vec<usize>>> {
    let Some(ps) = cfg.preselect else {
        return Ok(None);
    };
    validate(&ps)?;
    if ps.p >= x.rows() {
        return Ok(None);
    }
    let scores =
        leverage_scores_stored(x, cfg.lambda, &ps, KernelKind::active())?;
    Ok(Some(top_p(&scores, ps.p)))
}

/// The factored score pass: everything the per-feature closure needs,
/// built once per filter invocation by streaming the data a single
/// time through a caller-supplied row accessor.
enum SketchPlan {
    /// Exact path: `K⁻¹ = (XᵀX + λI)⁻¹` (m × m).
    Exact { kinv: Matrix, kind: KernelKind },
    /// Projected path: `B = ΠX` (d × m) and `S⁻¹ = (BBᵀ + λI)⁻¹`
    /// (d × d), evaluated through the Woodbury identity.
    Projected { b: Matrix, sinv: Matrix, lambda: f64, kind: KernelKind },
}

impl SketchPlan {
    fn build<F>(
        n: usize,
        m: usize,
        lambda: f64,
        ps: &PreselectConfig,
        kind: KernelKind,
        mut row: F,
    ) -> Result<SketchPlan>
    where
        F: FnMut(usize, &mut Vec<f64>) -> Result<()>,
    {
        validate(ps)?;
        ensure!(
            lambda > 0.0,
            "lambda must be positive for leverage scores (got {lambda})"
        );
        ensure!(n > 0 && m > 0, "empty matrix has no leverage scores");
        let mut buf = vec![0.0; m];
        let d = ps.sketch_dim;
        if d == 0 || d >= n {
            // Exact Gram accumulation: K = Σ_i x_i x_iᵀ + λI. One
            // kernel-tier axpy per output row keeps the serial
            // operation sequence single-sourced.
            let mut k = Matrix::zeros(m, m);
            for i in 0..n {
                row(i, &mut buf)?;
                for r in 0..m {
                    kernel::axpy(kind, buf[r], &buf, k.row_mut(r));
                }
            }
            k.add_diag(lambda);
            let kinv = spd_inverse(&k).context(
                "ridge Gram matrix is not positive definite — is λ > 0 \
                 and the data finite?",
            )?;
            Ok(SketchPlan::Exact { kinv, kind })
        } else {
            // B = ΠX, accumulated feature-major so each row is
            // streamed off the backend exactly once; the sign sequence
            // is a pure function of the seed.
            let scale = 1.0 / (d as f64).sqrt();
            let mut rng = Pcg64::new(ps.seed, SKETCH_STREAM);
            let mut b = Matrix::zeros(d, m);
            for i in 0..n {
                row(i, &mut buf)?;
                for r in 0..d {
                    kernel::axpy(kind, rng.sign() * scale, &buf, b.row_mut(r));
                }
            }
            // S = BBᵀ + λI is d × d — small by construction.
            let mut s = Matrix::zeros(d, d);
            for r in 0..d {
                for q in 0..d {
                    s.row_mut(r)[q] = kernel::dot(kind, b.row(r), b.row(q));
                }
            }
            s.add_diag(lambda);
            let sinv = spd_inverse(&s).context(
                "sketch Gram matrix is not positive definite — is λ > 0 \
                 and the data finite?",
            )?;
            Ok(SketchPlan::Projected { b, sinv, lambda, kind })
        }
    }

    /// τ̃ of one feature row. Pure in `xi` and `self` — safe to fan out
    /// across scan workers.
    fn score(&self, xi: &[f64]) -> f64 {
        match self {
            SketchPlan::Exact { kinv, kind } => {
                kernel::dot(*kind, xi, &kinv.matvec(xi)).max(0.0)
            }
            SketchPlan::Projected { b, sinv, lambda, kind } => {
                let bi = b.matvec(xi);
                let ss = kernel::dot(*kind, xi, xi);
                let proj = kernel::dot(*kind, &bi, &sinv.matvec(&bi));
                ((ss - proj) / lambda).max(0.0)
            }
        }
    }
}

/// Filter-then-exact session selector: requires a configured
/// [`PreselectConfig`], then delegates to [`GreedyRls`] — the greedy
/// cores apply the filter themselves whenever `cfg.preselect` is set,
/// so sessions behave exactly like greedy sessions (checkpoints, warm
/// starts, observers, threads, precision, ram and mmap backends).
#[derive(Clone, Copy, Debug, Default)]
pub struct SketchedGreedy;

impl SessionSelector for SketchedGreedy {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> Result<Box<dyn Session + 'a>> {
        ensure!(
            cfg.preselect.is_some(),
            "sketched-greedy requires --preselect (an unfiltered run is \
             plain greedy-rls)"
        );
        GreedyRls.begin(x, y, cfg)
    }
}

impl Selector for SketchedGreedy {
    fn name(&self) -> &'static str {
        "sketched-greedy"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> Result<SelectionResult> {
        run_to_completion(SessionSelector::begin(self, x, y, cfg)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::assert_close;

    fn ps(p: usize, d: usize, seed: u64) -> PreselectConfig {
        PreselectConfig { p, sketch_dim: d, seed }
    }

    #[test]
    fn validate_rejects_empty_filter() {
        assert!(validate(&ps(0, 0, 7)).is_err());
        assert!(validate(&ps(1, 0, 7)).is_ok());
    }

    #[test]
    fn exact_scores_match_hand_computed_oracle() {
        // Feature rows (1, 0) and (0, 2); K = diag(1, 4) + I, so
        // τ₀ = 1/2 and τ₁ = 4/5.
        let x = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
        let t =
            leverage_scores(&x, 1.0, &ps(1, 0, 0), 1, KernelKind::Scalar)
                .unwrap();
        assert_close(&t, &[0.5, 0.8], 1e-12, "tau");
    }

    #[test]
    fn big_sketch_dim_takes_the_exact_path() {
        let x = Matrix::from_rows(&[&[1.0, 0.5], &[0.25, 2.0], &[3.0, 1.0]]);
        let exact =
            leverage_scores(&x, 0.5, &ps(2, 0, 3), 1, KernelKind::Scalar)
                .unwrap();
        // d >= n compresses nothing: identical bits, and the seed is
        // irrelevant because no RNG is consumed on the exact path.
        for d in [3, 4, 100] {
            let t = leverage_scores(
                &x,
                0.5,
                &ps(2, d, 99),
                1,
                KernelKind::Scalar,
            )
            .unwrap();
            for (a, b) in exact.iter().zip(&t) {
                assert_eq!(a.to_bits(), b.to_bits(), "d={d}");
            }
        }
    }

    #[test]
    fn sketched_scores_are_seed_deterministic() {
        let mut rng = Pcg64::seeded(11);
        let rows: Vec<Vec<f64>> = (0..12)
            .map(|_| (0..6).map(|_| rng.normal()).collect())
            .collect();
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let x = Matrix::from_rows(&refs);
        let a = leverage_scores(&x, 1.0, &ps(4, 3, 42), 1, KernelKind::Scalar)
            .unwrap();
        let b = leverage_scores(&x, 1.0, &ps(4, 3, 42), 4, KernelKind::Scalar)
            .unwrap();
        let c = leverage_scores(&x, 1.0, &ps(4, 3, 43), 1, KernelKind::Scalar)
            .unwrap();
        assert!(a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits()));
        assert!(
            a.iter().zip(&c).any(|(p, q)| p.to_bits() != q.to_bits()),
            "different sketch seeds should disagree somewhere"
        );
        assert!(a.iter().all(|&t| t >= 0.0 && t.is_finite()));
    }

    #[test]
    fn top_p_breaks_ties_low_and_returns_ascending() {
        let scores = [1.0, 3.0, 3.0, 0.5, 2.0];
        assert_eq!(top_p(&scores, 2), vec![1, 2]);
        assert_eq!(top_p(&scores, 3), vec![1, 2, 4]);
        assert_eq!(top_p(&scores, 99), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn identity_filter_yields_no_survivor_set() {
        let x = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
        let cfg = SelectionConfig::builder()
            .preselect(Some(ps(2, 0, 0)))
            .build();
        assert!(survivors(&x, &cfg).unwrap().is_none());
        let cfg = cfg.with().preselect(Some(ps(1, 0, 0))).build();
        assert_eq!(survivors(&x, &cfg).unwrap(), Some(vec![1]));
        let cfg = cfg.with().preselect(None).build();
        assert!(survivors(&x, &cfg).unwrap().is_none());
    }

    #[test]
    fn sketched_greedy_requires_a_filter() {
        let x = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
        let y = [1.0, -1.0];
        let cfg = SelectionConfig::builder().k(1).build();
        let err = SketchedGreedy.select(&x, &y, &cfg).unwrap_err();
        assert!(err.to_string().contains("--preselect"), "{err}");
    }
}
