//! Backward elimination (paper §5, future-work direction).
//!
//! Start from the **full** feature set and greedily remove the feature
//! whose removal gives the best LOO performance, until `k` remain. The
//! paper notes this is inherently more expensive than forward selection
//! because an RLS predictor must first be trained with every feature —
//! an O(m³ + m²n) initialization — after which the same cache machinery
//! as greedy RLS applies with the *sign-flipped* SMW identity:
//!
//! removing feature i (K ← K − v vᵀ):
//! ```text
//! u  = C[:,i] / (1 − vᵀ C[:,i])
//! ã  = a + u (vᵀ a)
//! d̃_j = d_j + u_j C[j,i]
//! C  ← C + u (vᵀ C)
//! ```
//!
//! so each elimination round is O(mn), and the whole run O((n−k)mn) after
//! the initialization — the forward algorithm's mirror image.
//!
//! The PJRT artifact twin is [`crate::runtime::engine::PjrtBackward`]:
//! the same rounds as one masked removal-score launch + one downdate
//! launch each, with the full-set initialization folded into a single
//! `full_init_state` artifact (n in-device rank-1 commits). Equivalence
//! is enforced by `rust/tests/pjrt_integration.rs`.

use anyhow::ensure;

use super::session::{
    CoreStep, PolicySession, Session, SessionCore, SessionSelector,
};
use super::{argmin, Round, SelectionConfig, SelectionResult, Selector, BIG};
use crate::kernel::{self, KernelKind};
use crate::linalg::{dot, spd_inverse, Matrix};
use crate::metrics::Loss;

/// Greedy backward elimination with LOO criterion.
#[derive(Clone, Copy, Debug, Default)]
pub struct BackwardElimination;

struct BackState {
    m: usize,
    n: usize,
    /// Cᵀ rows (as in the forward engine).
    ct: Vec<f64>,
    a: Vec<f64>,
    d: Vec<f64>,
    /// true while the feature is still in S.
    in_s: Vec<bool>,
    /// Resolved worker-thread count for the per-round scans/updates.
    threads: usize,
    /// Compute-kernel dispatch, fixed at construction
    /// ([`KernelKind::active`]).
    kernel: KernelKind,
}

impl BackState {
    /// Train on the full feature set: G = (XᵀX + λI)⁻¹, C = G Xᵀ.
    fn init(x: &Matrix, y: &[f64], lambda: f64) -> anyhow::Result<BackState> {
        let n = x.rows();
        let m = x.cols();
        let mut k = x.gram_t(); // XᵀX (m × m)
        k.add_diag(lambda);
        let g = spd_inverse(&k)
            .ok_or_else(|| anyhow::anyhow!("K + λI not SPD"))?;
        let mut ct = vec![0.0; n * m];
        for i in 0..n {
            let gxi = g.matvec(x.row(i)); // C[:, i]
            ct[i * m..(i + 1) * m].copy_from_slice(&gxi);
        }
        let a = g.matvec(y);
        let d = (0..m).map(|j| g[(j, j)]).collect();
        Ok(BackState {
            m,
            n,
            ct,
            a,
            d,
            in_s: vec![true; n],
            threads: 1,
            kernel: KernelKind::active(),
        })
    }

    /// LOO criterion of S \ {i} for one member i ([`BIG`] when the
    /// removal is numerically unrepresentable this round). Removal
    /// candidates are independent, so forced session rounds score only
    /// their own candidate through this same code path.
    fn removal_score(&self, x: &Matrix, y: &[f64], loss: Loss, i: usize) -> f64 {
        let m = self.m;
        let v = x.row(i);
        let c = &self.ct[i * m..(i + 1) * m];
        let vc = kernel::dot(self.kernel, v, c);
        let va = kernel::dot(self.kernel, v, &self.a);
        let denom = 1.0 - vc;
        if denom.abs() < 1e-12 {
            return BIG; // numerically unremovable this round
        }
        kernel::removal_loss(c, &self.a, &self.d, y, loss, va, denom)
    }

    /// LOO criterion of S \ {i} for every member i — independent per
    /// member, run on the shared deterministic parallel scan.
    fn score_removals(&self, x: &Matrix, y: &[f64], loss: Loss) -> Vec<f64> {
        super::scan_candidates(
            self.n,
            self.threads,
            |i| self.in_s[i],
            |i| self.removal_score(x, y, loss, i),
        )
    }

    /// Remove feature b from S (sign-flipped commit); the O(mn) cache
    /// update shards its independent rows like the forward engine's.
    fn remove(&mut self, x: &Matrix, b: usize) {
        let m = self.m;
        let v = x.row(b);
        let cb = self.ct[b * m..(b + 1) * m].to_vec();
        let denom = 1.0 - kernel::dot(self.kernel, v, &cb);
        let u: Vec<f64> = cb.iter().map(|&c| c / denom).collect();
        let va = kernel::dot(self.kernel, v, &self.a);
        // sign-flipped commit: a += u·va, d += u∘c_b
        kernel::update_ad(&mut self.a, &mut self.d, &u, &cb, va, 1.0);
        crate::parallel::rank1_row_update(
            self.kernel,
            self.threads,
            &mut self.ct,
            m,
            v,
            &u,
            1.0,
        );
        self.in_s[b] = false;
    }
}

/// Round-by-round engine: each round is one *elimination* (the session's
/// "feature" log records the removed feature; `selected()` is the set
/// still standing, in ascending index order).
struct BackwardCore<'a> {
    x: &'a Matrix,
    y: &'a [f64],
    loss: Loss,
    k: usize,
    st: BackState,
    rounds: Vec<Round>,
}

impl SessionCore for BackwardCore<'_> {
    fn target_reached(&self) -> bool {
        // n − (#removals) features remain
        self.st.n - self.rounds.len() <= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let (b, criterion) = match forced {
            Some(b) => {
                ensure!(
                    b < self.st.n,
                    "feature {b} out of range (n={})",
                    self.st.n
                );
                ensure!(self.st.in_s[b], "feature {b} already removed");
                let s = self.st.removal_score(self.x, self.y, self.loss, b);
                ensure!(
                    s < BIG,
                    "feature {b} is not numerically removable this round"
                );
                (b, s)
            }
            None => {
                let scores =
                    self.st.score_removals(self.x, self.y, self.loss);
                let b = argmin(&scores)
                    .ok_or_else(|| anyhow::anyhow!("no removable feature"))?;
                (b, scores[b])
            }
        };
        let round = Round { feature: b, criterion };
        self.st.remove(self.x, b);
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        (0..self.st.n).filter(|&i| self.st.in_s[i]).collect()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        Ok(self
            .selected()
            .iter()
            .map(|&i| dot(self.x.row(i), &self.st.a))
            .collect())
    }
}

impl SessionSelector for BackwardElimination {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let n = x.rows();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(x.cols() == y.len(), "shape mismatch");
        super::require_f64(cfg, "backward-elimination")?;
        super::require_no_preselect(cfg, "backward-elimination")?;
        let mut st = BackState::init(x, y, cfg.lambda)?;
        st.threads = crate::parallel::resolve(cfg.threads);
        let core = BackwardCore {
            x,
            y,
            loss: cfg.loss,
            k: cfg.k,
            st,
            rounds: Vec::new(),
        };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for BackwardElimination {
    fn name(&self) -> &'static str {
        "backward-elimination"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        super::run_to_completion(self.begin(x, y, cfg)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{assert_close, forall_seeds, Gen};

    /// Removal scores must equal retraining on S \ {i} + LOO shortcut.
    #[test]
    fn removal_scores_equal_explicit_loo() {
        forall_seeds(10, |seed| {
            let mut g = Gen::new(seed + 700);
            let n = g.size(3, 7);
            let m = g.size(4, 9);
            let lam = g.lambda(0, 1);
            let x = g.matrix(n, m);
            let y = g.targets(m);
            let st = BackState::init(&x, &y, lam).unwrap();
            let scores = st.score_removals(&x, &y, Loss::Squared);
            for i in 0..n {
                if scores[i] >= BIG {
                    continue;
                }
                let s: Vec<usize> = (0..n).filter(|&t| t != i).collect();
                let xs = x.select_rows(&s);
                let p = crate::rls::loo_dual(&xs, &y, lam);
                let want: f64 = y
                    .iter()
                    .zip(&p)
                    .map(|(&yv, &pv)| (yv - pv).powi(2))
                    .sum();
                assert!(
                    (scores[i] - want).abs() <= 1e-5 * want.abs().max(1.0),
                    "feature {i}: {} vs {want}",
                    scores[i]
                );
            }
        });
    }

    #[test]
    fn keeps_k_features_and_fits_them() {
        let ds = crate::data::synthetic::two_gaussians(50, 12, 4, 1.5, 8);
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let r = BackwardElimination.select(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(r.selected.len(), 5);
        assert_eq!(r.rounds.len(), 7); // 12 − 5 removals
        let xs = ds.x.select_rows(&r.selected);
        let w = crate::rls::train(&xs, &ds.y, cfg.lambda);
        assert_close(&r.weights, &w, 1e-6, "weights");
    }

    #[test]
    fn keeps_planted_support_on_regression() {
        let (ds, mut support) =
            crate::data::synthetic::sparse_regression(200, 15, 3, 0.05, 13);
        let cfg =
            SelectionConfig { k: 3, lambda: 0.1, loss: Loss::Squared, ..Default::default() };
        let r = BackwardElimination.select(&ds.x, &ds.y, &cfg).unwrap();
        let mut sel = r.selected.clone();
        sel.sort_unstable();
        support.sort_unstable();
        assert_eq!(sel, support);
    }

    #[test]
    fn k_equals_n_is_identity() {
        let mut g = Gen::new(5);
        let x = g.matrix(4, 6);
        let y = g.labels(6);
        let cfg = SelectionConfig { k: 4, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let r = BackwardElimination.select(&x, &y, &cfg).unwrap();
        assert_eq!(r.selected, vec![0, 1, 2, 3]);
        assert!(r.rounds.is_empty());
    }
}
