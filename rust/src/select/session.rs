//! Stepwise selection sessions — the crate's round-by-round API.
//!
//! The paper's complexity result makes each greedy round cheap (O(mn) for
//! Algorithm 3), so round-by-round control costs nothing extra. A
//! [`Session`] exposes exactly that: [`Session::step`] runs one selection
//! round (chosen feature + LOO criterion), [`Session::state`] snapshots
//! the trajectory so far, and [`Session::finish`] packages the usual
//! [`SelectionResult`]. Every selector in this crate implements
//! [`SessionSelector`]; the one-shot [`super::Selector::select`] is a thin
//! compatibility shim (`begin` + [`run_to_completion`]).
//!
//! Sessions enable what a blocking `select` cannot:
//!
//! * **early stopping** via [`StopPolicy`] — a round budget, a wall-clock
//!   budget, or a plateau detector on the LOO criterion curve (the
//!   overfitting guard suggested by the paper's Figs. 10–15);
//! * **warm starts** via [`SessionSelector::begin_from`] — the caches are
//!   rebuilt with the same rank-1 updates the selection itself uses, so a
//!   resumed session continues bit-identically to an uninterrupted run;
//! * **observation** via [`Observer`] — per-round progress logging and
//!   per-round timing without re-running the selection.
//!
//! Internally each selector contributes a [`SessionCore`] (one algorithm
//! round, forced or greedy) and [`PolicySession`] supplies the shared
//! budget/plateau/termination machinery on top.

use std::time::{Duration, Instant};

use anyhow::{bail, ensure};

use super::{Round, SelectionConfig, SelectionResult};
use crate::linalg::Matrix;

/// Early-stopping policy for session-driven selection.
///
/// The policy is evaluated before every [`Session::step`]; the hard cap of
/// the selector's natural target (`cfg.k` features selected, or `k`
/// features remaining for backward elimination) always applies on top.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StopPolicy {
    /// Stop after at most this many rounds. The default,
    /// `KBudget(usize::MAX)`, never fires — the session runs to `cfg.k`.
    KBudget(usize),
    /// Stop once this much wall-clock time has elapsed since `begin` —
    /// or, for a warm-started session, since the end of `begin_from`
    /// replay, so a resume gets its full budget for *new* rounds while
    /// caller-forced rounds (e.g. the fixed-order CV baseline) stay on
    /// the clock. A checkpoint resume ([`super::checkpoint`]) instead
    /// continues the original accounting: the prior run's elapsed time is
    /// re-armed via [`Session::bill_elapsed`], bounding total selection
    /// wall-clock across process restarts. Checked between rounds
    /// ([`Session::step`], or [`Session::check_stop`] for forced-order
    /// drivers): the round in flight always completes, so the overshoot
    /// is bounded by one round (O(mn) for greedy RLS).
    TimeBudget(Duration),
    /// Stop after `patience` consecutive rounds whose criterion failed to
    /// improve on the best seen so far by more than
    /// `min_rel_improvement · |best|` (the LOO plateau of Figs. 10–15).
    Plateau {
        /// Consecutive non-improving rounds tolerated before stopping.
        patience: usize,
        /// Relative improvement threshold (0 ⇒ any strict decrease
        /// counts as improvement).
        min_rel_improvement: f64,
    },
}

impl Default for StopPolicy {
    fn default() -> Self {
        StopPolicy::KBudget(usize::MAX)
    }
}

impl StopPolicy {
    /// Reject unusable parameter combinations.
    pub fn validate(&self) -> anyhow::Result<()> {
        match *self {
            StopPolicy::Plateau { patience, min_rel_improvement } => {
                ensure!(patience >= 1, "plateau patience must be ≥ 1");
                ensure!(
                    min_rel_improvement >= 0.0
                        && min_rel_improvement.is_finite(),
                    "min_rel_improvement must be finite and ≥ 0"
                );
                Ok(())
            }
            _ => Ok(()),
        }
    }
}

/// Why a session stopped selecting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The selector's natural target was reached (`cfg.k`).
    TargetReached,
    /// [`StopPolicy::KBudget`] round budget spent.
    RoundBudget,
    /// [`StopPolicy::TimeBudget`] wall-clock budget spent.
    TimeBudget,
    /// [`StopPolicy::Plateau`] fired on the criterion curve.
    Plateau,
    /// The algorithm itself is out of moves before the target: an
    /// internal step budget was spent (floating/FoBa `max_steps`), FoBa
    /// found no improving swap, or a forced-round session consumed the
    /// random order. Mid-run candidate exhaustion in the greedy-family
    /// selectors is an error (`"no candidate left"`), matching the
    /// pre-session `select` behavior.
    Exhausted,
}

impl std::fmt::Display for StopReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            StopReason::TargetReached => "target k reached",
            StopReason::RoundBudget => "round budget spent",
            StopReason::TimeBudget => "time budget spent",
            StopReason::Plateau => "criterion plateau",
            StopReason::Exhausted => "no further round possible",
        };
        f.write_str(s)
    }
}

/// Result of one [`Session::step`].
#[derive(Clone, Debug)]
pub enum StepOutcome {
    /// One more round committed.
    Selected(Round),
    /// The session stopped (idempotent: repeated `step` calls keep
    /// returning the same reason).
    Done(StopReason),
}

/// Owned snapshot of a session's trajectory so far.
#[derive(Clone, Debug)]
pub struct SessionState {
    /// Current feature set (selection order for forward selectors;
    /// ascending index order for backward elimination).
    pub selected: Vec<usize>,
    /// Per-round log, identical in layout to [`SelectionResult::rounds`].
    pub rounds: Vec<Round>,
    /// Model weights over `selected` for the *current* set (recomputed on
    /// each call; cheap for the cache-based selectors, one retraining for
    /// the wrapper-style ones).
    pub weights: Vec<f64>,
    /// Stop reason, once the session has stopped.
    pub stop_reason: Option<StopReason>,
}

impl SessionState {
    /// Criterion trajectory (one value per round).
    pub fn criterion_curve(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.criterion).collect()
    }
}

/// A selection run in progress.
///
/// Obtained from [`SessionSelector::begin`]; drive it with [`step`]
/// (greedy choice) or [`force`] (caller-chosen feature, used for warm
/// starts and fixed-order baselines), then [`finish`] it into a
/// [`SelectionResult`]. Finishing early is allowed — the result covers
/// the rounds executed so far.
///
/// [`step`]: Session::step
/// [`force`]: Session::force
/// [`finish`]: Session::finish
pub trait Session {
    /// Run one greedy round, or report why the session stopped.
    fn step(&mut self) -> anyhow::Result<StepOutcome>;

    /// Commit `feature` as this round's choice (bypassing the argmin but
    /// scoring through the identical code path, so the recorded criterion
    /// is bit-identical to what a greedy run would have logged for that
    /// feature). Errors if the feature is unavailable or the session has
    /// already stopped. `force` never *evaluates* the stop policy —
    /// warm-start replay must always be able to reconstruct its full
    /// prefix — so forced-order drivers that want the policy enforced
    /// call [`Session::check_stop`] between rounds.
    fn force(&mut self, feature: usize) -> anyhow::Result<Round>;

    /// Evaluate the stop policy now (the same check [`Session::step`]
    /// performs before a greedy round) and latch the session stopped if
    /// it fires. This is how forced-order drivers — the fixed-order CV
    /// baseline, external schedulers — honor a [`StopPolicy`]: call it
    /// before each [`Session::force`] and stop on `Some`. Idempotent once
    /// stopped. Deliberately has no default implementation: a
    /// stop_reason-echoing default would silently exempt an implementor
    /// from policy enforcement on forced-order runs — the exact bug
    /// class this method exists to fix.
    fn check_stop(&mut self) -> Option<StopReason>;

    /// Restart the wall-clock anchor so [`StopPolicy::TimeBudget`] and
    /// [`Session::elapsed`] bill only time spent *after* this call (any
    /// [`Session::bill_elapsed`] credit is preserved). Called once by
    /// [`SessionSelector::begin_from`] when its replay completes —
    /// replayed rounds never consume budget — and not meant for general
    /// use: resetting mid-run makes `elapsed()` non-monotone, which
    /// corrupts checkpointed accounting.
    fn reset_clock(&mut self) {}

    /// Rounds executed so far (including warm-start replay rounds).
    fn rounds_done(&self) -> usize;

    /// Snapshot of the trajectory so far. Errors only if the current
    /// weights cannot be computed (e.g. a PJRT state read fails).
    fn state(&self) -> anyhow::Result<SessionState>;

    /// Why the session stopped, once it has.
    fn stop_reason(&self) -> Option<StopReason>;

    /// Wall-clock this session has spent selecting: time since `begin`
    /// (or since the end of a warm start's `begin_from` replay) plus any
    /// prior elapsed time credited via [`Session::bill_elapsed`].
    /// Monotone over the session's lifetime — forced rounds accumulate
    /// like greedy ones — which is what makes the cumulative `elapsed_ns`
    /// a checkpoint persists safe for a resumed process to continue the
    /// [`StopPolicy::TimeBudget`] accounting where the killed one left
    /// off.
    fn elapsed(&self) -> Duration;

    /// Credit wall-clock already spent by a previous process on this
    /// trajectory (read back from a checkpoint). Re-arms the
    /// [`StopPolicy::TimeBudget`] clock so `budget` bounds the *total*
    /// selection time across restarts, and flows into [`Session::elapsed`]
    /// so follow-up checkpoints keep accumulating. Call it after
    /// `begin_from` replay — replayed rounds themselves never consume
    /// budget.
    fn bill_elapsed(&mut self, prior: Duration);

    /// Consume the session into a [`SelectionResult`] for the current
    /// feature set.
    fn finish(self: Box<Self>) -> anyhow::Result<SelectionResult>;
}

/// A selection algorithm that can run as a stepwise [`Session`].
///
/// Every selector in [`crate::select`] implements this; the blocking
/// [`super::Selector::select`] delegates here via [`run_to_completion`].
pub trait SessionSelector {
    /// Start a session on feature-major `x` (n × m) and labels `y`.
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>>;

    /// Start a session warm-started from a previous trajectory prefix:
    /// `selected` lists the features committed by the first rounds, in
    /// round order (for backward elimination these are the *eliminated*
    /// features). The caches are reconstructed through the same rank-1
    /// commit updates the original run performed, so stepping the
    /// returned session continues bit-identically to the uninterrupted
    /// run. Cost per replayed round: scoring the one replayed candidate
    /// (through the exact code path the original scan used, so the
    /// recorded criterion matches bit-for-bit) plus one commit. The PJRT
    /// engine is the exception — its scoring kernel evaluates every
    /// candidate in one launch, so each replayed round costs one
    /// score-step launch + one commit-step launch.
    ///
    /// Replay never consumes [`StopPolicy`] budget: the wall-clock anchor
    /// is restarted **once** when the replay completes
    /// ([`Session::reset_clock`]), so [`StopPolicy::TimeBudget`] and
    /// [`Session::elapsed`] bill only post-replay time — and stay
    /// monotone over any later forced rounds, which the checkpoint
    /// layer's cumulative `elapsed_ns` accounting relies on.
    fn begin_from<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
        selected: &[usize],
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let mut s = self.begin(x, y, cfg)?;
        for &f in selected {
            s.force(f)?;
        }
        s.reset_clock();
        Ok(s)
    }
}

/// Per-round callback, invoked by [`drive`].
pub trait Observer {
    /// Called after each committed round with its 0-based index and the
    /// wall-clock time the round took.
    fn on_round(&mut self, index: usize, round: &Round, elapsed: Duration) {
        let _ = (index, round, elapsed);
    }

    /// Called once when the session stops.
    fn on_stop(&mut self, reason: StopReason) {
        let _ = reason;
    }
}

/// Observer that ignores everything.
pub struct NoopObserver;

impl Observer for NoopObserver {}

/// Fan-out combinator: forwards every [`Observer`] callback to each
/// member, in insertion order.
///
/// This is how independent per-round concerns — progress logging, round
/// timing, checkpoint autosaving, bus publishing — share one session
/// drive without every driver growing a parameter per concern:
///
/// ```
/// use greedy_rls::data::synthetic::two_gaussians;
/// use greedy_rls::select::{
///     drive, greedy::GreedyRls, NoopObserver, Observers, Round,
///     SelectionConfig, SessionSelector,
/// };
///
/// struct Count(usize);
/// impl greedy_rls::select::Observer for Count {
///     fn on_round(&mut self, _i: usize, _r: &Round, _e: std::time::Duration) {
///         self.0 += 1;
///     }
/// }
///
/// let ds = two_gaussians(40, 8, 2, 1.0, 1);
/// let cfg = SelectionConfig::builder().k(3).build();
/// let mut session = GreedyRls.begin(&ds.x, &ds.y, &cfg)?;
/// let (mut count, mut noop) = (Count(0), NoopObserver);
/// let mut fan = Observers::new().with(&mut count).with(&mut noop);
/// drive(session.as_mut(), &mut fan)?;
/// assert_eq!(count.0, 3);
/// # anyhow::Ok(())
/// ```
#[derive(Default)]
pub struct Observers<'a> {
    members: Vec<&'a mut dyn Observer>,
}

impl<'a> Observers<'a> {
    /// An empty fan-out (equivalent to [`NoopObserver`]).
    pub fn new() -> Observers<'a> {
        Observers { members: Vec::new() }
    }

    /// Builder-style append; callbacks reach members in append order.
    pub fn with(mut self, observer: &'a mut dyn Observer) -> Observers<'a> {
        self.members.push(observer);
        self
    }

    /// Append a member observer.
    pub fn push(&mut self, observer: &'a mut dyn Observer) {
        self.members.push(observer);
    }
}

impl Observer for Observers<'_> {
    fn on_round(&mut self, index: usize, round: &Round, elapsed: Duration) {
        for obs in &mut self.members {
            obs.on_round(index, round, elapsed);
        }
    }

    fn on_stop(&mut self, reason: StopReason) {
        for obs in &mut self.members {
            obs.on_stop(reason);
        }
    }
}

/// An [`Observer`] that additionally needs the live [`Session`] after
/// each committed round — the shape shared by checkpoint autosaving
/// (snapshot [`Session::state`] to disk,
/// [`super::checkpoint::Autosaver`]) and in-process model publishing
/// (snapshot it onto a bus,
/// [`crate::coordinator::stream::PublishObserver`]). The plain
/// [`Observer`] callbacks can't serve this purpose: they only see the
/// [`Round`], never the session, because [`drive`] holds the session
/// borrow.
///
/// [`drive_tapped`] calls every tap's `Observer` callbacks first, then
/// `flush` for each tap **in slice order** — which makes cross-tap
/// ordering a caller-visible contract. Passing
/// `[&mut autosaver, &mut publisher]` guarantees a round's checkpoint is
/// durable on disk before the bus announces its version: the
/// publish-after-save ordering [`crate::coordinator::stream`] documents
/// and the kill/resume gauntlet relies on.
pub trait StateObserver: Observer {
    /// React to the session's new state (write a checkpoint, publish a
    /// model version, …). Called after each committed round and once
    /// after the stop notification.
    fn flush(&mut self, session: &(dyn Session + '_)) -> anyhow::Result<()>;
}

/// Drive a session until it stops, reporting each round to `observer`.
/// Returns the stop reason; call [`Session::finish`] afterwards for the
/// result.
pub fn drive(
    session: &mut (dyn Session + '_),
    observer: &mut dyn Observer,
) -> anyhow::Result<StopReason> {
    drive_tapped(session, observer, &mut [])
}

/// [`drive`] with state taps: after every committed round (and once on
/// stop) each [`StateObserver`] in `taps` sees the `Observer` callbacks
/// and is then `flush`ed with the session borrow, in slice order. This
/// is the one driver behind checkpointed runs
/// ([`super::checkpoint::drive_checkpointed`]) and the streaming
/// train-serve pipeline ([`crate::coordinator::stream::train_serve`]),
/// which composes both taps.
pub fn drive_tapped(
    session: &mut (dyn Session + '_),
    observer: &mut dyn Observer,
    taps: &mut [&mut dyn StateObserver],
) -> anyhow::Result<StopReason> {
    let mut index = session.rounds_done();
    loop {
        let t0 = Instant::now();
        match session.step()? {
            StepOutcome::Selected(round) => {
                let dt = t0.elapsed();
                observer.on_round(index, &round, dt);
                for tap in taps.iter_mut() {
                    tap.on_round(index, &round, dt);
                }
                for tap in taps.iter_mut() {
                    tap.flush(&*session)?;
                }
                index += 1;
            }
            StepOutcome::Done(reason) => {
                observer.on_stop(reason);
                for tap in taps.iter_mut() {
                    tap.on_stop(reason);
                }
                for tap in taps.iter_mut() {
                    tap.flush(&*session)?;
                }
                return Ok(reason);
            }
        }
    }
}

/// Drive a session to completion and finish it — the one-shot
/// compatibility shim behind every [`super::Selector::select`].
pub fn run_to_completion(
    mut session: Box<dyn Session + '_>,
) -> anyhow::Result<SelectionResult> {
    loop {
        if let StepOutcome::Done(_) = session.step()? {
            break;
        }
    }
    session.finish()
}

// ---------------------------------------------------------------------------
// Shared session machinery (crate-internal)
// ---------------------------------------------------------------------------

/// What one algorithm round produced.
pub(crate) enum CoreStep {
    /// A feature was committed (added, or removed for backward).
    Committed(Round),
    /// No further round is possible.
    Exhausted,
}

/// One selector's round-by-round engine. Implementations own their data
/// (borrowed `x`/`y` plus whatever caches the algorithm maintains);
/// [`PolicySession`] layers the stop policy and bookkeeping on top.
pub(crate) trait SessionCore {
    /// Has the selector's natural target been reached (`cfg.k`)?
    fn target_reached(&self) -> bool;

    /// Execute one round. `forced` bypasses the argmin (the candidate is
    /// still scored through the identical code path). Must only be called
    /// while `!target_reached()`.
    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep>;

    /// Rounds committed so far.
    fn rounds(&self) -> &[Round];

    /// Current feature set.
    fn selected(&self) -> Vec<usize>;

    /// Model weights for the current feature set.
    fn weights(&self) -> anyhow::Result<Vec<f64>>;
}

/// Generic [`Session`] implementation: a [`SessionCore`] plus the
/// [`StopPolicy`] state machine (round budget, time budget, plateau
/// tracking on the criterion curve).
pub(crate) struct PolicySession<C> {
    core: C,
    stop: StopPolicy,
    started: Instant,
    /// Wall-clock credited from a previous process ([`Session::bill_elapsed`]);
    /// added to `started.elapsed()` wherever elapsed time is consumed.
    billed: Duration,
    best: f64,
    has_best: bool,
    bad_streak: usize,
    done: Option<StopReason>,
}

impl<C: SessionCore> PolicySession<C> {
    pub(crate) fn new(core: C, cfg: &SelectionConfig) -> anyhow::Result<Self> {
        cfg.stop.validate()?;
        Ok(PolicySession {
            core,
            stop: cfg.stop,
            started: Instant::now(),
            billed: Duration::ZERO,
            best: f64::INFINITY,
            has_best: false,
            bad_streak: 0,
            done: None,
        })
    }

    fn pending_stop(&self) -> Option<StopReason> {
        if self.core.target_reached() {
            return Some(StopReason::TargetReached);
        }
        match self.stop {
            StopPolicy::KBudget(budget) => {
                (self.core.rounds().len() >= budget)
                    .then_some(StopReason::RoundBudget)
            }
            StopPolicy::TimeBudget(limit) => {
                (self.started.elapsed() + self.billed >= limit)
                    .then_some(StopReason::TimeBudget)
            }
            StopPolicy::Plateau { patience, .. } => {
                (self.bad_streak >= patience).then_some(StopReason::Plateau)
            }
        }
    }

    /// Feed one committed round through the plateau tracker. The first
    /// round establishes the baseline; afterwards a round "improves" iff
    /// it beats the best criterion so far by more than
    /// `min_rel_improvement · |best|`.
    fn note_round(&mut self, round: &Round) {
        let c = round.criterion;
        if !self.has_best {
            self.has_best = true;
            self.best = c;
            return;
        }
        if let StopPolicy::Plateau { min_rel_improvement, .. } = self.stop {
            let improving = (self.best - c) > min_rel_improvement * self.best.abs();
            if improving {
                self.bad_streak = 0;
            } else {
                self.bad_streak += 1;
            }
        }
        if c < self.best {
            self.best = c;
        }
    }
}

impl<C: SessionCore> Session for PolicySession<C> {
    fn step(&mut self) -> anyhow::Result<StepOutcome> {
        if let Some(reason) = self.done {
            return Ok(StepOutcome::Done(reason));
        }
        if let Some(reason) = self.pending_stop() {
            self.done = Some(reason);
            return Ok(StepOutcome::Done(reason));
        }
        match self.core.round(None)? {
            CoreStep::Committed(round) => {
                self.note_round(&round);
                Ok(StepOutcome::Selected(round))
            }
            CoreStep::Exhausted => {
                self.done = Some(StopReason::Exhausted);
                Ok(StepOutcome::Done(StopReason::Exhausted))
            }
        }
    }

    fn force(&mut self, feature: usize) -> anyhow::Result<Round> {
        if let Some(reason) = self.done {
            bail!("session already stopped ({reason})");
        }
        ensure!(
            !self.core.target_reached(),
            "session already at its target size"
        );
        match self.core.round(Some(feature))? {
            CoreStep::Committed(round) => {
                self.note_round(&round);
                Ok(round)
            }
            CoreStep::Exhausted => bail!("no further round possible"),
        }
    }

    fn check_stop(&mut self) -> Option<StopReason> {
        if self.done.is_none() {
            self.done = self.pending_stop();
        }
        self.done
    }

    fn reset_clock(&mut self) {
        self.started = Instant::now();
    }

    fn rounds_done(&self) -> usize {
        self.core.rounds().len()
    }

    fn state(&self) -> anyhow::Result<SessionState> {
        Ok(SessionState {
            selected: self.core.selected(),
            rounds: self.core.rounds().to_vec(),
            weights: self.core.weights()?,
            stop_reason: self.done,
        })
    }

    fn stop_reason(&self) -> Option<StopReason> {
        self.done
    }

    fn elapsed(&self) -> Duration {
        self.started.elapsed() + self.billed
    }

    fn bill_elapsed(&mut self, prior: Duration) {
        self.billed = prior;
    }

    fn finish(self: Box<Self>) -> anyhow::Result<SelectionResult> {
        let s = *self;
        Ok(SelectionResult {
            selected: s.core.selected(),
            rounds: s.core.rounds().to_vec(),
            weights: s.core.weights()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::forall_seeds;
    use crate::select::greedy::GreedyRls;
    use crate::select::Selector;

    fn overfit_dataset(seed: u64) -> crate::data::Dataset {
        // few informative features among many noise ones: the criterion
        // curve drops fast, then plateaus — the Figs. 10–15 mechanism
        crate::data::synthetic::planted_sparse(
            "overfit", 80, 40, 4, 1.2, 0.9, 0.05, seed,
        )
    }

    /// Reference implementation of the plateau rule applied to a full
    /// criterion curve: number of rounds a plateau session executes.
    fn expected_plateau_rounds(
        curve: &[f64],
        patience: usize,
        min_rel: f64,
    ) -> usize {
        let (mut best, mut has_best, mut bad) = (f64::INFINITY, false, 0usize);
        for (i, &c) in curve.iter().enumerate() {
            if has_best && bad >= patience {
                return i;
            }
            if !has_best {
                has_best = true;
                best = c;
                continue;
            }
            let improving = (best - c) > min_rel * best.abs();
            if improving {
                bad = 0;
            } else {
                bad += 1;
            }
            if c < best {
                best = c;
            }
        }
        curve.len()
    }

    #[test]
    fn default_policy_runs_to_k() {
        let ds = overfit_dataset(1);
        let cfg = SelectionConfig::builder().k(6).lambda(1.0).build();
        let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        let mut n_rounds = 0;
        loop {
            match s.step().unwrap() {
                StepOutcome::Selected(_) => n_rounds += 1,
                StepOutcome::Done(reason) => {
                    assert_eq!(reason, StopReason::TargetReached);
                    break;
                }
            }
        }
        assert_eq!(n_rounds, 6);
        let r = s.finish().unwrap();
        assert_eq!(r.selected.len(), 6);
        assert_eq!(r.rounds.len(), 6);
    }

    #[test]
    fn step_is_idempotent_after_done() {
        let ds = overfit_dataset(2);
        let cfg = SelectionConfig::builder().k(2).build();
        let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        while !matches!(s.step().unwrap(), StepOutcome::Done(_)) {}
        assert!(matches!(
            s.step().unwrap(),
            StepOutcome::Done(StopReason::TargetReached)
        ));
        assert_eq!(s.stop_reason(), Some(StopReason::TargetReached));
        assert!(s.force(0).is_err(), "force after done must fail");
    }

    #[test]
    fn round_budget_stops_early() {
        let ds = overfit_dataset(3);
        let cfg = SelectionConfig::builder()
            .k(10)
            .stop(StopPolicy::KBudget(3))
            .build();
        let r = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(r.selected.len(), 3);
        assert_eq!(r.weights.len(), 3);
    }

    #[test]
    fn zero_time_budget_selects_nothing() {
        let ds = overfit_dataset(4);
        let cfg = SelectionConfig::builder()
            .k(5)
            .stop(StopPolicy::TimeBudget(Duration::ZERO))
            .build();
        let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        assert!(matches!(
            s.step().unwrap(),
            StepOutcome::Done(StopReason::TimeBudget)
        ));
        let r = s.finish().unwrap();
        assert!(r.selected.is_empty());
        assert!(r.weights.is_empty());
    }

    #[test]
    fn billed_elapsed_counts_against_the_time_budget() {
        // a checkpoint resume credits the prior process's selection time:
        // billing more than the whole budget stops the session immediately
        let ds = overfit_dataset(9);
        let cfg = SelectionConfig::builder()
            .k(5)
            .stop(StopPolicy::TimeBudget(Duration::from_secs(3600)))
            .build();
        let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        s.bill_elapsed(Duration::from_secs(7200));
        assert!(s.elapsed() >= Duration::from_secs(7200));
        assert!(matches!(
            s.step().unwrap(),
            StepOutcome::Done(StopReason::TimeBudget)
        ));
    }

    /// Regression (stop-clock accounting): a `TimeBudget` must fire on a
    /// forced-order run. `force` used to reset the clock every round, so
    /// a fixed-order session could never exceed any budget.
    #[test]
    fn time_budget_fires_on_forced_order_runs() {
        let ds = overfit_dataset(10);
        let cfg = SelectionConfig::builder()
            .k(5)
            .stop(StopPolicy::TimeBudget(Duration::ZERO))
            .build();
        let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(s.check_stop(), Some(StopReason::TimeBudget));
        assert!(
            s.force(0).is_err(),
            "force after the policy latched must fail"
        );
        assert_eq!(s.stop_reason(), Some(StopReason::TimeBudget));
        assert!(s.finish().unwrap().selected.is_empty());
    }

    /// Regression (stop-clock accounting): `elapsed()` must be monotone
    /// across forced rounds — the per-round clock reset made Autosaver's
    /// cumulative `elapsed_ns` non-monotone on forced trajectories.
    #[test]
    fn elapsed_is_monotone_across_forced_rounds() {
        let ds = overfit_dataset(11);
        let cfg = SelectionConfig::builder().k(4).build();
        let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        let mut last = Duration::ZERO;
        for f in [0usize, 1, 2] {
            s.force(f).unwrap();
            std::thread::sleep(Duration::from_millis(5));
            let e = s.elapsed();
            assert!(
                e >= last,
                "elapsed went backwards after forcing {f}: {e:?} < {last:?}"
            );
            last = e;
        }
        // and it keeps growing without any round committed
        std::thread::sleep(Duration::from_millis(5));
        assert!(s.elapsed() > last);
    }

    /// A warm start still gets its full time budget for new rounds: the
    /// clock restarts once, when `begin_from`'s replay completes.
    #[test]
    fn warm_start_resets_the_clock_once_after_replay() {
        let ds = overfit_dataset(12);
        let full_cfg = SelectionConfig::builder().k(4).build();
        let full = GreedyRls.select(&ds.x, &ds.y, &full_cfg).unwrap();
        let cfg = SelectionConfig::builder()
            .k(4)
            .stop(StopPolicy::TimeBudget(Duration::from_secs(3600)))
            .build();
        let mut s = GreedyRls
            .begin_from(&ds.x, &ds.y, &cfg, &full.selected[..2])
            .unwrap();
        assert_eq!(s.rounds_done(), 2);
        // replay time was discounted; a generous budget lets it finish
        assert!(s.elapsed() < Duration::from_secs(3600));
        assert_eq!(s.check_stop(), None);
        let r = run_to_completion(s).unwrap();
        assert_eq!(r.selected, full.selected);
    }

    #[test]
    fn check_stop_is_idempotent_and_matches_step() {
        let ds = overfit_dataset(13);
        let cfg = SelectionConfig::builder()
            .k(10)
            .stop(StopPolicy::KBudget(2))
            .build();
        let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(s.check_stop(), None);
        s.step().unwrap();
        s.step().unwrap();
        assert_eq!(s.check_stop(), Some(StopReason::RoundBudget));
        assert_eq!(s.check_stop(), Some(StopReason::RoundBudget));
        assert!(matches!(
            s.step().unwrap(),
            StepOutcome::Done(StopReason::RoundBudget)
        ));
    }

    #[test]
    fn generous_time_budget_runs_to_k() {
        let ds = overfit_dataset(5);
        let cfg = SelectionConfig::builder()
            .k(4)
            .stop(StopPolicy::TimeBudget(Duration::from_secs(3600)))
            .build();
        let r = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(r.selected.len(), 4);
    }

    #[test]
    fn invalid_plateau_policy_rejected() {
        let ds = overfit_dataset(6);
        let cfg = SelectionConfig::builder()
            .k(3)
            .stop(StopPolicy::Plateau { patience: 0, min_rel_improvement: 0.0 })
            .build();
        assert!(GreedyRls.begin(&ds.x, &ds.y, &cfg).is_err());
        let cfg = SelectionConfig::builder()
            .k(3)
            .stop(StopPolicy::Plateau {
                patience: 2,
                min_rel_improvement: -1.0,
            })
            .build();
        assert!(GreedyRls.begin(&ds.x, &ds.y, &cfg).is_err());
    }

    /// Property: on the overfitting synthetic, a plateau session stops
    /// exactly where the reference rule applied to the full (unstopped)
    /// LOO criterion curve says it should — and therefore at or before
    /// the round where the curve stops improving by the threshold.
    #[test]
    fn plateau_stops_where_the_curve_plateaus() {
        forall_seeds(10, |seed| {
            let ds = overfit_dataset(100 + seed);
            let k = 20;
            let (patience, min_rel) = (3usize, 1e-3f64);
            let full_cfg = SelectionConfig::builder().k(k).lambda(1.0).build();
            let full = GreedyRls.select(&ds.x, &ds.y, &full_cfg).unwrap();
            let curve = full.criterion_curve();
            let expect = expected_plateau_rounds(&curve, patience, min_rel);

            let cfg = SelectionConfig::builder()
                .k(k)
                .lambda(1.0)
                .stop(StopPolicy::Plateau {
                    patience,
                    min_rel_improvement: min_rel,
                })
                .build();
            let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
            let reason = drive(s.as_mut(), &mut NoopObserver).unwrap();
            let r = s.finish().unwrap();

            assert_eq!(r.rounds.len(), expect, "seed {seed}: {curve:?}");
            assert!(r.rounds.len() <= k);
            // greedy is deterministic: the stopped run is a prefix
            assert_eq!(&full.selected[..r.selected.len()], &r.selected[..]);
            if expect < k {
                assert_eq!(reason, StopReason::Plateau);
                assert!(
                    r.selected.len() < k,
                    "seed {seed}: plateau should select fewer than k"
                );
            }
        });
    }

    #[test]
    fn observer_sees_every_round() {
        struct Count(usize, Option<StopReason>);
        impl Observer for Count {
            fn on_round(&mut self, index: usize, _r: &Round, _e: Duration) {
                assert_eq!(index, self.0);
                self.0 += 1;
            }
            fn on_stop(&mut self, reason: StopReason) {
                self.1 = Some(reason);
            }
        }
        let ds = overfit_dataset(7);
        let cfg = SelectionConfig::builder().k(5).build();
        let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        let mut obs = Count(0, None);
        drive(s.as_mut(), &mut obs).unwrap();
        assert_eq!(obs.0, 5);
        assert_eq!(obs.1, Some(StopReason::TargetReached));
    }

    #[test]
    fn state_snapshots_track_progress() {
        let ds = overfit_dataset(8);
        let cfg = SelectionConfig::builder().k(3).build();
        let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        assert_eq!(s.rounds_done(), 0);
        assert!(s.state().unwrap().selected.is_empty());
        s.step().unwrap();
        let st = s.state().unwrap();
        assert_eq!(st.selected.len(), 1);
        assert_eq!(st.weights.len(), 1);
        assert_eq!(st.criterion_curve().len(), 1);
        assert_eq!(st.stop_reason, None);
    }

    #[test]
    fn observers_fan_out_in_insertion_order() {
        struct Tag(&'static str, std::rc::Rc<std::cell::RefCell<Vec<String>>>);
        impl Observer for Tag {
            fn on_round(&mut self, i: usize, _r: &Round, _e: Duration) {
                self.1.borrow_mut().push(format!("{}:{i}", self.0));
            }
            fn on_stop(&mut self, _reason: StopReason) {
                self.1.borrow_mut().push(format!("{}:stop", self.0));
            }
        }
        let log = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let ds = overfit_dataset(14);
        let cfg = SelectionConfig::builder().k(2).build();
        let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        let (mut a, mut b) = (Tag("a", log.clone()), Tag("b", log.clone()));
        let mut fan = Observers::new().with(&mut a).with(&mut b);
        drive(s.as_mut(), &mut fan).unwrap();
        assert_eq!(
            *log.borrow(),
            vec!["a:0", "b:0", "a:1", "b:1", "a:stop", "b:stop"]
        );
    }

    /// `drive_tapped` flushes taps in slice order after each round — the
    /// ordering contract publish-after-save is built on.
    #[test]
    fn drive_tapped_flushes_in_slice_order() {
        struct Tap(&'static str, std::rc::Rc<std::cell::RefCell<Vec<String>>>);
        impl Observer for Tap {}
        impl StateObserver for Tap {
            fn flush(
                &mut self,
                session: &(dyn Session + '_),
            ) -> anyhow::Result<()> {
                self.1
                    .borrow_mut()
                    .push(format!("{}@{}", self.0, session.rounds_done()));
                Ok(())
            }
        }
        let log = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let ds = overfit_dataset(15);
        let cfg = SelectionConfig::builder().k(2).build();
        let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        let (mut save, mut publish) =
            (Tap("save", log.clone()), Tap("publish", log.clone()));
        drive_tapped(
            s.as_mut(),
            &mut NoopObserver,
            &mut [&mut save, &mut publish],
        )
        .unwrap();
        // two rounds + the on-stop flush, each save-before-publish
        assert_eq!(
            *log.borrow(),
            vec![
                "save@1", "publish@1", "save@2", "publish@2", "save@2",
                "publish@2"
            ]
        );
    }

    #[test]
    fn stop_reason_displays() {
        for (r, needle) in [
            (StopReason::TargetReached, "target"),
            (StopReason::RoundBudget, "round"),
            (StopReason::TimeBudget, "time"),
            (StopReason::Plateau, "plateau"),
            (StopReason::Exhausted, "no further"),
        ] {
            assert!(format!("{r}").contains(needle));
        }
    }
}
