//! Deterministic multi-threaded execution layer for the O(mn) hot paths.
//!
//! The paper makes each greedy round linear in the data (`score_all` and
//! `commit` are two memory-bound O(mn) passes), and both passes are
//! embarrassingly parallel across candidates / cache rows. This module is
//! the crate's one place that spawns threads: scoped workers over
//! contiguous index ranges, sized by [`SelectionConfig::threads`]
//! (`0` = available parallelism) with a serial fast path at one thread.
//!
//! **Determinism is the design constraint, not a hope.** Work is only ever
//! split at boundaries where the serial algorithm's arithmetic is already
//! independent:
//!
//! * per-candidate scans split the candidate list into contiguous ranges —
//!   each candidate's score involves no cross-candidate reduction, so the
//!   assembled score vector is bit-identical to the serial scan;
//! * the greedy engine's register-blocked scan splits the *active list at
//!   quad boundaries* ([`quad_ranges`]) so the blocks-of-4 grouping — and
//!   therefore the exact operation order per candidate — is the same at
//!   any thread count (and matches `GreedyState::score_of`);
//! * rank-1 cache downdates split the n independent cache rows
//!   ([`for_each_row_chunk`]); every row sees the identical serial update.
//!
//! Reductions (argmin, accumulation over folds / λ cells) always happen on
//! the calling thread, in the serial order. The bit-identity of selected
//! sets, criterion curves, and weights at `threads ∈ {1, 2, 4}` is
//! enforced by `rust/tests/equivalence.rs`.
//!
//! [`SelectionConfig::threads`]: crate::select::SelectionConfig::threads

use std::ops::Range;

/// Number of hardware threads the host reports (≥ 1).
pub fn available() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a configured thread count: `0` means "use available
/// parallelism", anything else is taken literally.
pub fn resolve(threads: usize) -> usize {
    if threads == 0 {
        available()
    } else {
        threads
    }
}

/// Split `0..len` into at most `parts` contiguous, non-empty, balanced
/// ranges (sizes differ by at most one), in order. Empty input yields no
/// ranges.
pub fn split_ranges(len: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.max(1).min(len);
    if parts == 0 {
        return Vec::new();
    }
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let size = base + usize::from(p < extra);
        out.push(start..start + size);
        start += size;
    }
    debug_assert_eq!(start, len);
    out
}

/// Split `0..len` into at most `parts` contiguous ranges whose *interior*
/// boundaries are multiples of 4, balanced by quad count; the final range
/// absorbs the `len % 4` remainder. This is the sharding under the greedy
/// engine's register-blocked scan: a range never cuts a quad in half, so
/// each worker's blocks-of-4 grouping matches the serial scan's exactly.
pub fn quad_ranges(len: usize, parts: usize) -> Vec<Range<usize>> {
    let quads = len / 4;
    if quads == 0 {
        return if len == 0 { Vec::new() } else { vec![0..len] };
    }
    let mut out: Vec<Range<usize>> = split_ranges(quads, parts.max(1))
        .into_iter()
        .map(|r| r.start * 4..r.end * 4)
        .collect();
    // the scalar remainder rides with the last worker
    // xtask-allow: no-panic-hot-path -- unreachable: quads >= 1 here, so
    // split_ranges returned at least one range.
    out.last_mut().expect("quads >= 1").end = len;
    out
}

/// Map `f` over `ranges` with one scoped worker per range beyond the
/// first (which runs on the calling thread); results are returned in
/// range order. With zero or one range no thread is spawned.
///
/// A panic in any worker is propagated to the caller.
pub fn map_ranges<R, F>(ranges: &[Range<usize>], f: F) -> Vec<R>
where
    R: Send,
    F: Fn(Range<usize>) -> R + Sync,
{
    if ranges.len() <= 1 {
        return ranges.iter().cloned().map(&f).collect();
    }
    let fref = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = ranges[1..]
            .iter()
            .map(|r| {
                let r = r.clone();
                s.spawn(move || fref(r))
            })
            .collect();
        let mut out = Vec::with_capacity(ranges.len());
        out.push(fref(ranges[0].clone()));
        for h in handles {
            out.push(
                h.join()
                    .unwrap_or_else(|e| std::panic::resume_unwind(e)),
            );
        }
        out
    })
}

/// Deterministic parallel map: `f(i)` for `i in 0..len`, results in index
/// order, computed on up to `threads` workers (resolved via [`resolve`]).
/// Bit-identical to the serial `(0..len).map(f)` because each element is
/// computed independently and assembled in order on the calling thread.
pub fn par_map<R, F>(threads: usize, len: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let t = resolve(threads).min(len);
    if t <= 1 {
        return (0..len).map(f).collect();
    }
    let ranges = split_ranges(len, t);
    map_ranges(&ranges, |r| r.map(&f).collect::<Vec<R>>())
        .into_iter()
        .flatten()
        .collect()
}

/// Apply `f` to balanced, row-aligned chunks of a flat row-major buffer
/// (`buf.len()` must be a multiple of `row_len`); the first chunk runs on
/// the calling thread (as in [`map_ranges`]) and each further chunk gets
/// a scoped worker. `f` receives the chunk's first row index and the
/// mutable chunk. Rows are disjoint and each receives the identical
/// serial update, so the result is bit-identical at any thread count.
/// Generic over the element type so the same sharding drives both the
/// f64 cache and the mixed-precision f32 cache.
pub fn for_each_row_chunk<T, F>(
    threads: usize,
    buf: &mut [T],
    row_len: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(row_len > 0, "row_len must be positive");
    assert_eq!(buf.len() % row_len, 0, "buffer not row-aligned");
    let rows = buf.len() / row_len;
    if rows == 0 {
        return;
    }
    let t = resolve(threads).min(rows);
    if t <= 1 {
        f(0, buf);
        return;
    }
    let rows_per = (rows + t - 1) / t;
    let fref = &f;
    let mut chunks: Vec<(usize, &mut [T])> = Vec::with_capacity(t);
    let mut start_row = 0;
    for chunk in buf.chunks_mut(rows_per * row_len) {
        let rows_here = chunk.len() / row_len;
        chunks.push((start_row, chunk));
        start_row += rows_here;
    }
    std::thread::scope(|s| {
        let mut rest = chunks.into_iter();
        let (first_row, first_chunk) =
            // xtask-allow: no-panic-hot-path -- unreachable: rows >= 1 was
            // checked above, so chunks_mut yielded at least one chunk.
            rest.next().expect("rows >= 1 implies at least one chunk");
        for (sr, chunk) in rest {
            s.spawn(move || fref(sr, chunk));
        }
        fref(first_row, first_chunk);
    });
}

/// Shared SMW rank-1 row update — the O(mn) cache downdate of the
/// greedy-family engines: for every row r of row-major `buf`,
/// `w = v·r; if w ≠ 0 { r ← r + sign·w·u }`, rows sharded across
/// `threads` workers. The per-row arithmetic is
/// [`crate::kernel::rank1_update_row`] dispatched by `kind` (every kind
/// is bit-identical — the SIMD lanes mirror the scalar partial sums).
/// `sign` is `-1.0` for the forward commit downdate and `+1.0` for
/// backward elimination's sign-flipped removal; the negation is exact
/// in IEEE 754, so both directions stay bit-identical to their fused
/// serial loops.
pub fn rank1_row_update(
    kind: crate::kernel::KernelKind,
    threads: usize,
    buf: &mut [f64],
    row_len: usize,
    v: &[f64],
    u: &[f64],
    sign: f64,
) {
    for_each_row_chunk(threads, buf, row_len, |_, chunk| {
        for row in chunk.chunks_exact_mut(row_len) {
            crate::kernel::rank1_update_row(kind, row, v, u, sign);
        }
    });
}

/// The per-row body of [`rank1_row_update`], evaluated in column tiles
/// of `tile` elements (a positive multiple of 4) via
/// [`crate::kernel::rank1_update_row_tiled`]: the dot pass carries its
/// four partial sums across tiles and the update pass walks the same
/// tiles elementwise. Both phases perform literally the serial
/// operation sequence per row, so results are bit-identical to the
/// untiled update for every tile width.
///
/// Exposed separately so the out-of-core store can run it inside its
/// own windowed row blocks (`MatrixStore::par_update_row_blocks`).
pub fn rank1_block_update(
    kind: crate::kernel::KernelKind,
    chunk: &mut [f64],
    row_len: usize,
    v: &[f64],
    u: &[f64],
    sign: f64,
    tile: usize,
) {
    debug_assert!(tile > 0 && tile % 4 == 0, "tile must be a multiple of 4");
    for row in chunk.chunks_exact_mut(row_len) {
        crate::kernel::rank1_update_row_tiled(kind, row, v, u, sign, tile);
    }
}

/// [`rank1_row_update`] with LLC column tiling: `tile == 0` falls back
/// to the untiled update, otherwise rows run through
/// [`rank1_block_update`]. Either way the result is bit-identical —
/// tiling only reorders memory traffic, never arithmetic.
#[allow(clippy::too_many_arguments)]
pub fn rank1_row_update_tiled(
    kind: crate::kernel::KernelKind,
    threads: usize,
    buf: &mut [f64],
    row_len: usize,
    v: &[f64],
    u: &[f64],
    sign: f64,
    tile: usize,
) {
    if tile == 0 {
        rank1_row_update(kind, threads, buf, row_len, v, u, sign);
        return;
    }
    for_each_row_chunk(threads, buf, row_len, |_, chunk| {
        rank1_block_update(kind, chunk, row_len, v, u, sign, tile);
    });
}

/// Mixed-precision twin of [`rank1_row_update`]: the same row sharding
/// over an **f32** cache, per-row arithmetic in
/// [`crate::kernel::f32c::rank1_update_row`] (compensated f64 dot, one
/// storage rounding per element). Scalar-only by the f32c contract —
/// there is no kernel-kind dispatch here.
pub fn rank1_row_update_f32c(
    threads: usize,
    buf: &mut [f32],
    row_len: usize,
    v: &[f64],
    u: &[f64],
    sign: f64,
) {
    for_each_row_chunk(threads, buf, row_len, |_, chunk| {
        for row in chunk.chunks_exact_mut(row_len) {
            crate::kernel::f32c::rank1_update_row(row, v, u, sign);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_partition(ranges: &[Range<usize>], len: usize) {
        let mut cursor = 0;
        for r in ranges {
            assert_eq!(r.start, cursor, "gap/overlap in {ranges:?}");
            assert!(r.end > r.start, "empty range in {ranges:?}");
            cursor = r.end;
        }
        assert_eq!(cursor, len, "ranges don't cover 0..{len}: {ranges:?}");
    }

    #[test]
    fn resolve_zero_is_auto() {
        assert_eq!(resolve(0), available());
        assert!(available() >= 1);
        assert_eq!(resolve(3), 3);
    }

    #[test]
    fn split_ranges_partitions_and_balances() {
        for len in 0..40 {
            for parts in 1..8 {
                let r = split_ranges(len, parts);
                assert_partition(&r, len);
                assert!(r.len() <= parts);
                if len > 0 {
                    let sizes: Vec<usize> =
                        r.iter().map(|x| x.end - x.start).collect();
                    let min = sizes.iter().min().unwrap();
                    let max = sizes.iter().max().unwrap();
                    assert!(max - min <= 1, "unbalanced {sizes:?}");
                }
            }
        }
    }

    /// The quad-sharding property the greedy scan's determinism rests on:
    /// every interior boundary sits on a multiple of 4, for every uneven
    /// (len, parts) combination.
    #[test]
    fn quad_ranges_never_split_a_quad() {
        for len in 0..50 {
            for parts in 1..8 {
                let r = quad_ranges(len, parts);
                assert_partition(&r, len);
                for w in r.windows(2) {
                    assert_eq!(
                        w[0].end % 4,
                        0,
                        "interior boundary off-quad: {r:?} (len={len})"
                    );
                }
            }
        }
    }

    #[test]
    fn quad_ranges_remainder_rides_last() {
        let r = quad_ranges(11, 2); // 2 quads + 3 remainder
        assert_eq!(r, vec![0..4, 4..11]);
        let r = quad_ranges(3, 4); // no full quad at all
        assert_eq!(r, vec![0..3]);
        assert!(quad_ranges(0, 3).is_empty());
    }

    #[test]
    fn par_map_matches_serial_for_any_thread_count() {
        let serial: Vec<u64> = (0..37).map(|i| (i as u64) * 3 + 1).collect();
        for t in [1, 2, 3, 4, 9] {
            let par = par_map(t, 37, |i| (i as u64) * 3 + 1);
            assert_eq!(par, serial, "threads={t}");
        }
        let empty: Vec<u64> = par_map(4, 0, |_| unreachable!());
        assert!(empty.is_empty());
    }

    #[test]
    fn map_ranges_preserves_order() {
        let ranges = split_ranges(10, 3);
        let got = map_ranges(&ranges, |r| r.start);
        assert_eq!(got, vec![0, 4, 7]);
    }

    #[test]
    fn row_chunks_cover_every_row_once() {
        for rows in [1usize, 2, 5, 8, 13] {
            for t in [1usize, 2, 3, 4] {
                let row_len = 3;
                let mut buf = vec![0.0; rows * row_len];
                for_each_row_chunk(t, &mut buf, row_len, |first, chunk| {
                    for (r, row) in chunk.chunks_exact(row_len).enumerate() {
                        let _ = row;
                        let idx = first + r;
                        assert!(idx < rows);
                    }
                    for v in chunk.iter() {
                        assert_eq!(*v, 0.0);
                    }
                });
                // now a mutating pass: row i gets value i+1 everywhere
                for_each_row_chunk(t, &mut buf, row_len, |first, chunk| {
                    for (r, row) in
                        chunk.chunks_exact_mut(row_len).enumerate()
                    {
                        for v in row {
                            *v += (first + r + 1) as f64;
                        }
                    }
                });
                for (i, row) in buf.chunks_exact(row_len).enumerate() {
                    for v in row {
                        assert_eq!(*v, (i + 1) as f64, "rows={rows} t={t}");
                    }
                }
            }
        }
    }

    #[test]
    fn rank1_row_update_matches_fused_serial_loop() {
        let (rows, m) = (7usize, 5usize);
        let v: Vec<f64> = (0..m).map(|j| 0.3 * j as f64 - 0.7).collect();
        let u: Vec<f64> = (0..m).map(|j| 1.0 / (j + 2) as f64).collect();
        let base: Vec<f64> =
            (0..rows * m).map(|i| (i as f64).sin()).collect();
        for sign in [-1.0, 1.0] {
            // reference: the fused serial loop the engines used before
            let mut want = base.clone();
            for row in want.chunks_exact_mut(m) {
                let w = crate::linalg::dot(&v, row);
                if w != 0.0 {
                    if sign < 0.0 {
                        for (r, &uj) in row.iter_mut().zip(&u) {
                            *r -= w * uj;
                        }
                    } else {
                        for (r, &uj) in row.iter_mut().zip(&u) {
                            *r += w * uj;
                        }
                    }
                }
            }
            for t in [1usize, 2, 3, 4] {
                let mut got = base.clone();
                rank1_row_update(
                    crate::kernel::KernelKind::active(),
                    t,
                    &mut got,
                    m,
                    &v,
                    &u,
                    sign,
                );
                for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "sign={sign} t={t} i={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn tiled_rank1_update_is_bit_identical() {
        let (rows, m) = (9usize, 23usize);
        let v: Vec<f64> = (0..m).map(|j| (j as f64 * 0.7).cos()).collect();
        let u: Vec<f64> = (0..m).map(|j| 1.0 / (j + 3) as f64).collect();
        let base: Vec<f64> =
            (0..rows * m).map(|i| (i as f64).sin()).collect();
        let kind = crate::kernel::KernelKind::active();
        for sign in [-1.0, 1.0] {
            let mut want = base.clone();
            rank1_row_update(kind, 1, &mut want, m, &v, &u, sign);
            for tile in [0usize, 4, 8, 16, 40] {
                for t in [1usize, 2, 4] {
                    let mut got = base.clone();
                    rank1_row_update_tiled(
                        kind, t, &mut got, m, &v, &u, sign, tile,
                    );
                    for (a, b) in want.iter().zip(&got) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "sign={sign} tile={tile} t={t}"
                        );
                    }
                }
            }
        }
    }

    /// The f32 cache downdate must be thread-count independent exactly
    /// like the f64 one: disjoint rows, identical per-row arithmetic.
    #[test]
    fn f32c_rank1_update_matches_serial_at_any_thread_count() {
        let (rows, m) = (7usize, 13usize);
        let v: Vec<f64> = (0..m).map(|j| (j as f64 * 0.9).sin()).collect();
        let u: Vec<f64> = (0..m).map(|j| 1.0 / (j + 2) as f64).collect();
        let base: Vec<f32> =
            (0..rows * m).map(|i| (i as f32 * 0.31).cos()).collect();
        let mut want = base.clone();
        rank1_row_update_f32c(1, &mut want, m, &v, &u, -1.0);
        for t in [2usize, 3, 4] {
            let mut got = base.clone();
            rank1_row_update_f32c(t, &mut got, m, &v, &u, -1.0);
            assert_eq!(want, got, "threads={t}");
        }
    }

    #[test]
    fn worker_panics_propagate() {
        let caught = std::panic::catch_unwind(|| {
            par_map(4, 8, |i| {
                if i == 6 {
                    panic!("boom");
                }
                i
            })
        });
        assert!(caught.is_err());
    }
}
