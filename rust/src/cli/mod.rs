//! Command-line interface (hand-rolled; `clap` unavailable offline).
//!
//! Flag conventions: `--name value` or `--name=value`; `--flag` with no
//! value is boolean true. The first non-flag token is the subcommand.

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context};

use crate::select::StopPolicy;

/// Parsed command line: subcommand + flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Subcommand (first positional token).
    pub command: Option<String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding `argv[0]`).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // value style: `--k 5` unless next token is a flag
                    match it.next_if(|next| !next.starts_with("--")) {
                        Some(v) => {
                            out.flags.insert(stripped.to_string(), v);
                        }
                        None => {
                            out.flags
                                .insert(stripped.to_string(), "true".into());
                        }
                    }
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Raw flag lookup.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Boolean flag (present and not "false").
    pub fn has(&self, name: &str) -> bool {
        matches!(self.get(name), Some(v) if v != "false")
    }

    /// Typed flag with default.
    pub fn get_or<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} {v:?}: {e}")),
        }
    }

    /// Required typed flag.
    pub fn require<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let v = self
            .get(name)
            .with_context(|| format!("missing required flag --{name}"))?;
        v.parse().map_err(|e| anyhow!("--{name} {v:?}: {e}"))
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }
}

/// Parse the session stopping flags into a [`StopPolicy`].
///
/// `--stop k|plateau|time` selects the policy explicitly; without it,
/// `--patience`/`--time-budget-s` imply `plateau`/`time` respectively and
/// the default is `k` (run to `--k` features). Plateau reads
/// `--patience` (default 2) and `--min-rel-improvement` (default 1e-3);
/// time reads `--time-budget-s` (seconds, fractional allowed).
pub fn parse_stop_policy(args: &Args) -> anyhow::Result<StopPolicy> {
    let mode = match args.get("stop") {
        Some(m) => m.to_string(),
        None if args.get("time-budget-s").is_some() => "time".into(),
        None if args.get("patience").is_some()
            || args.get("min-rel-improvement").is_some() =>
        {
            "plateau".into()
        }
        None => "k".into(),
    };
    // reject flags the selected mode would silently ignore
    if mode != "plateau" {
        for flag in ["patience", "min-rel-improvement"] {
            ensure!(
                args.get(flag).is_none(),
                "--{flag} requires --stop plateau (got --stop {mode})"
            );
        }
    }
    if mode != "time" {
        ensure!(
            args.get("time-budget-s").is_none(),
            "--time-budget-s requires --stop time (got --stop {mode})"
        );
    }
    match mode.as_str() {
        "k" => Ok(StopPolicy::KBudget(usize::MAX)),
        "plateau" => {
            let patience: usize = args.get_or("patience", 2usize)?;
            let min_rel: f64 =
                args.get_or("min-rel-improvement", 1e-3f64)?;
            let policy = StopPolicy::Plateau {
                patience,
                min_rel_improvement: min_rel,
            };
            policy.validate()?;
            Ok(policy)
        }
        "time" => {
            let secs: f64 = args.require("time-budget-s")?;
            ensure!(
                secs.is_finite() && secs >= 0.0,
                "--time-budget-s must be ≥ 0"
            );
            Ok(StopPolicy::TimeBudget(Duration::from_secs_f64(secs)))
        }
        other => bail!("unknown --stop {other:?} (expected k|plateau|time)"),
    }
}

/// Usage text shared by `--help` and error paths.
pub const USAGE: &str = "\
greedy-rls — linear-time greedy forward feature selection for RLS
(Pahikkala, Airola, Salakoski 2010), three-layer Rust + JAX + Pallas.

USAGE: greedy-rls <command> [flags]

COMMANDS
  select     run greedy RLS on a dataset, print/save the sparse model
             --dataset NAME | --synthetic M,N   --k K  [--lambda L]
             [--loss 01|squared] [--engine native|pjrt] [--out FILE]
             [--seed S] [--full] [--threads T] [--precision f64|f32c]
             session control: [--stop k|plateau|time] [--patience N]
             [--min-rel-improvement F] [--time-budget-s S]
             [--warm-start I1,I2,...] [--progress]
             sketched preselection: [--preselect P] [--sketch-dim D]
             (filter to the top-P approximate ridge leverage scores,
             then run exact greedy on the survivors; D=0 scores
             exactly, D>0 scores through a seeded random projection;
             greedy engine only, p >= n is a no-op identity filter)
             data backend: [--backend ram|mmap] [--tile-cols C]
             [--window-mb MB] [--chunk-mb MB] [--scratch DIR]  (mmap
             streams X and the greedy cache through bounded windows so
             selection runs on datasets larger than RAM, bit-identical
             to the ram backend; greedy engine only)
             durability: [--checkpoint-dir DIR] [--checkpoint-every N]
             [--resume]  (a killed run resumes bit-identically from its
             latest checkpoint; --resume with an empty DIR starts fresh;
             checkpoints interchange between backends)
  cv         paper §4.2 protocol: stratified CV accuracy curves
             --dataset NAME [--folds 10] [--kmax K] [--seed S] [--full]
             [--threads T] [--engine native|pjrt] [--tile-cols C]
             [--preselect P] [--sketch-dim D]  (filters the greedy
             sessions only; fixed-order baselines stay unfiltered)
             [--checkpoint-dir DIR]  (fold-level resume)
             sweep stopping: [--stop k|plateau|time] [--patience N]
             [--min-rel-improvement F] [--time-budget-s S]  (one wall
             clock budget caps the whole sweep; time stops truncate
             curves, never reorder them, and are not resumable)
  scaling    paper §4.1 runtime scaling experiment
             [--sizes 500,1000,...] [--n 1000] [--k 50] [--baseline]
             [--threads T] [--backend ram|mmap] [--tile-cols C]
             [--window-mb MB] [--chunk-mb MB] [--scratch DIR]
             [--json FILE]  (mmap rows measure the out-of-core path;
             --json writes one JSON row per size for the bench harness)
  serve      batched predictions with a saved model, or hot-swap serving
             that follows a live session's checkpoint directory
             --model FILE --dataset NAME [--batch 64] [--engine native|pjrt]
             --follow DIR --dataset NAME [--batch 64] [--passes P]
             [--poll-ms MS] [--wait-s S]  (swaps to each newer checkpoint
             between batches; in-flight batches always complete)
             --bus  alias for train-serve: train and serve in one
             process over the in-memory model bus (no disk on the path)
             fabric worker: --listen ADDR --connect ADDR [--follow DIR]
             [--heartbeat-ms MS] [--serve-threads W] [--queue-depth Q]
             [--wait-s S]  (answers queries over the socket, hot-swaps
             models pushed by a train-serve --publish trainer, falls
             back to the checkpoint trail when the socket is down;
             ADDR is unix:/path or tcp:host:port)
  train-serve  run selection and serve it at the same time: every
             committed round is published on an in-process bus and
             hot-swapped into N serve workers the instant it commits;
             prints per-version latency percentiles and a final
             deterministic pass served by the finished model
             --dataset NAME | --synthetic M,N  --k K  [--lambda L]
             [--loss 01|squared] [--engine native|pjrt] [--threads T]
             [--precision f64|f32c] [--serve-threads W] [--batch 64]
             [--queue-depth Q] [--out FILE] [--progress]
             session control + durability: same --stop family,
             --warm-start, --checkpoint-dir/--checkpoint-every/--resume
             flags as select (a version reaches the bus only after its
             checkpoint is on disk, so kill + --resume stays exact)
             fabric: [--publish ADDR] [--heartbeat-ms MS]  (bridge the
             bus onto a socket; remote serve --connect workers follow)
  fleet      spawn one train-serve trainer + N serve --listen workers
             over the fabric, drive load at every worker, optionally
             SIGKILL one mid-stream, and verify all workers end up
             serving the byte-identical final model
             --dataset NAME | --synthetic M,N  --k K  [--seed S]
             [--servers 2] [--kill-one] [--scratch DIR] [--queries Q]
             [--batch 16] [--heartbeat-ms MS]
  compare    quality-vs-time frontier: every selection algorithm on one
             dataset side by side, one row per selector with wall-clock,
             per-round time, rounds, scan-op count, final criterion, and
             held-out accuracy
             --dataset NAME | --synthetic M,N  [--k 5] [--lambda 1.0]
             [--loss 01|squared] [--seed S] [--threads T]
             [--engine native|pjrt] [--json FILE]  (writes the frontier
             rows as a JSON array)
             [--preselect P] [--sketch-dim D]  (sizes the
             sketched-greedy row; default keeps half the features)
             same --stop family as select: a zero budget still emits a
             well-formed row per selector (pjrt compares the
             artifact-backed selectors: greedy, foba, nfold, backward,
             floating; sketched-greedy and dropping-foba are
             native-only)
  datasets   print the benchmark registry (paper Table 1)
  check      verify artifacts: compile all buckets, cross-check every
             artifact-backed selector (greedy, backward, nfold, foba,
             floating) against its native engine on a probe problem
             [--artifacts DIR]  (defaults to ./artifacts)
  help       this text

--threads T sizes the deterministic parallel execution layer for the
O(mn) per-round scans and cache updates (0 = all hardware threads, the
default; 1 = serial). Selected features, criterion curves, and weights
are bit-identical at every thread count — only the wall-clock changes.

--precision f32c stores the greedy scan cache in f32 (halving its
memory traffic) while accumulating in compensated f64; selections are
deterministic per run but follow a different — tolerance-gated —
trajectory than the default f64, so checkpoints never interchange
across precisions. greedy selector, native engine, ram backend only.

--backend mmap keeps X and the greedy cache in mmap-backed scratch
files, streamed through per-worker windows of --window-mb MiB (default
256), scanning in tiles of --tile-cols columns (0 = auto-sized to the
LLC);
--chunk-mb bounds loader/generator staging (default 8) and --scratch
picks the scratch directory (default: the system temp dir). Results are
bit-identical to --backend ram at every window, tile, and thread
setting — see ARCHITECTURE.md §Data backends.

Artifacts: run `make artifacts` once; the binary never invokes Python.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["select", "--k", "5", "--dataset", "adult"]);
        assert_eq!(a.command.as_deref(), Some("select"));
        assert_eq!(a.get("k"), Some("5"));
        assert_eq!(a.get("dataset"), Some("adult"));
    }

    #[test]
    fn equals_style() {
        let a = parse(&["cv", "--folds=10", "--kmax=20"]);
        assert_eq!(a.get_or("folds", 0usize).unwrap(), 10);
        assert_eq!(a.get_or("kmax", 0usize).unwrap(), 20);
    }

    #[test]
    fn boolean_flags() {
        let a = parse(&["scaling", "--baseline", "--n", "100"]);
        assert!(a.has("baseline"));
        assert!(!a.has("full"));
        assert_eq!(a.get_or("n", 0usize).unwrap(), 100);
    }

    #[test]
    fn negative_number_values() {
        // `--exp -3` — the value starts with '-' but not '--'
        let a = parse(&["x", "--exp", "-3"]);
        assert_eq!(a.get_or("exp", 0i32).unwrap(), -3);
    }

    #[test]
    fn typed_errors_are_reported() {
        let a = parse(&["x", "--k", "banana"]);
        assert!(a.get_or("k", 1usize).is_err());
        assert!(a.require::<usize>("missing").is_err());
    }

    #[test]
    fn list_flag() {
        let a = parse(&["x", "--sizes", "500,1000, 2000"]);
        assert_eq!(
            a.get_list("sizes").unwrap(),
            vec!["500", "1000", "2000"]
        );
    }

    #[test]
    fn positionals_collected() {
        let a = parse(&["cmd", "pos1", "--f", "v", "pos2"]);
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn stop_policy_default_runs_to_k() {
        let a = parse(&["select", "--k", "5"]);
        assert_eq!(
            parse_stop_policy(&a).unwrap(),
            StopPolicy::KBudget(usize::MAX)
        );
    }

    #[test]
    fn stop_policy_plateau_with_flags() {
        let a = parse(&[
            "select",
            "--stop",
            "plateau",
            "--patience",
            "4",
            "--min-rel-improvement",
            "0.01",
        ]);
        assert_eq!(
            parse_stop_policy(&a).unwrap(),
            StopPolicy::Plateau { patience: 4, min_rel_improvement: 0.01 }
        );
        // --patience alone implies plateau
        let a = parse(&["select", "--patience", "3"]);
        assert_eq!(
            parse_stop_policy(&a).unwrap(),
            StopPolicy::Plateau { patience: 3, min_rel_improvement: 1e-3 }
        );
    }

    #[test]
    fn stop_policy_time_budget() {
        let a = parse(&["select", "--stop", "time", "--time-budget-s", "2.5"]);
        assert_eq!(
            parse_stop_policy(&a).unwrap(),
            StopPolicy::TimeBudget(Duration::from_secs_f64(2.5))
        );
        // --time-budget-s alone implies time
        let a = parse(&["select", "--time-budget-s", "1"]);
        assert_eq!(
            parse_stop_policy(&a).unwrap(),
            StopPolicy::TimeBudget(Duration::from_secs(1))
        );
        // time mode without a budget is an error
        let a = parse(&["select", "--stop", "time"]);
        assert!(parse_stop_policy(&a).is_err());
    }

    #[test]
    fn stop_policy_rejects_garbage() {
        let a = parse(&["select", "--stop", "banana"]);
        assert!(parse_stop_policy(&a).is_err());
        let a = parse(&["select", "--stop", "plateau", "--patience", "0"]);
        assert!(parse_stop_policy(&a).is_err());
        let a = parse(&["select", "--stop", "time", "--time-budget-s", "-1"]);
        assert!(parse_stop_policy(&a).is_err());
    }

    #[test]
    fn stop_policy_rejects_conflicting_flags() {
        // flags the chosen mode would silently ignore are errors
        let a = parse(&["select", "--stop", "k", "--patience", "3"]);
        assert!(parse_stop_policy(&a).is_err());
        let a =
            parse(&["select", "--stop", "plateau", "--time-budget-s", "5"]);
        assert!(parse_stop_policy(&a).is_err());
        let a = parse(&["select", "--stop", "time", "--time-budget-s", "5"]);
        assert!(parse_stop_policy(&a).is_ok());
    }
}
