//! Measurement harness for the paper-figure benches.
//!
//! `criterion` is not in the offline crate cache, so `rust/benches/*`
//! (built with `harness = false`) use this module instead: warmup,
//! repeated timing, robust summary statistics, and aligned table / CSV
//! emission so each bench prints the same rows/series as the paper's
//! figures.

use std::time::{Duration, Instant};

use crate::select::{Observer, Round, StopReason};

/// Timing summary over repetitions.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    /// Median seconds per run.
    pub median_s: f64,
    /// Minimum seconds per run.
    pub min_s: f64,
    /// Mean seconds per run.
    pub mean_s: f64,
    /// Sample standard deviation.
    pub std_s: f64,
    /// Number of measured repetitions.
    pub reps: usize,
}

/// Time `f` with `warmup` unmeasured runs followed by `reps` measured ones.
pub fn time<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Sample {
    assert!(reps >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        // xtask-allow: no-raw-instant -- measurement harness: this module
        // *is* the bench clock; the session clock only covers selection.
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    summarize(&times)
}

/// Time a single run (large workloads where repetition is unaffordable).
pub fn time_once<F: FnOnce()>(f: F) -> f64 {
    // xtask-allow: no-raw-instant -- measurement harness (see `time`).
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// [`Observer`] that records per-round wall time and the criterion
/// trajectory of a selection session — the figure benches get per-round
/// numbers from a single run instead of re-running the selection at
/// every k.
#[derive(Clone, Debug, Default)]
pub struct TimingObserver {
    /// Seconds each round took, in round order.
    pub per_round_s: Vec<f64>,
    /// Feature committed each round.
    pub features: Vec<usize>,
    /// Criterion value each round.
    pub criteria: Vec<f64>,
    /// Stop reason, once the drive loop finished.
    pub stop: Option<StopReason>,
}

impl TimingObserver {
    /// Total time across observed rounds (excludes `begin` setup).
    pub fn total_s(&self) -> f64 {
        self.per_round_s.iter().sum()
    }
}

impl Observer for TimingObserver {
    fn on_round(&mut self, _index: usize, round: &Round, elapsed: Duration) {
        self.per_round_s.push(elapsed.as_secs_f64());
        self.features.push(round.feature);
        self.criteria.push(round.criterion);
    }

    fn on_stop(&mut self, reason: StopReason) {
        self.stop = Some(reason);
    }
}

fn summarize(times: &[f64]) -> Sample {
    let mut sorted = times.to_vec();
    sorted.sort_by(f64::total_cmp);
    let reps = sorted.len();
    let median_s = if reps % 2 == 1 {
        sorted[reps / 2]
    } else {
        0.5 * (sorted[reps / 2 - 1] + sorted[reps / 2])
    };
    let mean = sorted.iter().sum::<f64>() / reps as f64;
    let std = if reps > 1 {
        (sorted.iter().map(|t| (t - mean).powi(2)).sum::<f64>()
            / (reps - 1) as f64)
            .sqrt()
    } else {
        0.0
    };
    Sample { median_s, min_s: sorted[0], mean_s: mean, std_s: std, reps }
}

/// A long-format results table (one row per measured configuration)
/// printed both human-readable and as CSV for downstream plotting.
pub struct Table {
    title: String,
    columns: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (stringified cells).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.columns.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: format common cell types.
    pub fn cells(parts: &[CellValue]) -> Vec<String> {
        parts.iter().map(|c| c.render()).collect()
    }

    /// Print the aligned table followed by a CSV block.
    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut widths: Vec<usize> =
            self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let header: Vec<String> = self
            .columns
            .iter()
            .zip(&widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect();
        println!("{}", header.join("  "));
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect();
            println!("{}", line.join("  "));
        }
        println!("\n-- CSV: {} --", self.title);
        println!("{}", self.columns.join(","));
        for row in &self.rows {
            println!("{}", row.join(","));
        }
    }

    /// Write the CSV block to a file under `bench_results/`.
    pub fn write_csv(&self, stem: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all("bench_results")?;
        let path = std::path::Path::new("bench_results")
            .join(format!("{stem}.csv"));
        let mut out = String::new();
        out.push_str(&self.columns.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        std::fs::write(&path, out)?;
        Ok(path)
    }
}

/// Typed cell values with sensible default formatting.
pub enum CellValue {
    /// Signed integer, plain decimal.
    Int(i64),
    /// Unsigned size, plain decimal.
    Usize(usize),
    /// Float at 3 decimal places (timings in seconds).
    F3(f64),
    /// Float at 6 decimal places (per-batch latencies, rates).
    F6(f64),
    /// Preformatted string, verbatim.
    Str(String),
}

impl CellValue {
    fn render(&self) -> String {
        match self {
            CellValue::Int(v) => v.to_string(),
            CellValue::Usize(v) => v.to_string(),
            CellValue::F3(v) => format!("{v:.3}"),
            CellValue::F6(v) => format!("{v:.6}"),
            CellValue::Str(s) => s.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reports_positive_durations() {
        let s = time(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.median_s >= 0.0);
        assert!(s.min_s <= s.median_s);
        assert_eq!(s.reps, 5);
    }

    #[test]
    fn timing_observer_records_rounds() {
        use crate::select::{
            drive, greedy::GreedyRls, SelectionConfig, SessionSelector,
        };
        let ds = crate::data::synthetic::two_gaussians(40, 10, 3, 1.0, 1);
        let cfg = SelectionConfig::builder().k(4).build();
        let mut s = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        let mut obs = TimingObserver::default();
        drive(s.as_mut(), &mut obs).unwrap();
        assert_eq!(obs.per_round_s.len(), 4);
        assert_eq!(obs.features.len(), 4);
        assert_eq!(obs.criteria.len(), 4);
        assert_eq!(obs.stop, Some(StopReason::TargetReached));
        assert!(obs.total_s() >= 0.0);
    }

    #[test]
    fn summarize_median_even_odd() {
        let s = summarize(&[3.0, 1.0, 2.0]);
        assert_eq!(s.median_s, 2.0);
        let s = summarize(&[4.0, 1.0, 2.0, 3.0]);
        assert_eq!(s.median_s, 2.5);
    }

    #[test]
    fn table_rejects_bad_row() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(&["only-one".into()])
        }));
        assert!(r.is_err());
    }

    #[test]
    fn cells_render() {
        let cells = Table::cells(&[
            CellValue::Int(-3),
            CellValue::Usize(7),
            CellValue::F3(1.23456),
            CellValue::Str("x".into()),
        ]);
        assert_eq!(cells, vec!["-3", "7", "1.235", "x"]);
    }
}
