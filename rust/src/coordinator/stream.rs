//! In-process streaming serve pipeline: session → bus → hot-swap server.
//!
//! `serve --follow` (PR 3) ships models from a live session to a server
//! through the filesystem: the session autosaves `ckpt-*.ckpt` files and
//! a [`CheckpointFollower`] polls the directory. This module is the
//! in-memory counterpart the ROADMAP flags as the natural follow-up. The
//! paper's O(mn)-per-round bound is exactly what makes it worthwhile:
//! every committed round is cheap, and every committed round *is* a
//! servable sparse predictor (cf. the Dropping Forward-Backward line of
//! work on mid-run models), so rounds should reach the server the
//! instant they commit — no disk, no polling latency.
//!
//! The pieces:
//!
//! * [`ModelBus`] — a single-slot, latest-wins publish/subscribe channel
//!   (a `Mutex<Arc<ModelVersion>>` slot plus a `Condvar`; no new
//!   dependencies). Publishing is O(1) and never waits on subscribers;
//!   subscribers coalesce — a slow reader skips straight to the newest
//!   version instead of back-pressuring the trainer.
//! * [`PublishObserver`] — the [`StateObserver`] that publishes the
//!   session's current model after every committed round and on stop,
//!   then closes the bus. It composes with the checkpoint [`Autosaver`]
//!   through [`drive_tapped`]'s ordered taps.
//! * [`BusFollower`] — the subscriber handle, mirroring
//!   [`CheckpointFollower`]'s API ([`BusFollower::poll`],
//!   [`BusFollower::wait_for_model`]) plus the blocking
//!   [`BusFollower::wait_newer`]. It implements [`ModelSource`], so
//!   [`crate::coordinator::serve::serve_hotswap`] runs unchanged over
//!   the bus, and hot swaps keep the checkpoint path's guarantee:
//!   in-flight batches always complete on the model they started with.
//! * [`train_serve`] — the end-to-end pipeline behind the `train-serve`
//!   CLI subcommand (and `serve --bus`): selection runs on the calling
//!   thread, a swapper thread applies bus versions to a
//!   [`HotSwapServer`], and N worker threads answer query batches pulled
//!   from a **bounded** job queue (`std::sync::mpsc::sync_channel`). The
//!   bounded queue is the backpressure boundary — the batch feeder
//!   blocks when workers fall behind — while serving never waits on the
//!   trainer: workers always answer with the newest swapped-in model.
//!   After training stops, one final pass is served entirely by the
//!   final model; that half is deterministic and is what the end-to-end
//!   tests compare bit-for-bit against `serve --follow`.
//!
//! # Crash consistency: publish-after-save
//!
//! The bus composes with durable checkpoints, and ordering is part of
//! the contract: [`train_serve`] installs its taps as
//! `[&mut autosaver, &mut publisher]` **and hands the publisher the
//! saver's own policy** ([`PublishObserver::with_policy`] — both run
//! the same [`PolicyTicker`] state machine), so for any published
//! version, its round's checkpoint is durable on disk before the bus
//! announces it — at every `--checkpoint-every` and on-stop setting,
//! not just the defaults. A process killed at any instant therefore
//! never served a model that its checkpoint trail cannot reproduce:
//! `--resume` replays to a state at least as advanced as anything a
//! subscriber ever saw, and continues bit-identically (the kill/resume
//! gauntlet runs a `train-serve` leg to prove it). The reverse order
//! would open a window where version `r` answers queries, the process
//! dies, and the resumed run re-derives round `r` from a trail that
//! ends at `r−1` — harmless for greedy RLS only by accident of
//! determinism, and wrong the moment a stop policy depends on
//! wall-clock.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure};

use super::serve::{
    percentile, summarize, CheckpointFollower, HotSwapServer, ModelSource,
    ModelUpdate, ModelVersion, ServeStats,
};
use crate::linalg::Matrix;
use crate::rls::Predictor;
use crate::select::checkpoint::{AutosavePolicy, Autosaver, PolicyTicker};
use crate::select::session::{
    drive_tapped, Observer, Session, StateObserver, StopReason,
};
use crate::select::{Round, SelectionResult};

// ---------------------------------------------------------------------------
// The bus
// ---------------------------------------------------------------------------

/// Shared slot behind a [`ModelBus`] and its followers.
struct BusInner {
    latest: Option<Arc<ModelVersion>>,
    published: u64,
    closed: bool,
}

/// Single-slot, latest-wins in-process model bus.
///
/// The publisher side of the streaming serve pipeline: each
/// [`ModelBus::publish`] replaces the slot with a new
/// [`ModelVersion`] and wakes every waiting [`BusFollower`]. Versions
/// are monotone; followers that fall behind observe only the newest
/// version (serving wants the best model now, not a replay of history —
/// the full trajectory is the checkpoint trail's job). Publishing never
/// blocks on subscribers, so a slow reader cannot stall training.
///
/// ```
/// use greedy_rls::coordinator::stream::ModelBus;
/// use greedy_rls::rls::Predictor;
///
/// let bus = ModelBus::new();
/// let mut follower = bus.follower();
/// assert!(follower.poll().is_none());
/// bus.publish(Predictor { selected: vec![3], weights: vec![0.5] }, 1);
/// bus.publish(Predictor { selected: vec![3, 0], weights: vec![0.4, 0.1] }, 2);
/// let v = follower.poll().expect("newest version");
/// assert_eq!((v.version, v.rounds), (2, 2)); // latest wins, v1 skipped
/// assert!(follower.poll().is_none());        // nothing newer yet
/// bus.close();
/// ```
pub struct ModelBus {
    shared: Arc<(Mutex<BusInner>, Condvar)>,
}

impl Default for ModelBus {
    fn default() -> Self {
        ModelBus::new()
    }
}

/// Cloning shares the underlying bus (one more handle on the same
/// versions, not a new bus) — this is what lets the socket publisher's
/// accept loop mint a [`BusFollower`] per connection from another
/// thread. Close remains idempotent and observed by every handle.
impl Clone for ModelBus {
    fn clone(&self) -> ModelBus {
        ModelBus { shared: self.shared.clone() }
    }
}

impl ModelBus {
    /// An open bus with nothing published yet.
    pub fn new() -> ModelBus {
        ModelBus {
            shared: Arc::new((
                Mutex::new(BusInner {
                    latest: None,
                    published: 0,
                    closed: false,
                }),
                Condvar::new(),
            )),
        }
    }

    /// Publish `predictor` (trained through `rounds` rounds) as the next
    /// version; returns the version number (1 for the first publish).
    /// O(1); never waits on subscribers.
    ///
    /// # Panics
    ///
    /// Panics if the bus has been [`ModelBus::close`]d — publishing
    /// after close is a pipeline-ordering bug, not a runtime condition.
    pub fn publish(&self, predictor: Predictor, rounds: usize) -> u64 {
        let (lock, cvar) = &*self.shared;
        // Lock-poison recovery throughout the bus: every critical
        // section is a couple of field assignments with no intermediate
        // state a panicking holder could expose, so continuing with the
        // recovered guard is sound — and a serving worker must not be
        // torn down because an unrelated thread panicked.
        let mut inner =
            lock.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        assert!(!inner.closed, "publish on a closed ModelBus");
        let version = inner.published + 1;
        inner.published = version;
        inner.latest =
            Some(Arc::new(ModelVersion { predictor, version, rounds }));
        cvar.notify_all();
        version
    }

    /// Close the bus: followers drain the final version (if they have
    /// not yet seen it) and then observe [`BusWait::Closed`]. Idempotent.
    pub fn close(&self) {
        let (lock, cvar) = &*self.shared;
        lock.lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .closed = true;
        cvar.notify_all();
    }

    /// Whether [`ModelBus::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.shared
            .0
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .closed
    }

    /// Versions published so far.
    pub fn published(&self) -> u64 {
        self.shared
            .0
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .published
    }

    /// A new subscriber that has seen nothing yet: its first
    /// [`BusFollower::poll`] reports the current latest version, if any.
    pub fn follower(&self) -> BusFollower {
        BusFollower { shared: self.shared.clone(), last_version: 0 }
    }
}

/// Outcome of [`BusFollower::wait_newer`].
#[derive(Clone, Debug)]
pub enum BusWait {
    /// A version newer than the follower's last-reported one.
    Newer(Arc<ModelVersion>),
    /// The bus is closed and this follower has drained every version.
    Closed,
    /// The timeout expired with nothing newer published.
    TimedOut,
}

/// Subscriber handle on a [`ModelBus`] — the in-memory mirror of a
/// [`CheckpointFollower`] — `poll` for something newer, or block in
/// [`BusFollower::wait_for_model`] until the first servable model
/// arrives. Implements [`ModelSource`], so
/// [`crate::coordinator::serve::serve_hotswap`] serves from a bus
/// exactly as it serves from a checkpoint directory.
pub struct BusFollower {
    shared: Arc<(Mutex<BusInner>, Condvar)>,
    last_version: u64,
}

impl BusFollower {
    /// Non-blocking: the newest version strictly newer than the last one
    /// this follower reported, or `None`. Latest-wins — intermediate
    /// versions published since the last poll are skipped, mirroring how
    /// [`CheckpointFollower::poll`] reports only the most advanced
    /// checkpoint.
    pub fn poll(&mut self) -> Option<Arc<ModelVersion>> {
        let inner = self
            .shared
            .0
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        match &inner.latest {
            Some(v) if v.version > self.last_version => {
                self.last_version = v.version;
                Some(v.clone())
            }
            _ => None,
        }
    }

    /// Block until a version newer than the last-reported one is
    /// published, the bus closes (with nothing newer left to drain), or
    /// `timeout` expires — whichever comes first. The close case is what
    /// lets a swapper loop terminate deterministically once training
    /// stops. A `timeout` too large to represent as a deadline (e.g.
    /// `Duration::MAX`) means "no timeout": wait for a publish or close.
    pub fn wait_newer(&mut self, timeout: Duration) -> BusWait {
        // None = unrepresentable deadline = wait indefinitely
        // xtask-allow: no-raw-instant -- condvar wait-deadline anchor;
        // wall-clock by nature, unrelated to session time accounting
        let deadline = Instant::now().checked_add(timeout);
        let (lock, cvar) = &*self.shared;
        let mut inner =
            lock.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        loop {
            if let Some(v) = &inner.latest {
                if v.version > self.last_version {
                    self.last_version = v.version;
                    return BusWait::Newer(v.clone());
                }
            }
            if inner.closed {
                return BusWait::Closed;
            }
            inner = match deadline {
                Some(deadline) => {
                    // xtask-allow: no-raw-instant -- remaining-wait
                    // computation against the condvar deadline above
                    let now = Instant::now();
                    if now >= deadline {
                        return BusWait::TimedOut;
                    }
                    cvar.wait_timeout(inner, deadline - now)
                        .unwrap_or_else(std::sync::PoisonError::into_inner)
                        .0
                }
                None => cvar
                    .wait(inner)
                    .unwrap_or_else(std::sync::PoisonError::into_inner),
            };
        }
    }

    /// Block until the bus offers a version with a non-empty model (a
    /// 0-round model has nothing to serve) — the bus counterpart of
    /// [`CheckpointFollower::wait_for_model`]. No poll interval: the
    /// condvar wakes the caller the instant a publish lands. Errors if
    /// `timeout` expires first, or if the bus closes without ever having
    /// published a servable model.
    pub fn wait_for_model(
        &mut self,
        timeout: Duration,
    ) -> anyhow::Result<Arc<ModelVersion>> {
        // xtask-allow: no-raw-instant -- wait-timeout deadline anchor,
        // same contract as wait_newer
        let deadline = Instant::now().checked_add(timeout);
        loop {
            // an unrepresentable deadline means wait indefinitely
            let left = match deadline {
                // xtask-allow: no-raw-instant -- remaining-wait
                // computation against the deadline anchor above
                Some(d) => d.saturating_duration_since(Instant::now()),
                None => Duration::MAX,
            };
            match self.wait_newer(left) {
                BusWait::Newer(v) if !v.predictor.selected.is_empty() => {
                    return Ok(v)
                }
                BusWait::Newer(_) => continue,
                BusWait::Closed => {
                    bail!("bus closed before a servable model was published")
                }
                BusWait::TimedOut => bail!(
                    "no servable model appeared on the bus within {:.1}s",
                    timeout.as_secs_f64()
                ),
            }
        }
    }
}

impl ModelSource for BusFollower {
    fn poll_model(&mut self) -> anyhow::Result<Option<ModelUpdate>> {
        Ok(self.poll().map(|v| ModelUpdate {
            predictor: v.predictor.clone(),
            rounds: v.rounds,
            // in-process: publisher and server share the dataset by
            // construction, there is no fingerprint to re-check
            data_hash: None,
        }))
    }
}

// ---------------------------------------------------------------------------
// The publisher tap
// ---------------------------------------------------------------------------

/// [`StateObserver`] that publishes the session's current model on a
/// [`ModelBus`] — by default after every committed round and once more
/// on stop (the final version) — then closes the bus when the session
/// stops, so subscribers terminate.
///
/// Compose it with an [`Autosaver`] via
/// [`drive_tapped`]`(session, obs, &mut [&mut saver, &mut publisher])` —
/// tap order **is** the publish-after-save contract (see the
/// [module docs](self)). When the run is checkpointed, construct with
/// [`PublishObserver::with_policy`] handing over the saver's own
/// [`AutosavePolicy`]: both run the same [`PolicyTicker`] state
/// machine, so a publish can only ever fire in a flush cycle where the
/// matching checkpoint write just fired — no version is announced whose
/// round has no durable checkpoint. [`train_serve`] wires this up
/// automatically.
pub struct PublishObserver<'b> {
    bus: &'b ModelBus,
    /// Shared firing rule — the identical state machine [`Autosaver`]
    /// runs, so alignment is by construction, not by parallel code.
    ticker: PolicyTicker,
    stopped: bool,
    /// Dedupe key of the last publish (rounds, stop reason), mirroring
    /// the [`Autosaver`] rule — the on-stop publish is not deduped
    /// against the same round's mid-run publish, so subscribers always
    /// see a final version once the session has stopped.
    last_published: Option<(usize, Option<StopReason>)>,
    /// Versions this observer has published (monotone; for logs/tests).
    pub published: u64,
}

impl<'b> PublishObserver<'b> {
    /// Publish onto `bus` after every committed round and on stop (the
    /// default [`AutosavePolicy`]); the bus is closed when the session
    /// stops.
    pub fn new(bus: &'b ModelBus) -> PublishObserver<'b> {
        PublishObserver::with_policy(bus, AutosavePolicy::default())
    }

    /// Publish on `policy`'s cadence (every N committed rounds, on
    /// stop). Hand this the checkpoint [`Autosaver`]'s own policy and
    /// the publish-after-save ordering holds at **any** checkpoint
    /// interval and on-stop setting, not just the defaults. Whatever
    /// the policy, the bus is still closed once the session stops.
    pub fn with_policy(
        bus: &'b ModelBus,
        policy: AutosavePolicy,
    ) -> PublishObserver<'b> {
        PublishObserver {
            bus,
            ticker: PolicyTicker::new(policy),
            stopped: false,
            last_published: None,
            published: 0,
        }
    }
}

impl Observer for PublishObserver<'_> {
    fn on_round(&mut self, _index: usize, _round: &Round, _e: Duration) {
        self.ticker.on_round();
    }

    fn on_stop(&mut self, _reason: StopReason) {
        self.ticker.on_stop();
        self.stopped = true;
    }
}

impl StateObserver for PublishObserver<'_> {
    fn flush(&mut self, session: &(dyn Session + '_)) -> anyhow::Result<()> {
        if self.ticker.take_due() {
            let key = (session.rounds_done(), session.stop_reason());
            if self.last_published != Some(key) {
                let st = session.state()?;
                self.bus.publish(
                    Predictor { selected: st.selected, weights: st.weights },
                    st.rounds.len(),
                );
                self.last_published = Some(key);
                self.ticker.fired();
                self.published += 1;
            }
        }
        if self.stopped && !self.bus.is_closed() {
            self.bus.close();
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// The train-serve pipeline
// ---------------------------------------------------------------------------

/// Tuning knobs for [`train_serve`].
#[derive(Clone, Copy, Debug)]
pub struct TrainServeOptions {
    /// Serve worker threads (`0` = available parallelism).
    pub workers: usize,
    /// Examples per query batch.
    pub batch: usize,
    /// Bounded job-queue capacity in batches (`0` = 2 × workers). The
    /// queue is the backpressure boundary: the feeder blocks when it
    /// fills, workers never wait on the trainer.
    pub queue_depth: usize,
}

impl Default for TrainServeOptions {
    fn default() -> Self {
        TrainServeOptions { workers: 2, batch: 64, queue_depth: 0 }
    }
}

/// Serving latency of one hot-swap server version during a
/// [`train_serve`] run.
#[derive(Clone, Debug)]
pub struct VersionStats {
    /// Hot-swap server version (0 = the model installed before the
    /// first swap — non-empty only for resumed sessions).
    pub version: u64,
    /// Selection rounds behind that version's model.
    pub rounds: usize,
    /// Batches this version answered (exact count — every batch is
    /// counted even when the percentile sample is capped).
    pub batches: usize,
    /// p50 per-batch latency, seconds (interpolated, [`percentile`];
    /// computed over at most 4096 retained samples per version per
    /// worker, so memory stays bounded on long runs).
    pub p50_s: f64,
    /// p99 per-batch latency, seconds (same sampling as `p50_s`).
    pub p99_s: f64,
}

/// Everything a [`train_serve`] run produced.
#[derive(Clone, Debug)]
pub struct TrainServeReport {
    /// The finished selection — identical to what a plain `select` run
    /// on the same config would return (serving never perturbs it).
    pub result: SelectionResult,
    /// Why selection stopped.
    pub stop: StopReason,
    /// Wall-clock the *training* half took (the drive only — excludes
    /// serving shutdown and the final pass), comparable 1:1 with a
    /// plain `select` run's selection time.
    pub train_seconds: f64,
    /// Versions published on the bus: one per committed round plus the
    /// on-stop publish when serving uncheckpointed or checkpointing
    /// every round, one per *checkpointed* round otherwise (the publish
    /// cadence follows the autosave interval — see the module docs).
    pub published: u64,
    /// Hot swaps applied to the server (≤ `published`: latest-wins
    /// coalescing may skip versions a busy swap cycle missed).
    pub swaps: u64,
    /// Query batches answered while training was still running.
    pub live_batches: usize,
    /// Per-version latency percentiles over every batch served (live
    /// and final pass), in version order.
    pub version_stats: Vec<VersionStats>,
    /// Predictions of the final pass — served entirely by the final
    /// model, hence deterministic and bit-comparable with
    /// `serve --follow` over the finished checkpoint trail.
    pub final_preds: Vec<f64>,
    /// Latency/throughput of the final pass. `throughput` is measured
    /// over the pass's wall-clock span across all workers (examples per
    /// second of real time), not per-worker busy time.
    pub final_serve: ServeStats,
}

/// One query batch handed to the serve workers.
struct Job {
    start: usize,
    end: usize,
    final_pass: bool,
}

/// Retained latency samples per (version, rounds) per worker. Long
/// training runs serve unbounded batch counts, so raw per-batch logs
/// would grow without limit; every batch is *counted*, but at most this
/// many samples per version per worker feed the percentiles (documented
/// on [`VersionStats::batches`]).
const LATENCY_SAMPLE_CAP: usize = 4096;

/// Per-worker serving log: bounded latency samples grouped by server
/// version, plus exact batch counts.
#[derive(Default)]
struct WorkerLog {
    /// (version, rounds) → (batches answered, retained samples).
    versions: BTreeMap<(u64, usize), (usize, Vec<f64>)>,
    /// Final-pass latencies (bounded by ⌈m / batch⌉).
    final_lat: Vec<f64>,
    /// Earliest start / latest end of this worker's final-pass batches —
    /// merged across workers into the pass's wall-clock span, so the
    /// reported throughput reflects N workers running concurrently
    /// rather than the sum of their busy times.
    final_span: Option<(Instant, Instant)>,
    /// Batches answered while training was live.
    live_batches: usize,
}

impl WorkerLog {
    fn record(
        &mut self,
        version: u64,
        rounds: usize,
        t0: Instant,
        t1: Instant,
        fin: bool,
    ) {
        let lat = (t1 - t0).as_secs_f64();
        let (count, samples) =
            self.versions.entry((version, rounds)).or_default();
        *count += 1;
        if samples.len() < LATENCY_SAMPLE_CAP {
            samples.push(lat);
        }
        if fin {
            self.final_lat.push(lat);
            self.final_span = Some(match self.final_span {
                None => (t0, t1),
                Some((s, e)) => (s.min(t0), e.max(t1)),
            });
        } else {
            self.live_batches += 1;
        }
    }
}

/// Unwind-safe serving shutdown: closing the bus and raising the
/// training-done flag must happen even when the trainer half panics (a
/// caller-supplied [`Observer`] or tap can) — `std::thread::scope`
/// joins every spawned thread *before* propagating a panic, so leaving
/// the feeder spinning on `training_done` would hang the process
/// instead of crashing it.
struct ServingShutdown<'a> {
    bus: &'a ModelBus,
    done: &'a AtomicBool,
}

impl Drop for ServingShutdown<'_> {
    fn drop(&mut self) {
        if !self.bus.is_closed() {
            self.bus.close();
        }
        self.done.store(true, Ordering::Release);
    }
}

/// Run selection and serve it at the same time, in one process, with no
/// filesystem on the publish path.
///
/// Topology (all threads scoped; the function returns only when every
/// one has exited):
///
/// ```text
/// calling thread   drive_tapped(session, [autosaver?, publisher])
///       │ publishes ModelVersion per round          (ModelBus)
///       ▼
/// swapper thread   wait_newer → HotSwapServer::swap  (latest wins)
///       ▼
/// worker × N       bounded job queue → snapshot() → predict_matrix
///       ▲
/// feeder thread    batches of x, pass after pass, until training stops;
///                  then joins the swapper and feeds one final pass
/// ```
///
/// While training runs, workers continuously answer passes over `x`
/// with whatever model is current — those batches are timing-dependent
/// and contribute latency statistics only. Once the session stops the
/// feeder waits for the swapper to drain the bus (so the **final**
/// model is installed) and feeds one more pass, whose predictions are
/// returned in [`TrainServeReport::final_preds`] — deterministic for a
/// deterministic selector, whatever the thread timing did.
///
/// When `saver` is supplied the run is also durably checkpointed, with
/// the publish-after-save ordering documented in the
/// [module docs](self); a killed `train-serve --checkpoint-dir` run
/// resumes bit-identically via `--resume` exactly like `select` does.
pub fn train_serve(
    session: Box<dyn Session + '_>,
    observer: &mut dyn Observer,
    saver: Option<&mut Autosaver>,
    x: &Matrix,
    opts: &TrainServeOptions,
) -> anyhow::Result<TrainServeReport> {
    train_serve_bridged(session, observer, saver, x, opts, |_| Ok(()))
}

/// [`train_serve`] with a bridge hook: `bridge` runs once, right after
/// the bus is created and before any training round, and whatever it
/// returns is held alive until training, serving, and the final pass
/// have all completed. This is how `train-serve --publish` attaches a
/// [`crate::coordinator::fabric::publish::SocketPublisher`] (the hook
/// clones the bus handle) without the streaming pipeline knowing
/// anything about sockets.
pub fn train_serve_bridged<'s, G>(
    mut session: Box<dyn Session + 's>,
    observer: &mut dyn Observer,
    saver: Option<&mut Autosaver>,
    x: &Matrix,
    opts: &TrainServeOptions,
    bridge: impl FnOnce(&ModelBus) -> anyhow::Result<G>,
) -> anyhow::Result<TrainServeReport> {
    ensure!(opts.batch > 0, "batch must be positive");
    let m = x.cols();
    ensure!(m > 0, "no examples to serve");
    let workers = crate::parallel::resolve(opts.workers);
    let depth =
        if opts.queue_depth == 0 { 2 * workers } else { opts.queue_depth };
    let batch = opts.batch;

    let bus = ModelBus::new();
    // bridge first (e.g. bind the fabric socket) so subscribers can be
    // connected before round 1 publishes; the guard lives to the end
    let bridge_guard = bridge(&bus)?;
    // give the publisher the saver's own policy so the publish-after-save
    // guarantee holds at any --checkpoint-every and on-stop setting: a
    // version is announced only in a flush cycle where its round's
    // checkpoint was just written (no saver = publish every round)
    let policy =
        saver.as_deref().map_or_else(AutosavePolicy::default, |s| s.policy());
    let mut publisher = PublishObserver::with_policy(&bus, policy);
    // seed the server with the session's current model: empty for a
    // fresh session, the replayed prefix for a checkpoint resume
    let st0 = session.state()?;
    let server = HotSwapServer::new(Predictor {
        selected: st0.selected,
        weights: st0.weights,
    });

    let training_done = AtomicBool::new(false);
    let (tx, rx) = sync_channel::<Job>(depth);
    let rx = Arc::new(Mutex::new(rx));
    let final_preds = Mutex::new(vec![0.0; m]);

    let (train_result, train_seconds, swaps, logs) =
        std::thread::scope(|scope| {
        // unwind guard first: any panic inside this scope must still
        // close the bus and raise training_done, or joining the feeder
        // would hang the process instead of propagating the panic
        let shutdown = ServingShutdown { bus: &bus, done: &training_done };
        // swapper: install each bus version the instant it lands
        let mut bus_follower = bus.follower();
        let server_ref = &server;
        let swapper = scope.spawn(move || -> u64 {
            let mut swaps = 0u64;
            loop {
                match bus_follower.wait_newer(Duration::from_millis(200)) {
                    BusWait::Newer(v) => {
                        if !v.predictor.selected.is_empty() {
                            server_ref.swap(v.predictor.clone(), v.rounds);
                            swaps += 1;
                        }
                    }
                    BusWait::Closed => return swaps,
                    BusWait::TimedOut => {}
                }
            }
        });

        // feeder: live passes while training runs, then one final pass
        // served entirely by the final model
        let done_ref = &training_done;
        let feeder = scope.spawn(move || -> u64 {
            'live: while !done_ref.load(Ordering::Acquire) {
                let mut start = 0;
                while start < m {
                    if done_ref.load(Ordering::Acquire) {
                        break 'live;
                    }
                    let end = (start + batch).min(m);
                    // blocking send = backpressure on the feeder
                    if tx.send(Job { start, end, final_pass: false }).is_err()
                    {
                        return 0;
                    }
                    start = end;
                }
            }
            // bus is closed once training is done; drain it into the
            // server before the deterministic final pass. A panicked
            // thread re-raises its payload on the joiner (the parallel
            // layer's idiom) instead of a second, cause-hiding panic.
            let swaps = swapper
                .join()
                .unwrap_or_else(|e| std::panic::resume_unwind(e));
            let mut start = 0;
            while start < m {
                let end = (start + batch).min(m);
                if tx.send(Job { start, end, final_pass: true }).is_err() {
                    return swaps;
                }
                start = end;
            }
            drop(tx); // closes the queue: workers drain and exit
            swaps
        });

        // workers: answer batches against the current snapshot
        let mut worker_handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = rx.clone();
            let server_ref = &server;
            let preds_ref = &final_preds;
            worker_handles.push(scope.spawn(move || -> WorkerLog {
                let mut log = WorkerLog::default();
                loop {
                    let job = {
                        // recv() is the only op under this lock — no
                        // state a panicking holder could have torn
                        let queue = rx
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner);
                        queue.recv()
                    };
                    let Ok(job) = job else { break };
                    let snapshot = server_ref.snapshot();
                    // xtask-allow: no-raw-instant -- per-batch serving
                    // latency sample; workers have no session clock
                    let t0 = Instant::now();
                    // range prediction: no n-row sub-matrix copy on the
                    // hot loop, and the latency stat covers all the work
                    let pb = snapshot
                        .predictor
                        .predict_range(x, job.start, job.end);
                    log.record(
                        snapshot.version,
                        snapshot.rounds,
                        t0,
                        // xtask-allow: no-raw-instant -- batch-end stamp
                        // paired with the t0 sample above
                        Instant::now(),
                        job.final_pass,
                    );
                    if job.final_pass {
                        // slice assignment only; disjoint ranges per job
                        let mut out = preds_ref
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner);
                        out[job.start..job.end].copy_from_slice(&pb);
                    }
                }
                log
            }));
        }

        // trainer, on the calling thread: taps ordered save-then-publish
        // xtask-allow: no-raw-instant -- training-only wall clock for
        // the report; the session bills its own elapsed time separately
        let t_train = Instant::now();
        let train_result = {
            let mut taps: Vec<&mut dyn StateObserver> = Vec::new();
            if let Some(saver) = saver {
                taps.push(saver);
            }
            taps.push(&mut publisher);
            drive_tapped(session.as_mut(), observer, &mut taps)
        };
        // training-only wall clock: excludes the serving shutdown and
        // the final pass below, so it compares 1:1 with `select`
        let train_seconds = t_train.elapsed().as_secs_f64();
        drop(shutdown); // close the bus + raise training_done now

        let swaps = feeder
            .join()
            .unwrap_or_else(|e| std::panic::resume_unwind(e));
        let mut logs = Vec::new();
        for handle in worker_handles {
            logs.push(
                handle
                    .join()
                    .unwrap_or_else(|e| std::panic::resume_unwind(e)),
            );
        }
        (train_result, train_seconds, swaps, logs)
    });
    let stop = train_result?;
    // the bus is closed: release the bridge now (a socket publisher
    // sends Shutdown frames and joins its writers here) rather than
    // after the stats crunch below
    drop(bridge_guard);

    // merge the per-worker logs: exact batch counts, capped samples
    let mut groups: BTreeMap<(u64, usize), (usize, Vec<f64>)> =
        BTreeMap::new();
    let mut live_batches = 0usize;
    let mut final_lat = Vec::new();
    let mut final_span: Option<(Instant, Instant)> = None;
    for log in logs {
        for ((version, rounds), (count, samples)) in log.versions {
            let entry = groups.entry((version, rounds)).or_default();
            entry.0 += count;
            entry.1.extend(samples);
        }
        live_batches += log.live_batches;
        final_lat.extend(log.final_lat);
        if let Some((s, e)) = log.final_span {
            final_span = Some(match final_span {
                None => (s, e),
                Some((gs, ge)) => (gs.min(s), ge.max(e)),
            });
        }
    }
    let version_stats = groups
        .into_iter()
        .map(|((version, rounds), (count, mut lats))| {
            lats.sort_by(f64::total_cmp);
            VersionStats {
                version,
                rounds,
                batches: count,
                p50_s: percentile(&lats, 0.5),
                p99_s: percentile(&lats, 0.99),
            }
        })
        .collect();
    let mut final_serve = summarize(m, &final_lat);
    // summarize() divides by summed busy time — right for one serial
    // server, but the final pass ran on N workers concurrently, so use
    // the pass's wall-clock span for the throughput figure instead
    if let Some((s, e)) = final_span {
        let wall = (e - s).as_secs_f64();
        if wall > 0.0 {
            final_serve.throughput = m as f64 / wall;
        }
    }
    let final_preds = final_preds
        .into_inner()
        .unwrap_or_else(std::sync::PoisonError::into_inner);

    Ok(TrainServeReport {
        result: session.finish()?,
        stop,
        train_seconds,
        published: bus.published(),
        swaps,
        live_batches,
        version_stats,
        final_preds,
        final_serve,
    })
}

/// Convenience for tests and examples: [`train_serve`] over a finished
/// checkpoint trail's dataset is cumbersome to compare against by hand,
/// so this serves one deterministic pass over `x` through
/// [`serve_hotswap`] following `dir` — the filesystem twin of a
/// [`train_serve`] final pass (an already-complete trail means every
/// batch is answered by the final model).
///
/// [`serve_hotswap`]: crate::coordinator::serve::serve_hotswap
pub fn follow_final_pass(
    dir: &std::path::Path,
    x: &Matrix,
    batch: usize,
) -> anyhow::Result<Vec<f64>> {
    let mut follower = CheckpointFollower::new(dir);
    let first = follower
        .wait_for_model(Duration::from_secs(5), Duration::from_millis(5))?;
    let server = HotSwapServer::new(first.predictor());
    let (preds, _) = super::serve::serve_hotswap(
        &server,
        &mut follower,
        x,
        batch,
        1,
        None,
    )?;
    Ok(preds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::checkpoint::{
        self, drive_checkpointed, AutosavePolicy,
    };
    use crate::select::greedy::GreedyRls;
    use crate::select::{
        NoopObserver, SelectionConfig, SessionSelector, StopPolicy,
    };

    fn dataset() -> crate::data::Dataset {
        crate::data::synthetic::two_gaussians(60, 14, 4, 1.5, 33)
    }

    #[test]
    fn bus_is_latest_wins_and_versions_are_monotone() {
        let bus = ModelBus::new();
        let mut f = bus.follower();
        assert!(f.poll().is_none());
        assert_eq!(
            bus.publish(Predictor { selected: vec![1], weights: vec![1.0] }, 1),
            1
        );
        assert_eq!(
            bus.publish(Predictor { selected: vec![2], weights: vec![2.0] }, 2),
            2
        );
        let v = f.poll().expect("sees newest");
        assert_eq!(v.version, 2);
        assert_eq!(v.rounds, 2);
        assert!(f.poll().is_none(), "nothing newer");
        assert_eq!(bus.published(), 2);
        // a late follower still sees the latest
        let mut late = bus.follower();
        assert_eq!(late.poll().unwrap().version, 2);
    }

    #[test]
    fn wait_newer_drains_then_reports_closed() {
        let bus = ModelBus::new();
        let mut f = bus.follower();
        assert!(matches!(
            f.wait_newer(Duration::from_millis(1)),
            BusWait::TimedOut
        ));
        bus.publish(Predictor { selected: vec![0], weights: vec![1.0] }, 1);
        bus.close();
        // the last version published before close is still delivered
        let BusWait::Newer(v) = f.wait_newer(Duration::from_secs(1)) else {
            panic!("expected the drained version");
        };
        assert_eq!(v.version, 1);
        assert!(matches!(
            f.wait_newer(Duration::from_millis(1)),
            BusWait::Closed
        ));
        assert!(bus.is_closed());
    }

    #[test]
    fn wait_for_model_skips_empty_models_and_errors_on_close() {
        let bus = ModelBus::new();
        let mut f = bus.follower();
        bus.publish(Predictor { selected: vec![], weights: vec![] }, 0);
        assert!(
            f.wait_for_model(Duration::from_millis(5)).is_err(),
            "an empty model is not servable"
        );
        bus.publish(Predictor { selected: vec![4], weights: vec![2.0] }, 1);
        let v = f.wait_for_model(Duration::from_secs(1)).unwrap();
        assert_eq!(v.predictor.selected, vec![4]);
        bus.close();
        let err = f.wait_for_model(Duration::from_secs(1)).unwrap_err();
        assert!(format!("{err:#}").contains("closed"), "{err:#}");
    }

    #[test]
    fn wait_for_model_wakes_on_cross_thread_publish() {
        let bus = ModelBus::new();
        let mut f = bus.follower();
        std::thread::scope(|scope| {
            let bus_ref = &bus;
            scope.spawn(move || {
                std::thread::sleep(Duration::from_millis(20));
                bus_ref.publish(
                    Predictor { selected: vec![7], weights: vec![1.0] },
                    1,
                );
            });
            let v = f.wait_for_model(Duration::from_secs(5)).unwrap();
            assert_eq!(v.predictor.selected, vec![7]);
        });
    }

    #[test]
    #[should_panic(expected = "closed ModelBus")]
    fn publish_after_close_panics() {
        let bus = ModelBus::new();
        bus.close();
        bus.publish(Predictor { selected: vec![0], weights: vec![1.0] }, 1);
    }

    #[test]
    fn publisher_publishes_every_round_and_closes_on_stop() {
        let ds = dataset();
        let cfg = SelectionConfig::builder().k(4).build();
        let bus = ModelBus::new();
        let mut publisher = PublishObserver::new(&bus);
        let mut collector = bus.follower();
        let mut session = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        let mut seen = Vec::new();
        use crate::select::StepOutcome;
        // single-threaded drive: poll after every step so nothing coalesces
        loop {
            let t0 = Instant::now();
            let out = session.step().unwrap();
            match out {
                StepOutcome::Selected(round) => {
                    publisher.on_round(0, &round, t0.elapsed());
                    publisher.flush(session.as_ref()).unwrap();
                    seen.push(collector.poll().expect("one per round"));
                }
                StepOutcome::Done(reason) => {
                    publisher.on_stop(reason);
                    publisher.flush(session.as_ref()).unwrap();
                    break;
                }
            }
        }
        // k rounds + the on-stop republish of the final model
        assert_eq!(bus.published(), 5);
        assert_eq!(publisher.published, 5);
        assert!(bus.is_closed());
        assert_eq!(seen.len(), 4);
        for (i, v) in seen.iter().enumerate() {
            assert_eq!(v.version, i as u64 + 1);
            assert_eq!(v.rounds, i + 1);
            assert_eq!(v.predictor.selected.len(), i + 1);
        }
        // the drained final version equals the finished model
        let last = collector.poll().expect("final version");
        assert_eq!(last.version, 5);
        let r = session.finish().unwrap();
        assert_eq!(last.predictor.selected, r.selected);
        assert_eq!(last.predictor.weights, r.weights);
    }

    /// Publish-after-save: with taps ordered `[saver, publisher]`, at
    /// the instant any version is visible on the bus, its round's
    /// checkpoint is already durable on disk.
    #[test]
    fn bus_version_never_precedes_its_checkpoint() {
        struct BusAudit<'b> {
            follower: BusFollower,
            dir: std::path::PathBuf,
            checked: &'b mut usize,
        }
        impl Observer for BusAudit<'_> {}
        impl StateObserver for BusAudit<'_> {
            fn flush(
                &mut self,
                _session: &(dyn Session + '_),
            ) -> anyhow::Result<()> {
                while let Some(v) = self.follower.poll() {
                    let path = checkpoint::checkpoint_path(&self.dir, v.rounds);
                    anyhow::ensure!(
                        path.exists(),
                        "bus announced rounds={} before {} existed",
                        v.rounds,
                        path.display()
                    );
                    // and the durable model matches the published one
                    let ckpt =
                        crate::select::Checkpoint::load(&path).unwrap();
                    assert_eq!(ckpt.selected, v.predictor.selected);
                    *self.checked += 1;
                }
                Ok(())
            }
        }

        let dir = std::env::temp_dir().join("greedy_rls_stream_ordering");
        let _ = std::fs::remove_dir_all(&dir);
        let ds = dataset();
        let cfg = SelectionConfig::builder().k(3).build();
        let fp = checkpoint::fingerprint(&ds.x, &ds.y, &cfg);
        let mut saver =
            Autosaver::new(&dir, AutosavePolicy::default(), fp).unwrap();
        let bus = ModelBus::new();
        let mut publisher = PublishObserver::new(&bus);
        let mut checked = 0usize;
        let mut audit = BusAudit {
            follower: bus.follower(),
            dir: dir.clone(),
            checked: &mut checked,
        };
        let mut session = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        drive_tapped(
            session.as_mut(),
            &mut NoopObserver,
            // the audit tap runs *after* the publisher, so every version
            // is examined at (or later than) the instant it became
            // visible — existence of the checkpoint then proves ordering
            &mut [&mut saver, &mut publisher, &mut audit],
        )
        .unwrap();
        assert!(checked >= 3, "audit saw {checked} versions");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// With `--checkpoint-every 2`, the publisher must follow the same
    /// cadence: versions land only at checkpointed rounds (and on
    /// stop), and each one's checkpoint is already on disk.
    #[test]
    fn publish_cadence_follows_the_autosave_interval() {
        struct CadenceAudit {
            follower: BusFollower,
            dir: std::path::PathBuf,
            rounds_seen: Vec<usize>,
        }
        impl Observer for CadenceAudit {}
        impl StateObserver for CadenceAudit {
            fn flush(
                &mut self,
                _session: &(dyn Session + '_),
            ) -> anyhow::Result<()> {
                while let Some(v) = self.follower.poll() {
                    let path = checkpoint::checkpoint_path(&self.dir, v.rounds);
                    anyhow::ensure!(
                        path.exists(),
                        "version for rounds={} published without a durable \
                         checkpoint",
                        v.rounds
                    );
                    self.rounds_seen.push(v.rounds);
                }
                Ok(())
            }
        }

        let dir = std::env::temp_dir().join("greedy_rls_stream_cadence");
        let _ = std::fs::remove_dir_all(&dir);
        let ds = dataset();
        let cfg = SelectionConfig::builder().k(5).build();
        let fp = checkpoint::fingerprint(&ds.x, &ds.y, &cfg);
        let policy = AutosavePolicy { every: 2, on_stop: true };
        let mut saver = Autosaver::new(&dir, policy, fp).unwrap();
        let bus = ModelBus::new();
        let mut publisher = PublishObserver::with_policy(&bus, policy);
        let mut audit = CadenceAudit {
            follower: bus.follower(),
            dir: dir.clone(),
            rounds_seen: Vec::new(),
        };
        let mut session = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        drive_tapped(
            session.as_mut(),
            &mut NoopObserver,
            &mut [&mut saver, &mut publisher, &mut audit],
        )
        .unwrap();
        // rounds 2 and 4 on the interval, round 5 from the on-stop save
        assert_eq!(audit.rounds_seen, vec![2, 4, 5]);
        assert_eq!(publisher.published, 3);
        assert!(bus.is_closed());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn train_serve_end_to_end_matches_plain_select() {
        let ds = dataset();
        let cfg = SelectionConfig::builder().k(4).build();
        let reference = crate::select::run_to_completion(
            GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap(),
        )
        .unwrap();

        let session = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        let opts =
            TrainServeOptions { workers: 2, batch: 16, queue_depth: 2 };
        let report = train_serve(
            session,
            &mut NoopObserver,
            None,
            &ds.x,
            &opts,
        )
        .unwrap();

        // training unperturbed by serving
        assert_eq!(report.result.selected, reference.selected);
        assert_eq!(report.result.weights, reference.weights);
        assert_eq!(report.stop, crate::select::StopReason::TargetReached);
        // k rounds + the on-stop publish
        assert_eq!(report.published, 5);
        assert!(report.swaps >= 1, "at least the final model swaps in");
        // the final pass is served by the final model, bit-for-bit
        let direct = reference.predictor().predict_matrix(&ds.x);
        assert_eq!(report.final_preds.len(), direct.len());
        for (a, b) in report.final_preds.iter().zip(&direct) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(report.final_serve.requests, ds.x.cols());
        assert!(!report.version_stats.is_empty());
        let total: usize =
            report.version_stats.iter().map(|v| v.batches).sum();
        assert_eq!(
            total,
            report.live_batches + report.final_serve.batches
        );
    }

    #[test]
    fn train_serve_zero_budget_serves_nothing_but_terminates() {
        let ds = dataset();
        let cfg = SelectionConfig::builder()
            .k(4)
            .stop(StopPolicy::TimeBudget(Duration::ZERO))
            .build();
        let session = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        let opts =
            TrainServeOptions { workers: 1, batch: 32, queue_depth: 1 };
        let report =
            train_serve(session, &mut NoopObserver, None, &ds.x, &opts)
                .unwrap();
        assert!(report.result.selected.is_empty());
        // one on-stop publish of the empty model
        assert_eq!(report.published, 1);
        assert_eq!(report.swaps, 0, "empty models are never swapped in");
        // the final pass ran against the empty model: all-zero scores
        assert!(report.final_preds.iter().all(|&p| p == 0.0));
    }

    #[test]
    fn train_serve_composes_with_checkpointing_and_resume() {
        let dir = std::env::temp_dir().join("greedy_rls_stream_ts_resume");
        let _ = std::fs::remove_dir_all(&dir);
        let ds = dataset();
        let cfg = SelectionConfig::builder().k(5).build();
        let fp = checkpoint::fingerprint(&ds.x, &ds.y, &cfg);

        // uninterrupted reference (checkpointed, drive_checkpointed)
        let mut ref_session = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        let ref_dir = dir.join("ref");
        let mut ref_saver =
            Autosaver::new(&ref_dir, AutosavePolicy::default(), fp).unwrap();
        drive_checkpointed(
            ref_session.as_mut(),
            &mut NoopObserver,
            &mut ref_saver,
        )
        .unwrap();
        let reference = ref_session.finish().unwrap();

        // train-serve with checkpointing, "killed" after round 2 by
        // truncating the trail
        let ts_dir = dir.join("ts");
        let mut saver =
            Autosaver::new(&ts_dir, AutosavePolicy::default(), fp).unwrap();
        let session = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
        let opts =
            TrainServeOptions { workers: 2, batch: 16, queue_depth: 0 };
        let report = train_serve(
            session,
            &mut NoopObserver,
            Some(&mut saver),
            &ds.x,
            &opts,
        )
        .unwrap();
        assert_eq!(report.result.selected, reference.selected);
        for rounds in 3..=5 {
            let f = checkpoint::checkpoint_path(&ts_dir, rounds);
            assert!(f.exists(), "missing {f:?}");
            std::fs::remove_file(f).unwrap();
        }

        // resume from the truncated trail and train-serve again: the
        // final model must converge to the identical reference
        let latest = checkpoint::latest_in_dir(&ts_dir).unwrap().unwrap();
        let (resumed, _ckpt) = checkpoint::resume_from_path(
            &GreedyRls, &ds.x, &ds.y, &cfg, &latest,
        )
        .unwrap();
        let mut saver2 =
            Autosaver::new(&ts_dir, AutosavePolicy::default(), fp).unwrap();
        let report2 = train_serve(
            resumed,
            &mut NoopObserver,
            Some(&mut saver2),
            &ds.x,
            &opts,
        )
        .unwrap();
        assert_eq!(report2.result.selected, reference.selected);
        assert_eq!(report2.result.weights, reference.weights);
        for (a, b) in report2
            .result
            .rounds
            .iter()
            .zip(&reference.rounds)
        {
            assert_eq!(a.criterion.to_bits(), b.criterion.to_bits());
        }
        // resumed run publishes the 3 new rounds + the on-stop version
        assert_eq!(report2.published, 4);

        // and the final pass agrees with serve --follow over the trail
        let followed = follow_final_pass(&ts_dir, &ds.x, 16).unwrap();
        for (a, b) in report2.final_preds.iter().zip(&followed) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
