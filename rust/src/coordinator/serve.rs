//! Serving: load a selected sparse model and answer prediction requests.
//!
//! The paper motivates sparse predictors with "limited memory and
//! real-time response demands" (embedded deployment): prediction is O(k)
//! per example. This module provides a small batched serving loop with
//! latency accounting, over either execution path:
//!
//! * **native** — the [`Predictor`] dot product (the realistic deployment
//!   for k-sparse linear models);
//! * **PJRT** — the AOT `predict` artifact, demonstrating that the same
//!   artifact pipeline that trains also serves (weights padded into the
//!   artifact's (k, t) bucket).
//!
//! For serve-while-training, [`HotSwapServer`] holds the current model
//! behind a versioned slot: batches predict against an [`Arc`] snapshot
//! taken at batch start, so a [`HotSwapServer::swap`] never invalidates
//! an in-flight batch. Two [`ModelSource`]s feed those swaps:
//!
//! * [`CheckpointFollower`] — polls a live session's checkpoint
//!   directory (`serve --follow`, cross-process through the filesystem);
//! * [`crate::coordinator::stream::BusFollower`] — subscribes to the
//!   in-process [`crate::coordinator::stream::ModelBus`] (`train-serve`,
//!   no disk on the request path).

use std::path::PathBuf;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context};

use crate::linalg::Matrix;
use crate::rls::Predictor;
use crate::runtime::{lit, Runtime};
use crate::select::checkpoint::{self, Checkpoint};

/// Latency/throughput statistics of a serving run.
#[derive(Clone, Copy, Debug)]
pub struct ServeStats {
    /// Requests (examples) answered.
    pub requests: usize,
    /// Batches executed.
    pub batches: usize,
    /// Mean per-batch latency, seconds.
    pub mean_batch_s: f64,
    /// p50 per-batch latency.
    pub p50_batch_s: f64,
    /// p99 per-batch latency.
    pub p99_batch_s: f64,
    /// Examples per second.
    pub throughput: f64,
}

/// Quantile of an ascending-sorted latency sample with **linear
/// interpolation** between order statistics (the numpy `linear` method).
///
/// The previous nearest-rank rule (`round((len-1)·q)`) misreported tail
/// quantiles on small samples — p99 of anything under ~50 batches simply
/// returned the maximum. Interpolating keeps p99 meaningful at every
/// batch count; [`serve_native`], [`serve_pjrt`], and the `train-serve`
/// pipeline's per-version stats ([`crate::coordinator::stream`]) all
/// share this rule, so every serving path's stats agree.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = (sorted.len() - 1) as f64 * q.clamp(0.0, 1.0);
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        return sorted[lo];
    }
    sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
}

/// Serve every column of `x` (full feature-major matrix) in batches with
/// the native predictor. Returns predictions and stats. Errors on a
/// zero batch size, mirroring [`serve_pjrt`].
///
/// The core of the `examples/serve.rs` flow — train a sparse model,
/// serve it back in batches, read the latency stats:
///
/// ```
/// use greedy_rls::coordinator::{fit, serve, EngineKind};
/// use greedy_rls::data::synthetic::two_gaussians;
/// use greedy_rls::select::SelectionConfig;
///
/// let ds = two_gaussians(120, 30, 5, 1.0, 42);
/// let cfg = SelectionConfig::builder().k(5).build();
/// let model = fit(EngineKind::Native, None, &ds, &cfg)?;
/// let (preds, stats) = serve::serve_native(&model, &ds.x, 16)?;
/// assert_eq!(preds.len(), 120);
/// assert_eq!(stats.batches, 8); // ceil(120/16)
/// assert!(stats.p99_batch_s >= stats.p50_batch_s);
/// # anyhow::Ok(())
/// ```
pub fn serve_native(
    p: &Predictor,
    x: &Matrix,
    batch: usize,
) -> anyhow::Result<(Vec<f64>, ServeStats)> {
    ensure!(batch > 0, "batch must be positive");
    let m = x.cols();
    let mut preds = vec![0.0; m];
    let mut lat = Vec::new();
    let mut start = 0;
    while start < m {
        let end = (start + batch).min(m);
        // xtask-allow: no-raw-instant -- per-batch serving latency
        // measurement; serving has no session clock to route through
        let t0 = std::time::Instant::now();
        // range prediction: no n-row sub-matrix copy per batch, and the
        // latency stat measures prediction, not the copy
        let pb = p.predict_range(x, start, end);
        lat.push(t0.elapsed().as_secs_f64());
        preds[start..end].copy_from_slice(&pb);
        start = end;
    }
    let stats = summarize(m, &lat);
    Ok((preds, stats))
}

/// Serve through the PJRT `predict` artifact. The predictor's weights are
/// padded into the artifact's (k_b, t_b) bucket; each batch pads the
/// selected-feature rows of the batch into the same bucket.
pub fn serve_pjrt(
    rt: &Runtime,
    p: &Predictor,
    x: &Matrix,
    batch: usize,
) -> anyhow::Result<(Vec<f64>, ServeStats)> {
    ensure!(batch > 0, "batch must be positive");
    let k = p.selected.len();
    // pick the smallest predict bucket that fits (k, batch)
    let mut buckets: Vec<(usize, usize)> = rt
        .manifest()
        .iter()
        .filter(|e| e.entry == "predict")
        .map(|e| (e.dim1.1, e.dim2.1))
        .collect();
    buckets.sort_by_key(|&(kb, tb)| kb * tb);
    let (kb, tb) = buckets
        .into_iter()
        .find(|&(kb, tb)| kb >= k && tb >= batch)
        .ok_or_else(|| {
            anyhow!("no predict artifact fits (k={k}, batch={batch})")
        })?;
    let exe = rt.executable("predict", kb, tb)?;

    let mut w_pad = vec![0.0; kb];
    w_pad[..k].copy_from_slice(&p.weights);
    let w_lit = lit::vec_f64(&w_pad);

    let m = x.cols();
    let mut preds = vec![0.0; m];
    let mut lat = Vec::new();
    let mut start = 0;
    while start < m {
        let end = (start + batch).min(m);
        let t = end - start;
        // gather selected-feature rows of this batch into (kb, tb)
        let mut xb = vec![0.0; kb * tb];
        for (r, &feat) in p.selected.iter().enumerate() {
            let row = x.row(feat);
            xb[r * tb..r * tb + t].copy_from_slice(&row[start..end]);
        }
        let x_lit = lit::mat_f64(&xb, kb, tb)?;
        // xtask-allow: no-raw-instant -- per-batch serving latency
        // measurement on the PJRT path, same contract as serve_native
        let t0 = std::time::Instant::now();
        let outs = Runtime::run_tuple(&exe, &[w_lit.clone(), x_lit])?;
        lat.push(t0.elapsed().as_secs_f64());
        let out = lit::to_vec_f64(&outs[0]).context("predict output")?;
        preds[start..end].copy_from_slice(&out[..t]);
        start = end;
    }
    Ok((preds, summarize(m, &lat)))
}

pub(crate) fn summarize(requests: usize, lat: &[f64]) -> ServeStats {
    let mut sorted = lat.to_vec();
    sorted.sort_by(f64::total_cmp);
    let total: f64 = lat.iter().sum();
    ServeStats {
        requests,
        batches: lat.len(),
        mean_batch_s: if lat.is_empty() { 0.0 } else { total / lat.len() as f64 },
        p50_batch_s: percentile(&sorted, 0.5),
        p99_batch_s: percentile(&sorted, 0.99),
        throughput: if total > 0.0 { requests as f64 / total } else { 0.0 },
    }
}

// ---------------------------------------------------------------------------
// Hot-swap serving: serve the k-so-far model while selection continues
// ---------------------------------------------------------------------------

/// One immutable published model: the predictor plus bookkeeping about
/// where it came from. Batches hold an `Arc<ModelVersion>` for their whole
/// lifetime, so swapping the server's slot never pulls a model out from
/// under an in-flight batch.
#[derive(Clone, Debug)]
pub struct ModelVersion {
    /// The sparse model served.
    pub predictor: Predictor,
    /// Monotonic swap counter (0 for the model the server started with).
    pub version: u64,
    /// Rounds of the source checkpoint/session (`selected.len()` for a
    /// plain model file).
    pub rounds: usize,
}

/// A serving slot whose model can be replaced while batches are in
/// flight.
///
/// Readers take a cheap [`HotSwapServer::snapshot`] (an `Arc` clone under
/// a read lock) at batch start and compute against that; [`swap`] briefly
/// takes the write lock to publish a new [`ModelVersion`]. The old model
/// stays alive until its last in-flight batch drops the `Arc` — no batch
/// is ever dropped or torn by a refresh.
///
/// ```
/// use greedy_rls::coordinator::serve::HotSwapServer;
/// use greedy_rls::rls::Predictor;
///
/// let server = HotSwapServer::new(Predictor {
///     selected: vec![0, 2],
///     weights: vec![1.0, -2.0],
/// });
/// let in_flight = server.snapshot(); // a batch holds this Arc
/// let v = server.swap(Predictor { selected: vec![1], weights: vec![3.0] }, 5);
/// assert_eq!(v, 1);
/// // the swap never tears the batch already in flight …
/// assert_eq!(in_flight.predictor.selected, vec![0, 2]);
/// // … and the next batch sees the new model
/// assert_eq!(server.snapshot().predictor.selected, vec![1]);
/// ```
///
/// [`swap`]: HotSwapServer::swap
pub struct HotSwapServer {
    slot: RwLock<Arc<ModelVersion>>,
}

impl HotSwapServer {
    /// Start serving `predictor` as version 0.
    pub fn new(predictor: Predictor) -> HotSwapServer {
        let rounds = predictor.selected.len();
        HotSwapServer {
            slot: RwLock::new(Arc::new(ModelVersion {
                predictor,
                version: 0,
                rounds,
            })),
        }
    }

    /// Publish a new model; returns its version number. In-flight batches
    /// keep predicting with the snapshot they already hold.
    pub fn swap(&self, predictor: Predictor, rounds: usize) -> u64 {
        // Poison recovery is sound here: the slot's only mutation is the
        // single Arc assignment below, so a panicked holder can never
        // leave a half-updated value behind.
        let mut slot = self
            .slot
            .write()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let version = slot.version + 1;
        *slot = Arc::new(ModelVersion { predictor, version, rounds });
        version
    }

    /// The currently published model (cheap: one `Arc` clone).
    pub fn snapshot(&self) -> Arc<ModelVersion> {
        self.slot
            .read()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .clone()
    }

    /// Version of the currently published model.
    pub fn version(&self) -> u64 {
        self.slot
            .read()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .version
    }

    /// Predict one batch against a snapshot taken at call start; returns
    /// the predictions and the version that computed them.
    pub fn predict_batch(&self, xb: &Matrix) -> (Vec<f64>, u64) {
        let model = self.snapshot();
        (model.predictor.predict_matrix(xb), model.version)
    }

    /// [`HotSwapServer::predict_batch`] over columns `start..end` of a
    /// full feature-major matrix, without materializing a sub-matrix
    /// ([`Predictor::predict_range`] — bit-identical, batch after batch,
    /// to a whole-matrix pass). The serving loops' hot path.
    pub fn predict_range(
        &self,
        x: &Matrix,
        start: usize,
        end: usize,
    ) -> (Vec<f64>, u64) {
        let model = self.snapshot();
        (model.predictor.predict_range(x, start, end), model.version)
    }
}

/// One model refresh delivered by a [`ModelSource`].
#[derive(Clone, Debug)]
pub struct ModelUpdate {
    /// The new model to serve.
    pub predictor: Predictor,
    /// Selection rounds behind this model (for reporting).
    pub rounds: usize,
    /// Fingerprint of the training data, when the source carries one.
    /// Checkpoints do ([`crate::data::fingerprint::fingerprint_xy`]); the
    /// in-process bus reports `None` — publisher and server share one
    /// process and one dataset by construction.
    pub data_hash: Option<u64>,
}

/// A source of successively newer models for hot-swap serving: the
/// checkpoint trail on disk ([`CheckpointFollower`], `serve --follow`) or
/// the in-process bus ([`crate::coordinator::stream::BusFollower`],
/// `train-serve`). [`serve_hotswap`] polls it between batches; the
/// concurrent-swap stress tests exercise [`HotSwapServer`] through both
/// implementations.
pub trait ModelSource {
    /// The newest model strictly newer than the last one this source
    /// reported, or `None` when nothing newer exists yet.
    fn poll_model(&mut self) -> anyhow::Result<Option<ModelUpdate>>;
}

/// Watches a checkpoint directory for newer checkpoints than the last one
/// it reported — the refresh source for `serve --follow`.
pub struct CheckpointFollower {
    dir: PathBuf,
    last_rounds: Option<usize>,
    warned: std::collections::HashSet<PathBuf>,
}

impl CheckpointFollower {
    /// Follow `dir` (which need not exist yet).
    pub fn new(dir: impl Into<PathBuf>) -> CheckpointFollower {
        CheckpointFollower {
            dir: dir.into(),
            last_rounds: None,
            warned: std::collections::HashSet::new(),
        }
    }

    /// Load the most advanced checkpoint newer than the last one this
    /// follower reported; `None` when nothing newer exists. Atomic
    /// write-rename on the producer side means a complete `.ckpt` is the
    /// norm — but a crash mid-write (or a copied-in partial file) can
    /// still leave a torn newest checkpoint, so an unloadable candidate
    /// is skipped (warned once per file) and the next most advanced
    /// valid one wins instead of erroring the whole follow loop.
    pub fn poll(&mut self) -> anyhow::Result<Option<Checkpoint>> {
        let mut candidates =
            newer_checkpoints(&self.dir, self.last_rounds)?;
        candidates.sort_by(|a, b| b.0.cmp(&a.0));
        for (rounds, path) in candidates {
            match Checkpoint::load(&path) {
                Ok(ckpt) => {
                    self.last_rounds = Some(rounds);
                    return Ok(Some(ckpt));
                }
                Err(err) => {
                    if self.warned.insert(path.clone()) {
                        eprintln!(
                            "[serve] skipping corrupt checkpoint {}: \
                             {err:#}",
                            path.display()
                        );
                    }
                }
            }
        }
        Ok(None)
    }

    /// Block until the directory offers a checkpoint with a non-empty
    /// model (a 0-round checkpoint has nothing to serve), polling every
    /// `poll` up to `timeout` — a wall-clock deadline: sleeps are
    /// clamped to the time remaining, so `--wait-s` means seconds even
    /// when `poll` is long or the scheduler is unkind.
    pub fn wait_for_model(
        &mut self,
        timeout: Duration,
        poll: Duration,
    ) -> anyhow::Result<Checkpoint> {
        // xtask-allow: no-raw-instant -- poll-timeout deadline for a
        // filesystem watcher; no selection session exists yet to bill
        let deadline = Instant::now().checked_add(timeout);
        loop {
            if let Some(ckpt) = self.poll()? {
                if !ckpt.selected.is_empty() {
                    return Ok(ckpt);
                }
            }
            // xtask-allow: no-raw-instant -- remaining-time computation
            // against the deadline anchored above
            let now = Instant::now();
            let remaining = match deadline {
                // an unrepresentable deadline means wait indefinitely
                None => poll,
                Some(d) if now < d => d - now,
                Some(_) => bail!(
                    "no servable checkpoint appeared in {} within {:.1}s",
                    self.dir.display(),
                    timeout.as_secs_f64()
                ),
            };
            std::thread::sleep(poll.min(remaining));
        }
    }
}

/// `(rounds, path)` for every well-named checkpoint in `dir` strictly
/// newer than `after`. A missing directory is an empty trail (the
/// trainer may not have created it yet), not an error — the same
/// contract as [`checkpoint::latest_in_dir`].
fn newer_checkpoints(
    dir: &std::path::Path,
    after: Option<usize>,
) -> anyhow::Result<Vec<(usize, PathBuf)>> {
    let entries = match std::fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(err) if err.kind() == std::io::ErrorKind::NotFound => {
            return Ok(Vec::new())
        }
        Err(err) => {
            return Err(err)
                .with_context(|| format!("reading {}", dir.display()))
        }
    };
    let mut out = Vec::new();
    for entry in entries {
        let path = entry
            .with_context(|| format!("reading {}", dir.display()))?
            .path();
        if let Some(rounds) = checkpoint::round_count_in_name(&path) {
            if Some(rounds) > after {
                out.push((rounds, path));
            }
        }
    }
    Ok(out)
}

impl ModelSource for CheckpointFollower {
    fn poll_model(&mut self) -> anyhow::Result<Option<ModelUpdate>> {
        Ok(self.poll()?.map(|ckpt| ModelUpdate {
            predictor: ckpt.predictor(),
            rounds: ckpt.rounds.len(),
            data_hash: Some(ckpt.fingerprint.data),
        }))
    }
}

/// Statistics of a hot-swap serving run.
#[derive(Clone, Copy, Debug)]
pub struct HotSwapStats {
    /// Latency/throughput of the batches served.
    pub serve: ServeStats,
    /// Model swaps performed during the run.
    pub swaps: usize,
    /// Version of the model that served the final batch.
    pub final_version: u64,
    /// Rounds of the model that served the final batch.
    pub final_rounds: usize,
}

/// Serve every column of `x` for `passes` passes with the native
/// predictor, polling `source` between batches and hot-swapping the
/// server's model whenever a newer one appears. Returns the predictions
/// of the **last** pass (computed by whatever models were current
/// batch-by-batch) and run statistics. Works over either kind of
/// [`ModelSource`] — a [`CheckpointFollower`] (`serve --follow`) or a
/// [`crate::coordinator::stream::BusFollower`].
///
/// `expect_data_hash` guards against following a model trail that
/// belongs to a different dataset (compare with
/// [`crate::data::fingerprint::fingerprint_xy`] of the serving data);
/// updates carrying a differing fingerprint are refused. Sources that
/// carry no fingerprint (the in-process bus) skip the check.
pub fn serve_hotswap(
    server: &HotSwapServer,
    source: &mut dyn ModelSource,
    x: &Matrix,
    batch: usize,
    passes: usize,
    expect_data_hash: Option<u64>,
) -> anyhow::Result<(Vec<f64>, HotSwapStats)> {
    ensure!(batch > 0, "batch must be positive");
    ensure!(passes > 0, "passes must be positive");
    let m = x.cols();
    let mut preds = vec![0.0; m];
    let mut lat = Vec::new();
    let mut swaps = 0usize;
    let mut last_version = server.version();
    let mut last_rounds = server.snapshot().rounds;
    for _pass in 0..passes {
        let mut start = 0;
        while start < m {
            // refresh point: between batches, never mid-batch
            if let Some(update) = source.poll_model()? {
                if let (Some(expect), Some(got)) =
                    (expect_data_hash, update.data_hash)
                {
                    ensure!(
                        got == expect,
                        "checkpoint data hash {got:016x} does not match \
                         the serving dataset's {expect:016x}"
                    );
                }
                if !update.predictor.selected.is_empty() {
                    last_rounds = update.rounds;
                    last_version =
                        server.swap(update.predictor, last_rounds);
                    swaps += 1;
                }
            }
            let end = (start + batch).min(m);
            // xtask-allow: no-raw-instant -- per-batch serving latency
            // measurement (same contract as serve_native)
            let t0 = Instant::now();
            let (pb, _version) = server.predict_range(x, start, end);
            lat.push(t0.elapsed().as_secs_f64());
            preds[start..end].copy_from_slice(&pb);
            start = end;
        }
    }
    let stats = HotSwapStats {
        serve: summarize(m * passes, &lat),
        swaps,
        final_version: last_version,
        final_rounds: last_rounds,
    };
    Ok((preds, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_predictor() -> Predictor {
        Predictor { selected: vec![0, 2], weights: vec![1.0, -2.0] }
    }

    #[test]
    fn zero_batch_is_an_error_not_a_panic() {
        let ds = crate::data::synthetic::two_gaussians(10, 5, 2, 1.0, 2);
        let err = serve_native(&toy_predictor(), &ds.x, 0).unwrap_err();
        assert!(err.to_string().contains("batch"), "{err}");
    }

    #[test]
    fn native_serving_matches_direct_prediction() {
        let ds = crate::data::synthetic::two_gaussians(37, 5, 2, 1.0, 1);
        let p = toy_predictor();
        let (preds, stats) = serve_native(&p, &ds.x, 8).unwrap();
        assert_eq!(preds.len(), 37);
        assert_eq!(stats.requests, 37);
        assert_eq!(stats.batches, 5); // ceil(37/8)
        let direct = p.predict_matrix(&ds.x);
        for (a, b) in preds.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-12);
        }
        assert!(stats.throughput > 0.0);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn percentile_interpolates_between_order_statistics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        // p50 of an even-sized sample is the midpoint, not an element
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
        // p99 on a small sample must NOT collapse to the max (the old
        // nearest-rank bug): (4-1)*0.99 = 2.97 ⇒ 3 + 0.97*(4-3) = 3.97
        let p99 = percentile(&xs, 0.99);
        assert!((p99 - 3.97).abs() < 1e-12, "p99 = {p99}");
        assert!(p99 < 4.0);
        // single sample: every quantile is that sample
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        // out-of-range q clamps instead of indexing out of bounds
        assert_eq!(percentile(&xs, 1.5), 4.0);
        assert_eq!(percentile(&xs, -0.5), 1.0);
    }

    #[test]
    fn hot_swap_preserves_in_flight_snapshots() {
        let server = HotSwapServer::new(toy_predictor());
        let in_flight = server.snapshot();
        assert_eq!(in_flight.version, 0);
        let v = server.swap(
            Predictor { selected: vec![1], weights: vec![3.0] },
            5,
        );
        assert_eq!(v, 1);
        // the old snapshot is still fully usable mid-"flight"
        assert_eq!(in_flight.predictor.selected, vec![0, 2]);
        let now = server.snapshot();
        assert_eq!(now.version, 1);
        assert_eq!(now.rounds, 5);
        assert_eq!(now.predictor.selected, vec![1]);
    }

    #[test]
    fn hot_swap_is_safe_under_concurrent_readers() {
        let ds = crate::data::synthetic::two_gaussians(64, 5, 2, 1.0, 3);
        let server = HotSwapServer::new(toy_predictor());
        std::thread::scope(|scope| {
            let srv = &server;
            let x = &ds.x;
            let reader = scope.spawn(move || {
                let mut last = 0u64;
                for _ in 0..200 {
                    let (preds, version) = srv.predict_batch(x);
                    assert_eq!(preds.len(), 64);
                    assert!(version >= last, "versions must be monotone");
                    last = version;
                }
            });
            for i in 0..50u64 {
                srv.swap(
                    Predictor {
                        selected: vec![(i as usize) % 5],
                        weights: vec![i as f64],
                    },
                    i as usize,
                );
            }
            reader.join().unwrap();
        });
        assert_eq!(server.version(), 50);
    }

    fn write_checkpoint(dir: &std::path::Path, rounds: usize, data: u64) {
        let ckpt = Checkpoint {
            fingerprint: crate::select::checkpoint::Fingerprint {
                config: 1,
                data,
            },
            elapsed: Duration::ZERO,
            stop_reason: None,
            rounds: (0..rounds)
                .map(|i| crate::select::Round {
                    feature: i,
                    criterion: 1.0 / (i + 1) as f64,
                })
                .collect(),
            selected: (0..rounds).collect(),
            weights: (0..rounds).map(|i| i as f64 + 0.5).collect(),
        };
        ckpt.save_atomic(&checkpoint::checkpoint_path(dir, rounds))
            .unwrap();
    }

    #[test]
    fn follower_reports_only_newer_checkpoints() {
        let dir = std::env::temp_dir().join("greedy_rls_serve_follow_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut f = CheckpointFollower::new(&dir);
        assert!(f.poll().unwrap().is_none(), "missing dir is quiet");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(f.poll().unwrap().is_none(), "empty dir is quiet");
        write_checkpoint(&dir, 2, 7);
        let c = f.poll().unwrap().expect("first checkpoint seen");
        assert_eq!(c.rounds.len(), 2);
        assert!(f.poll().unwrap().is_none(), "same checkpoint not re-reported");
        write_checkpoint(&dir, 4, 7);
        let c = f.poll().unwrap().expect("newer checkpoint seen");
        assert_eq!(c.rounds.len(), 4);
        assert!(f.poll().unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Hand-truncate a checkpoint file for `rounds` — the torn newest
    /// file a producer crash mid-write (pre-rename copy) would leave.
    fn write_truncated_checkpoint(dir: &std::path::Path, rounds: usize) {
        let ckpt = Checkpoint {
            fingerprint: crate::select::checkpoint::Fingerprint {
                config: 1,
                data: 7,
            },
            elapsed: Duration::ZERO,
            stop_reason: None,
            rounds: (0..rounds)
                .map(|i| crate::select::Round {
                    feature: i,
                    criterion: 1.0 / (i + 1) as f64,
                })
                .collect(),
            selected: (0..rounds).collect(),
            weights: (0..rounds).map(|i| i as f64 + 0.5).collect(),
        };
        let text = ckpt.to_text();
        std::fs::write(
            checkpoint::checkpoint_path(dir, rounds),
            &text[..text.len() / 2],
        )
        .unwrap();
    }

    #[test]
    fn follower_skips_truncated_newest_checkpoint() {
        let dir = std::env::temp_dir().join("greedy_rls_serve_corrupt_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // a valid ckpt-2 and a torn ckpt-4: the follower must fall back
        // to the newest *valid* checkpoint instead of erroring out
        write_checkpoint(&dir, 2, 7);
        write_truncated_checkpoint(&dir, 4);
        let mut f = CheckpointFollower::new(&dir);
        let c = f.poll().unwrap().expect("valid fallback served");
        assert_eq!(c.rounds.len(), 2, "fell back past the torn ckpt-4");
        // the torn file alone is not "newer work": stay quiet
        assert!(f.poll().unwrap().is_none());
        // a later valid checkpoint is picked up normally
        write_checkpoint(&dir, 6, 7);
        let c = f.poll().unwrap().expect("recovered to valid ckpt-6");
        assert_eq!(c.rounds.len(), 6);
        // a torn file that is the *only* newer candidate yields None,
        // never an error and never a torn model
        write_truncated_checkpoint(&dir, 8);
        assert!(f.poll().unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wait_for_model_honors_wall_clock_deadline() {
        let dir = std::env::temp_dir().join("greedy_rls_serve_deadline_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut f = CheckpointFollower::new(&dir);
        // nothing will ever appear: a 200ms timeout with a 10s poll
        // interval must still give up in ~200ms, because the sleep is
        // clamped to the time remaining — not `timeout / poll` naps
        let t0 = Instant::now();
        let err = f
            .wait_for_model(
                Duration::from_millis(200),
                Duration::from_secs(10),
            )
            .unwrap_err();
        let elapsed = t0.elapsed();
        assert!(err.to_string().contains("within"), "{err}");
        assert!(
            elapsed < Duration::from_secs(5),
            "deadline ignored: waited {elapsed:?} for a 200ms timeout"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_hotswap_swaps_between_batches_and_checks_data_hash() {
        let dir = std::env::temp_dir().join("greedy_rls_serve_hotswap_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ds = crate::data::synthetic::two_gaussians(20, 6, 2, 1.0, 9);
        write_checkpoint(&dir, 1, 7);
        let mut f = CheckpointFollower::new(&dir);
        let first = f
            .wait_for_model(Duration::from_secs(5), Duration::from_millis(1))
            .unwrap();
        let server = HotSwapServer::new(first.predictor());
        // a newer checkpoint lands before the serving loop starts: it
        // must be picked up at the first between-batch refresh point
        write_checkpoint(&dir, 3, 7);
        let (preds, stats) =
            serve_hotswap(&server, &mut f, &ds.x, 8, 2, Some(7)).unwrap();
        assert_eq!(preds.len(), 20);
        assert_eq!(stats.swaps, 1);
        assert_eq!(stats.final_rounds, 3);
        assert_eq!(stats.final_version, 1);
        assert_eq!(stats.serve.requests, 40); // 2 passes
        assert_eq!(stats.serve.batches, 6); // ceil(20/8) × 2
        // the final pass was fully served by the 3-round model
        let direct = Checkpoint {
            fingerprint: first.fingerprint,
            elapsed: Duration::ZERO,
            stop_reason: None,
            rounds: vec![],
            selected: (0..3).collect(),
            weights: (0..3).map(|i| i as f64 + 0.5).collect(),
        }
        .predictor()
        .predict_matrix(&ds.x);
        for (a, b) in preds.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-12);
        }
        // a checkpoint for different data is refused
        write_checkpoint(&dir, 5, 8);
        let err = serve_hotswap(&server, &mut f, &ds.x, 8, 1, Some(7))
            .unwrap_err();
        assert!(format!("{err:#}").contains("data hash"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
