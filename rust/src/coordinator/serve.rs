//! Serving: load a selected sparse model and answer prediction requests.
//!
//! The paper motivates sparse predictors with "limited memory and
//! real-time response demands" (embedded deployment): prediction is O(k)
//! per example. This module provides a small batched serving loop with
//! latency accounting, over either execution path:
//!
//! * **native** — the [`Predictor`] dot product (the realistic deployment
//!   for k-sparse linear models);
//! * **PJRT** — the AOT `predict` artifact, demonstrating that the same
//!   artifact pipeline that trains also serves (weights padded into the
//!   artifact's (k, t) bucket).

use anyhow::{anyhow, ensure, Context};

use crate::linalg::Matrix;
use crate::rls::Predictor;
use crate::runtime::{lit, Runtime};

/// Latency/throughput statistics of a serving run.
#[derive(Clone, Copy, Debug)]
pub struct ServeStats {
    /// Requests (examples) answered.
    pub requests: usize,
    /// Batches executed.
    pub batches: usize,
    /// Mean per-batch latency, seconds.
    pub mean_batch_s: f64,
    /// p50 per-batch latency.
    pub p50_batch_s: f64,
    /// p99 per-batch latency.
    pub p99_batch_s: f64,
    /// Examples per second.
    pub throughput: f64,
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[pos]
}

/// Serve every column of `x` (full feature-major matrix) in batches with
/// the native predictor. Returns predictions and stats. Errors on a
/// zero batch size, mirroring [`serve_pjrt`].
pub fn serve_native(
    p: &Predictor,
    x: &Matrix,
    batch: usize,
) -> anyhow::Result<(Vec<f64>, ServeStats)> {
    ensure!(batch > 0, "batch must be positive");
    let m = x.cols();
    let mut preds = vec![0.0; m];
    let mut lat = Vec::new();
    let mut start = 0;
    while start < m {
        let end = (start + batch).min(m);
        let idx: Vec<usize> = (start..end).collect();
        let xb = x.select_cols(&idx);
        let t0 = std::time::Instant::now();
        let pb = p.predict_matrix(&xb);
        lat.push(t0.elapsed().as_secs_f64());
        preds[start..end].copy_from_slice(&pb);
        start = end;
    }
    let stats = summarize(m, &lat);
    Ok((preds, stats))
}

/// Serve through the PJRT `predict` artifact. The predictor's weights are
/// padded into the artifact's (k_b, t_b) bucket; each batch pads the
/// selected-feature rows of the batch into the same bucket.
pub fn serve_pjrt(
    rt: &Runtime,
    p: &Predictor,
    x: &Matrix,
    batch: usize,
) -> anyhow::Result<(Vec<f64>, ServeStats)> {
    ensure!(batch > 0, "batch must be positive");
    let k = p.selected.len();
    // pick the smallest predict bucket that fits (k, batch)
    let mut buckets: Vec<(usize, usize)> = rt
        .manifest()
        .iter()
        .filter(|e| e.entry == "predict")
        .map(|e| (e.dim1.1, e.dim2.1))
        .collect();
    buckets.sort_by_key(|&(kb, tb)| kb * tb);
    let (kb, tb) = buckets
        .into_iter()
        .find(|&(kb, tb)| kb >= k && tb >= batch)
        .ok_or_else(|| {
            anyhow!("no predict artifact fits (k={k}, batch={batch})")
        })?;
    let exe = rt.executable("predict", kb, tb)?;

    let mut w_pad = vec![0.0; kb];
    w_pad[..k].copy_from_slice(&p.weights);
    let w_lit = lit::vec_f64(&w_pad);

    let m = x.cols();
    let mut preds = vec![0.0; m];
    let mut lat = Vec::new();
    let mut start = 0;
    while start < m {
        let end = (start + batch).min(m);
        let t = end - start;
        // gather selected-feature rows of this batch into (kb, tb)
        let mut xb = vec![0.0; kb * tb];
        for (r, &feat) in p.selected.iter().enumerate() {
            let row = x.row(feat);
            xb[r * tb..r * tb + t].copy_from_slice(&row[start..end]);
        }
        let x_lit = lit::mat_f64(&xb, kb, tb)?;
        let t0 = std::time::Instant::now();
        let outs = Runtime::run_tuple(&exe, &[w_lit.clone(), x_lit])?;
        lat.push(t0.elapsed().as_secs_f64());
        let out = lit::to_vec_f64(&outs[0]).context("predict output")?;
        preds[start..end].copy_from_slice(&out[..t]);
        start = end;
    }
    Ok((preds, summarize(m, &lat)))
}

fn summarize(requests: usize, lat: &[f64]) -> ServeStats {
    let mut sorted = lat.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total: f64 = lat.iter().sum();
    ServeStats {
        requests,
        batches: lat.len(),
        mean_batch_s: if lat.is_empty() { 0.0 } else { total / lat.len() as f64 },
        p50_batch_s: percentile(&sorted, 0.5),
        p99_batch_s: percentile(&sorted, 0.99),
        throughput: if total > 0.0 { requests as f64 / total } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_predictor() -> Predictor {
        Predictor { selected: vec![0, 2], weights: vec![1.0, -2.0] }
    }

    #[test]
    fn zero_batch_is_an_error_not_a_panic() {
        let ds = crate::data::synthetic::two_gaussians(10, 5, 2, 1.0, 2);
        let err = serve_native(&toy_predictor(), &ds.x, 0).unwrap_err();
        assert!(err.to_string().contains("batch"), "{err}");
    }

    #[test]
    fn native_serving_matches_direct_prediction() {
        let ds = crate::data::synthetic::two_gaussians(37, 5, 2, 1.0, 1);
        let p = toy_predictor();
        let (preds, stats) = serve_native(&p, &ds.x, 8).unwrap();
        assert_eq!(preds.len(), 37);
        assert_eq!(stats.requests, 37);
        assert_eq!(stats.batches, 5); // ceil(37/8)
        let direct = p.predict_matrix(&ds.x);
        for (a, b) in preds.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-12);
        }
        assert!(stats.throughput > 0.0);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }
}
