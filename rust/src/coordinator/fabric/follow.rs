//! `SocketFollower`: a [`ModelSource`] fed over the fabric, with
//! graceful degradation to the checkpoint trail.
//!
//! A background reader owns the connection lifecycle: connect with a
//! deadline, read frames under a read timeout (a trainer that sends
//! neither models nor heartbeats for that long is declared hung), and
//! reconnect through capped exponential backoff with deterministic
//! jitter ([`super::Backoff`]). Received models land on an internal
//! [`ModelBus`], so [`SocketFollower::poll_model`] never blocks the
//! serving loop.
//!
//! Degradation ladder: while connected, the wire is the source of
//! truth; on publisher loss the follower keeps serving its last-good
//! model and — when a checkpoint trail is configured — picks up
//! anything newer the trainer managed to flush before dying; when the
//! trainer restarts, the socket wins again. A `rounds`-monotonic
//! filter across both sources guarantees the served model never
//! regresses.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::net::{Addr, Conn};
use super::wire::{self, Frame};
use super::{Backoff, FabricOptions};
use crate::coordinator::serve::{
    CheckpointFollower, ModelSource, ModelUpdate,
};
use crate::coordinator::stream::{BusFollower, ModelBus};

/// Follower health snapshot (observability for tests and the fleet).
#[derive(Clone, Copy, Debug)]
pub struct FollowerStatus {
    /// Currently holding a live connection to the publisher.
    pub connected: bool,
    /// Successful connections beyond the first (i.e. recoveries).
    pub reconnects: u64,
    /// The publisher sent [`Frame::Shutdown`]: the model stream is
    /// complete and no further reconnects will be attempted.
    pub publisher_done: bool,
}

#[derive(Default)]
struct Shared {
    connected: AtomicBool,
    connects: AtomicU64,
    done: AtomicBool,
    data_hash: Mutex<Option<u64>>,
}

/// A [`ModelSource`] whose models arrive over a fabric socket, with an
/// optional checkpoint-trail fallback for publisher outages.
pub struct SocketFollower {
    relay: BusFollower,
    trail: Option<CheckpointFollower>,
    last_rounds: usize,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
    reader: Option<std::thread::JoinHandle<()>>,
}

impl SocketFollower {
    /// Start following `addr`. Construction never fails: all fallible
    /// work (connecting, reconnecting) happens on the background
    /// reader, which retries under backoff until the publisher
    /// appears. `trail` names a checkpoint directory to fall back to
    /// while disconnected.
    pub fn connect(
        addr: Addr,
        trail: Option<PathBuf>,
        opts: FabricOptions,
    ) -> SocketFollower {
        let bus = ModelBus::new();
        let relay = bus.follower();
        let shared = Arc::new(Shared::default());
        let stop = Arc::new(AtomicBool::new(false));
        let t_shared = Arc::clone(&shared);
        let t_stop = Arc::clone(&stop);
        let reader = std::thread::spawn(move || {
            reader_loop(addr, opts, bus, t_shared, t_stop)
        });
        SocketFollower {
            relay,
            trail: trail.map(CheckpointFollower::new),
            last_rounds: 0,
            shared,
            stop,
            reader: Some(reader),
        }
    }

    /// Current health snapshot.
    pub fn status(&self) -> FollowerStatus {
        FollowerStatus {
            connected: self.shared.connected.load(Ordering::SeqCst),
            reconnects: self
                .shared
                .connects
                .load(Ordering::SeqCst)
                .saturating_sub(1),
            publisher_done: self.shared.done.load(Ordering::SeqCst),
        }
    }

    /// Block until a non-empty model is available (from the wire or
    /// the trail), honoring `timeout` as wall-clock seconds.
    pub fn wait_for_model(
        &mut self,
        timeout: Duration,
        poll: Duration,
    ) -> anyhow::Result<ModelUpdate> {
        // xtask-allow: no-raw-instant -- startup deadline for the first
        // model over the fabric; wall-clock by nature, no session exists
        let deadline = std::time::Instant::now().checked_add(timeout);
        loop {
            if let Some(update) = self.poll_model()? {
                return Ok(update);
            }
            // xtask-allow: no-raw-instant -- same startup deadline
            let now = std::time::Instant::now();
            let remaining = match deadline {
                Some(d) if now < d => d - now,
                Some(_) => anyhow::bail!(
                    "no model arrived over the fabric within {:.1}s",
                    timeout.as_secs_f64()
                ),
                None => poll,
            };
            std::thread::sleep(poll.min(remaining));
        }
    }
}

impl ModelSource for SocketFollower {
    fn poll_model(&mut self) -> anyhow::Result<Option<ModelUpdate>> {
        // the wire is the fresh source: latest-wins via the relay bus
        if let Some(v) = self.relay.poll() {
            if v.rounds > self.last_rounds {
                self.last_rounds = v.rounds;
                let data_hash = *self
                    .shared
                    .data_hash
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                return Ok(Some(ModelUpdate {
                    predictor: v.predictor.clone(),
                    rounds: v.rounds,
                    data_hash,
                }));
            }
        }
        // degraded: publisher unreachable — consult the trail, never
        // surfacing anything older than what the wire already served
        if !self.shared.connected.load(Ordering::SeqCst)
            && !self.shared.done.load(Ordering::SeqCst)
        {
            if let Some(trail) = &mut self.trail {
                if let Some(update) = trail.poll_model()? {
                    if update.rounds > self.last_rounds {
                        self.last_rounds = update.rounds;
                        return Ok(Some(update));
                    }
                }
            }
        }
        Ok(None)
    }
}

impl Drop for SocketFollower {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// Background connection owner: connect → drain frames → reconnect,
/// forever (until stop or a clean publisher shutdown).
fn reader_loop(
    addr: Addr,
    opts: FabricOptions,
    bus: ModelBus,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
) {
    let mut backoff = Backoff::from_options(&opts);
    let mut last_published = 0usize;
    while !stop.load(Ordering::SeqCst) {
        let conn = match Conn::connect(&addr, opts.connect_timeout) {
            Ok(c) => c,
            Err(_) => {
                sleep_interruptible(backoff.next_delay(), &stop);
                continue;
            }
        };
        if conn
            .set_timeouts(Some(opts.read_timeout), Some(opts.write_timeout))
            .is_err()
        {
            conn.shutdown();
            sleep_interruptible(backoff.next_delay(), &stop);
            continue;
        }
        backoff.reset();
        shared.connects.fetch_add(1, Ordering::SeqCst);
        shared.connected.store(true, Ordering::SeqCst);
        let done = drain_connection(
            conn,
            &bus,
            &shared,
            &stop,
            &mut last_published,
        );
        shared.connected.store(false, Ordering::SeqCst);
        if done {
            shared.done.store(true, Ordering::SeqCst);
            bus.close();
            return;
        }
        // lost mid-stream: retry from a fresh (short) backoff — the
        // publisher was just here, so it is likely restarting
        sleep_interruptible(backoff.next_delay(), &stop);
    }
}

/// Read frames until error, stop, or shutdown. Returns `true` on a
/// clean [`Frame::Shutdown`] (stream complete), `false` to reconnect.
fn drain_connection(
    mut conn: Conn,
    bus: &ModelBus,
    shared: &Shared,
    stop: &AtomicBool,
    last_published: &mut usize,
) -> bool {
    loop {
        if stop.load(Ordering::SeqCst) {
            conn.shutdown();
            // treated as done: the follower itself is being dropped
            return true;
        }
        match wire::read_frame(&mut conn) {
            Ok(Frame::Model(m)) => {
                *shared
                    .data_hash
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner) =
                    m.data_hash;
                // monotone + non-empty guard: a restarted trainer
                // replaying older rounds must never regress the server
                if m.rounds > *last_published
                    && !m.predictor.selected.is_empty()
                {
                    *last_published = m.rounds;
                    bus.publish(m.predictor, m.rounds);
                }
            }
            Ok(Frame::Heartbeat { .. }) => {}
            Ok(Frame::Shutdown) => {
                conn.shutdown();
                return true;
            }
            Ok(_) => {
                // protocol confusion: this socket is not a publisher
                conn.shutdown();
                return false;
            }
            Err(_) => {
                // torn frame, EOF, or heartbeat silence past the read
                // timeout: drop the connection and reconnect
                conn.shutdown();
                return false;
            }
        }
    }
}

/// Sleep in small slices so a drop of the follower is not stuck behind
/// a long backoff delay.
fn sleep_interruptible(total: Duration, stop: &AtomicBool) {
    let slice = Duration::from_millis(20);
    let mut remaining = total;
    while remaining > Duration::ZERO {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let step = remaining.min(slice);
        std::thread::sleep(step);
        remaining = remaining.saturating_sub(step);
    }
}
