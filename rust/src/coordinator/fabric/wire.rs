//! Length-prefixed binary wire format for model versions.
//!
//! Every frame is a self-checking envelope, byte-compatible across
//! processes and platforms:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"GRLF"
//! 4       4     format version (u32 LE, currently 1)
//! 8       1     frame kind
//! 9       4     payload length (u32 LE, capped at MAX_PAYLOAD)
//! 13      L     payload (kind-specific, fixed little-endian layout)
//! 13+L    8     FNV-1a 64 end-checksum of bytes 0..13+L (u64 LE)
//! ```
//!
//! Model payloads carry **exact `f64` bit patterns** (`to_bits`, LE) —
//! the same contract as the checkpoint codec, so a model that crossed
//! the wire predicts bit-identically to the one the trainer published.
//! The checksum reuses [`Fnv64`], the hasher behind checkpoint
//! fingerprints, and is recomputed field-by-field on decode
//! ([`Fnv64::write_u32`] for the header words); any torn, bit-flipped,
//! wrong-version, or oversized frame is refused with a distinct error
//! instead of ever yielding a wrong model.

use std::io::Read;

use anyhow::{bail, ensure, Context};

use crate::data::fingerprint::Fnv64;
use crate::rls::Predictor;

/// Frame magic: "GRLF" (greedy-rls fabric).
pub const MAGIC: [u8; 4] = *b"GRLF";

/// Wire format version; bump on any layout change.
pub const FORMAT_VERSION: u32 = 1;

/// Fixed envelope sizes: header (magic + version + kind + length) and
/// trailing checksum.
pub const HEADER_LEN: usize = 13;

/// Trailing checksum size in bytes.
pub const CHECKSUM_LEN: usize = 8;

/// Hard cap on a frame payload. A length prefix above this is refused
/// before any allocation — a torn stream or hostile peer cannot make a
/// follower allocate gigabytes.
pub const MAX_PAYLOAD: usize = 16 * 1024 * 1024;

/// A model as it travels on the wire: the predictor plus provenance.
/// Selection `rounds` is the version key — it is monotone for a live
/// trainer and comparable with the checkpoint trail, so a follower fed
/// from both sources never regresses.
#[derive(Clone, Debug, PartialEq)]
pub struct WireModel {
    /// Selection rounds behind this model.
    pub rounds: usize,
    /// Fingerprint of the training data
    /// ([`crate::data::fingerprint::fingerprint_xy`]) when the publisher
    /// carries one; `None` for sources without a dataset in hand.
    pub data_hash: Option<u64>,
    /// The sparse model itself, exact to the bit.
    pub predictor: Predictor,
}

/// One fabric frame. Kinds 1–2 and 7 flow trainer → server
/// (model push); 3–6 and 8 serve the query front of `serve --listen`.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// A published model version (kind 1).
    Model(WireModel),
    /// Publisher liveness beacon (kind 2); `seq` increases per beacon.
    Heartbeat {
        /// Beacon sequence number on this connection.
        seq: u64,
    },
    /// Prediction request (kind 3): a feature-major `rows × cols` batch
    /// of full feature vectors, column per example.
    Query {
        /// Feature count (matrix rows).
        rows: usize,
        /// Example count (matrix columns).
        cols: usize,
        /// Feature-major values, `rows * cols` exactly.
        values: Vec<f64>,
    },
    /// Answer to a [`Frame::Query`] (kind 4).
    Predictions {
        /// Selection rounds of the model that answered.
        rounds: usize,
        /// One prediction per queried example.
        values: Vec<f64>,
    },
    /// Admission control: the server's queues are full (kind 5). The
    /// client should back off for `retry_after_ms` instead of queueing
    /// behind growing latency.
    Overloaded {
        /// Suggested client back-off, milliseconds.
        retry_after_ms: u64,
    },
    /// Ask a server for its current model (kind 6).
    ModelRequest,
    /// Clean end-of-stream: the trainer's bus closed; no newer versions
    /// will ever arrive on this connection (kind 7).
    Shutdown,
    /// Protocol-level refusal with a reason (kind 8) — e.g. a query
    /// whose feature count is smaller than the model's largest selected
    /// index.
    Refused {
        /// Human-readable refusal reason.
        reason: String,
    },
}

impl Frame {
    fn kind(&self) -> u8 {
        match self {
            Frame::Model(_) => 1,
            Frame::Heartbeat { .. } => 2,
            Frame::Query { .. } => 3,
            Frame::Predictions { .. } => 4,
            Frame::Overloaded { .. } => 5,
            Frame::ModelRequest => 6,
            Frame::Shutdown => 7,
            Frame::Refused { .. } => 8,
        }
    }

    /// Serialize to the full framed byte sequence (header + payload +
    /// end-checksum), ready for one `write_all`.
    pub fn encode(&self) -> Vec<u8> {
        let payload = self.encode_payload();
        let mut out =
            Vec::with_capacity(HEADER_LEN + payload.len() + CHECKSUM_LEN);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.push(self.kind());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        let sum = seal_hash(self.kind(), &payload);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            Frame::Model(m) => {
                p.extend_from_slice(&(m.rounds as u64).to_le_bytes());
                p.push(u8::from(m.data_hash.is_some()));
                p.extend_from_slice(
                    &m.data_hash.unwrap_or(0).to_le_bytes(),
                );
                let k = m.predictor.selected.len();
                p.extend_from_slice(&(k as u32).to_le_bytes());
                for &f in &m.predictor.selected {
                    p.extend_from_slice(&(f as u64).to_le_bytes());
                }
                for &w in &m.predictor.weights {
                    p.extend_from_slice(&w.to_bits().to_le_bytes());
                }
            }
            Frame::Heartbeat { seq } => {
                p.extend_from_slice(&seq.to_le_bytes());
            }
            Frame::Query { rows, cols, values } => {
                p.extend_from_slice(&(*rows as u32).to_le_bytes());
                p.extend_from_slice(&(*cols as u32).to_le_bytes());
                for &v in values {
                    p.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
            Frame::Predictions { rounds, values } => {
                p.extend_from_slice(&(*rounds as u64).to_le_bytes());
                p.extend_from_slice(&(values.len() as u32).to_le_bytes());
                for &v in values {
                    p.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
            Frame::Overloaded { retry_after_ms } => {
                p.extend_from_slice(&retry_after_ms.to_le_bytes());
            }
            Frame::ModelRequest | Frame::Shutdown => {}
            Frame::Refused { reason } => {
                let bytes = reason.as_bytes();
                p.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
                p.extend_from_slice(bytes);
            }
        }
        p
    }

    /// Decode one complete frame from its exact byte sequence. Refuses
    /// (with distinct errors) truncation, bad magic, an unsupported
    /// format version, an oversized length prefix, checksum mismatch,
    /// and unknown kinds — a torn frame can never decode into a wrong
    /// model.
    pub fn decode(bytes: &[u8]) -> anyhow::Result<Frame> {
        ensure!(
            bytes.len() >= HEADER_LEN + CHECKSUM_LEN,
            "truncated frame: {} bytes is shorter than the {} byte \
             envelope",
            bytes.len(),
            HEADER_LEN + CHECKSUM_LEN
        );
        let mut header = [0u8; HEADER_LEN];
        header.copy_from_slice(&bytes[..HEADER_LEN]);
        let (kind, plen) = parse_header(&header)?;
        ensure!(
            bytes.len() == HEADER_LEN + plen + CHECKSUM_LEN,
            "truncated frame: payload declares {plen} bytes but the \
             frame carries {}",
            bytes.len().saturating_sub(HEADER_LEN + CHECKSUM_LEN)
        );
        let payload = &bytes[HEADER_LEN..HEADER_LEN + plen];
        let stored = read_u64_le(&bytes[HEADER_LEN + plen..]);
        let computed = seal_hash(kind, payload);
        ensure!(
            stored == computed,
            "frame checksum mismatch: stored {stored:016x}, computed \
             {computed:016x} — corrupt frame"
        );
        Frame::decode_payload(kind, payload)
    }

    fn decode_payload(kind: u8, payload: &[u8]) -> anyhow::Result<Frame> {
        let mut c = Cursor { buf: payload, pos: 0 };
        let frame = match kind {
            1 => {
                let rounds = c.u64()? as usize;
                let has_hash = c.u8()? != 0;
                let hash = c.u64()?;
                let k = c.u32()? as usize;
                let mut selected = Vec::with_capacity(k.min(1 << 20));
                for _ in 0..k {
                    selected.push(c.u64()? as usize);
                }
                let mut weights = Vec::with_capacity(k.min(1 << 20));
                for _ in 0..k {
                    weights.push(f64::from_bits(c.u64()?));
                }
                Frame::Model(WireModel {
                    rounds,
                    data_hash: has_hash.then_some(hash),
                    predictor: Predictor { selected, weights },
                })
            }
            2 => Frame::Heartbeat { seq: c.u64()? },
            3 => {
                let rows = c.u32()? as usize;
                let cols = c.u32()? as usize;
                let count = rows.checked_mul(cols).with_context(|| {
                    format!("query dims {rows}×{cols} overflow")
                })?;
                let mut values = Vec::with_capacity(count.min(1 << 21));
                for _ in 0..count {
                    values.push(f64::from_bits(c.u64()?));
                }
                Frame::Query { rows, cols, values }
            }
            4 => {
                let rounds = c.u64()? as usize;
                let count = c.u32()? as usize;
                let mut values = Vec::with_capacity(count.min(1 << 21));
                for _ in 0..count {
                    values.push(f64::from_bits(c.u64()?));
                }
                Frame::Predictions { rounds, values }
            }
            5 => Frame::Overloaded { retry_after_ms: c.u64()? },
            6 => Frame::ModelRequest,
            7 => Frame::Shutdown,
            8 => {
                let len = c.u32()? as usize;
                let bytes = c.take(len)?.to_vec();
                let reason = String::from_utf8(bytes)
                    .map_err(|_| anyhow::anyhow!(
                        "invalid utf-8 in refusal reason"
                    ))?;
                Frame::Refused { reason }
            }
            other => bail!("unknown frame kind {other}"),
        };
        c.finished()?;
        Ok(frame)
    }
}

/// End-checksum over the framed fields, recomputed field-by-field in
/// exactly the byte order they serialize (so it equals the FNV-1a of
/// the raw header + payload bytes).
fn seal_hash(kind: u8, payload: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(&MAGIC);
    h.write_u32(FORMAT_VERSION);
    h.write(&[kind]);
    h.write_u32(payload.len() as u32);
    h.write(payload);
    h.finish()
}

/// Validate a frame header; returns (kind, payload length).
fn parse_header(h: &[u8; HEADER_LEN]) -> anyhow::Result<(u8, usize)> {
    ensure!(
        h[..4] == MAGIC,
        "bad frame magic {:02x}{:02x}{:02x}{:02x} (stream desynchronized \
         or corrupt)",
        h[0],
        h[1],
        h[2],
        h[3]
    );
    let version = u32::from_le_bytes([h[4], h[5], h[6], h[7]]);
    ensure!(
        version == FORMAT_VERSION,
        "unsupported wire format version {version} (this build speaks \
         {FORMAT_VERSION})"
    );
    let plen = u32::from_le_bytes([h[9], h[10], h[11], h[12]]) as usize;
    ensure!(
        plen <= MAX_PAYLOAD,
        "frame length {plen} exceeds the {MAX_PAYLOAD} byte payload cap \
         (corrupt or hostile length prefix)"
    );
    Ok((h[8], plen))
}

fn read_u64_le(bytes: &[u8]) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&bytes[..8]);
    u64::from_le_bytes(b)
}

/// Bounds-checked payload reader: every decode error is "truncated",
/// never a panic.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(end) => {
                let out = &self.buf[self.pos..end];
                self.pos = end;
                Ok(out)
            }
            None => bail!(
                "truncated frame payload: wanted {n} bytes at offset {} \
                 of {}",
                self.pos,
                self.buf.len()
            ),
        }
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(read_u64_le(self.take(8)?))
    }

    fn finished(&self) -> anyhow::Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "frame payload has {} trailing bytes",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

/// Read exactly one frame from a stream whose read timeout is already
/// configured. Any mid-frame timeout, EOF, or validation failure is an
/// error — the caller treats it as a lost/hung peer and reconnects.
pub fn read_frame<R: Read>(r: &mut R) -> anyhow::Result<Frame> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header).context("frame header read")?;
    read_after_header(r, header)
}

/// Like [`read_frame`], but a read timeout *before the first byte* of a
/// frame returns `Ok(None)` (an idle tick) instead of an error, so a
/// serving loop can interleave shutdown checks with blocking reads.
/// A timeout *inside* a frame is still an error: the peer is hung
/// mid-send and the connection cannot be trusted.
pub fn read_frame_or_idle<R: Read>(
    r: &mut R,
) -> anyhow::Result<Option<Frame>> {
    let mut header = [0u8; HEADER_LEN];
    match r.read(&mut header[..1]) {
        Ok(0) => bail!("connection closed by peer"),
        Ok(_) => {}
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::Interrupted
            ) =>
        {
            return Ok(None)
        }
        Err(e) => return Err(e).context("frame read"),
    }
    r.read_exact(&mut header[1..]).context("frame header read")?;
    read_after_header(r, header).map(Some)
}

fn read_after_header<R: Read>(
    r: &mut R,
    header: [u8; HEADER_LEN],
) -> anyhow::Result<Frame> {
    // validate the length prefix BEFORE allocating or reading: an
    // oversized or garbage length must not drive an unbounded read
    let (_kind, plen) = parse_header(&header)?;
    let mut rest = vec![0u8; plen + CHECKSUM_LEN];
    r.read_exact(&mut rest).context("frame body read")?;
    let mut full = Vec::with_capacity(HEADER_LEN + rest.len());
    full.extend_from_slice(&header);
    full.extend_from_slice(&rest);
    Frame::decode(&full)
}

/// Write one frame as a single `write_all` (frame granularity is what
/// the fault-injection wrapper keys on) and flush it.
pub fn write_frame<W: std::io::Write>(
    w: &mut W,
    frame: &Frame,
) -> anyhow::Result<()> {
    w.write_all(&frame.encode()).context("frame write")?;
    w.flush().context("frame flush")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_model() -> Frame {
        Frame::Model(WireModel {
            rounds: 3,
            data_hash: Some(0xdead_beef_cafe_f00d),
            predictor: Predictor {
                selected: vec![4, 0, 17],
                weights: vec![1.5, -0.25, f64::from_bits(0x7ff8_0000_0000_0001)],
            },
        })
    }

    #[test]
    fn roundtrip_every_kind() {
        let frames = vec![
            sample_model(),
            Frame::Heartbeat { seq: 9 },
            Frame::Query {
                rows: 2,
                cols: 3,
                values: vec![1.0, -0.0, 2.5, 3.0, f64::MIN, f64::MAX],
            },
            Frame::Predictions { rounds: 5, values: vec![0.25, -1.0] },
            Frame::Overloaded { retry_after_ms: 40 },
            Frame::ModelRequest,
            Frame::Shutdown,
            Frame::Refused { reason: "nope".into() },
        ];
        for f in frames {
            let bytes = f.encode();
            let back = Frame::decode(&bytes).unwrap();
            // bit-identity: re-encoding the decoded frame reproduces
            // the exact byte sequence (covers every f64 bit pattern)
            assert_eq!(back.encode(), bytes, "{f:?}");
        }
    }

    #[test]
    fn truncated_frames_are_refused() {
        let bytes = sample_model().encode();
        for cut in [0, 1, HEADER_LEN - 1, HEADER_LEN + 3, bytes.len() - 1] {
            let err = Frame::decode(&bytes[..cut]).unwrap_err();
            assert!(
                err.to_string().contains("truncated"),
                "cut={cut}: {err}"
            );
        }
    }

    #[test]
    fn every_single_bit_flip_is_refused() {
        let bytes = sample_model().encode();
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 1;
            assert!(
                Frame::decode(&bad).is_err(),
                "bit flip at byte {i} went undetected"
            );
        }
    }

    #[test]
    fn oversized_length_prefix_is_refused_before_allocation() {
        let mut bytes = sample_model().encode();
        bytes[9..13].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = Frame::decode(&bytes).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
    }

    #[test]
    fn wrong_version_is_refused_even_resealed() {
        // bump the version and re-seal the checksum, mirroring the
        // checkpoint refusal suite: the version check itself must fire
        let f = sample_model();
        let payload = f.encode_payload();
        let mut bytes = f.encode();
        bytes[4..8].copy_from_slice(&2u32.to_le_bytes());
        let mut h = Fnv64::new();
        h.write(&MAGIC);
        h.write_u32(2);
        h.write(&[1]);
        h.write_u32(payload.len() as u32);
        h.write(&payload);
        let n = bytes.len();
        bytes[n - 8..].copy_from_slice(&h.finish().to_le_bytes());
        let err = Frame::decode(&bytes).unwrap_err();
        assert!(
            err.to_string().contains("unsupported wire format version"),
            "{err}"
        );
    }

    #[test]
    fn seal_hash_equals_fnv_of_raw_bytes() {
        let bytes = sample_model().encode();
        let body = &bytes[..bytes.len() - CHECKSUM_LEN];
        assert_eq!(
            crate::data::fingerprint::fnv64(body),
            read_u64_le(&bytes[bytes.len() - CHECKSUM_LEN..])
        );
    }
}
