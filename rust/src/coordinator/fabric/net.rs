//! Transport: Unix sockets first, TCP second, one `Conn` type over
//! both so the rest of the fabric never branches on transport.
//!
//! Every constructor here is deadline-aware: outbound connects use
//! `TcpStream::connect_timeout` (Unix connects carry a justified
//! allow — see the `no-unbounded-io` analyzer rule), accept loops are
//! non-blocking polls, and [`Conn::set_timeouts`] arms `SO_RCVTIMEO` /
//! `SO_SNDTIMEO` so no fabric read or write can hang forever on a
//! dead peer.

use std::fmt;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::str::FromStr;
use std::time::Duration;

use anyhow::{bail, Context};

/// A fabric endpoint address.
///
/// Accepted spellings: `unix:/path/to.sock`, `tcp:host:port`, a bare
/// path containing `/` (Unix), or a bare `host:port` (TCP).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Addr {
    /// Unix domain socket at the given path.
    Unix(PathBuf),
    /// TCP endpoint as `host:port`.
    Tcp(String),
}

impl Addr {
    /// Parse an address from its CLI spelling.
    pub fn parse(s: &str) -> anyhow::Result<Addr> {
        if let Some(rest) = s.strip_prefix("unix:") {
            if rest.is_empty() {
                bail!("empty unix socket path in {s:?}");
            }
            return Ok(Addr::Unix(PathBuf::from(rest)));
        }
        if let Some(rest) = s.strip_prefix("tcp:") {
            if !rest.contains(':') {
                bail!("tcp address {s:?} must be tcp:host:port");
            }
            return Ok(Addr::Tcp(rest.to_string()));
        }
        if s.contains('/') {
            return Ok(Addr::Unix(PathBuf::from(s)));
        }
        if s.contains(':') {
            return Ok(Addr::Tcp(s.to_string()));
        }
        bail!(
            "cannot parse address {s:?}: use unix:/path, tcp:host:port, \
             a /path, or host:port"
        )
    }
}

impl FromStr for Addr {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Addr> {
        Addr::parse(s)
    }
}

impl fmt::Display for Addr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Addr::Unix(p) => write!(f, "unix:{}", p.display()),
            Addr::Tcp(hp) => write!(f, "tcp:{hp}"),
        }
    }
}

/// A bound, non-blocking listener over either transport.
pub enum Listener {
    /// Unix domain socket listener.
    Unix(UnixListener),
    /// TCP listener.
    Tcp(TcpListener),
}

impl Listener {
    /// Bind `addr` in non-blocking mode (accepts are polled via
    /// [`Listener::accept_idle`] so a serving loop stays responsive to
    /// shutdown). A stale Unix socket file from a crashed predecessor
    /// is removed first.
    pub fn bind(addr: &Addr) -> anyhow::Result<Listener> {
        let listener = match addr {
            Addr::Unix(path) => {
                if path.exists() {
                    // stale socket from a SIGKILLed process; bind()
                    // would otherwise fail with AddrInUse forever
                    let _ = std::fs::remove_file(path);
                }
                let l = UnixListener::bind(path)
                    .with_context(|| format!("bind {addr}"))?;
                l.set_nonblocking(true)
                    .with_context(|| format!("nonblocking {addr}"))?;
                Listener::Unix(l)
            }
            Addr::Tcp(hp) => {
                let l = TcpListener::bind(hp.as_str())
                    .with_context(|| format!("bind {addr}"))?;
                l.set_nonblocking(true)
                    .with_context(|| format!("nonblocking {addr}"))?;
                Listener::Tcp(l)
            }
        };
        Ok(listener)
    }

    /// Poll for one pending connection. Returns `Ok(None)` when no
    /// client is waiting (the caller sleeps and re-checks its stop
    /// flag). Accepted connections are switched back to blocking mode;
    /// the caller must arm timeouts via [`Conn::set_timeouts`].
    pub fn accept_idle(&self) -> anyhow::Result<Option<Conn>> {
        let conn = match self {
            Listener::Unix(l) => match l.accept() {
                Ok((s, _)) => Conn::Unix(s),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    return Ok(None)
                }
                Err(e) => return Err(e).context("accept"),
            },
            Listener::Tcp(l) => match l.accept() {
                Ok((s, _)) => Conn::Tcp(s),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    return Ok(None)
                }
                Err(e) => return Err(e).context("accept"),
            },
        };
        conn.set_blocking().context("accepted conn mode")?;
        Ok(Some(conn))
    }
}

/// One established fabric connection over either transport.
pub enum Conn {
    /// Unix domain stream.
    Unix(UnixStream),
    /// TCP stream.
    Tcp(TcpStream),
}

impl Conn {
    /// Connect to `addr` with a deadline. TCP resolves and uses
    /// `connect_timeout`; Unix connects complete (or refuse)
    /// immediately unless the listener backlog is saturated.
    pub fn connect(addr: &Addr, timeout: Duration) -> anyhow::Result<Conn> {
        match addr {
            Addr::Unix(path) => {
                // xtask-allow: no-unbounded-io -- unix connect has no connect_timeout in std; the very next fabric step arms read/write timeouts via set_timeouts, bounding every subsequent op
                let s = UnixStream::connect(path)
                    .with_context(|| format!("connect {addr}"))?;
                Ok(Conn::Unix(s))
            }
            Addr::Tcp(hp) => {
                let mut last = None;
                for sa in hp
                    .as_str()
                    .to_socket_addrs()
                    .with_context(|| format!("resolve {addr}"))?
                {
                    match TcpStream::connect_timeout(&sa, timeout) {
                        Ok(s) => return Ok(Conn::Tcp(s)),
                        Err(e) => last = Some(e),
                    }
                }
                match last {
                    Some(e) => {
                        Err(e).with_context(|| format!("connect {addr}"))
                    }
                    None => bail!("{addr} resolved to no addresses"),
                }
            }
        }
    }

    /// Arm read/write deadlines (`SO_RCVTIMEO` / `SO_SNDTIMEO`) so no
    /// blocking I/O on this connection can outlive them.
    pub fn set_timeouts(
        &self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> std::io::Result<()> {
        match self {
            Conn::Unix(s) => {
                s.set_read_timeout(read)?;
                s.set_write_timeout(write)
            }
            Conn::Tcp(s) => {
                s.set_read_timeout(read)?;
                s.set_write_timeout(write)
            }
        }
    }

    fn set_blocking(&self) -> std::io::Result<()> {
        match self {
            Conn::Unix(s) => s.set_nonblocking(false),
            Conn::Tcp(s) => s.set_nonblocking(false),
        }
    }

    /// Shut down both directions, unblocking any peer mid-read.
    /// Errors are ignored — the socket may already be gone.
    pub fn shutdown(&self) {
        match self {
            Conn::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            Conn::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Unix(s) => s.read(buf),
            Conn::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Unix(s) => s.write(buf),
            Conn::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Unix(s) => s.flush(),
            Conn::Tcp(s) => s.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_spellings_parse() {
        assert_eq!(
            Addr::parse("unix:/tmp/a.sock").unwrap(),
            Addr::Unix(PathBuf::from("/tmp/a.sock"))
        );
        assert_eq!(
            Addr::parse("tcp:127.0.0.1:9000").unwrap(),
            Addr::Tcp("127.0.0.1:9000".into())
        );
        assert_eq!(
            Addr::parse("/tmp/b.sock").unwrap(),
            Addr::Unix(PathBuf::from("/tmp/b.sock"))
        );
        assert_eq!(
            Addr::parse("localhost:80").unwrap(),
            Addr::Tcp("localhost:80".into())
        );
        assert!(Addr::parse("nonsense").is_err());
        assert!(Addr::parse("unix:").is_err());
        assert!(Addr::parse("tcp:noport").is_err());
    }

    #[test]
    fn addr_display_roundtrips() {
        for s in ["unix:/tmp/a.sock", "tcp:127.0.0.1:9000"] {
            assert_eq!(Addr::parse(s).unwrap().to_string(), s);
        }
    }
}
