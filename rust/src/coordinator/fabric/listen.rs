//! `serve --listen`: a query-serving front over the fabric with
//! admission control, plus the client-side load generator that the
//! serve bench and fleet gauntlet drive against it.
//!
//! Queries fan out to a fixed pool of worker threads behind
//! **per-worker bounded queues**. A connection thread offers each
//! query to every worker once (round-robin from a rotating start); if
//! all queues are full the server answers
//! [`Frame::Overloaded`] immediately — shedding load with an explicit
//! retry-after beats queueing unbounded latency, and the client knows
//! exactly what happened.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Context;

use super::net::{Addr, Conn, Listener};
use super::wire::{self, Frame, WireModel};
use super::FabricOptions;
use crate::coordinator::serve::HotSwapServer;
use crate::linalg::Matrix;

/// Knobs for a listening server.
#[derive(Clone, Copy, Debug)]
pub struct ListenOptions {
    /// Prediction worker threads.
    pub workers: usize,
    /// Bounded queue depth per worker; the admission-control knob.
    pub queue_depth: usize,
    /// Retry-after hint (milliseconds) sent with
    /// [`Frame::Overloaded`].
    pub retry_after_ms: u64,
    /// Artificial per-query cost, for tests and benches that need a
    /// deterministically saturated worker pool. Zero in production.
    pub worker_delay: Duration,
    /// Fabric-wide timeouts.
    pub fabric: FabricOptions,
}

impl Default for ListenOptions {
    fn default() -> ListenOptions {
        ListenOptions {
            workers: 2,
            queue_depth: 2,
            retry_after_ms: 25,
            worker_delay: Duration::ZERO,
            fabric: FabricOptions::default(),
        }
    }
}

/// Monotonic counters, shared with tests and the fleet.
#[derive(Clone, Copy, Debug, Default)]
pub struct ListenCounts {
    /// Queries answered with predictions.
    pub answered: u64,
    /// Queries shed by admission control.
    pub shed: u64,
    /// Model snapshots served over [`Frame::ModelRequest`].
    pub model_requests: u64,
}

#[derive(Default)]
struct Stats {
    answered: AtomicU64,
    shed: AtomicU64,
    model_requests: AtomicU64,
}

struct Job {
    query: Matrix,
    reply: SyncSender<Frame>,
}

/// A running `serve --listen` front. Dropping it stops the accept
/// loop, drains the workers, and joins every connection thread.
pub struct ListenServer {
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    stats: Arc<Stats>,
}

impl ListenServer {
    /// Bind `addr` and serve queries against `server` (whose model a
    /// separate swap loop keeps fresh).
    pub fn spawn(
        addr: &Addr,
        server: Arc<HotSwapServer>,
        opts: ListenOptions,
    ) -> anyhow::Result<ListenServer> {
        let listener = Listener::bind(addr).context("listen bind")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(Stats::default());
        let workers_n = opts.workers.max(1);
        let depth = opts.queue_depth.max(1);
        let mut senders = Vec::with_capacity(workers_n);
        let mut workers = Vec::with_capacity(workers_n);
        for _ in 0..workers_n {
            let (tx, rx) = sync_channel::<Job>(depth);
            senders.push(tx);
            let w_server = Arc::clone(&server);
            workers.push(std::thread::spawn(move || {
                worker_loop(rx, w_server, opts.worker_delay)
            }));
        }
        let rr = Arc::new(AtomicUsize::new(0));
        let conn_handles: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let t_stop = Arc::clone(&stop);
        let t_stats = Arc::clone(&stats);
        let accept = std::thread::spawn(move || {
            while !t_stop.load(Ordering::SeqCst) {
                match listener.accept_idle() {
                    Ok(Some(conn)) => {
                        let c_senders = senders.clone();
                        let c_server = Arc::clone(&server);
                        let c_stats = Arc::clone(&t_stats);
                        let c_stop = Arc::clone(&t_stop);
                        let c_rr = Arc::clone(&rr);
                        let h = std::thread::spawn(move || {
                            serve_client(
                                conn, c_senders, c_server, c_stats,
                                c_stop, c_rr, opts,
                            )
                        });
                        conn_handles
                            .lock()
                            .unwrap_or_else(
                                std::sync::PoisonError::into_inner,
                            )
                            .push(h);
                    }
                    Ok(None) | Err(_) => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            }
            // joining here (not in drop) keeps ListenServer's drop from
            // racing conn threads that still hold sender clones
            let handles: Vec<_> = conn_handles
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .drain(..)
                .collect();
            for h in handles {
                let _ = h.join();
            }
        });
        Ok(ListenServer {
            stop,
            accept: Some(accept),
            workers,
            stats,
        })
    }

    /// Counter snapshot.
    pub fn counts(&self) -> ListenCounts {
        ListenCounts {
            answered: self.stats.answered.load(Ordering::SeqCst),
            shed: self.stats.shed.load(Ordering::SeqCst),
            model_requests: self
                .stats
                .model_requests
                .load(Ordering::SeqCst),
        }
    }
}

impl Drop for ListenServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // accept loop has joined the conn threads, so every worker
        // sender clone is gone once this vector drops below
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    rx: Receiver<Job>,
    server: Arc<HotSwapServer>,
    delay: Duration,
) {
    while let Ok(job) = rx.recv() {
        if delay > Duration::ZERO {
            std::thread::sleep(delay);
        }
        let model = server.snapshot();
        let max_feat =
            model.predictor.selected.iter().copied().max();
        let frame = match max_feat {
            Some(f) if f >= job.query.rows() => Frame::Refused {
                reason: format!(
                    "query has {} features but the model selects \
                     feature {f}",
                    job.query.rows()
                ),
            },
            _ => Frame::Predictions {
                rounds: model.rounds,
                values: model.predictor.predict_matrix(&job.query),
            },
        };
        let _ = job.reply.send(frame);
    }
}

/// One client connection: read frames under a short poll timeout (so
/// the stop flag stays live), answer queries through the worker pool,
/// shed on full queues.
fn serve_client(
    mut conn: Conn,
    senders: Vec<SyncSender<Job>>,
    server: Arc<HotSwapServer>,
    stats: Arc<Stats>,
    stop: Arc<AtomicBool>,
    rr: Arc<AtomicUsize>,
    opts: ListenOptions,
) {
    if conn
        .set_timeouts(
            Some(Duration::from_millis(100)),
            Some(opts.fabric.write_timeout),
        )
        .is_err()
    {
        return;
    }
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let frame = match wire::read_frame_or_idle(&mut conn) {
            Ok(None) => continue,
            Ok(Some(f)) => f,
            Err(_) => break,
        };
        let reply = match frame {
            Frame::Query { rows, cols, values } => {
                let query = Matrix::from_vec(rows, cols, values);
                match offer(&senders, &rr, query) {
                    Some(reply_rx) => {
                        match reply_rx.recv_timeout(Duration::from_secs(10))
                        {
                            Ok(f) => {
                                stats
                                    .answered
                                    .fetch_add(1, Ordering::SeqCst);
                                f
                            }
                            Err(_) => Frame::Refused {
                                reason: "worker reply timed out".into(),
                            },
                        }
                    }
                    None => {
                        stats.shed.fetch_add(1, Ordering::SeqCst);
                        Frame::Overloaded {
                            retry_after_ms: opts.retry_after_ms,
                        }
                    }
                }
            }
            Frame::ModelRequest => {
                stats.model_requests.fetch_add(1, Ordering::SeqCst);
                let model = server.snapshot();
                Frame::Model(WireModel {
                    rounds: model.rounds,
                    data_hash: None,
                    predictor: model.predictor.clone(),
                })
            }
            _ => Frame::Refused {
                reason: "unexpected frame kind for a serving front"
                    .into(),
            },
        };
        if wire::write_frame(&mut conn, &reply).is_err() {
            break;
        }
    }
    conn.shutdown();
}

/// Offer a query to each worker once, round-robin from a rotating
/// start. `None` means every queue was full: shed.
fn offer(
    senders: &[SyncSender<Job>],
    rr: &AtomicUsize,
    query: Matrix,
) -> Option<Receiver<Frame>> {
    let start = rr.fetch_add(1, Ordering::Relaxed);
    let (reply_tx, reply_rx) = sync_channel::<Frame>(1);
    let mut job = Job { query, reply: reply_tx };
    for i in 0..senders.len() {
        let idx = (start + i) % senders.len();
        match senders[idx].try_send(job) {
            Ok(()) => return Some(reply_rx),
            Err(TrySendError::Full(j) | TrySendError::Disconnected(j)) => {
                job = j;
            }
        }
    }
    None
}

/// Load-generator knobs.
#[derive(Clone, Copy, Debug)]
pub struct LoadOptions {
    /// Concurrent client connections.
    pub connections: usize,
    /// Queries sent per connection.
    pub queries_per_conn: usize,
    /// Examples per query batch.
    pub batch: usize,
    /// Aggregate target rate (queries/second) across all connections;
    /// 0 means unpaced (send as fast as the server answers).
    pub qps: f64,
    /// Seed for the per-connection batch offsets.
    pub seed: u64,
    /// Fabric-wide timeouts.
    pub fabric: FabricOptions,
}

impl Default for LoadOptions {
    fn default() -> LoadOptions {
        LoadOptions {
            connections: 2,
            queries_per_conn: 50,
            batch: 16,
            qps: 0.0,
            seed: 42,
            fabric: FabricOptions::default(),
        }
    }
}

/// Aggregate outcome of a load run.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadReport {
    /// Queries sent.
    pub sent: u64,
    /// Queries answered with predictions.
    pub answered: u64,
    /// Queries shed with [`Frame::Overloaded`].
    pub shed: u64,
    /// Queries refused at the protocol level.
    pub refused: u64,
    /// Transport errors (failed sends/reads, counted once each).
    pub errors: u64,
    /// Median answer latency, milliseconds.
    pub p50_ms: f64,
    /// Tail answer latency, milliseconds.
    pub p99_ms: f64,
    /// Wall-clock duration of the run, seconds.
    pub wall_s: f64,
    /// Achieved answered-queries-per-second.
    pub achieved_qps: f64,
}

/// Drive `opts.connections` clients against a listening server,
/// sending feature batches sliced out of `x`. Deterministic apart from
/// scheduling: batch offsets come from `opts.seed`.
pub fn run_load(
    addr: &Addr,
    x: &Matrix,
    opts: &LoadOptions,
) -> anyhow::Result<LoadReport> {
    let period = if opts.qps > 0.0 {
        Duration::from_secs_f64(opts.connections.max(1) as f64 / opts.qps)
    } else {
        Duration::ZERO
    };
    // xtask-allow: no-raw-instant -- load-generator latency clock;
    // wall-clock measurement is the whole point of the bench
    let t0 = std::time::Instant::now();
    let mut threads = Vec::new();
    for c in 0..opts.connections.max(1) {
        let addr = addr.clone();
        let opts = *opts;
        let batches = client_batches(x, &opts, c as u64);
        threads.push(std::thread::spawn(move || {
            client_loop(&addr, batches, &opts, period)
        }));
    }
    let mut report = LoadReport::default();
    let mut latencies: Vec<f64> = Vec::new();
    for t in threads {
        if let Ok((part, lats)) = t.join() {
            report.sent += part.sent;
            report.answered += part.answered;
            report.shed += part.shed;
            report.refused += part.refused;
            report.errors += part.errors;
            latencies.extend(lats);
        }
    }
    latencies.sort_by(f64::total_cmp);
    report.p50_ms = percentile(&latencies, 0.50);
    report.p99_ms = percentile(&latencies, 0.99);
    report.wall_s = t0.elapsed().as_secs_f64();
    report.achieved_qps = if report.wall_s > 0.0 {
        report.answered as f64 / report.wall_s
    } else {
        0.0
    };
    Ok(report)
}

/// Pre-slice up to 8 distinct feature-major batches for one client
/// (cycled during the run), offset deterministically by `conn_idx`.
fn client_batches(
    x: &Matrix,
    opts: &LoadOptions,
    conn_idx: u64,
) -> Vec<(usize, usize, Vec<f64>)> {
    let mut rng = crate::rng::Pcg64::new(opts.seed, conn_idx);
    let cols = x.cols();
    let batch = opts.batch.max(1).min(cols.max(1));
    let distinct = opts.queries_per_conn.clamp(1, 8);
    let mut out = Vec::with_capacity(distinct);
    for _ in 0..distinct {
        let start = if cols > batch { rng.below(cols - batch) } else { 0 };
        let mut values = Vec::with_capacity(x.rows() * batch);
        for r in 0..x.rows() {
            values.extend_from_slice(&x.row(r)[start..start + batch]);
        }
        out.push((x.rows(), batch, values));
    }
    out
}

fn client_loop(
    addr: &Addr,
    batches: Vec<(usize, usize, Vec<f64>)>,
    opts: &LoadOptions,
    period: Duration,
) -> (LoadReport, Vec<f64>) {
    let mut part = LoadReport::default();
    let mut latencies = Vec::new();
    let mut conn = match connect_client(addr, &opts.fabric) {
        Ok(c) => c,
        Err(_) => {
            part.errors += 1;
            return (part, latencies);
        }
    };
    for i in 0..opts.queries_per_conn {
        let (rows, cols, values) = &batches[i % batches.len()];
        let query = Frame::Query {
            rows: *rows,
            cols: *cols,
            values: values.clone(),
        };
        // xtask-allow: no-raw-instant -- per-query latency measurement
        let sent_at = std::time::Instant::now();
        part.sent += 1;
        let outcome = wire::write_frame(&mut conn, &query)
            .and_then(|()| wire::read_frame(&mut conn));
        match outcome {
            Ok(Frame::Predictions { .. }) => {
                part.answered += 1;
                latencies
                    .push(sent_at.elapsed().as_secs_f64() * 1000.0);
            }
            Ok(Frame::Overloaded { retry_after_ms }) => {
                part.shed += 1;
                std::thread::sleep(Duration::from_millis(
                    retry_after_ms.min(1000),
                ));
            }
            Ok(_) => part.refused += 1,
            Err(_) => {
                part.errors += 1;
                match connect_client(addr, &opts.fabric) {
                    Ok(c) => conn = c,
                    Err(_) => break,
                }
            }
        }
        if period > Duration::ZERO {
            let spent = sent_at.elapsed();
            if spent < period {
                std::thread::sleep(period - spent);
            }
        }
    }
    conn.shutdown();
    (part, latencies)
}

fn connect_client(
    addr: &Addr,
    fabric: &FabricOptions,
) -> anyhow::Result<Conn> {
    let conn = Conn::connect(addr, fabric.connect_timeout)?;
    conn.set_timeouts(
        Some(fabric.read_timeout.max(Duration::from_secs(5))),
        Some(fabric.write_timeout),
    )
    .context("client timeouts")?;
    Ok(conn)
}

fn percentile(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() as f64 - 1.0) * q).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}
