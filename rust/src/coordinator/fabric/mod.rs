//! Multi-process serving fabric: socket transport for model versions.
//!
//! The in-process [`crate::coordinator::stream::ModelBus`] stops at the
//! process boundary; this module carries it across one. A
//! [`publish::SocketPublisher`] bridges the bus onto a length-prefixed,
//! checksummed wire format ([`wire`]) over a Unix socket or TCP
//! ([`net`]); a [`follow::SocketFollower`] on the other side implements
//! [`crate::coordinator::serve::ModelSource`], so `serve_hotswap` works
//! unchanged whether its models arrive in-process, from a checkpoint
//! trail, or over the fabric.
//!
//! Robustness posture (every piece is exercised by fault injection in
//! `rust/tests/fabric.rs` and the CI fleet gauntlet):
//!
//! - **Torn frames never become models.** Frames end in an FNV-1a
//!   checksum; truncated, bit-flipped, wrong-version, or oversized
//!   frames are refused and the connection is dropped ([`wire`]).
//! - **No unbounded I/O.** Connects, reads, and writes all carry
//!   deadlines; heartbeats flow when the trainer is between rounds, so
//!   a silent peer is indistinguishable from a dead one only until the
//!   read timeout fires (enforced tree-wide by the `no-unbounded-io`
//!   analyzer rule).
//! - **Bounded, deterministic retry.** Reconnects use capped
//!   exponential backoff with jitter drawn from the repo's own
//!   [`crate::rng::Pcg64`] ([`Backoff`]), so fault-injection runs
//!   replay exactly.
//! - **Graceful degradation.** A follower that loses its publisher
//!   keeps serving the last-good model, falls back to the checkpoint
//!   trail if one is configured, and re-syncs over the socket when the
//!   trainer returns. Overloaded servers shed load with an explicit
//!   retry-after instead of queueing latency ([`listen`]).

pub mod fault;
pub mod fleet;
pub mod follow;
pub mod listen;
pub mod net;
pub mod publish;
pub mod wire;

use std::time::Duration;

use crate::rng::Pcg64;

/// Fabric-wide timing knobs. One struct so publisher, follower, and
/// fleet agree on defaults; every duration is a hard deadline, not a
/// hint.
#[derive(Clone, Copy, Debug)]
pub struct FabricOptions {
    /// Deadline for an outbound connect (TCP; Unix connects resolve
    /// immediately).
    pub connect_timeout: Duration,
    /// Read deadline on established connections. A follower that sees
    /// no frame (model *or* heartbeat) for this long declares the
    /// trainer hung and reconnects.
    pub read_timeout: Duration,
    /// Write deadline on established connections.
    pub write_timeout: Duration,
    /// Publisher heartbeat cadence; must be comfortably below
    /// `read_timeout` (the default is 3×).
    pub heartbeat: Duration,
    /// First reconnect delay; doubles per failed attempt.
    pub backoff_base: Duration,
    /// Ceiling on the reconnect delay.
    pub backoff_cap: Duration,
    /// Seed for deterministic backoff jitter.
    pub seed: u64,
}

impl Default for FabricOptions {
    fn default() -> FabricOptions {
        let heartbeat = Duration::from_millis(500);
        FabricOptions {
            connect_timeout: Duration::from_secs(1),
            read_timeout: heartbeat * 3,
            write_timeout: Duration::from_secs(1),
            heartbeat,
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(2),
            seed: 0x5eed_f8b1,
        }
    }
}

impl FabricOptions {
    /// Derive consistent options from a heartbeat cadence: the read
    /// timeout is 3 heartbeats (one lost beacon is tolerated, two are
    /// not), everything else keeps its default.
    pub fn with_heartbeat(heartbeat: Duration) -> FabricOptions {
        FabricOptions {
            heartbeat,
            read_timeout: heartbeat.saturating_mul(3),
            ..FabricOptions::default()
        }
    }
}

/// Capped exponential backoff with deterministic jitter.
///
/// Attempt `i` sleeps uniformly in `[d/2, d)` where
/// `d = min(base · 2^i, cap)`; the jitter stream is a dedicated
/// [`Pcg64`], so two followers with different seeds never thundering-herd
/// a restarted trainer, while a given seed replays the exact delay
/// sequence in tests.
#[derive(Clone, Debug)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempt: u32,
    rng: Pcg64,
}

impl Backoff {
    /// Backoff with explicit bounds and jitter seed.
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Backoff {
        Backoff { base, cap, attempt: 0, rng: Pcg64::new(seed, 77) }
    }

    /// Backoff using the bounds and seed from `opts`.
    pub fn from_options(opts: &FabricOptions) -> Backoff {
        Backoff::new(opts.backoff_base, opts.backoff_cap, opts.seed)
    }

    /// Next delay to sleep before retrying; advances the attempt
    /// counter.
    pub fn next_delay(&mut self) -> Duration {
        let doubled = self
            .base
            .saturating_mul(1u32.checked_shl(self.attempt).unwrap_or(u32::MAX));
        let d = doubled.min(self.cap).max(self.base.min(self.cap));
        self.attempt = self.attempt.saturating_add(1);
        let jitter = d.mul_f64(0.5 * self.rng.uniform());
        d / 2 + jitter
    }

    /// Reset after a successful connection so the next failure starts
    /// from `base` again.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }

    /// Failed attempts since the last [`Backoff::reset`].
    pub fn attempt(&self) -> u32 {
        self.attempt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_and_caps() {
        let base = Duration::from_millis(50);
        let cap = Duration::from_secs(2);
        let mut b = Backoff::new(base, cap, 7);
        let mut prev_bound = Duration::ZERO;
        for i in 0..12 {
            let d = b.next_delay();
            assert!(d >= base / 2, "attempt {i}: {d:?} below base/2");
            assert!(d <= cap, "attempt {i}: {d:?} above cap");
            // the deterministic lower bound (d_exp / 2) is monotone
            // until the cap is reached
            let exp = base
                .saturating_mul(1u32.checked_shl(i).unwrap_or(u32::MAX))
                .min(cap);
            assert!(d >= exp / 2);
            assert!(exp / 2 >= prev_bound);
            prev_bound = exp / 2;
        }
    }

    #[test]
    fn backoff_is_deterministic_per_seed() {
        let mk = |seed| {
            let mut b = Backoff::new(
                Duration::from_millis(10),
                Duration::from_millis(500),
                seed,
            );
            (0..8).map(|_| b.next_delay()).collect::<Vec<_>>()
        };
        assert_eq!(mk(1), mk(1));
        assert_ne!(mk(1), mk(2));
    }

    #[test]
    fn backoff_reset_restarts() {
        let mut b = Backoff::new(
            Duration::from_millis(10),
            Duration::from_secs(1),
            3,
        );
        for _ in 0..6 {
            b.next_delay();
        }
        assert_eq!(b.attempt(), 6);
        b.reset();
        assert_eq!(b.attempt(), 0);
        assert!(b.next_delay() < Duration::from_millis(10));
    }
}
