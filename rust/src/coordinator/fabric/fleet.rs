//! Fleet orchestration: one trainer + N serving processes, wired over
//! the fabric, with a built-in kill-one-server gauntlet.
//!
//! The `fleet` CLI subcommand builds a [`FleetPlan`] and calls
//! [`run_fleet`], which spawns real OS processes (the current
//! executable re-invoked as `train-serve --publish …` and
//! `serve --listen …`), drives load at them, optionally SIGKILLs one
//! server mid-stream, and proves the robustness story end to end:
//! the survivor keeps answering, the restarted server catches up from
//! the checkpoint trail, and every server ends the run serving the
//! same final model **byte-identically** (compared on the encoded
//! model frame, exact `f64` bit patterns included).

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use anyhow::{bail, ensure, Context};

use super::listen::{run_load, LoadOptions};
use super::net::{Addr, Conn};
use super::wire::{self, Frame, WireModel};
use super::FabricOptions;
use crate::linalg::Matrix;

/// Everything [`run_fleet`] needs to know.
#[derive(Clone, Debug)]
pub struct FleetPlan {
    /// Binary to re-invoke (normally `std::env::current_exe()`).
    pub exe: PathBuf,
    /// Scratch directory for sockets and the checkpoint trail.
    pub scratch: PathBuf,
    /// Dataset/selection flags forwarded verbatim to the trainer
    /// (e.g. `--synthetic 2000,300 --k 12 --seed 7`).
    pub dataset_flags: Vec<String>,
    /// Serving processes to spawn.
    pub servers: usize,
    /// Run the kill-one-server leg.
    pub kill_one: bool,
    /// Heartbeat cadence forwarded to every process, milliseconds.
    pub heartbeat_ms: u64,
    /// Selection budget `k` — the rounds every server must converge to.
    pub expected_rounds: usize,
    /// Queries per load leg (per server).
    pub queries: usize,
    /// Examples per query batch.
    pub batch: usize,
    /// Deadline for each server's first model and for final
    /// convergence.
    pub settle_timeout: Duration,
    /// Deadline for the trainer process to finish.
    pub train_timeout: Duration,
}

/// What the gauntlet observed.
#[derive(Clone, Copy, Debug)]
pub struct FleetOutcome {
    /// Servers that finished the run.
    pub servers: usize,
    /// Rounds of the converged final model.
    pub final_rounds: usize,
    /// Every server served the byte-identical final model frame.
    pub models_identical: bool,
    /// Queries the surviving server answered while one server was
    /// dead (kill leg only; 0 when `kill_one` is off).
    pub survivor_answered: u64,
    /// The SIGKILLed-and-restarted server reached the final model.
    pub restarted_caught_up: bool,
    /// Total queries shed by admission control across load legs.
    pub shed: u64,
}

/// Child processes with a kill-on-drop guard: whatever path exits
/// [`run_fleet`], no orphaned trainer or server outlives it.
struct Fleet {
    children: Vec<(String, Option<Child>)>,
}

impl Fleet {
    fn new() -> Fleet {
        Fleet { children: Vec::new() }
    }

    fn spawn(
        &mut self,
        name: &str,
        exe: &std::path::Path,
        args: &[String],
    ) -> anyhow::Result<usize> {
        let child = Command::new(exe)
            .args(args)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawn {name}"))?;
        self.children.push((name.to_string(), Some(child)));
        Ok(self.children.len() - 1)
    }

    /// SIGKILL one member (this is `Child::kill` — SIGKILL on unix, no
    /// chance to clean up; exactly the crash the gauntlet simulates).
    fn kill(&mut self, idx: usize) -> anyhow::Result<()> {
        if let Some((name, Some(child))) = self.children.get_mut(idx).map(
            |(n, c)| (n.clone(), c.as_mut()),
        ) {
            child.kill().with_context(|| format!("kill {name}"))?;
            let _ = child.wait();
        }
        if let Some((_, slot)) = self.children.get_mut(idx) {
            *slot = None;
        }
        Ok(())
    }

    /// Wait for one member with a deadline (polling `try_wait`).
    fn wait_with_deadline(
        &mut self,
        idx: usize,
        timeout: Duration,
    ) -> anyhow::Result<bool> {
        // xtask-allow: no-raw-instant -- subprocess wait deadline;
        // wall-clock supervision of real OS processes
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let Some((name, Some(child))) =
                self.children.get_mut(idx).map(|(n, c)| (n.clone(), c.as_mut()))
            else {
                return Ok(true);
            };
            match child.try_wait().with_context(|| format!("wait {name}"))? {
                Some(status) => {
                    ensure!(
                        status.success(),
                        "{name} exited with {status}"
                    );
                    if let Some((_, slot)) = self.children.get_mut(idx) {
                        *slot = None;
                    }
                    return Ok(true);
                }
                None => {
                    // xtask-allow: no-raw-instant -- same wait deadline
                    if std::time::Instant::now() >= deadline {
                        return Ok(false);
                    }
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        for (_, slot) in &mut self.children {
            if let Some(child) = slot.as_mut() {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

/// Fetch a server's current model over [`Frame::ModelRequest`].
pub fn fetch_model(
    addr: &Addr,
    opts: &FabricOptions,
) -> anyhow::Result<WireModel> {
    let mut conn = Conn::connect(addr, opts.connect_timeout)?;
    conn.set_timeouts(
        Some(opts.read_timeout.max(Duration::from_secs(2))),
        Some(opts.write_timeout),
    )
    .context("probe timeouts")?;
    wire::write_frame(&mut conn, &Frame::ModelRequest)?;
    let frame = wire::read_frame(&mut conn)?;
    conn.shutdown();
    match frame {
        Frame::Model(m) => Ok(m),
        other => bail!("expected a model frame, got {other:?}"),
    }
}

/// Poll until `addr` serves a model with at least `min_rounds`, or the
/// deadline passes.
pub fn wait_for_rounds(
    addr: &Addr,
    min_rounds: usize,
    timeout: Duration,
    opts: &FabricOptions,
) -> anyhow::Result<WireModel> {
    // xtask-allow: no-raw-instant -- fleet settle deadline across
    // process boundaries; wall-clock by nature
    let deadline = std::time::Instant::now() + timeout;
    loop {
        if let Ok(m) = fetch_model(addr, opts) {
            if m.rounds >= min_rounds {
                return Ok(m);
            }
        }
        // xtask-allow: no-raw-instant -- same settle deadline
        if std::time::Instant::now() >= deadline {
            bail!(
                "{addr} did not reach {min_rounds} rounds within {:.1}s",
                timeout.as_secs_f64()
            );
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

fn server_args(plan: &FleetPlan, idx: usize) -> (Addr, Vec<String>) {
    let sock = plan.scratch.join(format!("srv-{idx}.sock"));
    let addr = Addr::Unix(sock.clone());
    let args = vec![
        "serve".to_string(),
        "--listen".to_string(),
        format!("unix:{}", sock.display()),
        "--connect".to_string(),
        format!("unix:{}", plan.scratch.join("publish.sock").display()),
        "--follow".to_string(),
        plan.scratch.join("trail").display().to_string(),
        "--heartbeat-ms".to_string(),
        plan.heartbeat_ms.to_string(),
    ];
    (addr, args)
}

/// Run the fleet: spawn, load, (optionally) kill and recover, verify
/// byte-identical convergence, tear down. `x` supplies query batches
/// for the load legs (dimensions must match the trainer's dataset).
pub fn run_fleet(
    plan: &FleetPlan,
    x: &Matrix,
) -> anyhow::Result<FleetOutcome> {
    ensure!(plan.servers >= 1, "fleet needs at least one server");
    std::fs::create_dir_all(plan.scratch.join("trail"))
        .context("fleet scratch dir")?;
    let opts = FabricOptions::with_heartbeat(Duration::from_millis(
        plan.heartbeat_ms.max(1),
    ));
    let mut fleet = Fleet::new();

    // trainer: train-serve with the bus bridged onto the publish socket
    // and a checkpoint trail for degraded followers
    let mut trainer_args: Vec<String> = vec!["train-serve".into()];
    trainer_args.extend(plan.dataset_flags.iter().cloned());
    trainer_args.extend([
        "--publish".into(),
        format!("unix:{}", plan.scratch.join("publish.sock").display()),
        "--checkpoint-dir".into(),
        plan.scratch.join("trail").display().to_string(),
        "--checkpoint-every".into(),
        "1".into(),
        "--heartbeat-ms".into(),
        plan.heartbeat_ms.to_string(),
    ]);
    let trainer = fleet.spawn("trainer", &plan.exe, &trainer_args)?;

    let mut addrs = Vec::with_capacity(plan.servers);
    for i in 0..plan.servers {
        let (addr, args) = server_args(plan, i);
        fleet.spawn(&format!("server-{i}"), &plan.exe, &args)?;
        addrs.push(addr);
    }

    // every server must come up and serve *some* model
    for addr in &addrs {
        wait_for_rounds(addr, 1, plan.settle_timeout, &opts)
            .context("server startup")?;
    }

    let load = LoadOptions {
        connections: 2,
        queries_per_conn: plan.queries.max(1),
        batch: plan.batch,
        qps: 0.0,
        seed: 7,
        fabric: opts,
    };
    let mut shed = 0u64;
    for addr in &addrs {
        let report = run_load(addr, x, &load)?;
        ensure!(
            report.answered > 0,
            "{addr} answered no queries in the warm-up leg"
        );
        shed += report.shed;
    }

    // kill leg: SIGKILL the last server mid-stream, survivor must keep
    // answering, then the restarted process must catch up
    let mut survivor_answered = 0u64;
    let mut restarted_caught_up = false;
    if plan.kill_one && plan.servers >= 2 {
        let victim = plan.servers - 1;
        fleet.kill(1 + victim)?; // index 0 is the trainer
        let report = run_load(&addrs[0], x, &load)?;
        ensure!(
            report.answered > 0,
            "survivor stopped answering after the kill"
        );
        survivor_answered = report.answered;
        shed += report.shed;
        let (_, args) = server_args(plan, victim);
        fleet.spawn(&format!("server-{victim}-restarted"), &plan.exe, &args)?;
        wait_for_rounds(
            &addrs[victim],
            1,
            plan.settle_timeout,
            &opts,
        )
        .context("restarted server recovery")?;
        restarted_caught_up = true;
    }

    // the trainer must finish its selection budget and exit cleanly
    ensure!(
        fleet.wait_with_deadline(trainer, plan.train_timeout)?,
        "trainer did not finish within {:.1}s",
        plan.train_timeout.as_secs_f64()
    );

    // final convergence: every server serves the byte-identical model
    // at the full selection budget
    let mut frames: Vec<Vec<u8>> = Vec::with_capacity(addrs.len());
    for addr in &addrs {
        let m = wait_for_rounds(
            addr,
            plan.expected_rounds,
            plan.settle_timeout,
            &opts,
        )
        .context("final convergence")?;
        frames.push(
            Frame::Model(WireModel { data_hash: None, ..m }).encode(),
        );
    }
    let models_identical =
        frames.windows(2).all(|w| w[0] == w[1]);
    ensure!(
        models_identical,
        "servers converged to different model bytes"
    );
    let final_rounds = plan.expected_rounds;

    Ok(FleetOutcome {
        servers: plan.servers,
        final_rounds,
        models_identical,
        survivor_answered,
        restarted_caught_up,
        shed,
    })
}
