//! `SocketPublisher`: bridge the in-process [`ModelBus`] onto the wire.
//!
//! One accept loop, one writer thread per connection. Every connection
//! gets its own [`crate::coordinator::stream::BusFollower`], so a slow
//! or dead subscriber never blocks the trainer or the other
//! subscribers — the bus already coalesces versions (latest wins), and
//! the writer simply drops the connection on any write error. Between
//! model versions the writer emits heartbeats so followers can tell a
//! quiet trainer from a hung one; when the bus closes it sends
//! [`Frame::Shutdown`] so followers stop reconnecting.

use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Context;

use super::net::{Addr, Conn, Listener};
use super::wire::{self, Frame, WireModel};
use super::FabricOptions;
use crate::coordinator::stream::{BusWait, ModelBus};

/// Bridges a [`ModelBus`] to a socket endpoint until dropped.
pub struct SocketPublisher {
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    accepted: Arc<AtomicU64>,
}

impl SocketPublisher {
    /// Bind `addr` and start bridging `bus`. A connection immediately
    /// receives the newest published model (if any), then every newer
    /// version, with heartbeats in between; `data_hash` (the training
    /// data fingerprint) rides along on every model frame so followers
    /// can refuse a mismatched dataset.
    pub fn spawn(
        addr: &Addr,
        bus: ModelBus,
        data_hash: Option<u64>,
        opts: FabricOptions,
    ) -> anyhow::Result<SocketPublisher> {
        let listener = Listener::bind(addr).context("publisher bind")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accepted = Arc::new(AtomicU64::new(0));
        let t_stop = Arc::clone(&stop);
        let t_conns = Arc::clone(&conns);
        let t_accepted = Arc::clone(&accepted);
        let accept = std::thread::spawn(move || {
            while !t_stop.load(Ordering::SeqCst) {
                match listener.accept_idle() {
                    Ok(Some(conn)) => {
                        t_accepted.fetch_add(1, Ordering::SeqCst);
                        let follower = bus.follower();
                        let c_stop = Arc::clone(&t_stop);
                        let h = std::thread::spawn(move || {
                            serve_connection(conn, follower, data_hash, opts, c_stop)
                        });
                        t_conns
                            .lock()
                            .unwrap_or_else(
                                std::sync::PoisonError::into_inner,
                            )
                            .push(h);
                    }
                    Ok(None) | Err(_) => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            }
        });
        Ok(SocketPublisher {
            stop,
            accept: Some(accept),
            conns,
            accepted,
        })
    }

    /// Connections accepted so far (observability for tests).
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::SeqCst)
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = self
            .conns
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .drain(..)
            .collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for SocketPublisher {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Writer loop for one subscriber. Exits on write failure (subscriber
/// gone), bus close (after a [`Frame::Shutdown`]), or publisher stop.
fn serve_connection(
    mut conn: Conn,
    mut follower: crate::coordinator::stream::BusFollower,
    data_hash: Option<u64>,
    opts: FabricOptions,
    stop: Arc<AtomicBool>,
) {
    if conn
        .set_timeouts(Some(opts.read_timeout), Some(opts.write_timeout))
        .is_err()
    {
        return;
    }
    let mut seq = 0u64;
    // catch-up: a late subscriber gets the current model right away
    if let Some(v) = follower.poll() {
        if !v.predictor.selected.is_empty()
            && send_model(&mut conn, &v.predictor, v.rounds, data_hash)
                .is_err()
        {
            conn.shutdown();
            return;
        }
    }
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match follower.wait_newer(opts.heartbeat) {
            BusWait::Newer(v) => {
                if v.predictor.selected.is_empty() {
                    continue;
                }
                if send_model(&mut conn, &v.predictor, v.rounds, data_hash)
                    .is_err()
                {
                    break;
                }
            }
            BusWait::TimedOut => {
                seq += 1;
                if wire::write_frame(
                    &mut conn,
                    &Frame::Heartbeat { seq },
                )
                .is_err()
                {
                    break;
                }
            }
            BusWait::Closed => {
                let _ = wire::write_frame(&mut conn, &Frame::Shutdown);
                let _ = conn.flush();
                break;
            }
        }
    }
    conn.shutdown();
}

fn send_model(
    conn: &mut Conn,
    predictor: &crate::rls::Predictor,
    rounds: usize,
    data_hash: Option<u64>,
) -> anyhow::Result<()> {
    wire::write_frame(
        conn,
        &Frame::Model(WireModel {
            rounds,
            data_hash,
            predictor: predictor.clone(),
        }),
    )
}
