//! Seeded fault injection for the wire: drop, delay, truncate, or
//! corrupt whole frames on their way to a peer.
//!
//! [`FaultyStream`] wraps a writer and applies one seeded decision per
//! `write` call — [`super::wire::write_frame`] emits each frame as a
//! single `write_all`, so faults land on frame boundaries and a given
//! seed replays the exact same fault schedule. [`FaultyProxy`] runs the
//! same schedule between a real publisher and follower over sockets,
//! which is how the integration tests prove a follower never installs
//! a torn model.

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Context;

use super::net::{Addr, Conn, Listener};
use super::wire;
use super::FabricOptions;
use crate::rng::Pcg64;

/// Per-frame fault probabilities. The four faults are mutually
/// exclusive per frame (drawn from one uniform sample in cumulative
/// order: drop, corrupt, truncate, delay).
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// Probability a frame is silently dropped.
    pub drop_p: f64,
    /// Probability one byte of the frame is bit-flipped.
    pub corrupt_p: f64,
    /// Probability the frame is cut short mid-byte-sequence.
    pub truncate_p: f64,
    /// Probability the frame is delayed by up to `max_delay`.
    pub delay_p: f64,
    /// Upper bound for injected delays.
    pub max_delay: Duration,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            drop_p: 0.0,
            corrupt_p: 0.0,
            truncate_p: 0.0,
            delay_p: 0.0,
            max_delay: Duration::from_millis(20),
        }
    }
}

/// Counters for injected faults, shared with the test that asserts on
/// them.
#[derive(Debug, Default)]
pub struct FaultCounters {
    /// Frames written through unharmed.
    pub passed: AtomicU64,
    /// Frames silently dropped.
    pub dropped: AtomicU64,
    /// Frames with a flipped byte.
    pub corrupted: AtomicU64,
    /// Frames cut short.
    pub truncated: AtomicU64,
    /// Frames delayed before delivery.
    pub delayed: AtomicU64,
}

/// A writer that injects seeded faults at frame granularity.
///
/// Each `write` call is treated as one frame: the whole buffer is
/// consumed in a single fault decision and the call always reports the
/// full length as written (a dropped or truncated frame must look like
/// a successful send to the publisher — that is exactly the failure
/// the checksums exist to catch).
pub struct FaultyStream<S: Write> {
    inner: S,
    plan: FaultPlan,
    rng: Pcg64,
    enabled: Arc<AtomicBool>,
    counters: Arc<FaultCounters>,
}

impl<S: Write> FaultyStream<S> {
    /// Wrap `inner` with the given plan and seed. `enabled` can be
    /// flipped off at runtime to let a test's convergence phase run
    /// fault-free.
    pub fn new(
        inner: S,
        plan: FaultPlan,
        seed: u64,
        enabled: Arc<AtomicBool>,
        counters: Arc<FaultCounters>,
    ) -> FaultyStream<S> {
        Self::from_rng(inner, plan, Pcg64::new(seed, 1311), enabled, counters)
    }

    /// Like [`FaultyStream::new`] but with a caller-supplied generator —
    /// how [`FaultyProxy`] deals each connection a child schedule via
    /// [`Pcg64::split`].
    pub fn from_rng(
        inner: S,
        plan: FaultPlan,
        rng: Pcg64,
        enabled: Arc<AtomicBool>,
        counters: Arc<FaultCounters>,
    ) -> FaultyStream<S> {
        FaultyStream { inner, plan, rng, enabled, counters }
    }
}

impl<S: Write> Write for FaultyStream<S> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if !self.enabled.load(Ordering::SeqCst) || buf.is_empty() {
            self.counters.passed.fetch_add(1, Ordering::Relaxed);
            self.inner.write_all(buf)?;
            return Ok(buf.len());
        }
        let u = self.rng.uniform();
        let p = &self.plan;
        if u < p.drop_p {
            self.counters.dropped.fetch_add(1, Ordering::Relaxed);
            return Ok(buf.len());
        }
        if u < p.drop_p + p.corrupt_p {
            let mut bad = buf.to_vec();
            let at = self.rng.below(bad.len());
            let bit = 1u8 << (self.rng.below(8) as u8);
            bad[at] ^= bit;
            self.counters.corrupted.fetch_add(1, Ordering::Relaxed);
            self.inner.write_all(&bad)?;
            return Ok(buf.len());
        }
        if u < p.drop_p + p.corrupt_p + p.truncate_p {
            let keep = self.rng.below(buf.len());
            self.counters.truncated.fetch_add(1, Ordering::Relaxed);
            self.inner.write_all(&buf[..keep])?;
            return Ok(buf.len());
        }
        if u < p.drop_p + p.corrupt_p + p.truncate_p + p.delay_p {
            let ms = self.plan.max_delay.as_millis().max(1) as u64;
            let sleep = Duration::from_millis(self.rng.below(ms as usize) as u64);
            std::thread::sleep(sleep);
            self.counters.delayed.fetch_add(1, Ordering::Relaxed);
            self.inner.write_all(buf)?;
            return Ok(buf.len());
        }
        self.counters.passed.fetch_add(1, Ordering::Relaxed);
        self.inner.write_all(buf)?;
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// A frame-aware proxy that relays publisher → follower traffic
/// through a [`FaultyStream`]. It reads *valid* frames from the
/// upstream publisher and re-sends them downstream under the fault
/// plan; when either side dies it drops both and accepts again, so a
/// reconnecting follower meets a fresh (equally faulty) pipe.
pub struct FaultyProxy {
    stop: Arc<AtomicBool>,
    enabled: Arc<AtomicBool>,
    counters: Arc<FaultCounters>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl FaultyProxy {
    /// Listen on `listen`, relaying to `upstream` under `plan`.
    /// Connections are served one at a time (the tests drive a single
    /// follower); each gets a fresh deterministic fault schedule
    /// dealt from `seed` via [`Pcg64::split`].
    pub fn spawn(
        listen: &Addr,
        upstream: Addr,
        plan: FaultPlan,
        seed: u64,
        opts: FabricOptions,
    ) -> anyhow::Result<FaultyProxy> {
        let listener = Listener::bind(listen).context("proxy bind")?;
        let stop = Arc::new(AtomicBool::new(false));
        let enabled = Arc::new(AtomicBool::new(true));
        let counters = Arc::new(FaultCounters::default());
        let t_stop = Arc::clone(&stop);
        let t_enabled = Arc::clone(&enabled);
        let t_counters = Arc::clone(&counters);
        let handle = std::thread::spawn(move || {
            // one master generator deals each accepted connection its
            // own deterministic child schedule (accepts are serial, so
            // connection order — and thus every schedule — replays
            // exactly under the same seed)
            let mut schedules = Pcg64::new(seed, 1310);
            while !t_stop.load(Ordering::SeqCst) {
                let down = match listener.accept_idle() {
                    Ok(Some(c)) => c,
                    Ok(None) => {
                        std::thread::sleep(Duration::from_millis(10));
                        continue;
                    }
                    Err(_) => {
                        std::thread::sleep(Duration::from_millis(10));
                        continue;
                    }
                };
                let mut up = match Conn::connect(
                    &upstream,
                    opts.connect_timeout,
                ) {
                    Ok(c) => c,
                    Err(_) => {
                        down.shutdown();
                        continue;
                    }
                };
                let _ = up.set_timeouts(
                    Some(opts.read_timeout),
                    Some(opts.write_timeout),
                );
                let _ = down.set_timeouts(
                    Some(opts.read_timeout),
                    Some(opts.write_timeout),
                );
                let mut faulty = FaultyStream::from_rng(
                    down,
                    plan,
                    schedules.split(),
                    Arc::clone(&t_enabled),
                    Arc::clone(&t_counters),
                );
                while !t_stop.load(Ordering::SeqCst) {
                    match wire::read_frame(&mut up) {
                        Ok(frame) => {
                            if wire::write_frame(&mut faulty, &frame)
                                .is_err()
                            {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                }
                up.shutdown();
                faulty.inner.shutdown();
            }
        });
        Ok(FaultyProxy {
            stop,
            enabled,
            counters,
            handle: Some(handle),
        })
    }

    /// Flip fault injection on or off (e.g. off for a convergence
    /// phase after the fault storm).
    pub fn set_faults_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::SeqCst);
    }

    /// Shared fault counters for assertions.
    pub fn counters(&self) -> Arc<FaultCounters> {
        Arc::clone(&self.counters)
    }
}

impl Drop for FaultyProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
