//! Cross-validation orchestration — the paper's §4.2/§4.3 protocol.
//!
//! For each stratified fold: standardize with training statistics, pick λ
//! by full-feature LOO grid search on the training folds, then run the
//! incremental selection and record, **after every added feature**, the
//! LOO accuracy estimate on the training folds and the accuracy on the
//! held-out test fold. Figures 4–9 plot test accuracy for greedy vs
//! random; Figures 10–15 plot LOO vs test accuracy for greedy.

use anyhow::Result;

use crate::data::{folds::Folds, Dataset};
use crate::linalg::Matrix;
use crate::metrics::{accuracy, mean_std, Loss};
use crate::rng::Pcg64;
use crate::select::{
    greedy::GreedyRls, SelectionConfig, Selector, SessionSelector,
    StepOutcome,
};

/// How the next feature is chosen each round.
#[derive(Clone, Debug)]
pub enum Order {
    /// Greedy LOO argmin (the paper's method).
    Greedy,
    /// A fixed feature order (random baseline: a shuffled permutation).
    Fixed(Vec<usize>),
}

/// Accuracy trajectory of one selection run.
#[derive(Clone, Debug)]
pub struct Curve {
    /// Test accuracy after 1..=k features.
    pub test_acc: Vec<f64>,
    /// LOO accuracy estimate on the training folds after 1..=k features.
    pub loo_acc: Vec<f64>,
    /// Selected features in order.
    pub selected: Vec<usize>,
}

/// Run one incremental selection, recording per-round accuracies.
///
/// `x_train`/`x_test` are feature-major; the LOO accuracy is derived from
/// the zero-one LOO criterion of the *chosen* feature each round (exactly
/// the estimate the selection itself maximizes, as in §4.3). Both orders
/// drive the same greedy-RLS [`crate::select::Session`]: `Greedy` via
/// [`crate::select::Session::step`], `Fixed` via
/// [`crate::select::Session::force`].
pub fn selection_curve(
    x_train: &Matrix,
    y_train: &[f64],
    x_test: &Matrix,
    y_test: &[f64],
    lambda: f64,
    k: usize,
    order: &Order,
) -> Curve {
    selection_curve_threads(
        x_train, y_train, x_test, y_test, lambda, k, order, 0,
    )
}

/// [`selection_curve`] with an explicit worker-thread count for the
/// per-round scans (`0` = available parallelism). The curve is
/// bit-identical at any thread count; [`run_cv_threads`] passes `1` here
/// when the folds themselves run in parallel.
#[allow(clippy::too_many_arguments)]
pub fn selection_curve_threads(
    x_train: &Matrix,
    y_train: &[f64],
    x_test: &Matrix,
    y_test: &[f64],
    lambda: f64,
    k: usize,
    order: &Order,
    threads: usize,
) -> Curve {
    let m = y_train.len() as f64;
    let cfg = SelectionConfig::builder()
        .k(k)
        .lambda(lambda)
        .loss(Loss::ZeroOne)
        .threads(threads)
        .build();
    let mut session =
        GreedyRls.begin(x_train, y_train, &cfg).expect("begin session");
    let mut test_acc = Vec::with_capacity(k);
    let mut loo_acc = Vec::with_capacity(k);
    for round in 0..k {
        let r = match order {
            Order::Greedy => match session.step().expect("step") {
                StepOutcome::Selected(r) => r,
                StepOutcome::Done(_) => break,
            },
            Order::Fixed(perm) => {
                session.force(perm[round]).expect("candidates remain")
            }
        };
        // LOO zero-one criterion of the committed set S ∪ {b}:
        loo_acc.push(1.0 - r.criterion / m);

        // test accuracy of the current model
        let st = session.state().expect("session state");
        let mut p = vec![0.0; y_test.len()];
        for (&i, &w) in st.selected.iter().zip(&st.weights) {
            for (pj, &xv) in p.iter_mut().zip(x_test.row(i)) {
                *pj += w * xv;
            }
        }
        test_acc.push(accuracy(y_test, &p));
    }
    let selected = session.state().expect("session state").selected;
    Curve { test_acc, loo_acc, selected }
}

/// Mean ± std accuracy curves over folds (what the figures plot).
#[derive(Clone, Debug)]
pub struct CvCurves {
    /// k values 1..=k_max.
    pub ks: Vec<usize>,
    /// Mean test accuracy per k, greedy selection.
    pub greedy_test: Vec<f64>,
    /// Std of the above.
    pub greedy_test_std: Vec<f64>,
    /// Mean LOO accuracy per k, greedy selection.
    pub greedy_loo: Vec<f64>,
    /// Mean test accuracy per k, random selection baseline.
    pub random_test: Vec<f64>,
    /// λ chosen per fold by the grid search.
    pub lambdas: Vec<f64>,
}

/// Full §4.2 protocol on one dataset.
///
/// `folds` stratified folds, λ grid-searched per fold on the training
/// data, curves averaged over folds. `k_max` caps the number of selection
/// rounds (the paper runs to n; large-n datasets cap for tractability).
pub fn run_cv(
    ds: &Dataset,
    folds: usize,
    k_max: usize,
    seed: u64,
) -> Result<CvCurves> {
    run_cv_threads(ds, folds, k_max, seed, 0)
}

/// [`run_cv`] with an explicit worker-thread budget (`0` = available
/// parallelism). The folds are independent once the RNG-driven setup
/// (stratification + per-fold random permutations) is drawn up front in
/// fold order, so they run on parallel workers; per-fold results are
/// merged on the calling thread in fold order, making the curves
/// bit-identical to the serial protocol at any thread count. When more
/// than one fold worker runs, the inner selection sessions are serial;
/// with a single fold (or `threads == 1`) the thread budget goes to the
/// per-round scans instead.
pub fn run_cv_threads(
    ds: &Dataset,
    folds: usize,
    k_max: usize,
    seed: u64,
    threads: usize,
) -> Result<CvCurves> {
    let k_max = k_max.min(ds.n_features());
    let mut rng = Pcg64::new(seed, 71);
    let f = Folds::stratified(&ds.y, folds, &mut rng);
    let grid = super::grid::default_grid();

    // Draw all RNG-dependent state in fold order (the exact consumption
    // order of the serial protocol) before fanning out.
    let splits: Vec<(Vec<usize>, Vec<usize>)> = f.splits().collect();
    let perms: Vec<Vec<usize>> = splits
        .iter()
        .map(|_| {
            let mut perm: Vec<usize> = (0..ds.n_features()).collect();
            rng.shuffle(&mut perm);
            perm
        })
        .collect();

    let outer = crate::parallel::resolve(threads).min(splits.len());
    let inner = if outer > 1 { 1 } else { threads };
    let per_fold: Vec<(Curve, Curve, f64)> =
        crate::parallel::par_map(outer, splits.len(), |i| {
            let (train_idx, test_idx) = &splits[i];
            let mut train = ds.subset(train_idx);
            let mut test = ds.subset(test_idx);
            let stats = train.standardize();
            test.apply_standardization(&stats);

            let (lam, _) =
                super::grid::search(&train.x, &train.y, &grid, Loss::ZeroOne);

            let gc = selection_curve_threads(
                &train.x,
                &train.y,
                &test.x,
                &test.y,
                lam,
                k_max,
                &Order::Greedy,
                inner,
            );
            let rc = selection_curve_threads(
                &train.x,
                &train.y,
                &test.x,
                &test.y,
                lam,
                k_max,
                &Order::Fixed(perms[i].clone()),
                inner,
            );
            (gc, rc, lam)
        });

    let mut greedy_test = vec![Vec::new(); k_max];
    let mut greedy_loo = vec![Vec::new(); k_max];
    let mut random_test = vec![Vec::new(); k_max];
    let mut lambdas = Vec::new();
    for (gc, rc, lam) in &per_fold {
        lambdas.push(*lam);
        for k in 0..k_max {
            greedy_test[k].push(gc.test_acc[k]);
            greedy_loo[k].push(gc.loo_acc[k]);
            random_test[k].push(rc.test_acc[k]);
        }
    }

    let summarize = |per_k: &[Vec<f64>]| -> (Vec<f64>, Vec<f64>) {
        per_k
            .iter()
            .map(|xs| mean_std(xs))
            .unzip()
    };
    let (g_mean, g_std) = summarize(&greedy_test);
    let (l_mean, _) = summarize(&greedy_loo);
    let (r_mean, _) = summarize(&random_test);
    Ok(CvCurves {
        ks: (1..=k_max).collect(),
        greedy_test: g_mean,
        greedy_test_std: g_std,
        greedy_loo: l_mean,
        random_test: r_mean,
        lambdas,
    })
}

/// Convenience: single train/test split evaluation of a selection config
/// (used by examples and the serving path).
pub fn holdout_accuracy(
    ds: &Dataset,
    test_fraction: f64,
    cfg: &SelectionConfig,
    seed: u64,
) -> Result<(f64, Vec<usize>)> {
    let mut rng = Pcg64::new(seed, 73);
    let (train_idx, test_idx) =
        crate::data::folds::train_test_split(ds.n_examples(), test_fraction, &mut rng);
    let mut train = ds.subset(&train_idx);
    let mut test = ds.subset(&test_idx);
    let stats = train.standardize();
    test.apply_standardization(&stats);
    let r = crate::select::greedy::GreedyRls
        .select(&train.x, &train.y, cfg)
        .map_err(anyhow::Error::from)?;
    let p = r.predictor().predict_matrix(&test.x);
    Ok((accuracy(&test.y, &p), r.selected))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_curve_matches_selector_output() {
        let ds = crate::data::synthetic::two_gaussians(80, 12, 4, 1.5, 5);
        let (tr, te): (Vec<usize>, Vec<usize>) =
            ((0..60).collect(), (60..80).collect());
        let train = ds.subset(&tr);
        let test = ds.subset(&te);
        let c = selection_curve(
            &train.x, &train.y, &test.x, &test.y, 1.0, 5, &Order::Greedy,
        );
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let r = crate::select::greedy::GreedyRls
            .select(&train.x, &train.y, &cfg)
            .unwrap();
        assert_eq!(c.selected, r.selected);
        // LOO accuracy must equal 1 − criterion/m
        let m = train.n_examples() as f64;
        for (acc, round) in c.loo_acc.iter().zip(&r.rounds) {
            assert!((acc - (1.0 - round.criterion / m)).abs() < 1e-12);
        }
    }

    #[test]
    fn fixed_order_is_respected() {
        let ds = crate::data::synthetic::two_gaussians(40, 8, 3, 1.0, 6);
        let perm = vec![7, 0, 3];
        let c = selection_curve(
            &ds.x, &ds.y, &ds.x, &ds.y, 1.0, 3, &Order::Fixed(perm.clone()),
        );
        assert_eq!(c.selected, perm);
    }

    #[test]
    fn cv_shapes_and_sanity() {
        let ds = crate::data::synthetic::planted_sparse(
            "t", 120, 15, 4, 1.2, 0.9, 0.05, 7,
        );
        let cv = run_cv(&ds, 4, 8, 42).unwrap();
        assert_eq!(cv.ks.len(), 8);
        assert_eq!(cv.greedy_test.len(), 8);
        assert_eq!(cv.lambdas.len(), 4);
        for acc in cv.greedy_test.iter().chain(&cv.random_test) {
            assert!((0.0..=1.0).contains(acc));
        }
        // greedy with enough features should beat 0.5 clearly
        assert!(cv.greedy_test[7] > 0.6, "{:?}", cv.greedy_test);
    }

    #[test]
    fn greedy_beats_random_on_planted_data() {
        let ds = crate::data::synthetic::planted_sparse(
            "t", 150, 30, 3, 1.5, 1.0, 0.02, 9,
        );
        let cv = run_cv(&ds, 4, 3, 1).unwrap();
        // with only 3 of 30 features selectable, greedy (which finds the
        // 3 planted ones) must dominate random
        assert!(
            cv.greedy_test[2] > cv.random_test[2] + 0.1,
            "greedy {:?} random {:?}",
            cv.greedy_test,
            cv.random_test
        );
    }

    /// Parallel folds must reproduce the serial protocol exactly —
    /// identical curves and λ choices at every thread count.
    #[test]
    fn parallel_folds_are_bit_identical() {
        let ds = crate::data::synthetic::planted_sparse(
            "t", 90, 12, 3, 1.2, 0.9, 0.05, 17,
        );
        let serial = run_cv_threads(&ds, 3, 6, 5, 1).unwrap();
        for threads in [2usize, 4] {
            let par = run_cv_threads(&ds, 3, 6, 5, threads).unwrap();
            assert_eq!(serial.ks, par.ks, "threads={threads}");
            assert_eq!(serial.lambdas, par.lambdas, "threads={threads}");
            assert_eq!(
                serial.greedy_test, par.greedy_test,
                "threads={threads}"
            );
            assert_eq!(serial.greedy_loo, par.greedy_loo);
            assert_eq!(serial.random_test, par.random_test);
            assert_eq!(serial.greedy_test_std, par.greedy_test_std);
        }
    }

    #[test]
    fn holdout_runs() {
        let ds = crate::data::synthetic::two_gaussians(100, 10, 4, 2.0, 8);
        let cfg = SelectionConfig { k: 4, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let (acc, sel) = holdout_accuracy(&ds, 0.3, &cfg, 3).unwrap();
        assert_eq!(sel.len(), 4);
        assert!(acc > 0.6, "acc {acc}");
    }
}
