//! Cross-validation orchestration — the paper's §4.2/§4.3 protocol.
//!
//! For each stratified fold: standardize with training statistics, pick λ
//! by full-feature LOO grid search on the training folds, then run the
//! incremental selection and record, **after every added feature**, the
//! LOO accuracy estimate on the training folds and the accuracy on the
//! held-out test fold. Figures 4–9 plot test accuracy for greedy vs
//! random; Figures 10–15 plot LOO vs test accuracy for greedy.
//!
//! Sweeps accept a [`StopPolicy`] ([`CvOptions::stop`]) so a wall-clock
//! budget can cap a whole experiment, and an [`EngineKind`] so the
//! selection sessions run on the native engine or the PJRT artifacts.
//!
//! **Determinism caveat (time budgets):** a [`StopPolicy::TimeBudget`]
//! truncates curves, never reorders them — every recorded round is still
//! the exact round the unstopped protocol would have produced (greedy
//! argmin or forced order), only the stopping point is wall-clock
//! dependent, and the merged curves are cut at the shortest fold so the
//! mean ± std stay averages over *all* folds. Round budgets and plateau
//! policies remain fully deterministic.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use super::EngineKind;
use crate::data::fingerprint::Fnv64;
use crate::data::{folds::Folds, Dataset};
use crate::linalg::Matrix;
use crate::metrics::{accuracy, mean_std, Loss};
use crate::rng::Pcg64;
use crate::runtime::Runtime;
use crate::select::checkpoint;
use crate::select::{PreselectConfig, SelectionConfig, StepOutcome, StopPolicy};

/// How the next feature is chosen each round.
#[derive(Clone, Debug)]
pub enum Order {
    /// Greedy LOO argmin (the paper's method).
    Greedy,
    /// A fixed feature order (random baseline: a shuffled permutation).
    Fixed(Vec<usize>),
}

/// Accuracy trajectory of one selection run.
#[derive(Clone, Debug)]
pub struct Curve {
    /// Test accuracy after 1..=k features.
    pub test_acc: Vec<f64>,
    /// LOO accuracy estimate on the training folds after 1..=k features.
    pub loo_acc: Vec<f64>,
    /// Selected features in order.
    pub selected: Vec<usize>,
}

/// Parameters of one recorded selection curve: the per-session knobs a
/// CV fold derives from its protocol ([`CvOptions`]) plus the fold's
/// grid-searched λ. `Copy`, engine-agnostic — the PJRT runtime handle is
/// passed separately so native fold workers stay `Send`.
#[derive(Clone, Copy, Debug)]
pub struct CurveSpec {
    /// Regularization for this curve's sessions.
    pub lambda: f64,
    /// Rounds to record (clamped to the candidate count).
    pub k: usize,
    /// Worker threads for the per-round scans (`0` = auto); ignored by
    /// the PJRT engine.
    pub threads: usize,
    /// Early-stopping policy, enforced on greedy *and* forced-order
    /// sessions (see the module-level determinism caveat).
    pub stop: StopPolicy,
    /// Which engine executes the selection math.
    pub engine: EngineKind,
    /// Scan tile width in examples (`0` = untiled); a pure locality
    /// knob — curves are bit-identical at every setting. Ignored by the
    /// PJRT engine.
    pub tile_cols: usize,
    /// Sketched preselection filter for the *greedy* sessions (`None`
    /// disables). Fixed-order baseline sessions always run unfiltered:
    /// they force an arbitrary permutation, which must stay valid, and
    /// the baseline should sample the same feature universe the paper's
    /// does. Native engine only.
    pub preselect: Option<PreselectConfig>,
}

impl CurveSpec {
    /// Native-engine spec with the default (never-fires) stop policy.
    pub fn new(lambda: f64, k: usize, threads: usize) -> CurveSpec {
        CurveSpec {
            lambda,
            k,
            threads,
            stop: StopPolicy::default(),
            engine: EngineKind::Native,
            tile_cols: 0,
            preselect: None,
        }
    }
}

/// Run one incremental selection, recording per-round accuracies.
///
/// `x_train`/`x_test` are feature-major; the LOO accuracy is derived from
/// the zero-one LOO criterion of the *chosen* feature each round (exactly
/// the estimate the selection itself maximizes, as in §4.3). Both orders
/// drive the same greedy-RLS [`crate::select::Session`]: `Greedy` via
/// [`crate::select::Session::step`], `Fixed` via
/// [`crate::select::Session::force`] — with the stop policy evaluated
/// between forced rounds through [`crate::select::Session::check_stop`],
/// so a [`StopPolicy::TimeBudget`] fires on fixed-order runs too.
///
/// Stops cleanly (truncated curve, no panic) when the session's policy
/// fires, the fixed order runs out of entries, or `k` exceeds the
/// candidate count; errors only on real failures (a forced feature that
/// is out of range or already selected, engine faults).
pub fn selection_curve(
    x_train: &Matrix,
    y_train: &[f64],
    x_test: &Matrix,
    y_test: &[f64],
    lambda: f64,
    k: usize,
    order: &Order,
) -> Result<Curve> {
    selection_curve_spec(
        x_train,
        y_train,
        x_test,
        y_test,
        &CurveSpec::new(lambda, k, 0),
        order,
        None,
        Duration::ZERO,
    )
}

/// [`selection_curve`] with the full [`CurveSpec`], an optional PJRT
/// [`Runtime`] (required iff `spec.engine` is [`EngineKind::Pjrt`]), and
/// `prior` wall-clock already spent by the surrounding sweep — billed
/// against a [`StopPolicy::TimeBudget`] via
/// [`crate::select::Session::bill_elapsed`] so one budget caps a whole
/// multi-curve experiment. Curves are bit-identical at any thread count.
#[allow(clippy::too_many_arguments)]
pub fn selection_curve_spec(
    x_train: &Matrix,
    y_train: &[f64],
    x_test: &Matrix,
    y_test: &[f64],
    spec: &CurveSpec,
    order: &Order,
    runtime: Option<&Runtime>,
    prior: Duration,
) -> Result<Curve> {
    let m = y_train.len() as f64;
    let k = spec.k.min(x_train.rows());
    let cfg = SelectionConfig::builder()
        .k(k)
        .lambda(spec.lambda)
        .loss(Loss::ZeroOne)
        .threads(spec.threads)
        .stop(spec.stop)
        .tile_cols(spec.tile_cols)
        .preselect(match order {
            // forced permutations must stay valid — baselines never filter
            Order::Greedy => spec.preselect,
            Order::Fixed(_) => None,
        })
        .build();
    let mut session = super::begin_with_engine(
        spec.engine,
        runtime,
        x_train,
        y_train,
        &cfg,
    )?;
    if matches!(spec.stop, StopPolicy::TimeBudget(_)) {
        session.bill_elapsed(prior);
    }
    let rounds = match order {
        Order::Greedy => k,
        Order::Fixed(perm) => k.min(perm.len()),
    };
    let mut test_acc = Vec::with_capacity(rounds);
    let mut loo_acc = Vec::with_capacity(rounds);
    for round in 0..rounds {
        let r = match order {
            Order::Greedy => match session.step()? {
                StepOutcome::Selected(r) => r,
                StepOutcome::Done(_) => break,
            },
            Order::Fixed(perm) => {
                if session.check_stop().is_some() {
                    break;
                }
                session.force(perm[round])?
            }
        };
        // LOO zero-one criterion of the committed set S ∪ {b}:
        loo_acc.push(1.0 - r.criterion / m);

        // test accuracy of the current model
        let st = session.state()?;
        let mut p = vec![0.0; y_test.len()];
        for (&i, &w) in st.selected.iter().zip(&st.weights) {
            for (pj, &xv) in p.iter_mut().zip(x_test.row(i)) {
                *pj += w * xv;
            }
        }
        test_acc.push(accuracy(y_test, &p));
    }
    let selected = session.state()?.selected;
    Ok(Curve { test_acc, loo_acc, selected })
}

/// Mean ± std accuracy curves over folds (what the figures plot).
#[derive(Clone, Debug)]
pub struct CvCurves {
    /// k values 1..=k_max.
    pub ks: Vec<usize>,
    /// Mean test accuracy per k, greedy selection.
    pub greedy_test: Vec<f64>,
    /// Std of the above.
    pub greedy_test_std: Vec<f64>,
    /// Mean LOO accuracy per k, greedy selection.
    pub greedy_loo: Vec<f64>,
    /// Mean test accuracy per k, random selection baseline.
    pub random_test: Vec<f64>,
    /// λ chosen per fold by the grid search; `NaN` for folds a
    /// [`StopPolicy::TimeBudget`] skipped before their grid search ran.
    pub lambdas: Vec<f64>,
}

/// Protocol parameters of one CV sweep — everything except the dataset
/// and the checkpoint directory. `Copy`, so fold workers capture it
/// freely.
#[derive(Clone, Copy, Debug)]
pub struct CvOptions {
    /// Stratified fold count.
    pub folds: usize,
    /// Cap on selection rounds per curve (clamped to the feature count).
    pub k_max: usize,
    /// RNG seed for stratification + the fixed-order permutations.
    pub seed: u64,
    /// Worker-thread budget (`0` = available parallelism).
    pub threads: usize,
    /// Early-stopping policy armed on every selection session; a
    /// [`StopPolicy::TimeBudget`] is billed sweep-globally and also
    /// gates fold startup (grid searches included), so one budget caps
    /// the whole experiment — overshoot is bounded by the work already
    /// in flight: at most one λ grid search plus one selection round
    /// per fold worker (see the module-level caveat).
    pub stop: StopPolicy,
    /// Engine executing the selection math. The PJRT runtime is not
    /// shareable across threads, so PJRT sweeps run their folds serially
    /// (the parallelism lives in the compiled kernels).
    pub engine: EngineKind,
    /// Scan tile width for every fold's sessions (`0` = untiled);
    /// bit-identical at every setting, native engine only.
    pub tile_cols: usize,
    /// Sketched preselection for the greedy curves (`None` disables);
    /// the fixed-order baseline curves always run unfiltered — see
    /// [`CurveSpec::preselect`]. Participates in the fold fingerprint
    /// via a trailing marker (legacy fold files stay valid when unset).
    pub preselect: Option<PreselectConfig>,
}

impl Default for CvOptions {
    fn default() -> Self {
        CvOptions {
            folds: 10,
            k_max: 50,
            seed: 42,
            threads: 0,
            stop: StopPolicy::default(),
            engine: EngineKind::Native,
            tile_cols: 0,
            preselect: None,
        }
    }
}

/// Full §4.2 protocol on one dataset.
///
/// `folds` stratified folds, λ grid-searched per fold on the training
/// data, curves averaged over folds. `k_max` caps the number of selection
/// rounds (the paper runs to n; large-n datasets cap for tractability).
pub fn run_cv(
    ds: &Dataset,
    folds: usize,
    k_max: usize,
    seed: u64,
) -> Result<CvCurves> {
    run_cv_threads(ds, folds, k_max, seed, 0)
}

/// [`run_cv`] with an explicit worker-thread budget (`0` = available
/// parallelism).
pub fn run_cv_threads(
    ds: &Dataset,
    folds: usize,
    k_max: usize,
    seed: u64,
    threads: usize,
) -> Result<CvCurves> {
    let opts =
        CvOptions { folds, k_max, seed, threads, ..Default::default() };
    run_cv_opts(ds, &opts, None)
}

/// The §4.2 protocol under explicit [`CvOptions`]. `runtime` is required
/// iff `opts.engine` is [`EngineKind::Pjrt`].
///
/// Native sweeps run folds on parallel workers: the folds are independent
/// once the RNG-driven setup (stratification + per-fold random
/// permutations) is drawn up front in fold order, and per-fold results
/// are merged on the calling thread in fold order, making the curves
/// bit-identical to the serial protocol at any thread count. When more
/// than one fold worker runs, the inner selection sessions are serial;
/// with a single fold (or `threads == 1`) the thread budget goes to the
/// per-round scans instead. PJRT sweeps run folds serially on the
/// calling thread (the runtime handle is not `Sync`).
pub fn run_cv_opts(
    ds: &Dataset,
    opts: &CvOptions,
    runtime: Option<&Runtime>,
) -> Result<CvCurves> {
    let k_max = opts.k_max.min(ds.n_features());
    // xtask-allow: no-raw-instant -- sweep-wide wall-clock budget anchor:
    // spans every fold, so no single session clock can own it.
    let started = Instant::now();
    let mut rng = Pcg64::new(opts.seed, 71);
    let f = Folds::stratified(&ds.y, opts.folds, &mut rng);

    // Draw all RNG-dependent state in fold order (the exact consumption
    // order of the serial protocol) before fanning out.
    let splits: Vec<(Vec<usize>, Vec<usize>)> = f.splits().collect();
    let perms: Vec<Vec<usize>> = splits
        .iter()
        .map(|_| {
            let mut perm: Vec<usize> = (0..ds.n_features()).collect();
            rng.shuffle(&mut perm);
            perm
        })
        .collect();

    let all: Vec<usize> = (0..splits.len()).collect();
    let per_fold = compute_folds_at(
        ds, opts, runtime, started, &splits, &perms, &all, k_max,
    )?;
    Ok(merge_folds(&per_fold, k_max))
}

/// Compute the folds at `indices` under the engine dispatch shared by
/// [`run_cv_opts`] and [`run_cv_resumable`]: parallel fold workers for
/// the native engine (inner sessions serial when more than one worker
/// runs), serial calling-thread execution for PJRT (the runtime handle
/// is not `Sync`). The spec's λ is a placeholder — each fold
/// grid-searches its own inside [`compute_fold`].
#[allow(clippy::too_many_arguments)]
fn compute_folds_at(
    ds: &Dataset,
    opts: &CvOptions,
    runtime: Option<&Runtime>,
    started: Instant,
    splits: &[(Vec<usize>, Vec<usize>)],
    perms: &[Vec<usize>],
    indices: &[usize],
    k_max: usize,
) -> Result<Vec<(Curve, Curve, f64)>> {
    match opts.engine {
        EngineKind::Native => {
            let outer =
                crate::parallel::resolve(opts.threads).min(indices.len());
            let inner = if outer > 1 { 1 } else { opts.threads };
            let spec = CurveSpec {
                lambda: 1.0,
                k: k_max,
                threads: inner,
                stop: opts.stop,
                engine: EngineKind::Native,
                tile_cols: opts.tile_cols,
                preselect: opts.preselect,
            };
            crate::parallel::par_map(outer, indices.len(), |j| {
                let i = indices[j];
                compute_fold(
                    ds, &splits[i], &perms[i], &spec, None, started,
                )
            })
            .into_iter()
            .collect()
        }
        EngineKind::Pjrt => {
            let rt = runtime
                .context("PJRT engine requested but no runtime supplied")?;
            let spec = CurveSpec {
                lambda: 1.0,
                k: k_max,
                threads: opts.threads,
                stop: opts.stop,
                engine: EngineKind::Pjrt,
                tile_cols: opts.tile_cols,
                // rejected upstream if combined with --preselect (the
                // PJRT engine has no filter lowering)
                preselect: opts.preselect,
            };
            indices
                .iter()
                .map(|&i| {
                    compute_fold(
                        ds, &splits[i], &perms[i], &spec, Some(rt), started,
                    )
                })
                .collect()
        }
    }
}

/// One fold of the §4.2 protocol: standardize with training statistics,
/// grid-search λ, record the greedy and fixed-order accuracy curves.
/// Pure in its inputs (modulo a live [`StopPolicy::TimeBudget`], which
/// truncates but never reorders) — the same fold recomputes
/// bit-identically in any process, which is what makes fold-level
/// checkpoints sound.
fn compute_fold(
    ds: &Dataset,
    split: &(Vec<usize>, Vec<usize>),
    perm: &[usize],
    spec: &CurveSpec,
    runtime: Option<&Runtime>,
    sweep_started: Instant,
) -> Result<(Curve, Curve, f64)> {
    if let StopPolicy::TimeBudget(limit) = spec.stop {
        // the budget gates fold *startup* too — the λ grid search below
        // is not session work, so without this check an exhausted sweep
        // would still burn a full grid search per remaining fold. λ is
        // recorded as NaN for folds the time stop skipped entirely.
        if sweep_started.elapsed() >= limit {
            let empty =
                || Curve { test_acc: vec![], loo_acc: vec![], selected: vec![] };
            return Ok((empty(), empty(), f64::NAN));
        }
    }
    let (train_idx, test_idx) = split;
    let mut train = ds.subset(train_idx);
    let mut test = ds.subset(test_idx);
    let stats = train.standardize();
    test.apply_standardization(&stats);

    let grid = super::grid::default_grid();
    let (lam, _) =
        super::grid::search(&train.x, &train.y, &grid, Loss::ZeroOne);
    let spec = CurveSpec { lambda: lam, ..*spec };

    let gc = selection_curve_spec(
        &train.x,
        &train.y,
        &test.x,
        &test.y,
        &spec,
        &Order::Greedy,
        runtime,
        sweep_started.elapsed(),
    )?;
    let rc = selection_curve_spec(
        &train.x,
        &train.y,
        &test.x,
        &test.y,
        &spec,
        &Order::Fixed(perm.to_vec()),
        runtime,
        sweep_started.elapsed(),
    )?;
    Ok((gc, rc, lam))
}

/// Merge per-fold results (in fold order) into the mean ± std curves.
/// Folds truncated by a time budget cut the merged curves at the
/// shortest fold, so every reported k still averages all folds.
fn merge_folds(per_fold: &[(Curve, Curve, f64)], k_max: usize) -> CvCurves {
    let k_max = per_fold
        .iter()
        .map(|(gc, rc, _)| gc.test_acc.len().min(rc.test_acc.len()))
        .min()
        .unwrap_or(0)
        .min(k_max);
    let mut greedy_test = vec![Vec::new(); k_max];
    let mut greedy_loo = vec![Vec::new(); k_max];
    let mut random_test = vec![Vec::new(); k_max];
    let mut lambdas = Vec::new();
    for (gc, rc, lam) in per_fold {
        lambdas.push(*lam);
        for k in 0..k_max {
            greedy_test[k].push(gc.test_acc[k]);
            greedy_loo[k].push(gc.loo_acc[k]);
            random_test[k].push(rc.test_acc[k]);
        }
    }

    let summarize = |per_k: &[Vec<f64>]| -> (Vec<f64>, Vec<f64>) {
        per_k
            .iter()
            .map(|xs| mean_std(xs))
            .unzip()
    };
    let (g_mean, g_std) = summarize(&greedy_test);
    let (l_mean, _) = summarize(&greedy_loo);
    let (r_mean, _) = summarize(&random_test);
    CvCurves {
        ks: (1..=k_max).collect(),
        greedy_test: g_mean,
        greedy_test_std: g_std,
        greedy_loo: l_mean,
        random_test: r_mean,
        lambdas,
    }
}

// ---------------------------------------------------------------------------
// Fold-level checkpoints: resumable CV sweeps
// ---------------------------------------------------------------------------

/// Identity of one CV experiment: dataset content plus the protocol
/// parameters that determine every fold (fold count, k_max after
/// clamping, RNG seed, and any non-default deterministic stop policy).
/// Thread counts are excluded — fold results are bit-identical at any
/// (see [`run_cv_opts`]). The engine is tagged only for PJRT: its curves
/// match the native ones to tolerance, not bit-exactly, so fold files
/// must not be shared across engines. The default stop/engine hash to
/// the legacy fingerprint, keeping existing fold files valid.
fn cv_fingerprint(ds: &Dataset, opts: &CvOptions, k_max: usize) -> u64 {
    let mut h = Fnv64::new();
    h.write(b"greedy-rls-cv-fold-v1");
    h.write_u64(ds.fingerprint());
    h.write_usize(opts.folds);
    h.write_usize(k_max);
    h.write_u64(opts.seed);
    match opts.stop {
        StopPolicy::KBudget(usize::MAX) => {} // legacy default
        StopPolicy::KBudget(b) => {
            h.write(b"stop-kbudget");
            h.write_usize(b);
        }
        StopPolicy::Plateau { patience, min_rel_improvement } => {
            h.write(b"stop-plateau");
            h.write_usize(patience);
            h.write_u64(min_rel_improvement.to_bits());
        }
        // rejected by run_cv_resumable before fingerprinting
        StopPolicy::TimeBudget(_) => h.write(b"stop-time"),
    }
    if opts.engine == EngineKind::Pjrt {
        h.write(b"engine-pjrt");
    }
    if let Some(ps) = opts.preselect {
        // trailing marker, like the checkpoint config hash: unset
        // filters keep every pre-existing fold file valid
        h.write(b"preselect");
        h.write_usize(ps.p);
        h.write_usize(ps.sketch_dim);
        h.write_u64(ps.seed);
    }
    h.finish()
}

fn fold_path(dir: &Path, fold: usize) -> PathBuf {
    dir.join(format!("cv-fold-{fold:04}.ckpt"))
}

fn push_f64_line(s: &mut String, key: &str, vals: &[f64]) {
    use std::fmt::Write as _;
    let _ = write!(s, "{key} {}", vals.len());
    for v in vals {
        let _ = write!(s, " {:016x}", v.to_bits());
    }
    s.push('\n');
}

fn push_usize_line(s: &mut String, key: &str, vals: &[usize]) {
    use std::fmt::Write as _;
    let _ = write!(s, "{key} {}", vals.len());
    for v in vals {
        let _ = write!(s, " {v}");
    }
    s.push('\n');
}

/// Parse `<count> <v1> <v2> …` (the part of a counted line after its
/// key), enforcing that the count matches.
fn parse_counted_rest<T, F>(rest: &str, parse: F) -> Result<Vec<T>>
where
    F: Fn(&str) -> Result<T>,
{
    let mut tok = rest.split_whitespace();
    let n: usize = tok
        .next()
        .ok_or_else(|| anyhow!("counted line missing count"))?
        .parse()
        .context("counted line count")?;
    let vals: Vec<T> = tok.map(parse).collect::<Result<_>>()?;
    anyhow::ensure!(
        vals.len() == n,
        "counted line announces {n} values but carries {}",
        vals.len()
    );
    Ok(vals)
}

fn fold_to_text(
    fingerprint: u64,
    fold: usize,
    result: &(Curve, Curve, f64),
) -> String {
    use std::fmt::Write as _;
    let (gc, rc, lam) = result;
    let mut s = String::new();
    let _ = writeln!(s, "greedy-rls-cv-fold v1");
    let _ = writeln!(s, "fingerprint {fingerprint:016x}");
    let _ = writeln!(s, "fold {fold}");
    let _ = writeln!(s, "lambda {:016x}", lam.to_bits());
    push_usize_line(&mut s, "gsel", &gc.selected);
    push_f64_line(&mut s, "gtest", &gc.test_acc);
    push_f64_line(&mut s, "gloo", &gc.loo_acc);
    push_usize_line(&mut s, "rsel", &rc.selected);
    push_f64_line(&mut s, "rtest", &rc.test_acc);
    push_f64_line(&mut s, "rloo", &rc.loo_acc);
    // same integrity trailer as session checkpoints
    checkpoint::seal_with_checksum(s)
}

fn fold_from_text(text: &str) -> Result<(u64, usize, (Curve, Curve, f64))> {
    let body =
        checkpoint::checked_body(text).context("cv fold checkpoint")?;

    fn rest_of<'t>(
        lines: &mut std::str::Lines<'t>,
        key: &str,
    ) -> Result<&'t str> {
        let line = lines
            .next()
            .ok_or_else(|| anyhow!("cv fold ends before `{key}`"))?;
        line.strip_prefix(key)
            .and_then(|r| r.strip_prefix(' '))
            .ok_or_else(|| anyhow!("cv fold line {line:?}: expected `{key}`"))
    }
    fn parse_usize(t: &str) -> Result<usize> {
        t.parse().context("index value")
    }
    fn parse_f64_bits(t: &str) -> Result<f64> {
        Ok(f64::from_bits(
            u64::from_str_radix(t, 16).context("f64 bits")?,
        ))
    }

    let mut lines = body.lines();
    anyhow::ensure!(
        rest_of(&mut lines, "greedy-rls-cv-fold")? == "v1",
        "unsupported cv fold version"
    );
    let fingerprint =
        u64::from_str_radix(rest_of(&mut lines, "fingerprint")?.trim(), 16)
            .context("cv fold fingerprint")?;
    let fold: usize = rest_of(&mut lines, "fold")?
        .trim()
        .parse()
        .context("cv fold index")?;
    let lam = f64::from_bits(
        u64::from_str_radix(rest_of(&mut lines, "lambda")?.trim(), 16)
            .context("cv fold lambda")?,
    );
    let gsel = parse_counted_rest(rest_of(&mut lines, "gsel")?, parse_usize)?;
    let gtest =
        parse_counted_rest(rest_of(&mut lines, "gtest")?, parse_f64_bits)?;
    let gloo =
        parse_counted_rest(rest_of(&mut lines, "gloo")?, parse_f64_bits)?;
    let rsel = parse_counted_rest(rest_of(&mut lines, "rsel")?, parse_usize)?;
    let rtest =
        parse_counted_rest(rest_of(&mut lines, "rtest")?, parse_f64_bits)?;
    let rloo =
        parse_counted_rest(rest_of(&mut lines, "rloo")?, parse_f64_bits)?;
    Ok((
        fingerprint,
        fold,
        (
            Curve { test_acc: gtest, loo_acc: gloo, selected: gsel },
            Curve { test_acc: rtest, loo_acc: rloo, selected: rsel },
            lam,
        ),
    ))
}

/// Load one fold checkpoint; `None` (recompute) on any failure — a
/// missing, truncated, corrupt, stale-fingerprint, or wrong-index file is
/// simply treated as not-yet-computed and overwritten.
fn load_fold(
    path: &Path,
    fingerprint: u64,
    fold: usize,
) -> Option<(Curve, Curve, f64)> {
    let text = std::fs::read_to_string(path).ok()?;
    let (fp, idx, result) = fold_from_text(&text).ok()?;
    (fp == fingerprint && idx == fold).then_some(result)
}

/// Atomically persist one fold result (shared `.tmp` + fsync + rename
/// helper — a kill mid-save never corrupts a fold file).
fn save_fold(
    path: &Path,
    fingerprint: u64,
    fold: usize,
    result: &(Curve, Curve, f64),
) -> Result<()> {
    checkpoint::write_atomic(path, &fold_to_text(fingerprint, fold, result))
}

/// [`run_cv_opts`] with fold-level checkpoints: each completed fold is
/// persisted to `dir`, and a rerun (same dataset, protocol, seed, stop
/// policy, and engine — enforced by a fingerprint) loads finished folds
/// instead of recomputing them. Because every fold is a pure function of
/// its inputs and bit-identical at any thread count, the curves are
/// bit-identical to an uninterrupted [`run_cv_opts`] no matter where the
/// previous process was killed. A [`StopPolicy::TimeBudget`] is rejected
/// here: a wall-clock truncation is not reproducible, so its fold files
/// could never be trusted on resume.
pub fn run_cv_resumable(
    ds: &Dataset,
    opts: &CvOptions,
    runtime: Option<&Runtime>,
    dir: &Path,
) -> Result<CvCurves> {
    ensure!(
        !matches!(opts.stop, StopPolicy::TimeBudget(_)),
        "time-budgeted CV sweeps are not checkpoint-resumable (a \
         wall-clock truncation is not reproducible); drop \
         --checkpoint-dir or use a round/plateau stop"
    );
    let k_max = opts.k_max.min(ds.n_features());
    // xtask-allow: no-raw-instant -- sweep-wide wall clock (see run_cv_opts).
    let started = Instant::now();
    let fingerprint = cv_fingerprint(ds, opts, k_max);
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;

    // identical RNG-driven setup to run_cv_opts, drawn in fold order
    let mut rng = Pcg64::new(opts.seed, 71);
    let f = Folds::stratified(&ds.y, opts.folds, &mut rng);
    let splits: Vec<(Vec<usize>, Vec<usize>)> = f.splits().collect();
    let perms: Vec<Vec<usize>> = splits
        .iter()
        .map(|_| {
            let mut perm: Vec<usize> = (0..ds.n_features()).collect();
            rng.shuffle(&mut perm);
            perm
        })
        .collect();

    let mut per_fold: Vec<Option<(Curve, Curve, f64)>> = (0..splits.len())
        .map(|i| load_fold(&fold_path(dir, i), fingerprint, i))
        .collect();
    let missing: Vec<usize> = (0..splits.len())
        .filter(|&i| per_fold[i].is_none())
        .collect();
    if !missing.is_empty() {
        let computed = compute_folds_at(
            ds, opts, runtime, started, &splits, &perms, &missing, k_max,
        )?;
        for (j, result) in computed.into_iter().enumerate() {
            let i = missing[j];
            save_fold(&fold_path(dir, i), fingerprint, i, &result)?;
            per_fold[i] = Some(result);
        }
    }

    let per_fold: Vec<(Curve, Curve, f64)> =
        per_fold.into_iter().map(|r| r.expect("all folds done")).collect();
    Ok(merge_folds(&per_fold, k_max))
}

/// Convenience: single train/test split evaluation of a selection config
/// (used by examples and the serving path).
pub fn holdout_accuracy(
    ds: &Dataset,
    test_fraction: f64,
    cfg: &SelectionConfig,
    seed: u64,
) -> Result<(f64, Vec<usize>)> {
    let mut rng = Pcg64::new(seed, 73);
    let (train_idx, test_idx) =
        crate::data::folds::train_test_split(ds.n_examples(), test_fraction, &mut rng);
    let mut train = ds.subset(&train_idx);
    let mut test = ds.subset(&test_idx);
    let stats = train.standardize();
    test.apply_standardization(&stats);
    let r = crate::select::Selector::select(
        &crate::select::greedy::GreedyRls,
        &train.x,
        &train.y,
        cfg,
    )?;
    let p = r.predictor().predict_matrix(&test.x);
    Ok((accuracy(&test.y, &p), r.selected))
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::select::Selector as _;

    #[test]
    fn greedy_curve_matches_selector_output() {
        let ds = crate::data::synthetic::two_gaussians(80, 12, 4, 1.5, 5);
        let (tr, te): (Vec<usize>, Vec<usize>) =
            ((0..60).collect(), (60..80).collect());
        let train = ds.subset(&tr);
        let test = ds.subset(&te);
        let c = selection_curve(
            &train.x, &train.y, &test.x, &test.y, 1.0, 5, &Order::Greedy,
        )
        .unwrap();
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let r = crate::select::greedy::GreedyRls
            .select(&train.x, &train.y, &cfg)
            .unwrap();
        assert_eq!(c.selected, r.selected);
        // LOO accuracy must equal 1 − criterion/m
        let m = train.n_examples() as f64;
        for (acc, round) in c.loo_acc.iter().zip(&r.rounds) {
            assert!((acc - (1.0 - round.criterion / m)).abs() < 1e-12);
        }
    }

    #[test]
    fn fixed_order_is_respected() {
        let ds = crate::data::synthetic::two_gaussians(40, 8, 3, 1.0, 6);
        let perm = vec![7, 0, 3];
        let c = selection_curve(
            &ds.x, &ds.y, &ds.x, &ds.y, 1.0, 3, &Order::Fixed(perm.clone()),
        )
        .unwrap();
        assert_eq!(c.selected, perm);
    }

    /// Regression: `perm[round]` used to panic when k exceeded the
    /// permutation length — now the curve stops cleanly at the end of
    /// the order.
    #[test]
    fn fixed_order_short_perm_stops_cleanly() {
        let ds = crate::data::synthetic::two_gaussians(40, 8, 3, 1.0, 6);
        let perm = vec![2, 5];
        let c = selection_curve(
            &ds.x, &ds.y, &ds.x, &ds.y, 1.0, 6, &Order::Fixed(perm.clone()),
        )
        .unwrap();
        assert_eq!(c.selected, perm);
        assert_eq!(c.test_acc.len(), 2);
        assert_eq!(c.loo_acc.len(), 2);
    }

    /// Regression: `.expect("candidates remain")` used to panic on a bad
    /// order — a duplicated feature is now a clean error.
    #[test]
    fn fixed_order_duplicate_feature_is_an_error() {
        let ds = crate::data::synthetic::two_gaussians(40, 8, 3, 1.0, 6);
        let c = selection_curve(
            &ds.x, &ds.y, &ds.x, &ds.y, 1.0, 3, &Order::Fixed(vec![1, 1, 2]),
        );
        assert!(c.is_err(), "duplicate forced feature must error");
    }

    /// k beyond the candidate count is clamped, not a mid-run panic.
    #[test]
    fn k_beyond_candidates_is_clamped() {
        let ds = crate::data::synthetic::two_gaussians(40, 6, 2, 1.0, 9);
        let c = selection_curve(
            &ds.x, &ds.y, &ds.x, &ds.y, 1.0, 50, &Order::Greedy,
        )
        .unwrap();
        assert_eq!(c.selected.len(), 6);
        let perm: Vec<usize> = (0..6).collect();
        let c = selection_curve(
            &ds.x, &ds.y, &ds.x, &ds.y, 1.0, 50, &Order::Fixed(perm),
        )
        .unwrap();
        assert_eq!(c.selected.len(), 6);
    }

    /// Regression (stop-clock accounting): a time budget must stop a
    /// fixed-order curve — forced rounds used to reset the clock, so the
    /// budget never fired.
    #[test]
    fn zero_time_budget_stops_fixed_order_curve() {
        let ds = crate::data::synthetic::two_gaussians(40, 8, 3, 1.0, 6);
        let spec = CurveSpec {
            stop: StopPolicy::TimeBudget(Duration::ZERO),
            ..CurveSpec::new(1.0, 4, 1)
        };
        let perm: Vec<usize> = (0..8).collect();
        let c = selection_curve_spec(
            &ds.x,
            &ds.y,
            &ds.x,
            &ds.y,
            &spec,
            &Order::Fixed(perm),
            None,
            Duration::ZERO,
        )
        .unwrap();
        assert!(c.selected.is_empty(), "budget must fire before round 1");
        assert!(c.test_acc.is_empty());
    }

    /// A round budget truncates every fold's curves identically, so a
    /// stop-capped sweep equals the plain sweep at that k — the
    /// "truncates, never reorders" determinism contract.
    #[test]
    fn round_budget_caps_the_sweep_deterministically() {
        let ds = crate::data::synthetic::planted_sparse(
            "t", 90, 12, 3, 1.2, 0.9, 0.05, 19,
        );
        let plain = run_cv_threads(&ds, 3, 2, 5, 1).unwrap();
        let opts = CvOptions {
            folds: 3,
            k_max: 6,
            seed: 5,
            threads: 1,
            stop: StopPolicy::KBudget(2),
            engine: EngineKind::Native,
            tile_cols: 0,
        };
        let capped = run_cv_opts(&ds, &opts, None).unwrap();
        assert_eq!(capped.ks, plain.ks);
        assert_eq!(capped.greedy_test, plain.greedy_test);
        assert_eq!(capped.greedy_loo, plain.greedy_loo);
        assert_eq!(capped.random_test, plain.random_test);
        assert_eq!(capped.lambdas, plain.lambdas);
    }

    /// A zero time budget yields an empty (not panicking) sweep: the
    /// merged curves are cut at the shortest fold.
    #[test]
    fn zero_time_budget_yields_empty_sweep() {
        let ds = crate::data::synthetic::planted_sparse(
            "t", 60, 8, 3, 1.2, 0.9, 0.05, 11,
        );
        let opts = CvOptions {
            folds: 3,
            k_max: 4,
            seed: 2,
            threads: 1,
            stop: StopPolicy::TimeBudget(Duration::ZERO),
            engine: EngineKind::Native,
            tile_cols: 0,
        };
        let cv = run_cv_opts(&ds, &opts, None).unwrap();
        assert!(cv.ks.is_empty());
        assert!(cv.greedy_test.is_empty());
        // a zero budget skips every fold before its grid search: the λ
        // slots exist but record NaN (no unbudgeted work ran)
        assert_eq!(cv.lambdas.len(), 3);
        assert!(cv.lambdas.iter().all(|l| l.is_nan()), "{:?}", cv.lambdas);
    }

    #[test]
    fn merge_folds_handles_ragged_curves() {
        let curve = |len: usize| Curve {
            test_acc: vec![0.5; len],
            loo_acc: vec![0.5; len],
            selected: (0..len).collect(),
        };
        let per_fold = vec![
            (curve(4), curve(4), 1.0),
            (curve(2), curve(4), 0.1), // truncated greedy curve
            (curve(4), curve(3), 1.0), // truncated random curve
        ];
        let cv = merge_folds(&per_fold, 4);
        assert_eq!(cv.ks, vec![1, 2]);
        assert_eq!(cv.greedy_test.len(), 2);
        assert_eq!(cv.random_test.len(), 2);
        assert_eq!(cv.lambdas.len(), 3);
    }

    #[test]
    fn resumable_cv_rejects_time_budgets() {
        let dir = std::env::temp_dir().join("greedy_rls_cv_timebudget_test");
        let _ = std::fs::remove_dir_all(&dir);
        let ds = crate::data::synthetic::two_gaussians(40, 8, 3, 1.0, 6);
        let opts = CvOptions {
            folds: 2,
            k_max: 3,
            seed: 1,
            threads: 1,
            stop: StopPolicy::TimeBudget(Duration::from_secs(3600)),
            engine: EngineKind::Native,
            tile_cols: 0,
        };
        let err = run_cv_resumable(&ds, &opts, None, &dir).unwrap_err();
        assert!(
            format!("{err:#}").contains("not checkpoint-resumable"),
            "{err:#}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A non-default deterministic stop policy must not reuse fold files
    /// written under a different policy.
    #[test]
    fn resumable_cv_fingerprints_the_stop_policy() {
        let dir = std::env::temp_dir().join("greedy_rls_cv_stopfp_test");
        let _ = std::fs::remove_dir_all(&dir);
        let ds = crate::data::synthetic::planted_sparse(
            "t", 60, 8, 3, 1.2, 0.9, 0.05, 29,
        );
        let base = CvOptions {
            folds: 2,
            k_max: 4,
            seed: 3,
            threads: 1,
            stop: StopPolicy::default(),
            engine: EngineKind::Native,
            tile_cols: 0,
        };
        let full = run_cv_resumable(&ds, &base, None, &dir).unwrap();
        assert_eq!(full.ks.len(), 4);
        let capped = CvOptions { stop: StopPolicy::KBudget(2), ..base };
        let cv = run_cv_resumable(&ds, &capped, None, &dir).unwrap();
        assert_eq!(cv.ks.len(), 2, "stale full-curve folds must not load");
        // and the capped fold files don't poison the full protocol either
        let full2 = run_cv_resumable(&ds, &base, None, &dir).unwrap();
        assert_eq!(full2.ks.len(), 4);
        assert_eq!(full2.greedy_test, full.greedy_test);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cv_shapes_and_sanity() {
        let ds = crate::data::synthetic::planted_sparse(
            "t", 120, 15, 4, 1.2, 0.9, 0.05, 7,
        );
        let cv = run_cv(&ds, 4, 8, 42).unwrap();
        assert_eq!(cv.ks.len(), 8);
        assert_eq!(cv.greedy_test.len(), 8);
        assert_eq!(cv.lambdas.len(), 4);
        for acc in cv.greedy_test.iter().chain(&cv.random_test) {
            assert!((0.0..=1.0).contains(acc));
        }
        // greedy with enough features should beat 0.5 clearly
        assert!(cv.greedy_test[7] > 0.6, "{:?}", cv.greedy_test);
    }

    #[test]
    fn greedy_beats_random_on_planted_data() {
        let ds = crate::data::synthetic::planted_sparse(
            "t", 150, 30, 3, 1.5, 1.0, 0.02, 9,
        );
        let cv = run_cv(&ds, 4, 3, 1).unwrap();
        // with only 3 of 30 features selectable, greedy (which finds the
        // 3 planted ones) must dominate random
        assert!(
            cv.greedy_test[2] > cv.random_test[2] + 0.1,
            "greedy {:?} random {:?}",
            cv.greedy_test,
            cv.random_test
        );
    }

    /// Parallel folds must reproduce the serial protocol exactly —
    /// identical curves and λ choices at every thread count.
    #[test]
    fn parallel_folds_are_bit_identical() {
        let ds = crate::data::synthetic::planted_sparse(
            "t", 90, 12, 3, 1.2, 0.9, 0.05, 17,
        );
        let serial = run_cv_threads(&ds, 3, 6, 5, 1).unwrap();
        for threads in [2usize, 4] {
            let par = run_cv_threads(&ds, 3, 6, 5, threads).unwrap();
            assert_eq!(serial.ks, par.ks, "threads={threads}");
            assert_eq!(serial.lambdas, par.lambdas, "threads={threads}");
            assert_eq!(
                serial.greedy_test, par.greedy_test,
                "threads={threads}"
            );
            assert_eq!(serial.greedy_loo, par.greedy_loo);
            assert_eq!(serial.random_test, par.random_test);
            assert_eq!(serial.greedy_test_std, par.greedy_test_std);
        }
    }

    fn assert_curves_equal(a: &CvCurves, b: &CvCurves) {
        assert_eq!(a.ks, b.ks);
        assert_eq!(a.lambdas, b.lambdas);
        assert_eq!(a.greedy_test, b.greedy_test);
        assert_eq!(a.greedy_test_std, b.greedy_test_std);
        assert_eq!(a.greedy_loo, b.greedy_loo);
        assert_eq!(a.random_test, b.random_test);
    }

    #[test]
    fn resumable_cv_matches_uninterrupted_and_survives_fold_loss() {
        let dir = std::env::temp_dir().join("greedy_rls_cv_resume_test");
        let _ = std::fs::remove_dir_all(&dir);
        let ds = crate::data::synthetic::planted_sparse(
            "t", 90, 12, 3, 1.2, 0.9, 0.05, 23,
        );
        let opts = |seed, threads| CvOptions {
            folds: 3,
            k_max: 5,
            seed,
            threads,
            ..Default::default()
        };
        let reference = run_cv_threads(&ds, 3, 5, 9, 1).unwrap();

        // cold start: all folds computed, files written
        let cold = run_cv_resumable(&ds, &opts(9, 1), None, &dir).unwrap();
        assert_curves_equal(&reference, &cold);
        for i in 0..3 {
            assert!(fold_path(&dir, i).exists(), "fold {i} persisted");
        }

        // warm start: everything loaded from disk, still identical
        let warm = run_cv_resumable(&ds, &opts(9, 2), None, &dir).unwrap();
        assert_curves_equal(&reference, &warm);

        // simulate a kill that lost fold 1 and corrupted fold 2:
        // both are recomputed, result still identical
        std::fs::remove_file(fold_path(&dir, 1)).unwrap();
        let text = std::fs::read_to_string(fold_path(&dir, 2)).unwrap();
        std::fs::write(fold_path(&dir, 2), &text[..text.len() / 2]).unwrap();
        let healed = run_cv_resumable(&ds, &opts(9, 1), None, &dir).unwrap();
        assert_curves_equal(&reference, &healed);

        // a different protocol (other seed) must not reuse the files
        let other = run_cv_resumable(&ds, &opts(10, 1), None, &dir).unwrap();
        let other_ref = run_cv_threads(&ds, 3, 5, 10, 1).unwrap();
        assert_curves_equal(&other_ref, &other);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fold_text_roundtrip_is_bit_exact() {
        let gc = Curve {
            test_acc: vec![0.5, 0.75],
            loo_acc: vec![0.25, -0.0],
            selected: vec![7, 2],
        };
        let rc = Curve {
            test_acc: vec![0.1, 0.2],
            loo_acc: vec![0.3, 0.4],
            selected: vec![0, 5],
        };
        let text = fold_to_text(0xabc, 3, &(gc.clone(), rc.clone(), 0.125));
        let (fp, fold, (g2, r2, lam)) = fold_from_text(&text).unwrap();
        assert_eq!(fp, 0xabc);
        assert_eq!(fold, 3);
        assert_eq!(lam.to_bits(), 0.125f64.to_bits());
        assert_eq!(g2.selected, gc.selected);
        assert_eq!(r2.selected, rc.selected);
        for (a, b) in g2.loo_acc.iter().zip(&gc.loo_acc) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // corruption and truncation are refused
        assert!(fold_from_text(&text[..text.len() / 2]).is_err());
        assert!(fold_from_text(&text.replace("fold 3", "fold 4")).is_err());
    }

    #[test]
    fn holdout_runs() {
        let ds = crate::data::synthetic::two_gaussians(100, 10, 4, 2.0, 8);
        let cfg = SelectionConfig { k: 4, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let (acc, sel) = holdout_accuracy(&ds, 0.3, &cfg, 3).unwrap();
        assert_eq!(sel.len(), 4);
        assert!(acc > 0.6, "acc {acc}");
    }
}
