//! Regularization grid search (paper §4.2 protocol).
//!
//! "On each of the ten cross-validation rounds, before the feature
//! selection experiment is run we select the value of the regularization
//! parameter [by training] on the training folds using the full feature
//! set, and perform\[ing\] a grid search ... based on leave-one-out
//! performance."
//!
//! The LOO is computed with the closed-form shortcut — primal eq. (7)
//! when n ≤ m, dual eq. (8) otherwise — so the grid search costs one
//! factorization per λ, never m retrainings.

use crate::linalg::Matrix;
use crate::metrics::Loss;
use crate::rls;
use crate::select::{
    greedy::GreedyRls, SelectionConfig, SessionSelector, StepOutcome,
};

/// Default λ grid: 10^-4 … 10^4, decade steps.
pub fn default_grid() -> Vec<f64> {
    (-4..=4).map(|e| 10f64.powi(e)).collect()
}

/// LOO criterion (summed loss) of the full feature set at one λ.
pub fn loo_criterion(x: &Matrix, y: &[f64], lambda: f64, loss: Loss) -> f64 {
    let p = if x.rows() <= x.cols() {
        rls::loo_primal(x, y, lambda)
    } else {
        rls::loo_dual(x, y, lambda)
    };
    loss.total(y, &p)
}

/// Pick the λ from `grid` with the best (lowest) full-feature LOO
/// criterion; ties break toward stronger regularization (larger λ), the
/// conservative choice. Returns `(lambda, criterion)`.
pub fn search(
    x: &Matrix,
    y: &[f64],
    grid: &[f64],
    loss: Loss,
) -> (f64, f64) {
    assert!(!grid.is_empty());
    let mut best = (grid[0], f64::INFINITY);
    for &lam in grid {
        let e = loo_criterion(x, y, lam, loss);
        if e < best.1 || (e == best.1 && lam > best.0) {
            best = (lam, e);
        }
    }
    best
}

/// A jointly selected (λ, k) operating point.
#[derive(Clone, Copy, Debug)]
pub struct LambdaKChoice {
    /// Chosen regularization.
    pub lambda: f64,
    /// Number of features at the criterion minimum (1-based).
    pub k: usize,
    /// The winning LOO criterion value.
    pub criterion: f64,
}

/// Joint (λ, k) model selection by driving one greedy-RLS *session* per
/// grid point and reading the whole criterion curve — one selection run
/// per λ replaces `base.k` separate grid searches. Honors `base.stop`
/// (e.g. a plateau policy prunes hopeless λ early, and a
/// [`crate::select::StopPolicy::TimeBudget`] caps each cell so the whole
/// sweep is wall-clock bounded by `grid.len() ×` budget). Ties break
/// toward larger λ, then smaller k — the conservative choice, as in
/// [`search`].
///
/// **Determinism caveat:** a time budget *truncates* each λ cell's
/// criterion curve, never reorders it — every recorded round is exactly
/// the round the unstopped run would have produced — so a time-stopped
/// sweep picks its champion from curve prefixes. Round budgets and
/// plateau stops remain fully deterministic.
///
/// The λ cells are independent selection runs, so they execute on
/// parallel workers sized by `base.threads` (`0` = auto); each cell's
/// champion — the first k reaching that λ's criterion minimum, exactly
/// what the serial scan would retain — is reduced on the calling thread
/// in grid order with the same tie-break, so the choice is bit-identical
/// to the serial sweep at any thread count. With more than one λ worker
/// the per-cell sessions run serial scans; a single-cell grid gives its
/// session the whole thread budget instead.
pub fn sweep_lambda_k(
    x: &Matrix,
    y: &[f64],
    grid: &[f64],
    base: &SelectionConfig,
) -> anyhow::Result<LambdaKChoice> {
    let outer = crate::parallel::resolve(base.threads).min(grid.len().max(1));
    let inner = if outer > 1 { 1 } else { base.threads };
    let per_lambda: Vec<anyhow::Result<Option<LambdaKChoice>>> =
        crate::parallel::par_map(outer, grid.len(), |gi| {
            let lam = grid[gi];
            let cfg = base.with().lambda(lam).threads(inner).build();
            let mut session = GreedyRls.begin(x, y, &cfg)?;
            // champion of this λ: the first k achieving the running
            // strict minimum — the candidate the serial global fold
            // would retain from this cell
            let mut cell: Option<LambdaKChoice> = None;
            loop {
                match session.step()? {
                    StepOutcome::Selected(round) => {
                        let k = session.rounds_done();
                        let cand = LambdaKChoice {
                            lambda: lam,
                            k,
                            criterion: round.criterion,
                        };
                        let better = match cell {
                            None => true,
                            Some(c) => cand.criterion < c.criterion,
                        };
                        if better {
                            cell = Some(cand);
                        }
                    }
                    StepOutcome::Done(_) => break,
                }
            }
            Ok(cell)
        });

    let mut best: Option<LambdaKChoice> = None;
    for res in per_lambda {
        let Some(cand) = res? else { continue };
        let better = match best {
            None => true,
            Some(b) => {
                cand.criterion < b.criterion
                    || (cand.criterion == b.criterion
                        && (cand.lambda > b.lambda
                            || (cand.lambda == b.lambda && cand.k < b.k)))
            }
        };
        if better {
            best = Some(cand);
        }
    }
    best.ok_or_else(|| anyhow::anyhow!("no (λ, k) candidate evaluated"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::Gen;

    #[test]
    fn default_grid_spans_decades() {
        let g = default_grid();
        assert_eq!(g.len(), 9);
        assert_eq!(g[0], 1e-4);
        assert_eq!(g[8], 1e4);
    }

    #[test]
    fn search_returns_grid_member() {
        let ds = crate::data::synthetic::two_gaussians(80, 10, 4, 1.5, 5);
        let grid = default_grid();
        let (lam, e) = search(&ds.x, &ds.y, &grid, Loss::ZeroOne);
        assert!(grid.contains(&lam));
        assert!(e.is_finite());
    }

    #[test]
    fn criterion_matches_manual_loo() {
        let mut g = Gen::new(1);
        let x = g.matrix(4, 12);
        let y = g.targets(12);
        let e = loo_criterion(&x, &y, 0.7, Loss::Squared);
        let p = rls::loo_brute_force(&x, &y, 0.7);
        let want: f64 =
            y.iter().zip(&p).map(|(&a, &b)| (a - b) * (a - b)).sum();
        assert!((e - want).abs() < 1e-6 * want.max(1.0));
    }

    #[test]
    fn overfitting_lambda_scores_worse_on_noise() {
        // pure-noise labels: tiny λ interpolates LOO badly; large λ
        // shouldn't be worse than the most permissive setting
        let mut g = Gen::new(2);
        let x = g.matrix(20, 30);
        let y = g.labels(30);
        let tiny = loo_criterion(&x, &y, 1e-8, Loss::Squared);
        let large = loo_criterion(&x, &y, 1e2, Loss::Squared);
        assert!(large <= tiny * 2.0, "tiny {tiny} large {large}");
    }

    #[test]
    fn sweep_finds_the_planted_operating_point() {
        // 3 informative of 20 features: the criterion minimum should sit
        // at k ≈ 3 for some reasonable λ, never at the largest k
        let (ds, _) =
            crate::data::synthetic::sparse_regression(150, 20, 3, 0.05, 21);
        let base = SelectionConfig::builder()
            .k(8)
            .loss(Loss::Squared)
            .build();
        let grid = [0.01, 0.1, 1.0];
        let choice = sweep_lambda_k(&ds.x, &ds.y, &grid, &base).unwrap();
        assert!(grid.contains(&choice.lambda));
        assert!((1..=8).contains(&choice.k));
        assert!(choice.criterion.is_finite());
        assert!(
            choice.k >= 3,
            "needs at least the planted support: {choice:?}"
        );
    }

    /// The parallel λ sweep must make the exact choice of the serial
    /// sweep at every thread count.
    #[test]
    fn parallel_sweep_is_bit_identical() {
        let (ds, _) =
            crate::data::synthetic::sparse_regression(120, 15, 3, 0.05, 33);
        let grid = default_grid();
        let serial = sweep_lambda_k(
            &ds.x,
            &ds.y,
            &grid,
            &SelectionConfig::builder()
                .k(6)
                .loss(Loss::Squared)
                .threads(1)
                .build(),
        )
        .unwrap();
        for threads in [2usize, 4] {
            let par = sweep_lambda_k(
                &ds.x,
                &ds.y,
                &grid,
                &SelectionConfig::builder()
                    .k(6)
                    .loss(Loss::Squared)
                    .threads(threads)
                    .build(),
            )
            .unwrap();
            assert_eq!(serial.lambda, par.lambda, "threads={threads}");
            assert_eq!(serial.k, par.k, "threads={threads}");
            assert_eq!(
                serial.criterion.to_bits(),
                par.criterion.to_bits(),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn sweep_empty_grid_is_an_error() {
        let ds = crate::data::synthetic::two_gaussians(20, 5, 2, 1.0, 1);
        let base = SelectionConfig::builder().k(2).build();
        assert!(sweep_lambda_k(&ds.x, &ds.y, &[], &base).is_err());
    }

    #[test]
    fn sweep_criterion_matches_one_shot_curve() {
        let ds = crate::data::synthetic::two_gaussians(60, 10, 4, 1.5, 8);
        let base = SelectionConfig::builder().k(5).build();
        let grid = [1.0];
        let choice = sweep_lambda_k(&ds.x, &ds.y, &grid, &base).unwrap();
        let r = crate::select::Selector::select(
            &crate::select::greedy::GreedyRls,
            &ds.x,
            &ds.y,
            &base,
        )
        .unwrap();
        let curve = r.criterion_curve();
        assert_eq!(choice.criterion, curve[choice.k - 1]);
        let min = curve.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(choice.criterion, min);
    }

    #[test]
    fn dual_branch_used_when_n_exceeds_m() {
        // n=30 > m=8 exercises the dual path; just needs to be finite
        let mut g = Gen::new(3);
        let x = g.matrix(30, 8);
        let y = g.targets(8);
        let e = loo_criterion(&x, &y, 1.0, Loss::Squared);
        assert!(e.is_finite());
    }
}
