//! Layer-3 coordinator: engines, cross-validation, model lifecycle.
//!
//! The paper's contribution is the selection algorithm itself, so Layer 3
//! is the machinery a team would deploy around it:
//!
//! * [`EngineKind`] — run selection on the native Rust engine or through
//!   the AOT-compiled PJRT artifacts (identical results, checked by
//!   integration tests);
//! * [`cv`] — the paper's §4.2/§4.3 experimental protocol (stratified
//!   k-fold, per-fold λ grid search, accuracy-vs-#features curves);
//! * [`grid`] — regularization grid search with the LOO shortcut;
//! * [`serve`] — load a selected sparse model and answer batched
//!   prediction requests (native or PJRT path), including hot-swap
//!   serving from a live session's checkpoint directory
//!   ([`serve::HotSwapServer`], `serve --follow`);
//! * [`stream`] — the in-process streaming pipeline: a live session
//!   publishes every committed round onto a [`stream::ModelBus`] and
//!   worker threads serve it concurrently with no filesystem on the
//!   path ([`stream::train_serve`], `train-serve` / `serve --bus`);
//! * [`fabric`] — the multi-process serving fabric: a checksummed
//!   binary wire format carries bus versions across a Unix/TCP socket
//!   ([`fabric::publish::SocketPublisher`] →
//!   [`fabric::follow::SocketFollower`]), with admission-controlled
//!   serving fronts, fault injection, and fleet orchestration
//!   (`serve --listen`, `fleet`);
//! * model persistence in a dependency-free text format, plus
//!   checkpoint-driven session resume ([`resume_with_engine`]).
//!
//! The three serving paths (one-shot `serve --model`, checkpoint-follow
//! `serve --follow`, and the bus) and how they relate are mapped in the
//! repo's `ARCHITECTURE.md`.

pub mod cv;
pub mod fabric;
pub mod grid;
pub mod serve;
pub mod stream;

use anyhow::Context;

use crate::data::Dataset;
use crate::linalg::Matrix;
use crate::rls::Predictor;
use crate::runtime::{engine::PjrtGreedy, Runtime};
use crate::select::checkpoint::{self, Checkpoint};
use crate::select::{
    greedy::GreedyRls, run_to_completion, Observer, Round, SelectionConfig,
    SelectionResult, Session, SessionSelector, StopReason,
};

/// Which engine executes the O(mn) selection math.
///
/// Engine choice is threaded through the whole coordinator surface: the
/// greedy session constructors below, the CV protocol
/// ([`cv::CvOptions::engine`] / `greedy-rls cv --engine`), and the
/// selector comparison (`greedy-rls compare --engine`). Greedy RLS,
/// backward elimination, n-fold greedy, FoBa and floating selection all
/// have artifact engines (see [`crate::runtime::engine`]); the wrapper's
/// trajectory is served by the greedy engine, while RankRLS, reduced-set,
/// low-rank and random remain native-only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust Algorithm 3 (fastest on this CPU testbed).
    Native,
    /// AOT artifacts through PJRT (the three-layer architecture's hot
    /// path; Pallas kernel semantics, no Python at runtime).
    Pjrt,
}

impl std::str::FromStr for EngineKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "native" => Ok(EngineKind::Native),
            "pjrt" => Ok(EngineKind::Pjrt),
            other => Err(format!("unknown engine {other:?}")),
        }
    }
}

/// Begin a greedy-RLS [`Session`] on the chosen engine. For
/// [`EngineKind::Pjrt`] a [`Runtime`] must be supplied (artifacts built
/// via `make artifacts`). The session borrows only `x`/`y`, never the
/// runtime, so it can outlive the dispatch scope.
pub fn begin_with_engine<'a>(
    engine: EngineKind,
    runtime: Option<&Runtime>,
    x: &'a Matrix,
    y: &'a [f64],
    cfg: &SelectionConfig,
) -> anyhow::Result<Box<dyn Session + 'a>> {
    match engine {
        EngineKind::Native => GreedyRls.begin(x, y, cfg),
        EngineKind::Pjrt => {
            let rt = runtime
                .context("PJRT engine requested but no runtime supplied")?;
            PjrtGreedy::new(rt).begin(x, y, cfg)
        }
    }
}

/// [`begin_with_engine`] warm-started from a previously selected prefix
/// (feature indices in selection order). The greedy caches are rebuilt
/// with the paper's rank-1 updates; continuing the session is
/// bit-identical to an uninterrupted run.
pub fn begin_from_with_engine<'a>(
    engine: EngineKind,
    runtime: Option<&Runtime>,
    x: &'a Matrix,
    y: &'a [f64],
    cfg: &SelectionConfig,
    selected: &[usize],
) -> anyhow::Result<Box<dyn Session + 'a>> {
    match engine {
        EngineKind::Native => GreedyRls.begin_from(x, y, cfg, selected),
        EngineKind::Pjrt => {
            let rt = runtime
                .context("PJRT engine requested but no runtime supplied")?;
            PjrtGreedy::new(rt).begin_from(x, y, cfg, selected)
        }
    }
}

/// [`begin_from_with_engine`] fed from a checkpoint file: load it, refuse
/// a config/data fingerprint mismatch, replay the recorded rounds
/// (bit-identical cache reconstruction), and re-arm the time-budget clock
/// with the prior run's elapsed time. Returns the live session plus the
/// checkpoint it came from.
pub fn resume_with_engine<'a>(
    engine: EngineKind,
    runtime: Option<&Runtime>,
    x: &'a Matrix,
    y: &'a [f64],
    cfg: &SelectionConfig,
    path: &std::path::Path,
) -> anyhow::Result<(Box<dyn Session + 'a>, Checkpoint)> {
    let ckpt = Checkpoint::load(path)?;
    ckpt.verify(&checkpoint::fingerprint(x, y, cfg))?;
    let mut session = begin_from_with_engine(
        engine,
        runtime,
        x,
        y,
        cfg,
        &ckpt.replay_features(),
    )
    .with_context(|| {
        format!(
            "replaying {} checkpointed rounds from {}",
            ckpt.rounds.len(),
            path.display()
        )
    })?;
    session.bill_elapsed(ckpt.elapsed);
    Ok((session, ckpt))
}

/// Run greedy RLS on the chosen engine (one-shot; drives a session to
/// completion under `cfg.stop`).
pub fn select_with_engine(
    engine: EngineKind,
    runtime: Option<&Runtime>,
    x: &Matrix,
    y: &[f64],
    cfg: &SelectionConfig,
) -> anyhow::Result<SelectionResult> {
    run_to_completion(begin_with_engine(engine, runtime, x, y, cfg)?)
}

/// Per-round progress logging to stderr — the coordinator's standard
/// [`Observer`] for long selection runs (`--progress` on the CLI).
#[derive(Clone, Copy, Debug, Default)]
pub struct ProgressObserver;

impl Observer for ProgressObserver {
    fn on_round(
        &mut self,
        index: usize,
        round: &Round,
        elapsed: std::time::Duration,
    ) {
        eprintln!(
            "[select] round {:>4}: feature {:>6}  criterion {:>12.6}  \
             ({:.3}s)",
            index + 1,
            round.feature,
            round.criterion,
            elapsed.as_secs_f64()
        );
    }

    fn on_stop(&mut self, reason: StopReason) {
        eprintln!("[select] stopped: {reason}");
    }
}

/// Train a final sparse model on a dataset with the given config
/// (selection + weights), ready for serving.
pub fn fit(
    engine: EngineKind,
    runtime: Option<&Runtime>,
    ds: &Dataset,
    cfg: &SelectionConfig,
) -> anyhow::Result<Predictor> {
    let r = select_with_engine(engine, runtime, &ds.x, &ds.y, cfg)?;
    Ok(r.predictor())
}

// ---------------------------------------------------------------------------
// Model persistence (text format; no serde facade in the offline cache)
// ---------------------------------------------------------------------------

/// Serialize a predictor to the `greedy-rls-model v1` text format.
pub fn model_to_string(p: &Predictor) -> String {
    let mut out = String::from("greedy-rls-model v1\n");
    for (&i, &w) in p.selected.iter().zip(&p.weights) {
        out.push_str(&format!("{i} {w:.17e}\n"));
    }
    out
}

/// Parse the text format back into a predictor.
pub fn model_from_str(text: &str) -> anyhow::Result<Predictor> {
    let mut lines = text.lines();
    let header = lines.next().unwrap_or("");
    anyhow::ensure!(
        header.trim() == "greedy-rls-model v1",
        "bad model header {header:?}"
    );
    let mut selected = Vec::new();
    let mut weights = Vec::new();
    for (no, line) in lines.enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (i, w) = line
            .split_once(' ')
            .with_context(|| format!("model line {}", no + 2))?;
        selected.push(i.parse().context("feature index")?);
        weights.push(w.parse().context("weight")?);
    }
    anyhow::ensure!(!selected.is_empty(), "empty model");
    Ok(Predictor { selected, weights })
}

/// Save / load helpers.
pub fn save_model(p: &Predictor, path: &std::path::Path) -> anyhow::Result<()> {
    std::fs::write(path, model_to_string(p))
        .with_context(|| format!("writing {}", path.display()))
}

/// Load a model file.
pub fn load_model(path: &std::path::Path) -> anyhow::Result<Predictor> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    model_from_str(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;

    #[test]
    fn native_engine_fit_roundtrip() {
        let ds = crate::data::synthetic::two_gaussians(60, 12, 4, 1.5, 3);
        let cfg = SelectionConfig { k: 4, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let p = fit(EngineKind::Native, None, &ds, &cfg).unwrap();
        assert_eq!(p.selected.len(), 4);
        let text = model_to_string(&p);
        let q = model_from_str(&text).unwrap();
        assert_eq!(p.selected, q.selected);
        for (a, b) in p.weights.iter().zip(&q.weights) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    #[test]
    fn pjrt_without_runtime_errors() {
        let ds = crate::data::synthetic::two_gaussians(20, 6, 2, 1.0, 4);
        let cfg = SelectionConfig { k: 2, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        assert!(fit(EngineKind::Pjrt, None, &ds, &cfg).is_err());
        assert!(
            begin_with_engine(EngineKind::Pjrt, None, &ds.x, &ds.y, &cfg)
                .is_err()
        );
    }

    #[test]
    fn native_session_matches_one_shot() {
        let ds = crate::data::synthetic::two_gaussians(50, 14, 5, 1.5, 9);
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let one_shot =
            select_with_engine(EngineKind::Native, None, &ds.x, &ds.y, &cfg)
                .unwrap();
        let session =
            begin_with_engine(EngineKind::Native, None, &ds.x, &ds.y, &cfg)
                .unwrap();
        let stepped = run_to_completion(session).unwrap();
        assert_eq!(one_shot.selected, stepped.selected);
        assert_eq!(one_shot.weights, stepped.weights);
    }

    #[test]
    fn warm_started_session_continues_the_run() {
        let ds = crate::data::synthetic::two_gaussians(50, 14, 5, 1.5, 10);
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let full =
            select_with_engine(EngineKind::Native, None, &ds.x, &ds.y, &cfg)
                .unwrap();
        let session = begin_from_with_engine(
            EngineKind::Native,
            None,
            &ds.x,
            &ds.y,
            &cfg,
            &full.selected[..2],
        )
        .unwrap();
        assert_eq!(session.rounds_done(), 2);
        let resumed = run_to_completion(session).unwrap();
        assert_eq!(full.selected, resumed.selected);
        assert_eq!(full.weights, resumed.weights);
    }

    #[test]
    fn resume_with_engine_continues_from_checkpoint_file() {
        let ds = crate::data::synthetic::two_gaussians(50, 14, 5, 1.5, 12);
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let full =
            select_with_engine(EngineKind::Native, None, &ds.x, &ds.y, &cfg)
                .unwrap();

        // snapshot a partial run to a checkpoint file
        let fp = checkpoint::fingerprint(&ds.x, &ds.y, &cfg);
        let mut session =
            begin_with_engine(EngineKind::Native, None, &ds.x, &ds.y, &cfg)
                .unwrap();
        session.step().unwrap();
        session.step().unwrap();
        let ckpt = Checkpoint::from_session(session.as_ref(), fp).unwrap();
        let dir = std::env::temp_dir().join("greedy_rls_coord_resume_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = checkpoint::checkpoint_path(&dir, 2);
        ckpt.save_atomic(&path).unwrap();

        let (resumed, restored) = resume_with_engine(
            EngineKind::Native,
            None,
            &ds.x,
            &ds.y,
            &cfg,
            &path,
        )
        .unwrap();
        assert_eq!(restored.rounds.len(), 2);
        assert_eq!(resumed.rounds_done(), 2);
        let r = run_to_completion(resumed).unwrap();
        assert_eq!(r.selected, full.selected);
        assert_eq!(r.weights, full.weights);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn engine_kind_parses() {
        assert_eq!("native".parse::<EngineKind>(), Ok(EngineKind::Native));
        assert_eq!("pjrt".parse::<EngineKind>(), Ok(EngineKind::Pjrt));
        assert!("cuda".parse::<EngineKind>().is_err());
    }

    #[test]
    fn model_format_rejects_garbage() {
        assert!(model_from_str("wrong header\n1 2.0\n").is_err());
        assert!(model_from_str("greedy-rls-model v1\n").is_err());
        assert!(model_from_str("greedy-rls-model v1\nnot_a_pair\n").is_err());
    }
}
