//! Layer-3 coordinator: engines, cross-validation, model lifecycle.
//!
//! The paper's contribution is the selection algorithm itself, so Layer 3
//! is the machinery a team would deploy around it:
//!
//! * [`EngineKind`] — run selection on the native Rust engine or through
//!   the AOT-compiled PJRT artifacts (identical results, checked by
//!   integration tests);
//! * [`cv`] — the paper's §4.2/§4.3 experimental protocol (stratified
//!   k-fold, per-fold λ grid search, accuracy-vs-#features curves);
//! * [`grid`] — regularization grid search with the LOO shortcut;
//! * [`serve`] — load a selected sparse model and answer batched
//!   prediction requests (native or PJRT path);
//! * model persistence in a dependency-free text format.

pub mod cv;
pub mod grid;
pub mod serve;

use anyhow::Context;

use crate::data::Dataset;
use crate::linalg::Matrix;
use crate::rls::Predictor;
use crate::runtime::{engine::PjrtGreedy, Runtime};
use crate::select::{
    greedy::GreedyRls, SelectionConfig, SelectionResult, Selector,
};

/// Which engine executes the O(mn) selection math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust Algorithm 3 (fastest on this CPU testbed).
    Native,
    /// AOT artifacts through PJRT (the three-layer architecture's hot
    /// path; Pallas kernel semantics, no Python at runtime).
    Pjrt,
}

impl std::str::FromStr for EngineKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "native" => Ok(EngineKind::Native),
            "pjrt" => Ok(EngineKind::Pjrt),
            other => Err(format!("unknown engine {other:?}")),
        }
    }
}

/// Run greedy RLS on the chosen engine. For [`EngineKind::Pjrt`] a
/// [`Runtime`] must be supplied (artifacts built via `make artifacts`).
pub fn select_with_engine(
    engine: EngineKind,
    runtime: Option<&Runtime>,
    x: &Matrix,
    y: &[f64],
    cfg: &SelectionConfig,
) -> anyhow::Result<SelectionResult> {
    match engine {
        EngineKind::Native => GreedyRls.select(x, y, cfg),
        EngineKind::Pjrt => {
            let rt = runtime
                .context("PJRT engine requested but no runtime supplied")?;
            PjrtGreedy::new(rt).select(x, y, cfg)
        }
    }
}

/// Train a final sparse model on a dataset with the given config
/// (selection + weights), ready for serving.
pub fn fit(
    engine: EngineKind,
    runtime: Option<&Runtime>,
    ds: &Dataset,
    cfg: &SelectionConfig,
) -> anyhow::Result<Predictor> {
    let r = select_with_engine(engine, runtime, &ds.x, &ds.y, cfg)?;
    Ok(r.predictor())
}

// ---------------------------------------------------------------------------
// Model persistence (text format; no serde facade in the offline cache)
// ---------------------------------------------------------------------------

/// Serialize a predictor to the `greedy-rls-model v1` text format.
pub fn model_to_string(p: &Predictor) -> String {
    let mut out = String::from("greedy-rls-model v1\n");
    for (&i, &w) in p.selected.iter().zip(&p.weights) {
        out.push_str(&format!("{i} {w:.17e}\n"));
    }
    out
}

/// Parse the text format back into a predictor.
pub fn model_from_str(text: &str) -> anyhow::Result<Predictor> {
    let mut lines = text.lines();
    let header = lines.next().unwrap_or("");
    anyhow::ensure!(
        header.trim() == "greedy-rls-model v1",
        "bad model header {header:?}"
    );
    let mut selected = Vec::new();
    let mut weights = Vec::new();
    for (no, line) in lines.enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (i, w) = line
            .split_once(' ')
            .with_context(|| format!("model line {}", no + 2))?;
        selected.push(i.parse().context("feature index")?);
        weights.push(w.parse().context("weight")?);
    }
    anyhow::ensure!(!selected.is_empty(), "empty model");
    Ok(Predictor { selected, weights })
}

/// Save / load helpers.
pub fn save_model(p: &Predictor, path: &std::path::Path) -> anyhow::Result<()> {
    std::fs::write(path, model_to_string(p))
        .with_context(|| format!("writing {}", path.display()))
}

/// Load a model file.
pub fn load_model(path: &std::path::Path) -> anyhow::Result<Predictor> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    model_from_str(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Loss;

    #[test]
    fn native_engine_fit_roundtrip() {
        let ds = crate::data::synthetic::two_gaussians(60, 12, 4, 1.5, 3);
        let cfg = SelectionConfig { k: 4, lambda: 1.0, loss: Loss::ZeroOne };
        let p = fit(EngineKind::Native, None, &ds, &cfg).unwrap();
        assert_eq!(p.selected.len(), 4);
        let text = model_to_string(&p);
        let q = model_from_str(&text).unwrap();
        assert_eq!(p.selected, q.selected);
        for (a, b) in p.weights.iter().zip(&q.weights) {
            assert!((a - b).abs() < 1e-15);
        }
    }

    #[test]
    fn pjrt_without_runtime_errors() {
        let ds = crate::data::synthetic::two_gaussians(20, 6, 2, 1.0, 4);
        let cfg = SelectionConfig { k: 2, lambda: 1.0, loss: Loss::ZeroOne };
        assert!(fit(EngineKind::Pjrt, None, &ds, &cfg).is_err());
    }

    #[test]
    fn engine_kind_parses() {
        assert_eq!("native".parse::<EngineKind>(), Ok(EngineKind::Native));
        assert_eq!("pjrt".parse::<EngineKind>(), Ok(EngineKind::Pjrt));
        assert!("cuda".parse::<EngineKind>().is_err());
    }

    #[test]
    fn model_format_rejects_garbage() {
        assert!(model_from_str("wrong header\n1 2.0\n").is_err());
        assert!(model_from_str("greedy-rls-model v1\n").is_err());
        assert!(model_from_str("greedy-rls-model v1\nnot_a_pair\n").is_err());
    }
}
