//! `greedy-rls` — Layer-3 leader binary.
//!
//! Subcommand dispatch over the library's coordinator; see `cli::USAGE`.

use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use greedy_rls::bench::time_once;
use greedy_rls::cli::{self, Args, USAGE};
use greedy_rls::coordinator::{
    self, cv, serve, stream, EngineKind, ProgressObserver,
};
use greedy_rls::data::storage::{Backend, StorageOptions, StoredDataset};
use greedy_rls::data::{registry, synthetic, Dataset};
use greedy_rls::metrics::Loss;
use greedy_rls::runtime::Runtime;
use greedy_rls::select::checkpoint::{
    self, drive_checkpointed, AutosavePolicy, Autosaver,
};
use greedy_rls::select::{
    drive, greedy::GreedyRls, lowrank::LowRankLsSvm, run_to_completion,
    sketch, NoopObserver, Observer, Precision, PreselectConfig,
    SelectionConfig, Selector, Session, StopPolicy,
};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("select") => cmd_select(args),
        Some("cv") => cmd_cv(args),
        Some("scaling") => cmd_scaling(args),
        Some("serve") => cmd_serve(args),
        Some("train-serve") => cmd_train_serve(args),
        Some("fleet") => cmd_fleet(args),
        Some("datasets") => cmd_datasets(),
        Some("compare") => cmd_compare(args),
        Some("check") => cmd_check(args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn load_dataset(args: &Args) -> Result<Dataset> {
    let seed: u64 = args.get_or("seed", 42u64)?;
    if let Some(spec) = args.get("synthetic") {
        let parts: Vec<usize> = spec
            .split(',')
            .map(|t| t.trim().parse().context("--synthetic M,N"))
            .collect::<Result<_>>()?;
        if parts.len() != 2 {
            bail!("--synthetic expects M,N");
        }
        return Ok(synthetic::two_gaussians(parts[0], parts[1],
            (parts[1] / 10).max(1), 1.0, seed));
    }
    let name: String = args.require("dataset")?;
    registry::load(&name, args.has("full"), seed)
}

fn open_runtime_if(engine: EngineKind) -> Result<Option<Runtime>> {
    match engine {
        EngineKind::Native => Ok(None),
        EngineKind::Pjrt => Ok(Some(Runtime::open("artifacts")?)),
    }
}

/// Parse the shared selection-config flags (`--k/--lambda/--loss/--stop
/// family/--threads/--tile-cols/--precision/--preselect family`) —
/// identical between `select` and `train-serve`.
fn parse_selection_config(args: &Args) -> Result<SelectionConfig> {
    let stop = cli::parse_stop_policy(args)?;
    Ok(SelectionConfig::builder()
        .k(args.get_or("k", 10usize)?)
        .lambda(args.get_or("lambda", 1.0f64)?)
        .loss(args.get_or("loss", Loss::ZeroOne)?)
        .stop(stop)
        .threads(args.get_or("threads", 0usize)?)
        .tile_cols(args.get_or("tile-cols", 0usize)?)
        .precision(args.get_or("precision", Precision::F64)?)
        .preselect(parse_preselect(args)?)
        .build())
}

/// Parse the sketched-preselection flags (`--preselect P` with an
/// optional `--sketch-dim D`), shared by `select`, `cv`, and `compare`.
/// The sketch seed is the dataset `--seed` (default 42), so one flag
/// pins generation, splits, and the sketch together. Without
/// `--preselect`, a stray `--sketch-dim` is rejected instead of
/// silently ignored (same contract as the stop-policy and mmap flag
/// families).
fn parse_preselect(args: &Args) -> Result<Option<PreselectConfig>> {
    let Some(p) = args.get("preselect") else {
        ensure!(
            args.get("sketch-dim").is_none(),
            "--sketch-dim requires --preselect"
        );
        return Ok(None);
    };
    let ps = PreselectConfig {
        p: p.parse().context("--preselect P")?,
        sketch_dim: args.get_or("sketch-dim", 0usize)?,
        seed: args.get_or("seed", 42u64)?,
    };
    sketch::validate(&ps)?;
    Ok(Some(ps))
}

/// Parse the `--backend` family into [`StorageOptions`] (shared by
/// `select` and `scaling`). `--window-mb`/`--chunk-mb` are MiB on the
/// CLI, bytes in the options.
fn parse_storage_options(args: &Args) -> Result<StorageOptions> {
    let mut opts = StorageOptions::default()
        .backend(args.get_or("backend", Backend::Ram)?)
        .window_bytes(args.get_or("window-mb", 256usize)? << 20)
        .chunk_bytes(args.get_or("chunk-mb", 8usize)? << 20)
        .tile_cols(args.get_or("tile-cols", 0usize)?);
    if let Some(dir) = args.get("scratch") {
        opts = opts.scratch(dir);
    }
    Ok(opts)
}

/// Reject the mmap-only flags on the ram backend instead of silently
/// ignoring them (same contract as the stop-policy flag family).
fn ensure_no_mmap_flags(args: &Args) -> Result<()> {
    for flag in ["window-mb", "chunk-mb", "scratch"] {
        ensure!(
            args.get(flag).is_none(),
            "--{flag} requires --backend mmap"
        );
    }
    Ok(())
}

/// `--checkpoint-dir`/`--checkpoint-every`/`--resume`, parsed and
/// validated exactly once per command (shared by `select` and
/// `train-serve`; session construction and autosaver construction both
/// read from this struct, so the two can't desynchronize).
struct CheckpointFlags {
    dir: Option<std::path::PathBuf>,
    every: usize,
    resume: bool,
}

fn parse_checkpoint_flags(args: &Args) -> Result<CheckpointFlags> {
    let dir = args.get("checkpoint-dir").map(std::path::PathBuf::from);
    let every: usize = args.get_or("checkpoint-every", 1usize)?;
    let resume = args.has("resume");
    if dir.is_none() {
        ensure!(
            args.get("checkpoint-every").is_none(),
            "--checkpoint-every requires --checkpoint-dir"
        );
        ensure!(!resume, "--resume requires --checkpoint-dir");
    }
    Ok(CheckpointFlags { dir, every, resume })
}

/// Session construction shared by `select` and `train-serve`: validate
/// the `--warm-start`/`--resume` flag combination, then begin a fresh,
/// warm-started, or checkpoint-resumed session on the chosen engine
/// (printing the warm-start/resume banner). The second return is the
/// checkpoint's fingerprint on resume, so the autosaver can reuse it
/// instead of rehashing the O(mn) dataset.
fn build_session<'a>(
    args: &Args,
    engine: EngineKind,
    rt: Option<&Runtime>,
    ds: &'a Dataset,
    cfg: &SelectionConfig,
    ckpt: &CheckpointFlags,
) -> Result<(Box<dyn Session + 'a>, Option<checkpoint::Fingerprint>)> {
    let warm: Option<Vec<usize>> = match args.get_list("warm-start") {
        Some(items) => Some(
            items
                .iter()
                .map(|s| s.parse().context("--warm-start I1,I2,..."))
                .collect::<Result<_>>()?,
        ),
        None => None,
    };
    ensure!(
        !(ckpt.resume && warm.is_some()),
        "--resume and --warm-start are mutually exclusive (the checkpoint \
         already pins the prefix)"
    );
    if let Some(prefix) = &warm {
        println!("warm start from {} features: {prefix:?}", prefix.len());
        let s = coordinator::begin_from_with_engine(
            engine, rt, &ds.x, &ds.y, cfg, prefix,
        )?;
        return Ok((s, None));
    }
    let latest = if ckpt.resume {
        let dir = ckpt.dir.as_deref().with_context(|| {
            "--resume requires --checkpoint-dir (parse_checkpoint_flags \
             enforces this)"
        })?;
        checkpoint::latest_in_dir(dir)?
    } else {
        None
    };
    match latest {
        Some(path) => {
            let (s, ckpt) = coordinator::resume_with_engine(
                engine, rt, &ds.x, &ds.y, cfg, &path,
            )?;
            println!(
                "resumed from {} ({} rounds replayed, {:.3}s prior \
                 selection time)",
                path.display(),
                ckpt.rounds.len(),
                ckpt.elapsed.as_secs_f64()
            );
            Ok((s, Some(ckpt.fingerprint)))
        }
        None => {
            if ckpt.resume {
                if let Some(dir) = ckpt.dir.as_deref() {
                    println!(
                        "no checkpoint in {}; starting fresh",
                        dir.display()
                    );
                }
            }
            let s = coordinator::begin_with_engine(
                engine, rt, &ds.x, &ds.y, cfg,
            )?;
            Ok((s, None))
        }
    }
}

/// Build the autosaver for a checkpointed run (`None` without
/// `--checkpoint-dir`), reusing a resumed checkpoint's (verified-equal)
/// fingerprint when available instead of rehashing the O(mn) dataset.
/// The single constructor keeps `select` and `train-serve` durability
/// behavior in lockstep.
fn make_autosaver(
    ckpt: &CheckpointFlags,
    resumed_fp: Option<checkpoint::Fingerprint>,
    ds: &Dataset,
    cfg: &SelectionConfig,
) -> Result<Option<Autosaver>> {
    let Some(dir) = &ckpt.dir else {
        return Ok(None);
    };
    let fp = resumed_fp
        .unwrap_or_else(|| checkpoint::fingerprint(&ds.x, &ds.y, cfg));
    let policy = AutosavePolicy { every: ckpt.every, on_stop: true };
    Ok(Some(Autosaver::new(dir, policy, fp)?))
}

/// Report where a checkpointed run left its trail.
fn print_checkpoint_summary(saver: &Option<Autosaver>, ckpt: &CheckpointFlags) {
    if let (Some(s), Some(dir)) = (saver, ckpt.dir.as_deref()) {
        println!("checkpoints: {} written to {}", s.saves, dir.display());
    }
}

/// Echo the problem header every training-style command prints.
fn print_problem_header(
    ds: &Dataset,
    cfg: &SelectionConfig,
    engine: EngineKind,
    extra: &str,
) {
    println!(
        "dataset={} m={} n={} k={} lambda={} engine={engine:?} \
         threads={}{}{extra}",
        ds.name,
        ds.n_examples(),
        ds.n_features(),
        cfg.k,
        cfg.lambda,
        greedy_rls::parallel::resolve(cfg.threads),
        match cfg.stop {
            StopPolicy::KBudget(b) if b == usize::MAX => String::new(),
            other => format!(" stop={other:?}"),
        },
    );
    if cfg.precision != Precision::F64 {
        println!("precision={}", cfg.precision);
    }
    if let Some(ps) = cfg.preselect {
        println!(
            "preselect p={} sketch_dim={} seed={}",
            ps.p, ps.sketch_dim, ps.seed
        );
    }
}

/// Print the selection outcome lines shared by `select` and
/// `train-serve` (and diffed byte-for-byte by the kill/resume gauntlet).
fn print_selection_outcome(
    r: &greedy_rls::select::SelectionResult,
    reason: greedy_rls::select::StopReason,
    secs: f64,
) {
    println!("selected ({}): {:?}", r.selected.len(), r.selected);
    println!(
        "criterion trajectory: {:?}",
        r.criterion_curve()
            .iter()
            .map(|c| (c * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
    println!("stopped after {} rounds: {reason}", r.rounds.len());
    println!("selection time: {secs:.3}s");
}

fn cmd_select(args: &Args) -> Result<()> {
    if args.get_or("backend", Backend::Ram)? == Backend::Mmap {
        return cmd_select_stored(args);
    }
    ensure_no_mmap_flags(args)?;
    let mut ds = load_dataset(args)?;
    ds.standardize();
    let cfg = parse_selection_config(args)?;
    let engine: EngineKind = args.get_or("engine", EngineKind::Native)?;
    let rt = open_runtime_if(engine)?;
    let ckpt = parse_checkpoint_flags(args)?;
    print_problem_header(&ds, &cfg, engine, "");
    // xtask-allow: no-raw-instant -- whole-command wall clock for the
    // outcome line; the session separately bills selection time
    let t0 = std::time::Instant::now();
    let (mut session, resumed_fp) =
        build_session(args, engine, rt.as_ref(), &ds, &cfg, &ckpt)?;
    let mut observer: Box<dyn Observer> = if args.has("progress") {
        Box::new(ProgressObserver)
    } else {
        Box::new(NoopObserver)
    };
    let mut saver = make_autosaver(&ckpt, resumed_fp, &ds, &cfg)?;
    let reason = match saver.as_mut() {
        Some(saver) => drive_checkpointed(
            session.as_mut(),
            observer.as_mut(),
            saver,
        )?,
        None => drive(session.as_mut(), observer.as_mut())?,
    };
    print_checkpoint_summary(&saver, &ckpt);
    let r = session.finish()?;
    print_selection_outcome(&r, reason, t0.elapsed().as_secs_f64());
    if let Some(path) = args.get("out") {
        coordinator::save_model(&r.predictor(), std::path::Path::new(path))?;
        println!("model written to {path}");
    }
    Ok(())
}

/// Resolve the dataset for the mmap backend without materializing it in
/// RAM: `--synthetic` generates straight into a store through bounded
/// example slabs; `--dataset` takes a libsvm file path, or a registry
/// name whose real file sits under `data/real/`, loaded through the
/// chunked streaming parser.
fn load_stored_dataset(
    args: &Args,
    opts: &StorageOptions,
) -> Result<StoredDataset> {
    use greedy_rls::data::libsvm;

    let seed: u64 = args.get_or("seed", 42u64)?;
    if let Some(spec) = args.get("synthetic") {
        let parts: Vec<usize> = spec
            .split(',')
            .map(|t| t.trim().parse().context("--synthetic M,N"))
            .collect::<Result<_>>()?;
        if parts.len() != 2 {
            bail!("--synthetic expects M,N");
        }
        return synthetic::two_gaussians_stored(
            parts[0],
            parts[1],
            (parts[1] / 10).max(1),
            1.0,
            seed,
            opts,
        );
    }
    let name: String = args.require("dataset")?;
    let direct = std::path::PathBuf::from(&name);
    if direct.is_file() {
        return libsvm::parse_file_stored(&direct, None, opts);
    }
    let real = std::path::PathBuf::from(format!("data/real/{name}.libsvm"));
    if real.is_file() {
        // same declared width the registry's in-RAM loader pins
        let n = registry::SPECS
            .iter()
            .find(|s| s.name == name)
            .map(|s| s.paper_n);
        return libsvm::parse_file_stored(&real, n, opts);
    }
    bail!(
        "--backend mmap needs an on-disk dataset: {name:?} is neither a \
         libsvm file path nor a registry name with a file under \
         data/real/ (the synthetic registry stand-ins fit in RAM — use \
         --backend ram, or --synthetic M,N to generate out of core)"
    );
}

/// `select --backend mmap`: the out-of-core path. X and the greedy
/// cache live in mmap-backed scratch files and stream through bounded
/// per-worker windows, so selection runs on datasets larger than RAM;
/// the selected set, criterion trajectory, and weights are bit-identical
/// to `--backend ram` (the outcome lines below are diffed byte-for-byte
/// by the CI smoke job). Composes with `--checkpoint-dir`/`--resume`/
/// `--warm-start` exactly like `cmd_select` — checkpoint fingerprints
/// stream over the store and `config_hash` ignores the locality knobs,
/// so checkpoints interchange between backends.
fn cmd_select_stored(args: &Args) -> Result<()> {
    let engine: EngineKind = args.get_or("engine", EngineKind::Native)?;
    ensure!(
        engine == EngineKind::Native,
        "--backend mmap runs on the native engine"
    );
    let opts = parse_storage_options(args)?;
    let cfg = parse_selection_config(args)?;
    let ckpt = parse_checkpoint_flags(args)?;
    let mut ds = load_stored_dataset(args, &opts)?;
    ds.standardize()?;
    println!(
        "dataset={} m={} n={} k={} lambda={} engine={engine:?} \
         threads={} backend=mmap window_rows={}{}",
        ds.name,
        ds.n_examples(),
        ds.n_features(),
        cfg.k,
        cfg.lambda,
        greedy_rls::parallel::resolve(cfg.threads),
        ds.x.window_rows(),
        match cfg.stop {
            StopPolicy::KBudget(b) if b == usize::MAX => String::new(),
            other => format!(" stop={other:?}"),
        }
    );
    if let Some(ps) = cfg.preselect {
        println!(
            "preselect p={} sketch_dim={} seed={}",
            ps.p, ps.sketch_dim, ps.seed
        );
    }
    // xtask-allow: no-raw-instant -- whole-command wall clock for the
    // outcome line; the session separately bills selection time
    let t0 = std::time::Instant::now();
    // One streamed O(mn) pass serves both resume verification and the
    // autosaver; skipped entirely when the run is not checkpointed.
    let fp = match &ckpt.dir {
        Some(_) => Some(checkpoint::Fingerprint {
            // n-aware: an identity preselect filter (p >= n) leaves no
            // marker, so its checkpoints interchange with plain greedy
            config: checkpoint::config_hash_for(
                &cfg,
                Some(ds.n_features()),
            ),
            data: ds.fingerprint()?,
        }),
        None => None,
    };
    let warm: Option<Vec<usize>> = match args.get_list("warm-start") {
        Some(items) => Some(
            items
                .iter()
                .map(|s| s.parse().context("--warm-start I1,I2,..."))
                .collect::<Result<_>>()?,
        ),
        None => None,
    };
    ensure!(
        !(ckpt.resume && warm.is_some()),
        "--resume and --warm-start are mutually exclusive (the checkpoint \
         already pins the prefix)"
    );
    let latest = if ckpt.resume {
        let dir = ckpt.dir.as_deref().with_context(|| {
            "--resume requires --checkpoint-dir (parse_checkpoint_flags \
             enforces this)"
        })?;
        checkpoint::latest_in_dir(dir)?
    } else {
        None
    };
    let StoredDataset { x, y, .. } = ds;
    let mut session = if let Some(prefix) = &warm {
        println!("warm start from {} features: {prefix:?}", prefix.len());
        GreedyRls.begin_stored_from(x, y, &cfg, &opts, prefix)?
    } else if let Some(path) = latest {
        let c = checkpoint::Checkpoint::load(&path)?;
        let expect = fp.with_context(|| {
            "--resume requires --checkpoint-dir (parse_checkpoint_flags \
             enforces this)"
        })?;
        c.verify(&expect)?;
        let mut s = GreedyRls
            .begin_stored_from(x, y, &cfg, &opts, &c.replay_features())?;
        s.bill_elapsed(c.elapsed);
        println!(
            "resumed from {} ({} rounds replayed, {:.3}s prior \
             selection time)",
            path.display(),
            c.rounds.len(),
            c.elapsed.as_secs_f64()
        );
        s
    } else {
        if ckpt.resume {
            if let Some(dir) = ckpt.dir.as_deref() {
                println!(
                    "no checkpoint in {}; starting fresh",
                    dir.display()
                );
            }
        }
        GreedyRls.begin_stored(x, y, &cfg, &opts)?
    };
    let mut observer: Box<dyn Observer> = if args.has("progress") {
        Box::new(ProgressObserver)
    } else {
        Box::new(NoopObserver)
    };
    let mut saver = match (&ckpt.dir, fp) {
        (Some(dir), Some(fp)) => {
            let policy = AutosavePolicy { every: ckpt.every, on_stop: true };
            Some(Autosaver::new(dir, policy, fp)?)
        }
        _ => None,
    };
    let reason = match saver.as_mut() {
        Some(saver) => {
            drive_checkpointed(session.as_mut(), observer.as_mut(), saver)?
        }
        None => drive(session.as_mut(), observer.as_mut())?,
    };
    print_checkpoint_summary(&saver, &ckpt);
    let r = session.finish()?;
    print_selection_outcome(&r, reason, t0.elapsed().as_secs_f64());
    if let Some(path) = args.get("out") {
        coordinator::save_model(&r.predictor(), std::path::Path::new(path))?;
        println!("model written to {path}");
    }
    Ok(())
}

/// `train-serve` (also reachable as `serve --bus`): run selection on the
/// calling thread and serve the dataset's examples concurrently on
/// worker threads, hot-swapping in every committed round through the
/// in-process [`stream::ModelBus`] — no filesystem on the publish path.
/// Composes with `--checkpoint-dir`/`--resume` exactly like `select`
/// (checkpoints are written *before* the bus announces a version).
fn cmd_train_serve(args: &Args) -> Result<()> {
    let mut ds = load_dataset(args)?;
    ds.standardize();
    let cfg = parse_selection_config(args)?;
    let engine: EngineKind = args.get_or("engine", EngineKind::Native)?;
    let rt = open_runtime_if(engine)?;
    let ckpt = parse_checkpoint_flags(args)?;
    let opts = stream::TrainServeOptions {
        workers: args.get_or("serve-threads", 2usize)?,
        batch: args.get_or("batch", 64usize)?,
        queue_depth: args.get_or("queue-depth", 0usize)?,
    };
    ensure!(opts.batch > 0, "--batch must be positive");
    print_problem_header(
        &ds,
        &cfg,
        engine,
        &format!(
            " serve_threads={} batch={}",
            greedy_rls::parallel::resolve(opts.workers),
            opts.batch
        ),
    );
    // xtask-allow: no-raw-instant -- setup wall clock only; training
    // time is billed inside train_serve against the session clock
    let t0 = std::time::Instant::now();
    let (session, resumed_fp) =
        build_session(args, engine, rt.as_ref(), &ds, &cfg, &ckpt)?;
    let mut observer: Box<dyn Observer> = if args.has("progress") {
        Box::new(ProgressObserver)
    } else {
        Box::new(NoopObserver)
    };
    let mut saver = make_autosaver(&ckpt, resumed_fp, &ds, &cfg)?;
    // session setup (incl. any checkpoint replay) counts toward the
    // selection time, like `select`; the serving shutdown and final
    // pass do not — report.train_seconds covers the drive itself
    let setup_secs = t0.elapsed().as_secs_f64();
    // --publish bridges the in-process bus onto a fabric socket before
    // round 1, so remote `serve --connect` workers see every version;
    // the publisher guard is dropped (Shutdown frames sent, writers
    // joined) as soon as the bus closes
    let publish: Option<greedy_rls::coordinator::fabric::net::Addr> =
        args.get("publish").map(str::parse).transpose()?;
    let heartbeat_ms: u64 = args.get_or("heartbeat-ms", 500u64)?;
    ensure!(heartbeat_ms > 0, "--heartbeat-ms must be positive");
    let data_hash =
        greedy_rls::data::fingerprint::fingerprint_xy(&ds.x, &ds.y);
    let report = stream::train_serve_bridged(
        session,
        observer.as_mut(),
        saver.as_mut(),
        &ds.x,
        &opts,
        |bus| {
            publish
                .map(|addr| {
                    println!("publishing on {addr}");
                    let fopts = greedy_rls::coordinator::fabric::
                        FabricOptions::with_heartbeat(
                        Duration::from_millis(heartbeat_ms),
                    );
                    greedy_rls::coordinator::fabric::publish::
                        SocketPublisher::spawn(
                        &addr,
                        bus.clone(),
                        Some(data_hash),
                        fopts,
                    )
                })
                .transpose()
        },
    )?;
    print_checkpoint_summary(&saver, &ckpt);
    print_selection_outcome(
        &report.result,
        report.stop,
        setup_secs + report.train_seconds,
    );
    println!(
        "published={} versions, swaps={}, live_batches={}",
        report.published, report.swaps, report.live_batches
    );
    println!("version\trounds\tbatches\tp50_s\tp99_s");
    for v in &report.version_stats {
        println!(
            "{}\t{}\t{}\t{:.6}\t{:.6}",
            v.version, v.rounds, v.batches, v.p50_s, v.p99_s
        );
    }
    let acc = greedy_rls::metrics::accuracy(&ds.y, &report.final_preds);
    println!(
        "final pass: accuracy={acc:.4} batches={} mean={:.6}s p50={:.6}s \
         p99={:.6}s throughput={:.0}/s",
        report.final_serve.batches,
        report.final_serve.mean_batch_s,
        report.final_serve.p50_batch_s,
        report.final_serve.p99_batch_s,
        report.final_serve.throughput
    );
    if let Some(path) = args.get("out") {
        coordinator::save_model(
            &report.result.predictor(),
            std::path::Path::new(path),
        )?;
        println!("model written to {path}");
    }
    Ok(())
}

fn cmd_cv(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let folds: usize = args.get_or("folds", 10usize)?;
    let kmax: usize = args.get_or("kmax", ds.n_features().min(50))?;
    let seed: u64 = args.get_or("seed", 42u64)?;
    let threads: usize = args.get_or("threads", 0usize)?;
    let stop = cli::parse_stop_policy(args)?;
    let engine: EngineKind = args.get_or("engine", EngineKind::Native)?;
    let rt = open_runtime_if(engine)?;
    let opts = cv::CvOptions {
        folds,
        k_max: kmax,
        seed,
        threads,
        stop,
        engine,
        tile_cols: args.get_or("tile-cols", 0usize)?,
        preselect: parse_preselect(args)?,
    };
    println!(
        "# cv dataset={} m={} n={} folds={folds} kmax={kmax} \
         engine={engine:?}{}",
        ds.name,
        ds.n_examples(),
        ds.n_features(),
        match stop {
            StopPolicy::KBudget(b) if b == usize::MAX => String::new(),
            StopPolicy::TimeBudget(d) => format!(
                " stop=TimeBudget({d:?}) (time stops truncate curves, \
                 never reorder them)"
            ),
            other => format!(" stop={other:?}"),
        }
    );
    let curves = match args.get("checkpoint-dir") {
        Some(dir) => cv::run_cv_resumable(
            &ds,
            &opts,
            rt.as_ref(),
            std::path::Path::new(dir),
        )?,
        None => cv::run_cv_opts(&ds, &opts, rt.as_ref())?,
    };
    println!("k\tgreedy_test\tgreedy_loo\trandom_test\tgreedy_test_std");
    for (i, k) in curves.ks.iter().enumerate() {
        println!(
            "{k}\t{:.4}\t{:.4}\t{:.4}\t{:.4}",
            curves.greedy_test[i],
            curves.greedy_loo[i],
            curves.random_test[i],
            curves.greedy_test_std[i]
        );
    }
    println!("# per-fold lambdas: {:?}", curves.lambdas);
    Ok(())
}

fn cmd_scaling(args: &Args) -> Result<()> {
    let n: usize = args.get_or("n", 1000usize)?;
    let k: usize = args.get_or("k", 50usize)?;
    let seed: u64 = args.get_or("seed", 42u64)?;
    let sizes: Vec<usize> = match args.get_list("sizes") {
        Some(v) => v
            .iter()
            .map(|s| s.parse().context("--sizes"))
            .collect::<Result<_>>()?,
        None => vec![500, 1000, 1500, 2000, 2500, 3000],
    };
    let with_baseline = args.has("baseline");
    let threads: usize = args.get_or("threads", 0usize)?;
    let backend: Backend = args.get_or("backend", Backend::Ram)?;
    let opts = parse_storage_options(args)?;
    if backend == Backend::Ram {
        ensure_no_mmap_flags(args)?;
    } else {
        ensure!(
            !with_baseline,
            "--baseline requires --backend ram (the low-rank baseline \
             is in-RAM only)"
        );
    }
    println!(
        "# scaling n={n} k={k} threads={threads} backend={backend} \
         (paper §4.1; 0=auto)"
    );
    println!("m\tgreedy_rls_s{}", if with_baseline { "\tlowrank_s" } else { "" });
    let cfg = SelectionConfig::builder()
        .k(k)
        .lambda(1.0)
        .loss(Loss::ZeroOne)
        .threads(threads)
        .tile_cols(opts.tile_cols)
        .build();
    let mut json_rows: Vec<String> = Vec::new();
    for &m in &sizes {
        let informative = 50.min(n);
        let t_greedy = match backend {
            Backend::Ram => {
                let ds = synthetic::two_gaussians(m, n, informative, 1.0, seed);
                let mut greedy_run = Ok(());
                let t_greedy = time_once(|| {
                    greedy_run =
                        GreedyRls.select(&ds.x, &ds.y, &cfg).map(|_| ());
                });
                greedy_run?;
                if with_baseline {
                    let mut low_run = Ok(());
                    let t_low = time_once(|| {
                        low_run =
                            LowRankLsSvm.select(&ds.x, &ds.y, &cfg).map(|_| ());
                    });
                    low_run?;
                    println!("{m}\t{t_greedy:.3}\t{t_low:.3}");
                } else {
                    println!("{m}\t{t_greedy:.3}");
                }
                t_greedy
            }
            Backend::Mmap => {
                // generation stays outside the timed region, like the RAM
                // rows; the timing covers stored-engine init (cache fill)
                // plus the k selection rounds end to end
                let ds = synthetic::two_gaussians_stored(
                    m,
                    n,
                    informative,
                    1.0,
                    seed,
                    &opts,
                )?;
                let StoredDataset { x, y, .. } = ds;
                let mut run = Ok(());
                let t_greedy = {
                    let run_ref = &mut run;
                    let cfg_ref = &cfg;
                    let opts_ref = &opts;
                    time_once(move || {
                        *run_ref = GreedyRls
                            .begin_stored(x, y, cfg_ref, opts_ref)
                            .and_then(run_to_completion)
                            .map(|_| ());
                    })
                };
                run?;
                println!("{m}\t{t_greedy:.3}");
                t_greedy
            }
        };
        json_rows.push(format!(
            "{{\"m\":{m},\"n\":{n},\"k\":{k},\"backend\":\"{backend}\",\
             \"threads\":{threads},\"tile_cols\":{},\"window_mb\":{},\
             \"seconds\":{t_greedy:.6}}}",
            opts.tile_cols,
            opts.window_bytes >> 20
        ));
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, format!("[\n{}\n]\n", json_rows.join(",\n")))
            .with_context(|| format!("writing {path}"))?;
        println!("# bench rows written to {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.has("bus") {
        // `serve --bus` is the train-serve pipeline: the bus only exists
        // in-process, so serving from it means owning the trainer too
        ensure!(
            args.get("model").is_none() && args.get("follow").is_none(),
            "--bus trains in-process and serves from the in-memory bus; \
             it takes the train-serve flags, not --model/--follow"
        );
        return cmd_train_serve(args);
    }
    if args.get("listen").is_some() {
        return cmd_serve_listen(args);
    }
    if args.get("connect").is_some() {
        return cmd_serve_connect(args);
    }
    if args.get("follow").is_some() {
        return cmd_serve_follow(args);
    }
    let model_path: String = args.require("model")?;
    let p = coordinator::load_model(std::path::Path::new(&model_path))?;
    let mut ds = load_dataset(args)?;
    ds.standardize();
    let batch: usize = args.get_or("batch", 64usize)?;
    let engine: EngineKind = args.get_or("engine", EngineKind::Native)?;
    println!(
        "serving model k={} over {} examples, batch={batch}, engine={engine:?}",
        p.selected.len(),
        ds.n_examples()
    );
    let (preds, stats) = match engine {
        EngineKind::Native => serve::serve_native(&p, &ds.x, batch)?,
        EngineKind::Pjrt => {
            let rt = Runtime::open("artifacts")?;
            serve::serve_pjrt(&rt, &p, &ds.x, batch)?
        }
    };
    let acc = greedy_rls::metrics::accuracy(&ds.y, &preds);
    println!(
        "accuracy={acc:.4} batches={} mean={:.6}s p50={:.6}s p99={:.6}s \
         throughput={:.0}/s",
        stats.batches,
        stats.mean_batch_s,
        stats.p50_batch_s,
        stats.p99_batch_s,
        stats.throughput
    );
    Ok(())
}

/// `serve --follow DIR`: hot-swap serving from a (possibly live) session
/// checkpoint directory. Waits for the first servable checkpoint, then
/// serves `--passes` passes over the dataset, swapping to each newer
/// checkpoint at batch boundaries — in-flight batches always complete on
/// the model they started with.
fn cmd_serve_follow(args: &Args) -> Result<()> {
    let dir: String = args.require("follow")?;
    ensure!(
        args.get("model").is_none(),
        "--follow and --model are mutually exclusive"
    );
    let engine: EngineKind = args.get_or("engine", EngineKind::Native)?;
    ensure!(
        engine == EngineKind::Native,
        "serve --follow serves on the native engine"
    );
    let mut ds = load_dataset(args)?;
    ds.standardize();
    let batch: usize = args.get_or("batch", 64usize)?;
    let passes: usize = args.get_or("passes", 1usize)?;
    let poll_ms: u64 = args.get_or("poll-ms", 50u64)?;
    let wait_s: f64 = args.get_or("wait-s", 10.0f64)?;
    ensure!(
        wait_s.is_finite() && wait_s >= 0.0,
        "--wait-s must be ≥ 0"
    );
    let data_hash =
        greedy_rls::data::fingerprint::fingerprint_xy(&ds.x, &ds.y);

    let mut follower = serve::CheckpointFollower::new(&dir);
    let first = follower.wait_for_model(
        Duration::from_secs_f64(wait_s),
        Duration::from_millis(poll_ms),
    )?;
    ensure!(
        first.fingerprint.data == data_hash,
        "checkpoint data hash {:016x} does not match the serving dataset's \
         {data_hash:016x}",
        first.fingerprint.data
    );
    println!(
        "following {dir}: serving k={} model ({} rounds), batch={batch}, \
         passes={passes}",
        first.selected.len(),
        first.rounds.len()
    );
    let server = serve::HotSwapServer::new(first.predictor());
    let (preds, stats) = serve::serve_hotswap(
        &server,
        &mut follower,
        &ds.x,
        batch,
        passes,
        Some(data_hash),
    )?;
    let acc = greedy_rls::metrics::accuracy(&ds.y, &preds);
    println!(
        "swaps={} final_rounds={} final_version={}",
        stats.swaps, stats.final_rounds, stats.final_version
    );
    println!(
        "accuracy={acc:.4} batches={} mean={:.6}s p50={:.6}s p99={:.6}s \
         throughput={:.0}/s",
        stats.serve.batches,
        stats.serve.mean_batch_s,
        stats.serve.p50_batch_s,
        stats.serve.p99_batch_s,
        stats.serve.throughput
    );
    Ok(())
}

/// Shared fabric knobs: `--heartbeat-ms` (also scales the read timeout
/// that declares a silent trainer hung) and the `--wait-s` startup
/// deadline for the first model.
fn parse_fabric_options(
    args: &Args,
) -> Result<(greedy_rls::coordinator::fabric::FabricOptions, f64)> {
    let heartbeat_ms: u64 = args.get_or("heartbeat-ms", 500u64)?;
    ensure!(heartbeat_ms > 0, "--heartbeat-ms must be positive");
    let wait_s: f64 = args.get_or("wait-s", 30.0f64)?;
    ensure!(wait_s.is_finite() && wait_s >= 0.0, "--wait-s must be ≥ 0");
    let opts = greedy_rls::coordinator::fabric::FabricOptions::with_heartbeat(
        Duration::from_millis(heartbeat_ms),
    );
    Ok((opts, wait_s))
}

/// `serve --listen ADDR --connect ADDR [--follow DIR]`: a fabric
/// worker. Answers socket queries against a hot-swap slot fed by a
/// `train-serve --publish` trainer; while the trainer is unreachable it
/// keeps serving the last-good model and catches up from the
/// checkpoint trail. Runs until killed — exactly the process the
/// `fleet` gauntlet spawns, SIGKILLs, and restarts.
fn cmd_serve_listen(args: &Args) -> Result<()> {
    use greedy_rls::coordinator::fabric::follow::SocketFollower;
    use greedy_rls::coordinator::fabric::listen::{
        ListenOptions, ListenServer,
    };
    use greedy_rls::coordinator::fabric::net::Addr;

    let listen_addr: Addr = args.require("listen")?;
    let connect_addr: Addr = args.require("connect")?;
    let (fopts, wait_s) = parse_fabric_options(args)?;
    let trail = args.get("follow").map(std::path::PathBuf::from);
    let mut follower = SocketFollower::connect(connect_addr, trail, fopts);
    let first = follower.wait_for_model(
        Duration::from_secs_f64(wait_s),
        Duration::from_millis(20),
    )?;
    println!(
        "listening on {listen_addr}: serving k={} model ({} rounds)",
        first.predictor.selected.len(),
        first.rounds
    );
    let server =
        std::sync::Arc::new(serve::HotSwapServer::new(first.predictor));
    let opts = ListenOptions {
        workers: args.get_or("serve-threads", 2usize)?.max(1),
        queue_depth: args.get_or("queue-depth", 2usize)?.max(1),
        fabric: fopts,
        ..ListenOptions::default()
    };
    let _front =
        ListenServer::spawn(&listen_addr, std::sync::Arc::clone(&server), opts)?;
    // swap loop: the wire feeds swaps while connected, the trail while
    // degraded; a source hiccup is logged, never fatal — the worker
    // serves its last-good model until something newer arrives
    loop {
        match follower.poll_model() {
            Ok(Some(update))
                if !update.predictor.selected.is_empty() =>
            {
                let rounds = update.rounds;
                server.swap(update.predictor, rounds);
                println!("swapped to {rounds}-round model");
            }
            Ok(_) => {}
            Err(err) => eprintln!("[serve] model source error: {err:#}"),
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// `serve --connect ADDR [--follow DIR]`: hot-swap serving over a local
/// dataset with models arriving over the fabric — `serve_hotswap` is
/// unchanged, the socket is just another [`serve::ModelSource`].
fn cmd_serve_connect(args: &Args) -> Result<()> {
    use greedy_rls::coordinator::fabric::follow::SocketFollower;
    use greedy_rls::coordinator::fabric::net::Addr;

    ensure!(
        args.get("model").is_none(),
        "--connect and --model are mutually exclusive"
    );
    let connect_addr: Addr = args.require("connect")?;
    let mut ds = load_dataset(args)?;
    ds.standardize();
    let batch: usize = args.get_or("batch", 64usize)?;
    let passes: usize = args.get_or("passes", 1usize)?;
    let (fopts, wait_s) = parse_fabric_options(args)?;
    let data_hash =
        greedy_rls::data::fingerprint::fingerprint_xy(&ds.x, &ds.y);
    let mut follower = SocketFollower::connect(
        connect_addr,
        args.get("follow").map(std::path::PathBuf::from),
        fopts,
    );
    let first = follower.wait_for_model(
        Duration::from_secs_f64(wait_s),
        Duration::from_millis(20),
    )?;
    if let Some(got) = first.data_hash {
        ensure!(
            got == data_hash,
            "published data hash {got:016x} does not match the serving \
             dataset's {data_hash:016x}"
        );
    }
    println!(
        "following the fabric: serving k={} model ({} rounds), \
         batch={batch}, passes={passes}",
        first.predictor.selected.len(),
        first.rounds
    );
    let server = serve::HotSwapServer::new(first.predictor);
    let (preds, stats) = serve::serve_hotswap(
        &server,
        &mut follower,
        &ds.x,
        batch,
        passes,
        Some(data_hash),
    )?;
    let acc = greedy_rls::metrics::accuracy(&ds.y, &preds);
    println!(
        "swaps={} final_rounds={} final_version={}",
        stats.swaps, stats.final_rounds, stats.final_version
    );
    println!(
        "accuracy={acc:.4} batches={} mean={:.6}s p50={:.6}s p99={:.6}s \
         throughput={:.0}/s",
        stats.serve.batches,
        stats.serve.mean_batch_s,
        stats.serve.p50_batch_s,
        stats.serve.p99_batch_s,
        stats.serve.throughput
    );
    Ok(())
}

/// `fleet`: spawn one `train-serve --publish` trainer plus N
/// `serve --listen` workers, drive load at every worker, optionally
/// SIGKILL one mid-stream, and verify all workers converge to the
/// byte-identical final model (the kill-a-server gauntlet, as a
/// subcommand so CI and users run the same code path).
fn cmd_fleet(args: &Args) -> Result<()> {
    use greedy_rls::coordinator::fabric::fleet::{run_fleet, FleetPlan};

    let mut ds = load_dataset(args)?;
    ds.standardize();
    let k: usize = args.get_or("k", 8usize)?;
    ensure!(
        k > 0 && k <= ds.n_features(),
        "--k must be in 1..={} for this dataset",
        ds.n_features()
    );
    let servers: usize = args.get_or("servers", 2usize)?;
    let heartbeat_ms: u64 = args.get_or("heartbeat-ms", 200u64)?;
    ensure!(heartbeat_ms > 0, "--heartbeat-ms must be positive");
    let scratch = match args.get("scratch") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => std::env::temp_dir()
            .join(format!("greedy-rls-fleet-{}", std::process::id())),
    };
    // dataset + selection flags forwarded verbatim to the trainer —
    // both processes regenerate the same problem from the same flags
    let mut dataset_flags: Vec<String> = Vec::new();
    if let Some(spec) = args.get("synthetic") {
        dataset_flags.extend(["--synthetic".into(), spec.into()]);
    } else {
        let name: String = args.require("dataset")?;
        dataset_flags.extend(["--dataset".into(), name]);
        if args.has("full") {
            dataset_flags.push("--full".into());
        }
    }
    dataset_flags.extend([
        "--k".into(),
        k.to_string(),
        "--seed".into(),
        args.get_or("seed", 42u64)?.to_string(),
    ]);
    let plan = FleetPlan {
        exe: std::env::current_exe().context("locating own binary")?,
        scratch: scratch.clone(),
        dataset_flags,
        servers,
        kill_one: args.has("kill-one"),
        heartbeat_ms,
        expected_rounds: k,
        queries: args.get_or("queries", 40usize)?,
        batch: args.get_or("batch", 16usize)?,
        settle_timeout: Duration::from_secs(60),
        train_timeout: Duration::from_secs(300),
    };
    println!(
        "fleet: trainer + {servers} servers (kill_one={}), scratch={}",
        plan.kill_one,
        scratch.display()
    );
    let outcome = run_fleet(&plan, &ds.x)?;
    println!(
        "servers={} final_rounds={} models_identical={} \
         survivor_answered={} restarted_caught_up={} shed={}",
        outcome.servers,
        outcome.final_rounds,
        outcome.models_identical,
        outcome.survivor_answered,
        outcome.restarted_caught_up,
        outcome.shed
    );
    println!("fleet: PASS");
    Ok(())
}

/// `compare`: the quality-vs-time frontier over the selector zoo. Every
/// row runs as a session behind a [`TimingObserver`] and the library's
/// scan-op counter, so the table reports honest per-selector wall-clock,
/// rounds, and scan work at any `--stop` policy — a zero budget still
/// emits every row, with `-` in the criterion/accuracy cells.
/// `--preselect P` (plus optional `--sketch-dim D`) configures the
/// sketched-greedy row; absent the flag it keeps half the features
/// (never fewer than k) with exact leverage scores, so the row is a
/// real frontier point out of the box. `--json FILE` writes the table
/// as a JSON array (the CI sketch-smoke job uploads it as
/// `BENCH_frontier.json`).
fn cmd_compare(args: &Args) -> Result<()> {
    use greedy_rls::bench::TimingObserver;
    use greedy_rls::data::folds::train_test_split;
    use greedy_rls::rng::Pcg64;
    use greedy_rls::runtime::engine::{
        PjrtBackward, PjrtFloating, PjrtFoba, PjrtGreedy, PjrtNFold,
    };
    use greedy_rls::select::{
        backward::BackwardElimination,
        floating::FloatingForward,
        foba::{DroppingFoba, Foba},
        lowrank::LowRankLsSvm,
        nfold::NFoldGreedy,
        random::RandomSelector,
        scan_ops,
        sketch::SketchedGreedy,
        wrapper::Wrapper,
        SessionSelector,
    };

    let ds = load_dataset(args)?;
    let k: usize = args.get_or("k", 5usize)?;
    let lambda: f64 = args.get_or("lambda", 1.0f64)?;
    let loss: Loss = args.get_or("loss", Loss::ZeroOne)?;
    let seed: u64 = args.get_or("seed", 42u64)?;
    let threads: usize = args.get_or("threads", 0usize)?;
    let stop = cli::parse_stop_policy(args)?;
    let engine: EngineKind = args.get_or("engine", EngineKind::Native)?;
    let rt = open_runtime_if(engine)?;
    let cfg = SelectionConfig::builder()
        .k(k)
        .lambda(lambda)
        .loss(loss)
        .stop(stop)
        .threads(threads)
        .build();
    // The sketched row keeps the flagged survivor count, or defaults to
    // half the features (never fewer than k) so the frontier always has
    // a genuinely filtered data point.
    let preselect = match parse_preselect(args)? {
        Some(ps) => ps,
        None => PreselectConfig {
            p: (ds.n_features() / 2).max(k),
            sketch_dim: 0,
            seed,
        },
    };
    let sketched_cfg = cfg.with().preselect(Some(preselect)).build();

    let mut rng = Pcg64::new(seed, 91);
    let (tr, te) = train_test_split(ds.n_examples(), 0.25, &mut rng);
    let mut train = ds.subset(&tr);
    let mut test = ds.subset(&te);
    let stats = train.standardize();
    test.apply_standardization(&stats);

    let fast_only = train.n_examples() > 2000 || ds.n_features() > 300;
    let nfold_params =
        NFoldGreedy { folds: 10.min(train.n_examples()), seed };
    // One (name, session selector, config) triple per frontier row; the
    // config rides along because sketched-greedy needs the preselect
    // variant while every other selector rejects it.
    type Row<'a> =
        (&'static str, Box<dyn SessionSelector + 'a>, SelectionConfig);
    let mut rows: Vec<Row<'_>> = match engine {
        EngineKind::Native => vec![
            ("greedy-rls", Box::new(GreedyRls), cfg),
            ("sketched-greedy", Box::new(SketchedGreedy), sketched_cfg),
            ("random", Box::new(RandomSelector { seed }), cfg),
            ("foba", Box::new(Foba::default()), cfg),
            ("dropping-foba", Box::new(DroppingFoba::default()), cfg),
            ("nfold-greedy", Box::new(nfold_params), cfg),
        ],
        EngineKind::Pjrt => {
            let rt = rt
                .as_ref()
                .with_context(|| "pjrt engine requires an open runtime")?;
            vec![
                ("greedy-rls-pjrt", Box::new(PjrtGreedy::new(rt)), cfg),
                ("foba-pjrt", Box::new(PjrtFoba::new(rt)), cfg),
                (
                    "nfold-greedy-pjrt",
                    Box::new(PjrtNFold::with_params(rt, nfold_params)),
                    cfg,
                ),
            ]
        }
    };
    if !fast_only {
        match engine {
            EngineKind::Native => {
                rows.push(("lowrank-lssvm", Box::new(LowRankLsSvm), cfg));
                rows.push((
                    "wrapper-shortcut",
                    Box::new(Wrapper::shortcut()),
                    cfg,
                ));
                rows.push((
                    "backward-elimination",
                    Box::new(BackwardElimination),
                    cfg,
                ));
                rows.push((
                    "floating-forward",
                    Box::new(FloatingForward::default()),
                    cfg,
                ));
            }
            EngineKind::Pjrt => {
                let rt = rt
                    .as_ref()
                    .with_context(|| "pjrt engine requires an open runtime")?;
                rows.push((
                    "backward-elimination-pjrt",
                    Box::new(PjrtBackward::new(rt)),
                    cfg,
                ));
                rows.push((
                    "floating-forward-pjrt",
                    Box::new(PjrtFloating::new(rt)),
                    cfg,
                ));
            }
        }
    }

    println!(
        "# compare dataset={} m_train={} n={} k={k} lambda={lambda} \
         engine={engine:?} preselect_p={} sketch_dim={}{}",
        ds.name,
        train.n_examples(),
        ds.n_features(),
        preselect.p,
        preselect.sketch_dim,
        match stop {
            StopPolicy::KBudget(b) if b == usize::MAX => String::new(),
            other => format!(" stop={other:?}"),
        }
    );
    if engine == EngineKind::Pjrt {
        println!(
            "# pjrt parity: wrapper's trajectory is served by the greedy \
             engine; random/lowrank/rankrls/centers are native-only"
        );
        println!(
            "# sketched-greedy and dropping-foba rows are native-only \
             (the pjrt engine fences --preselect)"
        );
    }
    println!(
        "selector\tseconds\tround_s\trounds\tscan_ops\tcriterion\t\
         test_acc\tselected"
    );
    let mut json_rows: Vec<String> = Vec::new();
    for (name, sel, row_cfg) in &rows {
        scan_ops::reset();
        let mut obs = TimingObserver::default();
        let mut result = None;
        // one clock over setup + drive + finish; the observer splits out
        // the per-round share so truncated (--stop) rows stay honest
        let secs = time_once(|| {
            result = Some(sel.begin(&train.x, &train.y, row_cfg).and_then(
                |mut s| {
                    drive(s.as_mut(), &mut obs)?;
                    s.finish()
                },
            ));
        });
        let ops = scan_ops::total();
        let round_s = obs.total_s();
        // time_once runs the closure exactly once, so `result` is Some.
        let Some(outcome) = result else { continue };
        match outcome {
            Ok(r) => {
                let crit = r.criterion_curve().last().copied();
                let acc = if r.selected.is_empty() {
                    None
                } else {
                    let p = r.predictor().predict_matrix(&test.x);
                    Some(greedy_rls::metrics::accuracy(&test.y, &p))
                };
                let crit_cell = match crit {
                    Some(c) => format!("{c:.6}"),
                    None => "-".into(),
                };
                let acc_cell = match acc {
                    Some(a) => format!("{a:.4}"),
                    None => "-".into(),
                };
                println!(
                    "{name}\t{secs:.3}\t{round_s:.3}\t{}\t{ops}\t\
                     {crit_cell}\t{acc_cell}\t{:?}",
                    r.rounds.len(),
                    r.selected
                );
                json_rows.push(format!(
                    "{{\"selector\":\"{name}\",\"seconds\":{secs:.6},\
                     \"round_s\":{round_s:.6},\"rounds\":{},\
                     \"scan_ops\":{ops},\"criterion\":{},\
                     \"test_acc\":{},\"selected\":{:?}}}",
                    r.rounds.len(),
                    crit.map_or("null".into(), |c| format!("{c:.6}")),
                    acc.map_or("null".into(), |a| format!("{a:.4}")),
                    r.selected
                ));
            }
            Err(e) => println!("{name}\tfailed: {e}"),
        }
    }
    if fast_only {
        println!(
            "# quadratic baselines skipped (large problem); pass a smaller \
             dataset to include them"
        );
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, format!("[\n{}\n]\n", json_rows.join(",\n")))
            .with_context(|| format!("writing {path}"))?;
        println!("# frontier rows written to {path}");
    }
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!("# paper Table 1 (synthetic stand-ins generated on demand)");
    println!("dataset\tpaper_m\tpaper_n\tscaled_m");
    for s in registry::SPECS {
        println!("{}\t{}\t{}\t{}", s.name, s.paper_m, s.paper_n, s.scaled_m);
    }
    Ok(())
}

fn cmd_check(args: &Args) -> Result<()> {
    let rt = Runtime::open(args.get("artifacts").unwrap_or("artifacts"))?;
    println!(
        "platform={} devices={}",
        rt.client().platform_name(),
        rt.client().device_count()
    );
    let buckets = rt.selection_buckets();
    println!("selection buckets: {buckets:?}");
    if buckets.is_empty() {
        bail!("no complete selection buckets in artifacts/");
    }
    // probe: tiny problem through both engines must match, for every
    // selector with an artifact engine
    use greedy_rls::runtime::engine::{
        PjrtBackward, PjrtFloating, PjrtFoba, PjrtGreedy, PjrtNFold,
    };
    use greedy_rls::select::{
        backward::BackwardElimination, floating::FloatingForward,
        foba::Foba, nfold::NFoldGreedy,
    };
    let ds = synthetic::two_gaussians(48, 24, 6, 1.5, 7);
    let cfg = SelectionConfig::builder()
        .k(5)
        .lambda(1.0)
        .loss(Loss::ZeroOne)
        .build();
    let nfold = NFoldGreedy { folds: 6, seed: 7 };
    let probes: Vec<(&str, greedy_rls::select::SelectionResult,
                     greedy_rls::select::SelectionResult)> = vec![
        (
            "greedy",
            GreedyRls.select(&ds.x, &ds.y, &cfg)?,
            PjrtGreedy::new(&rt).select(&ds.x, &ds.y, &cfg)?,
        ),
        (
            "backward",
            BackwardElimination.select(&ds.x, &ds.y, &cfg)?,
            PjrtBackward::new(&rt).select(&ds.x, &ds.y, &cfg)?,
        ),
        (
            "nfold",
            nfold.select(&ds.x, &ds.y, &cfg)?,
            PjrtNFold::with_params(&rt, nfold).select(&ds.x, &ds.y, &cfg)?,
        ),
        (
            "foba",
            Foba::default().select(&ds.x, &ds.y, &cfg)?,
            PjrtFoba::new(&rt).select(&ds.x, &ds.y, &cfg)?,
        ),
        (
            "floating",
            FloatingForward::default().select(&ds.x, &ds.y, &cfg)?,
            PjrtFloating::new(&rt).select(&ds.x, &ds.y, &cfg)?,
        ),
    ];
    for (name, native, pjrt) in &probes {
        if native.selected != pjrt.selected {
            bail!(
                "{name} engine mismatch: native {:?} vs pjrt {:?}",
                native.selected,
                pjrt.selected
            );
        }
        println!("{name}: engines agree, selected {:?}", native.selected);
    }
    println!("compiled executables: {}", rt.compiled_count());
    println!("artifacts OK");
    Ok(())
}
