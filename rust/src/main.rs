//! `greedy-rls` — Layer-3 leader binary.
//!
//! Subcommand dispatch over the library's coordinator; see `cli::USAGE`.

use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use greedy_rls::bench::time_once;
use greedy_rls::cli::{self, Args, USAGE};
use greedy_rls::coordinator::{self, cv, serve, EngineKind, ProgressObserver};
use greedy_rls::data::{registry, synthetic, Dataset};
use greedy_rls::metrics::Loss;
use greedy_rls::runtime::Runtime;
use greedy_rls::select::checkpoint::{
    self, drive_checkpointed, AutosavePolicy, Autosaver,
};
use greedy_rls::select::{
    drive, greedy::GreedyRls, lowrank::LowRankLsSvm, NoopObserver, Observer,
    SelectionConfig, Selector, StopPolicy,
};

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("select") => cmd_select(args),
        Some("cv") => cmd_cv(args),
        Some("scaling") => cmd_scaling(args),
        Some("serve") => cmd_serve(args),
        Some("datasets") => cmd_datasets(),
        Some("compare") => cmd_compare(args),
        Some("check") => cmd_check(args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn load_dataset(args: &Args) -> Result<Dataset> {
    let seed: u64 = args.get_or("seed", 42u64)?;
    if let Some(spec) = args.get("synthetic") {
        let parts: Vec<usize> = spec
            .split(',')
            .map(|t| t.trim().parse().context("--synthetic M,N"))
            .collect::<Result<_>>()?;
        if parts.len() != 2 {
            bail!("--synthetic expects M,N");
        }
        return Ok(synthetic::two_gaussians(parts[0], parts[1],
            (parts[1] / 10).max(1), 1.0, seed));
    }
    let name: String = args.require("dataset")?;
    registry::load(&name, args.has("full"), seed)
}

fn open_runtime_if(engine: EngineKind) -> Result<Option<Runtime>> {
    match engine {
        EngineKind::Native => Ok(None),
        EngineKind::Pjrt => Ok(Some(Runtime::open("artifacts")?)),
    }
}

fn cmd_select(args: &Args) -> Result<()> {
    let mut ds = load_dataset(args)?;
    ds.standardize();
    let stop = cli::parse_stop_policy(args)?;
    let cfg = SelectionConfig::builder()
        .k(args.get_or("k", 10usize)?)
        .lambda(args.get_or("lambda", 1.0f64)?)
        .loss(args.get_or("loss", Loss::ZeroOne)?)
        .stop(stop)
        .threads(args.get_or("threads", 0usize)?)
        .build();
    let engine: EngineKind = args.get_or("engine", EngineKind::Native)?;
    let rt = open_runtime_if(engine)?;
    let warm: Option<Vec<usize>> = match args.get_list("warm-start") {
        Some(items) => Some(
            items
                .iter()
                .map(|s| s.parse().context("--warm-start I1,I2,..."))
                .collect::<Result<_>>()?,
        ),
        None => None,
    };
    let ckpt_dir = args.get("checkpoint-dir").map(std::path::PathBuf::from);
    let ckpt_every: usize = args.get_or("checkpoint-every", 1usize)?;
    let resume = args.has("resume");
    if ckpt_dir.is_none() {
        ensure!(
            args.get("checkpoint-every").is_none(),
            "--checkpoint-every requires --checkpoint-dir"
        );
        ensure!(!resume, "--resume requires --checkpoint-dir");
    }
    ensure!(
        !(resume && warm.is_some()),
        "--resume and --warm-start are mutually exclusive (the checkpoint \
         already pins the prefix)"
    );
    println!(
        "dataset={} m={} n={} k={} lambda={} engine={engine:?} threads={}{}",
        ds.name,
        ds.n_examples(),
        ds.n_features(),
        cfg.k,
        cfg.lambda,
        greedy_rls::parallel::resolve(cfg.threads),
        match cfg.stop {
            StopPolicy::KBudget(b) if b == usize::MAX => String::new(),
            other => format!(" stop={other:?}"),
        }
    );
    let t0 = std::time::Instant::now();
    // set on resume so the autosaver reuses the (verified-equal)
    // checkpoint fingerprint instead of rehashing the O(mn) dataset
    let mut resumed_fp: Option<checkpoint::Fingerprint> = None;
    let mut session = match &warm {
        Some(prefix) => {
            println!("warm start from {} features: {prefix:?}", prefix.len());
            coordinator::begin_from_with_engine(
                engine,
                rt.as_ref(),
                &ds.x,
                &ds.y,
                &cfg,
                prefix,
            )?
        }
        None => {
            let latest = if resume {
                checkpoint::latest_in_dir(
                    ckpt_dir.as_deref().expect("checked above"),
                )?
            } else {
                None
            };
            match latest {
                Some(path) => {
                    let (s, ckpt) = coordinator::resume_with_engine(
                        engine,
                        rt.as_ref(),
                        &ds.x,
                        &ds.y,
                        &cfg,
                        &path,
                    )?;
                    println!(
                        "resumed from {} ({} rounds replayed, {:.3}s prior \
                         selection time)",
                        path.display(),
                        ckpt.rounds.len(),
                        ckpt.elapsed.as_secs_f64()
                    );
                    resumed_fp = Some(ckpt.fingerprint);
                    s
                }
                None => {
                    if resume {
                        println!(
                            "no checkpoint in {}; starting fresh",
                            ckpt_dir.as_deref().expect("checked above").display()
                        );
                    }
                    coordinator::begin_with_engine(
                        engine,
                        rt.as_ref(),
                        &ds.x,
                        &ds.y,
                        &cfg,
                    )?
                }
            }
        }
    };
    let mut observer: Box<dyn Observer> = if args.has("progress") {
        Box::new(ProgressObserver)
    } else {
        Box::new(NoopObserver)
    };
    let reason = match &ckpt_dir {
        Some(dir) => {
            let fp = resumed_fp.unwrap_or_else(|| {
                checkpoint::fingerprint(&ds.x, &ds.y, &cfg)
            });
            let policy = AutosavePolicy { every: ckpt_every, on_stop: true };
            let mut saver = Autosaver::new(dir, policy, fp)?;
            let reason = drive_checkpointed(
                session.as_mut(),
                observer.as_mut(),
                &mut saver,
            )?;
            println!(
                "checkpoints: {} written to {}",
                saver.saves,
                dir.display()
            );
            reason
        }
        None => drive(session.as_mut(), observer.as_mut())?,
    };
    let r = session.finish()?;
    let secs = t0.elapsed().as_secs_f64();
    println!("selected ({}): {:?}", r.selected.len(), r.selected);
    println!(
        "criterion trajectory: {:?}",
        r.criterion_curve()
            .iter()
            .map(|c| (c * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
    println!("stopped after {} rounds: {reason}", r.rounds.len());
    println!("selection time: {secs:.3}s");
    if let Some(path) = args.get("out") {
        coordinator::save_model(&r.predictor(), std::path::Path::new(path))?;
        println!("model written to {path}");
    }
    Ok(())
}

fn cmd_cv(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let folds: usize = args.get_or("folds", 10usize)?;
    let kmax: usize = args.get_or("kmax", ds.n_features().min(50))?;
    let seed: u64 = args.get_or("seed", 42u64)?;
    let threads: usize = args.get_or("threads", 0usize)?;
    let stop = cli::parse_stop_policy(args)?;
    let engine: EngineKind = args.get_or("engine", EngineKind::Native)?;
    let rt = open_runtime_if(engine)?;
    let opts = cv::CvOptions { folds, k_max: kmax, seed, threads, stop, engine };
    println!(
        "# cv dataset={} m={} n={} folds={folds} kmax={kmax} \
         engine={engine:?}{}",
        ds.name,
        ds.n_examples(),
        ds.n_features(),
        match stop {
            StopPolicy::KBudget(b) if b == usize::MAX => String::new(),
            StopPolicy::TimeBudget(d) => format!(
                " stop=TimeBudget({d:?}) (time stops truncate curves, \
                 never reorder them)"
            ),
            other => format!(" stop={other:?}"),
        }
    );
    let curves = match args.get("checkpoint-dir") {
        Some(dir) => cv::run_cv_resumable(
            &ds,
            &opts,
            rt.as_ref(),
            std::path::Path::new(dir),
        )?,
        None => cv::run_cv_opts(&ds, &opts, rt.as_ref())?,
    };
    println!("k\tgreedy_test\tgreedy_loo\trandom_test\tgreedy_test_std");
    for (i, k) in curves.ks.iter().enumerate() {
        println!(
            "{k}\t{:.4}\t{:.4}\t{:.4}\t{:.4}",
            curves.greedy_test[i],
            curves.greedy_loo[i],
            curves.random_test[i],
            curves.greedy_test_std[i]
        );
    }
    println!("# per-fold lambdas: {:?}", curves.lambdas);
    Ok(())
}

fn cmd_scaling(args: &Args) -> Result<()> {
    let n: usize = args.get_or("n", 1000usize)?;
    let k: usize = args.get_or("k", 50usize)?;
    let seed: u64 = args.get_or("seed", 42u64)?;
    let sizes: Vec<usize> = match args.get_list("sizes") {
        Some(v) => v
            .iter()
            .map(|s| s.parse().context("--sizes"))
            .collect::<Result<_>>()?,
        None => vec![500, 1000, 1500, 2000, 2500, 3000],
    };
    let with_baseline = args.has("baseline");
    let threads: usize = args.get_or("threads", 0usize)?;
    println!("# scaling n={n} k={k} threads={threads} (paper §4.1; 0=auto)");
    println!("m\tgreedy_rls_s{}", if with_baseline { "\tlowrank_s" } else { "" });
    let cfg = SelectionConfig {
        k,
        lambda: 1.0,
        loss: Loss::ZeroOne,
        threads,
        ..Default::default()
    };
    for &m in &sizes {
        let ds = synthetic::two_gaussians(m, n, 50, 1.0, seed);
        let t_greedy =
            time_once(|| { GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap(); });
        if with_baseline {
            let t_low = time_once(|| {
                LowRankLsSvm.select(&ds.x, &ds.y, &cfg).unwrap();
            });
            println!("{m}\t{t_greedy:.3}\t{t_low:.3}");
        } else {
            println!("{m}\t{t_greedy:.3}");
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.get("follow").is_some() {
        return cmd_serve_follow(args);
    }
    let model_path: String = args.require("model")?;
    let p = coordinator::load_model(std::path::Path::new(&model_path))?;
    let mut ds = load_dataset(args)?;
    ds.standardize();
    let batch: usize = args.get_or("batch", 64usize)?;
    let engine: EngineKind = args.get_or("engine", EngineKind::Native)?;
    println!(
        "serving model k={} over {} examples, batch={batch}, engine={engine:?}",
        p.selected.len(),
        ds.n_examples()
    );
    let (preds, stats) = match engine {
        EngineKind::Native => serve::serve_native(&p, &ds.x, batch)?,
        EngineKind::Pjrt => {
            let rt = Runtime::open("artifacts")?;
            serve::serve_pjrt(&rt, &p, &ds.x, batch)?
        }
    };
    let acc = greedy_rls::metrics::accuracy(&ds.y, &preds);
    println!(
        "accuracy={acc:.4} batches={} mean={:.6}s p50={:.6}s p99={:.6}s \
         throughput={:.0}/s",
        stats.batches,
        stats.mean_batch_s,
        stats.p50_batch_s,
        stats.p99_batch_s,
        stats.throughput
    );
    Ok(())
}

/// `serve --follow DIR`: hot-swap serving from a (possibly live) session
/// checkpoint directory. Waits for the first servable checkpoint, then
/// serves `--passes` passes over the dataset, swapping to each newer
/// checkpoint at batch boundaries — in-flight batches always complete on
/// the model they started with.
fn cmd_serve_follow(args: &Args) -> Result<()> {
    let dir: String = args.require("follow")?;
    ensure!(
        args.get("model").is_none(),
        "--follow and --model are mutually exclusive"
    );
    let engine: EngineKind = args.get_or("engine", EngineKind::Native)?;
    ensure!(
        engine == EngineKind::Native,
        "serve --follow serves on the native engine"
    );
    let mut ds = load_dataset(args)?;
    ds.standardize();
    let batch: usize = args.get_or("batch", 64usize)?;
    let passes: usize = args.get_or("passes", 1usize)?;
    let poll_ms: u64 = args.get_or("poll-ms", 50u64)?;
    let wait_s: f64 = args.get_or("wait-s", 10.0f64)?;
    ensure!(
        wait_s.is_finite() && wait_s >= 0.0,
        "--wait-s must be ≥ 0"
    );
    let data_hash =
        greedy_rls::data::fingerprint::fingerprint_xy(&ds.x, &ds.y);

    let mut follower = serve::CheckpointFollower::new(&dir);
    let first = follower.wait_for_model(
        Duration::from_secs_f64(wait_s),
        Duration::from_millis(poll_ms),
    )?;
    ensure!(
        first.fingerprint.data == data_hash,
        "checkpoint data hash {:016x} does not match the serving dataset's \
         {data_hash:016x}",
        first.fingerprint.data
    );
    println!(
        "following {dir}: serving k={} model ({} rounds), batch={batch}, \
         passes={passes}",
        first.selected.len(),
        first.rounds.len()
    );
    let server = serve::HotSwapServer::new(first.predictor());
    let (preds, stats) = serve::serve_hotswap(
        &server,
        &mut follower,
        &ds.x,
        batch,
        passes,
        Some(data_hash),
    )?;
    let acc = greedy_rls::metrics::accuracy(&ds.y, &preds);
    println!(
        "swaps={} final_rounds={} final_version={}",
        stats.swaps, stats.final_rounds, stats.final_version
    );
    println!(
        "accuracy={acc:.4} batches={} mean={:.6}s p50={:.6}s p99={:.6}s \
         throughput={:.0}/s",
        stats.serve.batches,
        stats.serve.mean_batch_s,
        stats.serve.p50_batch_s,
        stats.serve.p99_batch_s,
        stats.serve.throughput
    );
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    use greedy_rls::data::folds::train_test_split;
    use greedy_rls::rng::Pcg64;
    use greedy_rls::runtime::engine::{
        PjrtBackward, PjrtFloating, PjrtFoba, PjrtGreedy, PjrtNFold,
    };
    use greedy_rls::select::{
        backward::BackwardElimination, floating::FloatingForward, foba::Foba,
        lowrank::LowRankLsSvm, nfold::NFoldGreedy, random::RandomSelector,
        wrapper::Wrapper,
    };

    let ds = load_dataset(args)?;
    let k: usize = args.get_or("k", 5usize)?;
    let lambda: f64 = args.get_or("lambda", 1.0f64)?;
    let loss: Loss = args.get_or("loss", Loss::ZeroOne)?;
    let seed: u64 = args.get_or("seed", 42u64)?;
    let threads: usize = args.get_or("threads", 0usize)?;
    let engine: EngineKind = args.get_or("engine", EngineKind::Native)?;
    let rt = open_runtime_if(engine)?;
    let cfg =
        SelectionConfig { k, lambda, loss, threads, ..Default::default() };

    let mut rng = Pcg64::new(seed, 91);
    let (tr, te) = train_test_split(ds.n_examples(), 0.25, &mut rng);
    let mut train = ds.subset(&tr);
    let mut test = ds.subset(&te);
    let stats = train.standardize();
    test.apply_standardization(&stats);

    let fast_only = train.n_examples() > 2000 || ds.n_features() > 300;
    let nfold_params =
        NFoldGreedy { folds: 10.min(train.n_examples()), seed };
    let mut selectors: Vec<Box<dyn Selector + '_>> = match engine {
        EngineKind::Native => vec![
            Box::new(GreedyRls),
            Box::new(RandomSelector { seed }),
            Box::new(Foba::default()),
            Box::new(nfold_params),
        ],
        EngineKind::Pjrt => {
            let rt = rt.as_ref().expect("runtime opened above");
            vec![
                Box::new(PjrtGreedy::new(rt)),
                Box::new(PjrtFoba::new(rt)),
                Box::new(PjrtNFold::with_params(rt, nfold_params)),
            ]
        }
    };
    if !fast_only {
        match engine {
            EngineKind::Native => {
                selectors.push(Box::new(LowRankLsSvm));
                selectors.push(Box::new(Wrapper::shortcut()));
                selectors.push(Box::new(BackwardElimination));
                selectors.push(Box::new(FloatingForward::default()));
            }
            EngineKind::Pjrt => {
                let rt = rt.as_ref().expect("runtime opened above");
                selectors.push(Box::new(PjrtBackward::new(rt)));
                selectors.push(Box::new(PjrtFloating::new(rt)));
            }
        }
    }

    println!(
        "# compare dataset={} m_train={} n={} k={k} lambda={lambda} \
         engine={engine:?}",
        ds.name,
        train.n_examples(),
        ds.n_features()
    );
    if engine == EngineKind::Pjrt {
        println!(
            "# pjrt parity: wrapper's trajectory is served by the greedy \
             engine; random/lowrank/rankrls/centers are native-only"
        );
    }
    println!("selector\tseconds\ttest_acc\tselected");
    for s in &selectors {
        let mut result = None;
        let secs = time_once(|| {
            result = Some(s.select(&train.x, &train.y, &cfg));
        });
        match result.unwrap() {
            Ok(r) => {
                let p = r.predictor().predict_matrix(&test.x);
                let acc = greedy_rls::metrics::accuracy(&test.y, &p);
                println!(
                    "{}\t{secs:.3}\t{acc:.4}\t{:?}",
                    s.name(),
                    r.selected
                );
            }
            Err(e) => println!("{}\tfailed: {e}", s.name()),
        }
    }
    if fast_only {
        println!(
            "# quadratic baselines skipped (large problem); pass a smaller \
             dataset to include them"
        );
    }
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!("# paper Table 1 (synthetic stand-ins generated on demand)");
    println!("dataset\tpaper_m\tpaper_n\tscaled_m");
    for s in registry::SPECS {
        println!("{}\t{}\t{}\t{}", s.name, s.paper_m, s.paper_n, s.scaled_m);
    }
    Ok(())
}

fn cmd_check(args: &Args) -> Result<()> {
    let rt = Runtime::open(args.get("artifacts").unwrap_or("artifacts"))?;
    println!(
        "platform={} devices={}",
        rt.client().platform_name(),
        rt.client().device_count()
    );
    let buckets = rt.selection_buckets();
    println!("selection buckets: {buckets:?}");
    if buckets.is_empty() {
        bail!("no complete selection buckets in artifacts/");
    }
    // probe: tiny problem through both engines must match, for every
    // selector with an artifact engine
    use greedy_rls::runtime::engine::{
        PjrtBackward, PjrtFloating, PjrtFoba, PjrtGreedy, PjrtNFold,
    };
    use greedy_rls::select::{
        backward::BackwardElimination, floating::FloatingForward,
        foba::Foba, nfold::NFoldGreedy,
    };
    let ds = synthetic::two_gaussians(48, 24, 6, 1.5, 7);
    let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
    let nfold = NFoldGreedy { folds: 6, seed: 7 };
    let probes: Vec<(&str, greedy_rls::select::SelectionResult,
                     greedy_rls::select::SelectionResult)> = vec![
        (
            "greedy",
            GreedyRls.select(&ds.x, &ds.y, &cfg)?,
            PjrtGreedy::new(&rt).select(&ds.x, &ds.y, &cfg)?,
        ),
        (
            "backward",
            BackwardElimination.select(&ds.x, &ds.y, &cfg)?,
            PjrtBackward::new(&rt).select(&ds.x, &ds.y, &cfg)?,
        ),
        (
            "nfold",
            nfold.select(&ds.x, &ds.y, &cfg)?,
            PjrtNFold::with_params(&rt, nfold).select(&ds.x, &ds.y, &cfg)?,
        ),
        (
            "foba",
            Foba::default().select(&ds.x, &ds.y, &cfg)?,
            PjrtFoba::new(&rt).select(&ds.x, &ds.y, &cfg)?,
        ),
        (
            "floating",
            FloatingForward::default().select(&ds.x, &ds.y, &cfg)?,
            PjrtFloating::new(&rt).select(&ds.x, &ds.y, &cfg)?,
        ),
    ];
    for (name, native, pjrt) in &probes {
        if native.selected != pjrt.selected {
            bail!(
                "{name} engine mismatch: native {:?} vs pjrt {:?}",
                native.selected,
                pjrt.selected
            );
        }
        println!("{name}: engines agree, selected {:?}", native.selected);
    }
    println!("compiled executables: {}", rt.compiled_count());
    println!("artifacts OK");
    Ok(())
}
