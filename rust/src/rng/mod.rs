//! Deterministic pseudo-random numbers for data generation and testing.
//!
//! The offline crate cache has no `rand`, so this module provides a small,
//! fully deterministic PCG-XSH-RR-64 generator plus the distributions the
//! rest of the crate needs (uniform, standard normal via Box–Muller,
//! Fisher–Yates shuffle, sampling without replacement). Streams are stable
//! across platforms and releases: tests and experiment seeds rely on it.

/// PCG-XSH-RR 64/32 (O'Neill 2014) with 64-bit output composed from two
/// 32-bit draws. Small state, excellent statistical quality for the data
/// synthesis / property-testing workloads here.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next 32 uniformly random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniformly random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased integer in [0, bound) (Lemire-style rejection).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0)");
        let bound = bound as u64;
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return (r % bound) as usize;
            }
        }
    }

    /// Standard normal deviate (Box–Muller, polar form).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `count` distinct indices from [0, n) in random order.
    pub fn choose_distinct(&mut self, n: usize, count: usize) -> Vec<usize> {
        assert!(count <= n, "choose_distinct: count {count} > n {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates: only the first `count` swaps are needed
        for i in 0..count {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(count);
        idx
    }

    /// Random sign: +1.0 or -1.0.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Derive an independent child generator from this one's stream.
    ///
    /// Seed and stream id are drawn from `self`, so successive splits
    /// yield decorrelated children while staying fully deterministic —
    /// a parent seeded the same way always deals the same children in
    /// the same order. The serving fabric uses this to hand each
    /// accepted connection its own fault-injection schedule.
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg64::new(seed, stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_is_deterministic() {
        let mut a = Pcg64::seeded(123);
        let mut b = Pcg64::seeded(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::new(7, 0);
        let mut b = Pcg64::new(7, 1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg64::seeded(5);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut rng = Pcg64::seeded(6);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(8);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(10);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn choose_distinct_properties() {
        let mut rng = Pcg64::seeded(11);
        let picked = rng.choose_distinct(100, 30);
        assert_eq!(picked.len(), 30);
        let mut uniq = picked.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 30);
        assert!(picked.iter().all(|&i| i < 100));
    }

    #[test]
    fn choose_distinct_full_range() {
        let mut rng = Pcg64::seeded(12);
        let mut picked = rng.choose_distinct(10, 10);
        picked.sort();
        assert_eq!(picked, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn split_children_are_deterministic_and_decorrelated() {
        let mut a = Pcg64::seeded(99);
        let mut b = Pcg64::seeded(99);
        // same parent state ⇒ identical children, in order
        for _ in 0..4 {
            let mut ca = a.split();
            let mut cb = b.split();
            for _ in 0..16 {
                assert_eq!(ca.next_u64(), cb.next_u64());
            }
        }
        // siblings disagree with each other and with the parent
        let mut parent = Pcg64::seeded(100);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64)
            .filter(|_| c1.next_u64() == c2.next_u64())
            .count();
        assert!(same < 4, "sibling streams overlap: {same}/64");
    }

    #[test]
    fn sign_is_balanced() {
        let mut rng = Pcg64::seeded(13);
        let pos = (0..10_000).filter(|_| rng.sign() > 0.0).count();
        assert!((4500..5500).contains(&pos), "pos {pos}");
    }
}
