//! Content fingerprinting for datasets — the identity half of durable
//! checkpoints.
//!
//! A resumed selection run is only bit-identical to the uninterrupted run
//! if it sees byte-identical inputs, so every checkpoint carries a 64-bit
//! data fingerprint and resume refuses a mismatch instead of silently
//! continuing a different problem. The hash is a hand-rolled streaming
//! FNV-1a (no new dependencies, stable across platforms and processes —
//! unlike `std::hash`, whose `RandomState` is seeded per process).
//!
//! The fingerprint covers the shape and every `f64` bit pattern of `X`
//! and `y`, so it distinguishes datasets that differ only in the last
//! mantissa bit — exactly the differences that would break bit-identical
//! resume. It deliberately ignores the dataset *name*: two loads of the
//! same synthetic problem under different labels resume interchangeably.

use super::storage::MatrixStore;
use crate::linalg::Matrix;

/// Streaming 64-bit FNV-1a hasher.
///
/// Process-stable and allocation-free; used for checkpoint fingerprints
/// and the end-of-file corruption checksum of the checkpoint format.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    /// Start a fresh hash at the FNV offset basis.
    pub fn new() -> Fnv64 {
        Fnv64(Self::OFFSET)
    }

    /// Absorb raw bytes.
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    /// Absorb a `u64` in little-endian byte order.
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// Absorb a `u32` in little-endian byte order (the serving fabric's
    /// wire header fields are `u32`; hashing them field-by-field must
    /// equal hashing the raw frame bytes).
    pub fn write_u32(&mut self, v: u32) {
        self.write(&v.to_le_bytes());
    }

    /// Absorb a `usize` widened to `u64` (stable across pointer widths).
    pub fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    /// Absorb an `f64` by bit pattern (distinguishes `-0.0` from `0.0`
    /// and every NaN payload — bit-identity is the contract).
    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    /// Final hash value.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64::new()
    }
}

/// One-shot FNV-1a of a byte slice.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

/// Fingerprint a selection problem's inputs: dimensions plus every value
/// of the feature-major `x` (n × m) and labels `y`, by `f64` bit pattern.
/// O(mn), run once per checkpointed session — negligible next to one
/// selection round.
pub fn fingerprint_xy(x: &Matrix, y: &[f64]) -> u64 {
    let mut h = Fnv64::new();
    h.write_usize(x.rows());
    h.write_usize(x.cols());
    for &v in x.as_slice() {
        h.write_f64(v);
    }
    h.write_usize(y.len());
    for &v in y {
        h.write_f64(v);
    }
    h.finish()
}

/// [`fingerprint_xy`] over a [`MatrixStore`], streaming row windows
/// through the hasher instead of requiring the matrix in RAM. FNV-1a is
/// a byte stream, so absorbing the same values in the same order yields
/// the **same hash** as `fingerprint_xy` on the materialized matrix —
/// checkpoints written by one backend resume under the other.
pub fn fingerprint_xy_stored(
    x: &MatrixStore,
    y: &[f64],
) -> anyhow::Result<u64> {
    let mut h = Fnv64::new();
    h.write_usize(x.rows());
    h.write_usize(x.row_len());
    let step = x.window_rows();
    let mut r0 = 0;
    while r0 < x.rows() {
        let r1 = (r0 + step).min(x.rows());
        x.read_rows(r0..r1, |rows| {
            for &v in rows {
                h.write_f64(v);
            }
        })?;
        r0 = r1;
    }
    h.write_usize(y.len());
    for &v in y {
        h.write_f64(v);
    }
    Ok(h.finish())
}

impl super::Dataset {
    /// Content fingerprint of this dataset (see [`fingerprint_xy`]).
    pub fn fingerprint(&self) -> u64 {
        fingerprint_xy(&self.x, &self.y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_reference_vectors() {
        // standard FNV-1a 64 test vectors
        assert_eq!(fnv64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn typed_writes_equal_raw_bytes() {
        // field-by-field hashing must equal hashing the concatenated
        // LE bytes — the wire codec's checksum relies on this
        let mut typed = Fnv64::new();
        typed.write(b"GR");
        typed.write_u32(0x0102_0304);
        typed.write_u64(0x0506_0708_090a_0b0c);
        typed.write_f64(f64::from_bits(0x7ff8_0000_dead_beef));
        let mut raw = Vec::new();
        raw.extend_from_slice(b"GR");
        raw.extend_from_slice(&0x0102_0304u32.to_le_bytes());
        raw.extend_from_slice(&0x0506_0708_090a_0b0cu64.to_le_bytes());
        raw.extend_from_slice(&0x7ff8_0000_dead_beefu64.to_le_bytes());
        assert_eq!(typed.finish(), fnv64(&raw));
    }

    #[test]
    fn fingerprint_is_deterministic_across_calls() {
        let ds = crate::data::synthetic::two_gaussians(30, 8, 3, 1.0, 5);
        assert_eq!(ds.fingerprint(), ds.fingerprint());
        let again = crate::data::synthetic::two_gaussians(30, 8, 3, 1.0, 5);
        assert_eq!(ds.fingerprint(), again.fingerprint());
    }

    #[test]
    fn fingerprint_sees_every_bit() {
        let ds = crate::data::synthetic::two_gaussians(30, 8, 3, 1.0, 5);
        let base = ds.fingerprint();

        // a one-ulp change in X must change the hash
        let mut bumped = ds.clone();
        let v = bumped.x[(2, 3)];
        bumped.x[(2, 3)] = f64::from_bits(v.to_bits() ^ 1);
        assert_ne!(base, bumped.fingerprint());

        // a label flip must change the hash
        let mut relabeled = ds.clone();
        relabeled.y[0] = -relabeled.y[0];
        assert_ne!(base, relabeled.fingerprint());

        // a different seed must change the hash
        let other = crate::data::synthetic::two_gaussians(30, 8, 3, 1.0, 6);
        assert_ne!(base, other.fingerprint());
    }

    #[test]
    fn stored_fingerprint_equals_ram_fingerprint() {
        use crate::data::storage::{Backend, StorageOptions};
        let ds = crate::data::synthetic::two_gaussians(20, 12, 3, 1.0, 9);
        let want = ds.fingerprint();
        let mut opts = vec![StorageOptions::default()];
        if cfg!(target_os = "linux") {
            opts.push(StorageOptions::default().backend(Backend::Mmap));
        }
        for o in opts {
            let st = MatrixStore::from_matrix(&ds.x, &o).unwrap();
            let got = fingerprint_xy_stored(&st, &ds.y).unwrap();
            assert_eq!(got, want, "{:?}", o.backend);
        }
    }

    #[test]
    fn fingerprint_distinguishes_transposed_shapes() {
        // same flat values, different (n, m) split — must differ
        let a = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let b = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let y2 = vec![1.0, -1.0];
        let y3 = vec![1.0, -1.0, 1.0];
        assert_ne!(fingerprint_xy(&a, &y3), fingerprint_xy(&b, &y2));
    }
}
