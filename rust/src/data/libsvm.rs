//! LIBSVM sparse text format parser.
//!
//! The paper's benchmark datasets (adult, australian, colon-cancer,
//! german.numer, ijcnn1, mnist) are distributed in LIBSVM format:
//!
//! ```text
//! <label> <index>:<value> <index>:<value> ...
//! ```
//!
//! Indices are 1-based and may be sparse. No network access is available
//! in this environment, so the registry falls back to synthetic
//! equivalents (see `registry.rs`), but any real file dropped into
//! `data/real/<name>.libsvm` is parsed by this module and used instead.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context};

use super::storage::{ChunkedLines, MatrixStore, StorageOptions, StoredDataset};
use super::Dataset;
use crate::linalg::Matrix;

/// One parsed LIBSVM line: label plus 0-based `(feature, value)` pairs.
/// `None` for blank and `#`-comment lines.
type ParsedLine = Option<(f64, Vec<(usize, f64)>)>;

/// Parse one LIBSVM text line. This is the single tokenizer behind both
/// the in-RAM reader ([`parse`]) and the out-of-core streaming loader
/// ([`parse_file_stored`]), so edge-case semantics (1-based indices,
/// unsorted pairs, trailing whitespace, comments) cannot drift between
/// backends. `lineno` is 0-based; errors report it 1-based.
fn parse_line(raw: &str, lineno: usize) -> anyhow::Result<ParsedLine> {
    let line = raw.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let label: f64 = match parts.next() {
        Some(tok) => tok
            .parse()
            .with_context(|| format!("bad label on line {}", lineno + 1))?,
        None => return Ok(None),
    };
    let mut feats = Vec::new();
    for tok in parts {
        let (idx, val) = tok
            .split_once(':')
            .with_context(|| format!("bad pair {tok:?} line {}", lineno + 1))?;
        let idx: usize = idx
            .parse()
            .with_context(|| format!("bad index {idx:?} line {}", lineno + 1))?;
        if idx == 0 {
            bail!("LIBSVM indices are 1-based; got 0 on line {}", lineno + 1);
        }
        let val: f64 = val
            .parse()
            .with_context(|| format!("bad value {val:?} line {}", lineno + 1))?;
        feats.push((idx - 1, val));
    }
    Ok(Some((label, feats)))
}

/// Parse LIBSVM text from any reader. `n_features` may be given (for
/// datasets whose tail features are absent in the file); otherwise the max
/// seen index is used. Labels are normalized: {0,1} and {1,2} label
/// schemes become ±1; ±1 and real-valued regression targets pass through.
pub fn parse<R: Read>(
    reader: R,
    name: &str,
    n_features: Option<usize>,
) -> anyhow::Result<Dataset> {
    let mut labels = Vec::new();
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut max_index = 0usize;

    let mut lines = ChunkedLines::new(reader, 64 << 10);
    let mut lineno = 0usize;
    while let Some(line) = lines.next_line()? {
        if let Some((label, feats)) = parse_line(line, lineno)? {
            for &(i, _) in &feats {
                max_index = max_index.max(i + 1);
            }
            labels.push(label);
            rows.push(feats);
        }
        lineno += 1;
    }

    if labels.is_empty() {
        bail!("empty LIBSVM file for {name}");
    }
    let n = n_features.unwrap_or(max_index);
    if max_index > n {
        bail!("feature index {max_index} exceeds declared n_features {n}");
    }
    let m = labels.len();
    let mut x = Matrix::zeros(n, m);
    for (j, feats) in rows.iter().enumerate() {
        for &(i, v) in feats {
            x[(i, j)] = v;
        }
    }
    let y = normalize_labels(&labels);
    Ok(Dataset::new(name, x, y))
}

/// Parse a file on disk.
pub fn parse_file(path: &Path, n_features: Option<usize>) -> anyhow::Result<Dataset> {
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "unnamed".into());
    let fh = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    parse(fh, &name, n_features)
}

/// Pending sparse entries, flushed window-by-window so the store maps
/// each row window once per flush instead of once per value. A stable
/// sort groups entries by window while preserving file order inside a
/// window, so duplicate `(i, j)` pairs keep last-write-wins semantics.
fn flush_entries(
    x: &mut MatrixStore,
    pending: &mut Vec<(usize, usize, f64)>,
) -> anyhow::Result<()> {
    if pending.is_empty() {
        return Ok(());
    }
    let window = x.window_rows();
    let m = x.row_len();
    pending.sort_by_key(|e| e.0 / window);
    let mut s = 0;
    while s < pending.len() {
        let w = pending[s].0 / window;
        let mut e = s;
        while e < pending.len() && pending[e].0 / window == w {
            e += 1;
        }
        let r0 = w * window;
        let r1 = (r0 + window).min(x.rows());
        let batch = &pending[s..e];
        x.write_rows(r0..r1, |rows| {
            for &(i, j, v) in batch {
                rows[(i - r0) * m + j] = v;
            }
        })?;
        s = e;
    }
    pending.clear();
    Ok(())
}

/// Parse a LIBSVM file into a [`StoredDataset`] on the backend `opts`
/// selects, streaming in two bounded passes — memory use is O(m) labels
/// plus the read chunk and entry buffer, never O(n·m), so GB-scale files
/// load under an address-space cap.
///
/// Pass 1 counts examples and the max feature index; pass 2 re-reads and
/// scatters values into row windows of the store. Both passes tokenize
/// through the same `parse_line` as [`parse`], so the resulting matrix
/// is byte-identical to the in-RAM loader's (asserted by
/// `rust/tests/backend_equivalence.rs`).
pub fn parse_file_stored(
    path: &Path,
    n_features: Option<usize>,
    opts: &StorageOptions,
) -> anyhow::Result<StoredDataset> {
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "unnamed".into());

    // Pass 1: shape discovery (labels, example count, max index).
    let fh = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut lines = ChunkedLines::new(fh, opts.chunk_bytes);
    let mut labels = Vec::new();
    let mut max_index = 0usize;
    let mut lineno = 0usize;
    while let Some(line) = lines.next_line()? {
        if let Some((label, feats)) = parse_line(line, lineno)? {
            for &(i, _) in &feats {
                max_index = max_index.max(i + 1);
            }
            labels.push(label);
        }
        lineno += 1;
    }
    if labels.is_empty() {
        bail!("empty LIBSVM file for {name}");
    }
    let n = n_features.unwrap_or(max_index);
    if max_index > n {
        bail!("feature index {max_index} exceeds declared n_features {n}");
    }
    let m = labels.len();

    // Pass 2: scatter values into the store through bounded buffers.
    let mut x = MatrixStore::zeros(n, m, opts)?;
    let flush_cap = (opts.chunk_bytes / 8).max(1024);
    let mut pending: Vec<(usize, usize, f64)> = Vec::new();
    let fh = std::fs::File::open(path)
        .with_context(|| format!("reopen {}", path.display()))?;
    let mut lines = ChunkedLines::new(fh, opts.chunk_bytes);
    let mut j = 0usize;
    let mut lineno = 0usize;
    while let Some(line) = lines.next_line()? {
        if let Some((_, feats)) = parse_line(line, lineno)? {
            if j >= m {
                bail!("{} changed between passes (extra example)", path.display());
            }
            for (i, v) in feats {
                pending.push((i, j, v));
            }
            if pending.len() >= flush_cap {
                flush_entries(&mut x, &mut pending)?;
            }
            j += 1;
        }
        lineno += 1;
    }
    if j != m {
        bail!(
            "{} changed between passes ({} examples, then {j})",
            path.display(),
            m
        );
    }
    flush_entries(&mut x, &mut pending)?;

    let y = normalize_labels(&labels);
    StoredDataset::new(name, x, y)
}

/// Load a LIBSVM file honoring the backend in `opts`: the RAM backend
/// takes the historical [`parse_file`] path; the mmap backend streams
/// through [`parse_file_stored`] and hands every selector a
/// mapped-matrix [`Dataset`] (zero extra RAM, full `Matrix` API).
pub fn load_file(
    path: &Path,
    n_features: Option<usize>,
    opts: &StorageOptions,
) -> anyhow::Result<Dataset> {
    match opts.backend {
        super::storage::Backend::Ram => parse_file(path, n_features),
        super::storage::Backend::Mmap => {
            parse_file_stored(path, n_features, opts)?.into_dataset()
        }
    }
}

/// Map common binary label encodings to ±1; leave regression targets alone.
fn normalize_labels(labels: &[f64]) -> Vec<f64> {
    let mut distinct: Vec<f64> = labels.to_vec();
    distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
    distinct.dedup();
    match distinct.as_slice() {
        [a, b] if *a == 0.0 && *b == 1.0 => {
            labels.iter().map(|&v| if v > 0.5 { 1.0 } else { -1.0 }).collect()
        }
        [a, b] if *a == 1.0 && *b == 2.0 => {
            labels.iter().map(|&v| if v > 1.5 { 1.0 } else { -1.0 }).collect()
        }
        [a, b] if *a == -1.0 && *b == 1.0 => labels.to_vec(),
        _ => labels.to_vec(), // regression or already-normalized
    }
}

/// Serialize a dataset to LIBSVM text (round-trip tests, interchange).
pub fn to_string(ds: &Dataset) -> String {
    let mut out = String::new();
    for j in 0..ds.n_examples() {
        out.push_str(&format!("{}", ds.y[j]));
        for i in 0..ds.n_features() {
            let v = ds.x[(i, j)];
            if v != 0.0 {
                out.push_str(&format!(" {}:{}", i + 1, v));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
+1 1:0.5 3:1.5
-1 2:2.0
+1 1:-1.0 2:0.25 3:0.125
";

    #[test]
    fn parses_sparse_rows() {
        let ds = parse(SAMPLE.as_bytes(), "sample", None).unwrap();
        assert_eq!(ds.n_features(), 3);
        assert_eq!(ds.n_examples(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.x[(0, 0)], 0.5);
        assert_eq!(ds.x[(2, 0)], 1.5);
        assert_eq!(ds.x[(1, 1)], 2.0);
        assert_eq!(ds.x[(0, 1)], 0.0); // absent => 0
    }

    #[test]
    fn declared_feature_count() {
        let ds = parse(SAMPLE.as_bytes(), "sample", Some(10)).unwrap();
        assert_eq!(ds.n_features(), 10);
    }

    #[test]
    fn declared_count_too_small_errors() {
        assert!(parse(SAMPLE.as_bytes(), "sample", Some(2)).is_err());
    }

    #[test]
    fn zero_index_rejected() {
        assert!(parse("1 0:3.0\n".as_bytes(), "bad", None).is_err());
    }

    #[test]
    fn empty_file_rejected() {
        assert!(parse("".as_bytes(), "empty", None).is_err());
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let text = "# header\n\n+1 1:1.0\n";
        let ds = parse(text.as_bytes(), "c", None).unwrap();
        assert_eq!(ds.n_examples(), 1);
    }

    #[test]
    fn zero_one_labels_normalized() {
        let text = "0 1:1.0\n1 1:2.0\n";
        let ds = parse(text.as_bytes(), "z", None).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
    }

    #[test]
    fn one_two_labels_normalized() {
        let text = "1 1:1.0\n2 1:2.0\n";
        let ds = parse(text.as_bytes(), "z", None).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
    }

    #[test]
    fn regression_labels_untouched() {
        let text = "0.7 1:1.0\n-3.2 1:2.0\n1.1 1:0.5\n";
        let ds = parse(text.as_bytes(), "r", None).unwrap();
        assert_eq!(ds.y, vec![0.7, -3.2, 1.1]);
    }

    #[test]
    fn round_trip() {
        let ds = parse(SAMPLE.as_bytes(), "sample", None).unwrap();
        let text = to_string(&ds);
        let ds2 = parse(text.as_bytes(), "sample", Some(3)).unwrap();
        assert_eq!(ds.y, ds2.y);
        assert!(ds.x.max_abs_diff(&ds2.x) < 1e-15);
    }

    #[test]
    fn malformed_pair_errors() {
        assert!(parse("1 broken\n".as_bytes(), "b", None).is_err());
        assert!(parse("1 a:1.0\n".as_bytes(), "b", None).is_err());
        assert!(parse("1 1:x\n".as_bytes(), "b", None).is_err());
        assert!(parse("notalabel 1:1\n".as_bytes(), "b", None).is_err());
    }

    // ---- edge cases shared by both loaders ------------------------------

    use crate::data::storage::{Backend, StorageOptions};

    /// Text exercising every loader edge case at once: comments, blank
    /// lines, unsorted 1-based indices, duplicate indices (last write
    /// wins), trailing whitespace, CRLF, and a final unterminated line.
    const EDGE: &str = "# leading comment\n\
        +1 3:3.0 1:1.0 2:2.0   \n\
        \n\
        -1 2:5.0 2:7.0\r\n\
        # mid comment\n\
        +1 1:-0.5\t4:4.0\n\
        -1 4:0.125";

    fn write_temp(text: &str) -> std::path::PathBuf {
        use std::io::Write;
        use std::sync::atomic::{AtomicU64, Ordering};
        static N: AtomicU64 = AtomicU64::new(0);
        let p = std::env::temp_dir().join(format!(
            "greedy-rls-libsvm-test-{}-{}.libsvm",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(text.as_bytes()).unwrap();
        p
    }

    fn stored_opts() -> Vec<StorageOptions> {
        let mut all = vec![
            StorageOptions::default(),
            StorageOptions::default().chunk_bytes(0), // clamps to the 4 KiB floor
        ];
        if cfg!(target_os = "linux") {
            all.push(StorageOptions::default().backend(Backend::Mmap));
        }
        all
    }

    #[test]
    fn edge_cases_parse_identically_in_both_loaders() {
        let path = write_temp(EDGE);
        let ram = parse_file(&path, None).unwrap();
        assert_eq!(ram.n_examples(), 4);
        assert_eq!(ram.n_features(), 4);
        // unsorted indices landed in the right slots
        assert_eq!(ram.x[(0, 0)], 1.0);
        assert_eq!(ram.x[(1, 0)], 2.0);
        assert_eq!(ram.x[(2, 0)], 3.0);
        // duplicate index: last write wins
        assert_eq!(ram.x[(1, 1)], 7.0);
        // final unterminated line parsed
        assert_eq!(ram.x[(3, 3)], 0.125);
        for opts in stored_opts() {
            let stored = parse_file_stored(&path, None, &opts).unwrap();
            let got = stored.to_dataset().unwrap();
            assert_eq!(got.y, ram.y, "{:?}", opts.backend);
            for (a, b) in got.x.as_slice().iter().zip(ram.x.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{:?}", opts.backend);
            }
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn stored_loader_rejects_index_beyond_declared_n() {
        let path = write_temp("+1 5:1.0\n-1 1:2.0\n");
        for opts in stored_opts() {
            let err =
                parse_file_stored(&path, Some(3), &opts).unwrap_err();
            assert!(
                err.to_string().contains("exceeds declared n_features"),
                "{err:#}"
            );
        }
        // and both loaders accept the declared count when it fits
        assert_eq!(parse_file(&path, Some(8)).unwrap().n_features(), 8);
        for opts in stored_opts() {
            let st = parse_file_stored(&path, Some(8), &opts).unwrap();
            assert_eq!(st.n_features(), 8);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn stored_loader_rejects_empty_and_zero_index() {
        let empty = write_temp("# only comments\n\n");
        let zero = write_temp("1 0:3.0\n");
        for opts in stored_opts() {
            assert!(parse_file_stored(&empty, None, &opts).is_err());
            let err = parse_file_stored(&zero, None, &opts).unwrap_err();
            assert!(err.to_string().contains("1-based"), "{err:#}");
        }
        std::fs::remove_file(&empty).unwrap();
        std::fs::remove_file(&zero).unwrap();
    }

    #[test]
    fn chunk_boundary_splitting_a_line_is_transparent() {
        // One example whose line is far longer than the 4 KiB minimum
        // chunk, so the streaming loader must reassemble it across many
        // refills; a second short line proves the split didn't desync.
        let mut text = String::from("+1");
        for i in 0..2000 {
            text.push_str(&format!(" {}:{}", i + 1, (i % 13) as f64 + 0.5));
        }
        text.push_str("\n-1 1:9.0\n");
        let path = write_temp(&text);
        let ram = parse_file(&path, None).unwrap();
        assert_eq!(ram.n_examples(), 2);
        assert_eq!(ram.n_features(), 2000);
        for opts in stored_opts() {
            let stored = parse_file_stored(&path, None, &opts).unwrap();
            let got = stored.to_dataset().unwrap();
            for (a, b) in got.x.as_slice().iter().zip(ram.x.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{:?}", opts.backend);
            }
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn stored_loader_handles_many_examples_across_windows() {
        // Enough rows/examples that tiny mmap windows and tiny chunks
        // both split work repeatedly; values dense enough to cross
        // flush boundaries.
        let mut text = String::new();
        for j in 0..97 {
            text.push_str(&format!("{}", if j % 2 == 0 { 1 } else { -1 }));
            for i in 0..23 {
                if (i + j) % 3 != 0 {
                    text.push_str(&format!(
                        " {}:{}",
                        i + 1,
                        (i * 97 + j) as f64 * 0.015625
                    ));
                }
            }
            text.push('\n');
        }
        let path = write_temp(&text);
        let ram = parse_file(&path, None).unwrap();
        for opts in stored_opts() {
            let stored = parse_file_stored(&path, None, &opts).unwrap();
            let got = stored.to_dataset().unwrap();
            for (a, b) in got.x.as_slice().iter().zip(ram.x.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{:?}", opts.backend);
            }
            let loaded = load_file(&path, None, &opts).unwrap();
            for (a, b) in loaded.x.as_slice().iter().zip(ram.x.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{:?}", opts.backend);
            }
        }
        std::fs::remove_file(&path).unwrap();
    }
}
