//! LIBSVM sparse text format parser.
//!
//! The paper's benchmark datasets (adult, australian, colon-cancer,
//! german.numer, ijcnn1, mnist) are distributed in LIBSVM format:
//!
//! ```text
//! <label> <index>:<value> <index>:<value> ...
//! ```
//!
//! Indices are 1-based and may be sparse. No network access is available
//! in this environment, so the registry falls back to synthetic
//! equivalents (see `registry.rs`), but any real file dropped into
//! `data/real/<name>.libsvm` is parsed by this module and used instead.

use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use anyhow::{bail, Context};

use super::Dataset;
use crate::linalg::Matrix;

/// Parse LIBSVM text from any reader. `n_features` may be given (for
/// datasets whose tail features are absent in the file); otherwise the max
/// seen index is used. Labels are normalized: {0,1} and {1,2} label
/// schemes become ±1; ±1 and real-valued regression targets pass through.
pub fn parse<R: Read>(
    reader: R,
    name: &str,
    n_features: Option<usize>,
) -> anyhow::Result<Dataset> {
    let mut labels = Vec::new();
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut max_index = 0usize;

    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line.context("read error")?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f64 = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("bad label on line {}", lineno + 1))?;
        let mut feats = Vec::new();
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .with_context(|| format!("bad pair {tok:?} line {}", lineno + 1))?;
            let idx: usize = idx
                .parse()
                .with_context(|| format!("bad index {idx:?} line {}", lineno + 1))?;
            if idx == 0 {
                bail!("LIBSVM indices are 1-based; got 0 on line {}", lineno + 1);
            }
            let val: f64 = val
                .parse()
                .with_context(|| format!("bad value {val:?} line {}", lineno + 1))?;
            max_index = max_index.max(idx);
            feats.push((idx - 1, val));
        }
        labels.push(label);
        rows.push(feats);
    }

    if labels.is_empty() {
        bail!("empty LIBSVM file for {name}");
    }
    let n = n_features.unwrap_or(max_index);
    if max_index > n {
        bail!("feature index {max_index} exceeds declared n_features {n}");
    }
    let m = labels.len();
    let mut x = Matrix::zeros(n, m);
    for (j, feats) in rows.iter().enumerate() {
        for &(i, v) in feats {
            x[(i, j)] = v;
        }
    }
    let y = normalize_labels(&labels);
    Ok(Dataset::new(name, x, y))
}

/// Parse a file on disk.
pub fn parse_file(path: &Path, n_features: Option<usize>) -> anyhow::Result<Dataset> {
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "unnamed".into());
    let fh = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    parse(fh, &name, n_features)
}

/// Map common binary label encodings to ±1; leave regression targets alone.
fn normalize_labels(labels: &[f64]) -> Vec<f64> {
    let mut distinct: Vec<f64> = labels.to_vec();
    distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
    distinct.dedup();
    match distinct.as_slice() {
        [a, b] if *a == 0.0 && *b == 1.0 => {
            labels.iter().map(|&v| if v > 0.5 { 1.0 } else { -1.0 }).collect()
        }
        [a, b] if *a == 1.0 && *b == 2.0 => {
            labels.iter().map(|&v| if v > 1.5 { 1.0 } else { -1.0 }).collect()
        }
        [a, b] if *a == -1.0 && *b == 1.0 => labels.to_vec(),
        _ => labels.to_vec(), // regression or already-normalized
    }
}

/// Serialize a dataset to LIBSVM text (round-trip tests, interchange).
pub fn to_string(ds: &Dataset) -> String {
    let mut out = String::new();
    for j in 0..ds.n_examples() {
        out.push_str(&format!("{}", ds.y[j]));
        for i in 0..ds.n_features() {
            let v = ds.x[(i, j)];
            if v != 0.0 {
                out.push_str(&format!(" {}:{}", i + 1, v));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
+1 1:0.5 3:1.5
-1 2:2.0
+1 1:-1.0 2:0.25 3:0.125
";

    #[test]
    fn parses_sparse_rows() {
        let ds = parse(SAMPLE.as_bytes(), "sample", None).unwrap();
        assert_eq!(ds.n_features(), 3);
        assert_eq!(ds.n_examples(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.x[(0, 0)], 0.5);
        assert_eq!(ds.x[(2, 0)], 1.5);
        assert_eq!(ds.x[(1, 1)], 2.0);
        assert_eq!(ds.x[(0, 1)], 0.0); // absent => 0
    }

    #[test]
    fn declared_feature_count() {
        let ds = parse(SAMPLE.as_bytes(), "sample", Some(10)).unwrap();
        assert_eq!(ds.n_features(), 10);
    }

    #[test]
    fn declared_count_too_small_errors() {
        assert!(parse(SAMPLE.as_bytes(), "sample", Some(2)).is_err());
    }

    #[test]
    fn zero_index_rejected() {
        assert!(parse("1 0:3.0\n".as_bytes(), "bad", None).is_err());
    }

    #[test]
    fn empty_file_rejected() {
        assert!(parse("".as_bytes(), "empty", None).is_err());
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let text = "# header\n\n+1 1:1.0\n";
        let ds = parse(text.as_bytes(), "c", None).unwrap();
        assert_eq!(ds.n_examples(), 1);
    }

    #[test]
    fn zero_one_labels_normalized() {
        let text = "0 1:1.0\n1 1:2.0\n";
        let ds = parse(text.as_bytes(), "z", None).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
    }

    #[test]
    fn one_two_labels_normalized() {
        let text = "1 1:1.0\n2 1:2.0\n";
        let ds = parse(text.as_bytes(), "z", None).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
    }

    #[test]
    fn regression_labels_untouched() {
        let text = "0.7 1:1.0\n-3.2 1:2.0\n1.1 1:0.5\n";
        let ds = parse(text.as_bytes(), "r", None).unwrap();
        assert_eq!(ds.y, vec![0.7, -3.2, 1.1]);
    }

    #[test]
    fn round_trip() {
        let ds = parse(SAMPLE.as_bytes(), "sample", None).unwrap();
        let text = to_string(&ds);
        let ds2 = parse(text.as_bytes(), "sample", Some(3)).unwrap();
        assert_eq!(ds.y, ds2.y);
        assert!(ds.x.max_abs_diff(&ds2.x) < 1e-15);
    }

    #[test]
    fn malformed_pair_errors() {
        assert!(parse("1 broken\n".as_bytes(), "b", None).is_err());
        assert!(parse("1 a:1.0\n".as_bytes(), "b", None).is_err());
        assert!(parse("1 1:x\n".as_bytes(), "b", None).is_err());
        assert!(parse("notalabel 1:1\n".as_bytes(), "b", None).is_err());
    }
}
