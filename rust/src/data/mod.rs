//! Dataset substrate: datasets, parsing, splitting, scaling, and the
//! out-of-core storage backends.
//!
//! Layout convention follows the paper: the design matrix `X` is
//! **feature-major**, `X[i][j]` = value of feature `i` on example `j`
//! (an `n × m` [`Matrix`]), so a feature's value vector `v = X_i` is a
//! contiguous row — exactly what the greedy scoring loop streams.
//!
//! A dataset's matrix lives either in RAM ([`Dataset`], the default) or
//! behind the [`storage`] backends ([`storage::StoredDataset`]), which
//! keep the same feature-major layout in file-backed scratch accessed
//! through bounded mmap windows — byte-identical selection results
//! either way.

pub mod fingerprint;
pub mod folds;
pub mod libsvm;
pub mod registry;
pub mod storage;
pub mod synthetic;

use crate::linalg::Matrix;

/// An in-memory supervised dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Feature-major design matrix, `n_features × m_examples`.
    pub x: Matrix,
    /// Labels, length `m` (±1 for classification).
    pub y: Vec<f64>,
    /// Human-readable name (registry key / file stem).
    pub name: String,
}

impl Dataset {
    /// Construct and validate shapes.
    pub fn new(name: impl Into<String>, x: Matrix, y: Vec<f64>) -> Self {
        assert_eq!(x.cols(), y.len(), "X columns must equal |y|");
        Dataset { x, y, name: name.into() }
    }

    /// Number of features `n`.
    pub fn n_features(&self) -> usize {
        self.x.rows()
    }

    /// Number of examples `m`.
    pub fn n_examples(&self) -> usize {
        self.x.cols()
    }

    /// Subset of examples (columns), preserving feature count.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let x = self.x.select_cols(idx);
        let y = idx.iter().map(|&j| self.y[j]).collect();
        Dataset { x, y, name: self.name.clone() }
    }

    /// Class balance: fraction of +1 labels (classification datasets).
    pub fn positive_fraction(&self) -> f64 {
        if self.y.is_empty() {
            return 0.0;
        }
        self.y.iter().filter(|&&v| v > 0.0).count() as f64
            / self.y.len() as f64
    }

    /// Standardize every feature to zero mean / unit variance **in place**,
    /// returning the per-feature (mean, std) so test data can be scaled
    /// with the training statistics. Constant features get std = 1.
    pub fn standardize(&mut self) -> Vec<(f64, f64)> {
        let m = self.n_examples() as f64;
        let mut stats = Vec::with_capacity(self.n_features());
        for i in 0..self.n_features() {
            let row = self.x.row_mut(i);
            let mean = row.iter().sum::<f64>() / m;
            let var = row.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / m;
            let std = if var > 0.0 { var.sqrt() } else { 1.0 };
            for v in row.iter_mut() {
                *v = (*v - mean) / std;
            }
            stats.push((mean, std));
        }
        stats
    }

    /// Apply previously computed standardization statistics.
    pub fn apply_standardization(&mut self, stats: &[(f64, f64)]) {
        assert_eq!(stats.len(), self.n_features());
        for (i, &(mean, std)) in stats.iter().enumerate() {
            for v in self.x.row_mut(i).iter_mut() {
                *v = (*v - mean) / std;
            }
        }
    }

    /// Append a constant bias feature (footnote 1 of the paper: a bias
    /// term is realized as an extra all-ones feature).
    pub fn with_bias_feature(&self) -> Dataset {
        let n = self.n_features();
        let m = self.n_examples();
        let mut x = Matrix::zeros(n + 1, m);
        for i in 0..n {
            x.row_mut(i).copy_from_slice(self.x.row(i));
        }
        for v in x.row_mut(n).iter_mut() {
            *v = 1.0;
        }
        Dataset { x, y: self.y.clone(), name: self.name.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Matrix::from_rows(&[
            &[1.0, 2.0, 3.0, 4.0],
            &[0.0, 0.0, 0.0, 0.0],
        ]);
        Dataset::new("toy", x, vec![1.0, -1.0, 1.0, -1.0])
    }

    #[test]
    fn shapes() {
        let d = toy();
        assert_eq!(d.n_features(), 2);
        assert_eq!(d.n_examples(), 4);
        assert_eq!(d.positive_fraction(), 0.5);
    }

    #[test]
    fn subset_selects_columns() {
        let d = toy().subset(&[3, 0]);
        assert_eq!(d.n_examples(), 2);
        assert_eq!(d.y, vec![-1.0, 1.0]);
        assert_eq!(d.x.row(0), &[4.0, 1.0]);
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut d = toy();
        let stats = d.standardize();
        let row = d.x.row(0);
        let mean: f64 = row.iter().sum::<f64>() / 4.0;
        let var: f64 = row.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / 4.0;
        assert!(mean.abs() < 1e-12);
        assert!((var - 1.0).abs() < 1e-12);
        // constant feature: untouched values, std reported as 1
        assert_eq!(stats[1].1, 1.0);
        assert!(d.x.row(1).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn apply_standardization_uses_train_stats() {
        let mut train = toy();
        let stats = train.standardize();
        let mut test = toy();
        test.apply_standardization(&stats);
        assert_eq!(train.x.row(0), test.x.row(0));
    }

    #[test]
    fn bias_feature_appended() {
        let d = toy().with_bias_feature();
        assert_eq!(d.n_features(), 3);
        assert!(d.x.row(2).iter().all(|&v| v == 1.0));
    }

    #[test]
    #[should_panic(expected = "X columns must equal")]
    fn shape_validation() {
        Dataset::new("bad", Matrix::zeros(2, 3), vec![1.0]);
    }
}
