//! Synthetic dataset generators.
//!
//! Two roles (DESIGN.md §6):
//!
//! 1. **Scaling experiments** (paper §4.1, Figs 1–3): the paper itself uses
//!    "randomly generated data from two normal distributions with 1000
//!    features" — [`two_gaussians`] is exactly that.
//! 2. **Benchmark stand-ins** (paper §4.2–4.3, Table 1, Figs 4–15): the
//!    real LIBSVM datasets are not downloadable in this offline
//!    environment, so [`planted_sparse`] generates datasets with a planted
//!    informative subset: `s` features carry class-conditional signal of
//!    decaying strength, the remaining `n − s` are pure noise. This
//!    reproduces the mechanisms the paper's quality/overfitting claims
//!    rest on (greedy ≫ random, plateau after the informative subset,
//!    LOO↔test gap driven by the m/n ratio).

use super::storage::{MatrixStore, StorageOptions, StoredDataset};
use super::Dataset;
use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Two-Gaussian classification data, the paper's §4.1 workload.
///
/// Each class is a spherical Gaussian in `n` dimensions with mean
/// `±separation/2 · μ̂` along a random unit direction; classes are
/// balanced. Returns a feature-major dataset with ±1 labels.
pub fn two_gaussians(
    m: usize,
    n: usize,
    informative: usize,
    separation: f64,
    seed: u64,
) -> Dataset {
    assert!(informative <= n);
    let mut rng = Pcg64::new(seed, 17);
    // random unit direction supported on the informative coordinates
    let mut mu = vec![0.0; n];
    let dims = rng.choose_distinct(n, informative.max(1));
    for &d in &dims {
        mu[d] = rng.normal();
    }
    let norm = crate::linalg::norm2(&mu).max(1e-12);
    for v in mu.iter_mut() {
        *v /= norm;
    }

    let mut x = Matrix::zeros(n, m);
    let mut y = vec![0.0; m];
    for j in 0..m {
        let label = if j % 2 == 0 { 1.0 } else { -1.0 };
        y[j] = label;
        for i in 0..n {
            x[(i, j)] = rng.normal() + 0.5 * separation * label * mu[i];
        }
    }
    Dataset::new(format!("two_gaussians_m{m}_n{n}"), x, y)
}

/// [`two_gaussians`] generated straight into a [`MatrixStore`], for
/// problems too large for a RAM matrix. The RNG is consumed in exactly
/// the in-RAM generator's order (example-major draws, buffered in
/// example slabs and scattered to feature-row windows), so for any
/// `(m, n, informative, separation, seed)` the stored matrix is
/// **bit-identical** to `two_gaussians`' — the out-of-core smoke test
/// and the uncapped RAM run select from literally the same data.
///
/// Peak RAM is one slab (~`opts.chunk_bytes`) plus `O(n + m)` vectors,
/// never the `n × m` matrix.
pub fn two_gaussians_stored(
    m: usize,
    n: usize,
    informative: usize,
    separation: f64,
    seed: u64,
    opts: &StorageOptions,
) -> anyhow::Result<StoredDataset> {
    anyhow::ensure!(informative <= n, "informative count {informative} > n {n}");
    anyhow::ensure!(m > 0 && n > 0, "m and n must be positive");
    let mut rng = Pcg64::new(seed, 17);
    // Identical preamble to `two_gaussians`: direction draws come first.
    let mut mu = vec![0.0; n];
    let dims = rng.choose_distinct(n, informative.max(1));
    for &d in &dims {
        mu[d] = rng.normal();
    }
    let norm = crate::linalg::norm2(&mu).max(1e-12);
    for v in mu.iter_mut() {
        *v /= norm;
    }

    let mut x = MatrixStore::zeros(n, m, opts)?;
    let mut y = vec![0.0; m];
    // Draw order must stay example-major (j outer, i inner) to match the
    // RAM generator; a slab of `block` examples buffers the draws, then
    // each feature-row window receives its slab columns in one mapping.
    let block = (opts.chunk_bytes / (8 * n)).max(1).min(m);
    let window = x.window_rows();
    let mut slab = vec![0.0; block * n];
    let mut j0 = 0;
    while j0 < m {
        let j1 = (j0 + block).min(m);
        let bw = j1 - j0;
        for j in j0..j1 {
            let label = if j % 2 == 0 { 1.0 } else { -1.0 };
            y[j] = label;
            for (i, &mui) in mu.iter().enumerate() {
                slab[i * bw + (j - j0)] =
                    rng.normal() + 0.5 * separation * label * mui;
            }
        }
        let mut r0 = 0;
        while r0 < n {
            let r1 = (r0 + window).min(n);
            x.write_rows(r0..r1, |rows| {
                for i in r0..r1 {
                    let src = &slab[i * bw..i * bw + bw];
                    let dst_row = &mut rows[(i - r0) * m..(i - r0) * m + m];
                    dst_row[j0..j1].copy_from_slice(src);
                }
            })?;
            r0 = r1;
        }
        j0 = j1;
    }
    StoredDataset::new(format!("two_gaussians_m{m}_n{n}"), x, y)
}

/// Planted-sparse benchmark generator.
///
/// `s` informative features: feature `i` (i < s) has class-conditional
/// mean `±signal · decay^i`, everything else is N(0, 1) noise. With
/// `flip_prob` label noise. Feature positions are shuffled so selection
/// cannot cheat on index order.
#[allow(clippy::too_many_arguments)]
pub fn planted_sparse(
    name: &str,
    m: usize,
    n: usize,
    s: usize,
    signal: f64,
    decay: f64,
    flip_prob: f64,
    seed: u64,
) -> Dataset {
    assert!(s <= n, "informative count {s} > n {n}");
    let mut rng = Pcg64::new(seed, 23);

    // true labels, balanced, then optionally flipped (label noise)
    let mut y_true = vec![0.0; m];
    for (j, v) in y_true.iter_mut().enumerate() {
        *v = if j % 2 == 0 { 1.0 } else { -1.0 };
    }
    rng.shuffle(&mut y_true);

    let mut x = Matrix::zeros(n, m);
    // informative rows first, then shuffled into random positions
    let positions = rng.choose_distinct(n, n);
    for (rank, &row) in positions.iter().enumerate() {
        let strength = if rank < s {
            signal * decay.powi(rank as i32)
        } else {
            0.0
        };
        let r = x.row_mut(row);
        for (j, v) in r.iter_mut().enumerate() {
            *v = rng.normal() + strength * y_true[j];
        }
    }

    let y = y_true
        .iter()
        .map(|&v| if rng.uniform() < flip_prob { -v } else { v })
        .collect();
    Dataset::new(name, x, y)
}

/// Sparse linear **regression** data: y = wᵀx + noise with `s`-sparse w.
/// Used by regression-mode tests and the squared-loss selection paths.
pub fn sparse_regression(
    m: usize,
    n: usize,
    s: usize,
    noise: f64,
    seed: u64,
) -> (Dataset, Vec<usize>) {
    assert!(s <= n);
    let mut rng = Pcg64::new(seed, 29);
    let support = rng.choose_distinct(n, s);
    let mut w = vec![0.0; n];
    for &i in &support {
        w[i] = rng.normal_ms(0.0, 1.0) + rng.sign(); // bounded away from 0
    }
    let mut x = Matrix::zeros(n, m);
    for i in 0..n {
        for j in 0..m {
            x[(i, j)] = rng.normal();
        }
    }
    let mut y = vec![0.0; m];
    for j in 0..m {
        let mut v = 0.0;
        for &i in &support {
            v += w[i] * x[(i, j)];
        }
        y[j] = v + noise * rng.normal();
    }
    (
        Dataset::new(format!("sparse_reg_m{m}_n{n}_s{s}"), x, y),
        support,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_gaussians_shapes_and_balance() {
        let ds = two_gaussians(200, 50, 10, 2.0, 1);
        assert_eq!(ds.n_examples(), 200);
        assert_eq!(ds.n_features(), 50);
        assert_eq!(ds.positive_fraction(), 0.5);
    }

    #[test]
    fn two_gaussians_is_separable_along_mu() {
        // with a large separation the class means must differ strongly on
        // at least one informative coordinate
        let ds = two_gaussians(500, 20, 5, 6.0, 2);
        let mut best_gap = 0.0_f64;
        for i in 0..20 {
            let row = ds.x.row(i);
            let (mut mp, mut mn, mut cp, mut cn) = (0.0, 0.0, 0, 0);
            for j in 0..500 {
                if ds.y[j] > 0.0 {
                    mp += row[j];
                    cp += 1;
                } else {
                    mn += row[j];
                    cn += 1;
                }
            }
            best_gap = best_gap.max((mp / cp as f64 - mn / cn as f64).abs());
        }
        assert!(best_gap > 1.0, "gap {best_gap}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = two_gaussians(50, 10, 3, 1.0, 7);
        let b = two_gaussians(50, 10, 3, 1.0, 7);
        assert!(a.x.max_abs_diff(&b.x) == 0.0);
        assert_eq!(a.y, b.y);
        let c = two_gaussians(50, 10, 3, 1.0, 8);
        assert!(a.x.max_abs_diff(&c.x) > 0.0);
    }

    #[test]
    fn stored_generator_matches_ram_bitwise() {
        use crate::data::storage::Backend;
        let ram = two_gaussians(37, 11, 4, 1.5, 13);
        // Tiny chunk (4 KiB floor) forces many slabs; tiny window (1 MiB
        // floor) is still several rows here but exercises the path.
        let mut all = vec![
            StorageOptions::default(),
            StorageOptions::default().chunk_bytes(0),
        ];
        if cfg!(target_os = "linux") {
            all.push(
                StorageOptions::default()
                    .backend(Backend::Mmap)
                    .chunk_bytes(0),
            );
        }
        for opts in all {
            let stored =
                two_gaussians_stored(37, 11, 4, 1.5, 13, &opts).unwrap();
            assert_eq!(stored.name, ram.name);
            assert_eq!(stored.y, ram.y);
            let got = stored.to_dataset().unwrap();
            for (a, b) in got.x.as_slice().iter().zip(ram.x.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{:?}", opts.backend);
            }
        }
    }

    #[test]
    fn planted_sparse_properties() {
        let ds = planted_sparse("t", 300, 40, 5, 1.5, 0.9, 0.0, 3);
        assert_eq!(ds.n_examples(), 300);
        assert_eq!(ds.n_features(), 40);
        // exactly s rows should correlate strongly with the labels
        let mut strong = 0;
        for i in 0..40 {
            let row = ds.x.row(i);
            let corr: f64 = row
                .iter()
                .zip(&ds.y)
                .map(|(&v, &l)| v * l)
                .sum::<f64>()
                / 300.0;
            if corr.abs() > 0.5 {
                strong += 1;
            }
        }
        assert!((4..=6).contains(&strong), "strong = {strong}");
    }

    #[test]
    fn label_noise_flips_labels() {
        let clean = planted_sparse("c", 500, 10, 2, 1.0, 1.0, 0.0, 9);
        let noisy = planted_sparse("n", 500, 10, 2, 1.0, 1.0, 0.3, 9);
        let diff = clean
            .y
            .iter()
            .zip(&noisy.y)
            .filter(|(a, b)| a != b)
            .count();
        assert!((100..200).contains(&diff), "flips {diff}");
    }

    #[test]
    fn sparse_regression_support_is_predictive() {
        let (ds, support) = sparse_regression(400, 30, 4, 0.01, 5);
        assert_eq!(support.len(), 4);
        // residual after regressing on the true support should be tiny
        for &i in &support {
            let row = ds.x.row(i);
            let corr: f64 = row
                .iter()
                .zip(&ds.y)
                .map(|(&v, &yv)| v * yv)
                .sum::<f64>()
                / 400.0;
            assert!(corr.abs() > 0.05, "support feature {i} uncorrelated");
        }
    }
}
