//! Out-of-core data backends: RAM or memory-mapped storage for the two
//! O(n·m) buffers of a selection run — the dataset `X` and the greedy
//! cache Cᵀ — plus the chunked line reader behind the GB-scale libsvm
//! loader.
//!
//! The greedy hot passes are pure streaming at 0.17–0.31 flop/byte
//! (EXPERIMENTS.md §Perf), exactly the access pattern that tolerates
//! spilling to disk: this module lets both big matrices live in
//! file-backed scratch, accessed through bounded **row windows** so the
//! process' address space stays capped no matter how large the data is
//! (the CI out-of-core smoke job runs selection under `ulimit -v`
//! smaller than the dataset).
//!
//! Three layers:
//!
//! * [`MatrixStore`] — an `n × row_len` f64 store that is either a RAM
//!   `Vec<f64>` ([`Backend::Ram`], current behavior, bit-identical) or a
//!   scratch file accessed through short-lived `mmap` windows of at most
//!   [`StorageOptions::window_bytes`] bytes ([`Backend::Mmap`]).
//! * [`ReadMap`] — a whole-file read-only mapping that backs a regular
//!   [`Matrix`], so *every* selector (not just greedy) can consume an
//!   mmap-backed dataset through the unchanged `Matrix` API.
//! * [`ChunkedLines`] — a bounded-buffer line splitter over any
//!   [`Read`], the substrate of `data::libsvm`'s streaming loader (a
//!   line crossing a chunk boundary is reassembled transparently).
//!
//! **Determinism.** Backends change *where bytes live*, never *what
//! arithmetic runs*: the scan and commit kernels receive the same row
//! slices in the same order whether a row comes from a `Vec` or a
//! mapping window, and column tiles only reorder memory traffic across
//! candidates while each candidate's own accumulator sequence stays the
//! serial one. Selected sets, criterion curves, and weights are
//! therefore byte-identical across backends, tile sizes, and thread
//! counts — enforced by `rust/tests/backend_equivalence.rs`.
//!
//! The mmap backend is implemented with raw `extern "C"` bindings (no
//! new dependencies) and is Linux-only; constructors return a clean
//! error elsewhere.

use std::fs::{File, OpenOptions};
use std::io::Read;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::str::FromStr;

use anyhow::{bail, ensure, Context};

use crate::linalg::Matrix;

// ---------------------------------------------------------------------------
// Backend + options
// ---------------------------------------------------------------------------

/// Where a [`MatrixStore`] keeps its bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// In-RAM `Vec<f64>` — the historical behavior and the default.
    #[default]
    Ram,
    /// File-backed scratch accessed through bounded mmap windows
    /// (Linux-only; requires no extra RAM beyond the window budget).
    Mmap,
}

impl FromStr for Backend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Backend> {
        match s {
            "ram" => Ok(Backend::Ram),
            "mmap" => Ok(Backend::Mmap),
            other => bail!("unknown backend {other:?} (expected ram|mmap)"),
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Backend::Ram => "ram",
            Backend::Mmap => "mmap",
        })
    }
}

/// Knobs for the storage layer: backend choice, mapping-window budget,
/// LLC column-tile width, loader chunk size, and the scratch directory.
///
/// ```
/// use greedy_rls::data::storage::{Backend, StorageOptions};
///
/// let opts = StorageOptions::default()
///     .backend("mmap".parse::<Backend>()?)
///     .window_bytes(16 << 20)
///     .chunk_bytes(1 << 20);
/// assert_eq!(opts.backend, Backend::Mmap);
/// assert_eq!(opts.window_bytes, 16 << 20);
/// # anyhow::Ok(())
/// ```
#[derive(Clone, Debug)]
pub struct StorageOptions {
    /// Backend for the big O(n·m) buffers.
    pub backend: Backend,
    /// Upper bound, in bytes, on one mapping window (per worker thread;
    /// the scan maps one dataset window plus one cache window at a
    /// time). Ignored by [`Backend::Ram`].
    pub window_bytes: usize,
    /// Column-tile width for the LLC-tiled scan/commit kernels:
    /// `0` = automatic (off for RAM, roofline-derived for mmap — see
    /// EXPERIMENTS.md §Out-of-core). Rounded down to a multiple of 8 so
    /// tiling never changes the kernels' accumulator pairing.
    pub tile_cols: usize,
    /// Read-chunk size for the streaming libsvm loader.
    pub chunk_bytes: usize,
    /// Directory for scratch files (`None` = the system temp dir).
    /// Scratch files are deleted when their store is dropped.
    pub scratch: Option<PathBuf>,
}

impl Default for StorageOptions {
    fn default() -> Self {
        StorageOptions {
            backend: Backend::Ram,
            window_bytes: 256 << 20,
            tile_cols: 0,
            chunk_bytes: 8 << 20,
            scratch: None,
        }
    }
}

impl StorageOptions {
    /// Set the backend.
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Set the mapping-window budget in bytes (clamped to ≥ 1 MiB so a
    /// window always holds a useful number of rows).
    pub fn window_bytes(mut self, bytes: usize) -> Self {
        self.window_bytes = bytes.max(1 << 20);
        self
    }

    /// Set the column-tile width (`0` = automatic).
    pub fn tile_cols(mut self, cols: usize) -> Self {
        self.tile_cols = cols;
        self
    }

    /// Set the loader read-chunk size in bytes (clamped to ≥ 4 KiB).
    pub fn chunk_bytes(mut self, bytes: usize) -> Self {
        self.chunk_bytes = bytes.max(4 << 10);
        self
    }

    /// Set the scratch directory.
    pub fn scratch(mut self, dir: impl Into<PathBuf>) -> Self {
        self.scratch = Some(dir.into());
        self
    }

    /// Resolved scratch directory (`scratch` or the system temp dir).
    pub fn scratch_dir(&self) -> PathBuf {
        self.scratch.clone().unwrap_or_else(std::env::temp_dir)
    }
}

// ---------------------------------------------------------------------------
// Scratch files
// ---------------------------------------------------------------------------

/// A scratch file that is removed from disk when dropped.
struct ScratchFile {
    path: PathBuf,
}

impl ScratchFile {
    fn create(dir: &Path) -> anyhow::Result<(ScratchFile, File)> {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let id = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = dir.join(format!(
            "greedy-rls-scratch-{}-{id}.bin",
            std::process::id()
        ));
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)
            .with_context(|| {
                format!("creating scratch file {}", path.display())
            })?;
        Ok((ScratchFile { path }, file))
    }
}

impl Drop for ScratchFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

// ---------------------------------------------------------------------------
// Raw mmap bindings (Linux-only, no external crates)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const PROT_WRITE: i32 = 2;
    pub const MAP_SHARED: i32 = 1;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
        fn sysconf(name: i32) -> i64;
    }

    /// Host page size (`_SC_PAGESIZE`; 4096 if the query fails).
    pub fn page_size() -> usize {
        const SC_PAGESIZE: i32 = 30;
        let v = unsafe { sysconf(SC_PAGESIZE) };
        if v > 0 {
            v as usize
        } else {
            4096
        }
    }
}

/// One short-lived mapping of byte range `[off, off + len)` of a file.
/// `off`/`len` are multiples of 8 (callers pass row-aligned f64 ranges);
/// the mapping itself is widened down to a page boundary.
#[cfg(target_os = "linux")]
struct Window {
    base: *mut u8,
    map_len: usize,
    delta: usize,
    f64_len: usize,
}

#[cfg(target_os = "linux")]
impl Window {
    fn map(
        file: &File,
        byte_off: u64,
        byte_len: usize,
        writable: bool,
    ) -> anyhow::Result<Window> {
        use std::os::unix::io::AsRawFd;
        ensure!(byte_off % 8 == 0, "window offset must be f64-aligned");
        ensure!(byte_len % 8 == 0, "window length must be f64-aligned");
        if byte_len == 0 {
            return Ok(Window {
                base: std::ptr::null_mut(),
                map_len: 0,
                delta: 0,
                f64_len: 0,
            });
        }
        let page = sys::page_size() as u64;
        let aligned_off = byte_off - byte_off % page;
        let delta = (byte_off - aligned_off) as usize;
        let map_len = byte_len + delta;
        let prot = if writable {
            sys::PROT_READ | sys::PROT_WRITE
        } else {
            sys::PROT_READ
        };
        let base = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                map_len,
                prot,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                aligned_off as i64,
            )
        };
        ensure!(
            !base.is_null() && base as isize != -1,
            "mmap of {map_len} bytes at offset {aligned_off} failed \
             (address-space limit or bad file?)"
        );
        Ok(Window {
            base: base as *mut u8,
            map_len,
            delta,
            f64_len: byte_len / 8,
        })
    }

    fn slice(&self) -> &[f64] {
        if self.f64_len == 0 {
            return &[];
        }
        // Alignment: page base + delta, both multiples of 8.
        unsafe {
            std::slice::from_raw_parts(
                self.base.add(self.delta) as *const f64,
                self.f64_len,
            )
        }
    }

    fn slice_mut(&mut self) -> &mut [f64] {
        if self.f64_len == 0 {
            return &mut [];
        }
        unsafe {
            std::slice::from_raw_parts_mut(
                self.base.add(self.delta) as *mut f64,
                self.f64_len,
            )
        }
    }
}

#[cfg(target_os = "linux")]
impl Drop for Window {
    fn drop(&mut self) {
        if !self.base.is_null() {
            unsafe {
                sys::munmap(self.base as *mut std::ffi::c_void, self.map_len);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// MatrixStore
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
struct Mapped {
    file: File,
    writable: bool,
    // Keeps the scratch file alive (and deletes it on drop).
    _scratch: Option<ScratchFile>,
}

enum Inner {
    Ram(Vec<f64>),
    #[cfg(target_os = "linux")]
    Mapped(Mapped),
}

/// A dense `rows × row_len` f64 store with a RAM or mmap backend,
/// accessed through contiguous row ranges.
///
/// This is the storage abstraction behind both out-of-core buffers: the
/// loader builds the dataset `X` into one, and the greedy engine keeps
/// its cache Cᵀ in another. RAM access is a plain subslice; mmap access
/// maps a short-lived window per call, so the caller's address-space
/// footprint is bounded by [`MatrixStore::window_rows`] rows per window
/// regardless of the store size.
///
/// ```
/// use greedy_rls::data::storage::{Backend, MatrixStore, StorageOptions};
///
/// // Exercise the mmap backend where available, RAM elsewhere.
/// let backend = if cfg!(target_os = "linux") { Backend::Mmap } else { Backend::Ram };
/// let opts = StorageOptions::default().backend(backend);
/// let mut store = MatrixStore::zeros(3, 4, &opts)?;
/// store.write_rows(1..2, |rows| rows.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]))?;
/// let sum = store.read_rows(0..3, |rows| rows.iter().sum::<f64>())?;
/// assert_eq!(sum, 10.0);
/// # anyhow::Ok(())
/// ```
pub struct MatrixStore {
    rows: usize,
    row_len: usize,
    window_rows: usize,
    inner: Inner,
}

impl MatrixStore {
    /// A zero-filled store on the backend `opts` selects.
    pub fn zeros(
        rows: usize,
        row_len: usize,
        opts: &StorageOptions,
    ) -> anyhow::Result<MatrixStore> {
        ensure!(row_len > 0, "row_len must be positive");
        let total = rows
            .checked_mul(row_len)
            .and_then(|n| n.checked_mul(8))
            .context("store size overflows usize")?;
        let window_rows = Self::window_rows_for(opts, row_len, rows);
        let inner = match opts.backend {
            Backend::Ram => Inner::Ram(vec![0.0; total / 8]),
            #[cfg(target_os = "linux")]
            Backend::Mmap => {
                let (scratch, file) = ScratchFile::create(&opts.scratch_dir())?;
                file.set_len(total as u64).with_context(|| {
                    format!("sizing scratch store to {total} bytes")
                })?;
                Inner::Mapped(Mapped {
                    file,
                    writable: true,
                    _scratch: Some(scratch),
                })
            }
            #[cfg(not(target_os = "linux"))]
            Backend::Mmap => {
                bail!("the mmap backend requires linux (raw mmap bindings)")
            }
        };
        Ok(MatrixStore { rows, row_len, window_rows, inner })
    }

    /// Copy a [`Matrix`] into a fresh store (rows map to rows).
    pub fn from_matrix(
        x: &Matrix,
        opts: &StorageOptions,
    ) -> anyhow::Result<MatrixStore> {
        let mut store = MatrixStore::zeros(x.rows(), x.cols(), opts)?;
        let step = store.window_rows;
        let mut r0 = 0;
        while r0 < x.rows() {
            let r1 = (r0 + step).min(x.rows());
            store.write_rows(r0..r1, |dst| {
                dst.copy_from_slice(
                    &x.as_slice()[r0 * x.cols()..r1 * x.cols()],
                );
            })?;
            r0 = r1;
        }
        Ok(store)
    }

    /// Open an existing dense row-major f64 file read-only through mmap
    /// windows (Linux-only). The file must hold exactly
    /// `rows · row_len` f64 values.
    pub fn open_readonly(
        path: &Path,
        rows: usize,
        row_len: usize,
        opts: &StorageOptions,
    ) -> anyhow::Result<MatrixStore> {
        ensure!(row_len > 0, "row_len must be positive");
        #[cfg(target_os = "linux")]
        {
            let file = File::open(path).with_context(|| {
                format!("opening dense store {}", path.display())
            })?;
            let want = (rows * row_len * 8) as u64;
            let got = file.metadata()?.len();
            ensure!(
                got == want,
                "dense store {} is {got} bytes, expected {want} \
                 ({rows} rows × {row_len})",
                path.display()
            );
            Ok(MatrixStore {
                rows,
                row_len,
                window_rows: Self::window_rows_for(opts, row_len, rows),
                inner: Inner::Mapped(Mapped {
                    file,
                    writable: false,
                    _scratch: None,
                }),
            })
        }
        #[cfg(not(target_os = "linux"))]
        {
            let _ = (path, opts);
            bail!("the mmap backend requires linux (raw mmap bindings)")
        }
    }

    fn window_rows_for(
        opts: &StorageOptions,
        row_len: usize,
        rows: usize,
    ) -> usize {
        match opts.backend {
            Backend::Ram => rows.max(1),
            Backend::Mmap => (opts.window_bytes / (row_len * 8))
                .clamp(1, rows.max(1)),
        }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Row length (the number of columns).
    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// The backend this store runs on.
    pub fn backend(&self) -> Backend {
        match self.inner {
            Inner::Ram(_) => Backend::Ram,
            #[cfg(target_os = "linux")]
            Inner::Mapped(_) => Backend::Mmap,
        }
    }

    /// How many rows one mapping window holds — callers chunk long scans
    /// by this to keep the address-space footprint bounded. RAM stores
    /// report the full row count (no windowing needed).
    pub fn window_rows(&self) -> usize {
        self.window_rows
    }

    /// Run `f` over the contiguous rows `r` (read-only). One mmap window
    /// is created for the call on the mmap backend; a subslice on RAM.
    pub fn read_rows<T>(
        &self,
        r: Range<usize>,
        f: impl FnOnce(&[f64]) -> T,
    ) -> anyhow::Result<T> {
        ensure!(
            r.start <= r.end && r.end <= self.rows,
            "row range {}..{} out of bounds (rows = {})",
            r.start,
            r.end,
            self.rows
        );
        match &self.inner {
            Inner::Ram(data) => {
                Ok(f(&data[r.start * self.row_len..r.end * self.row_len]))
            }
            #[cfg(target_os = "linux")]
            Inner::Mapped(map) => {
                let win = Window::map(
                    &map.file,
                    (r.start * self.row_len * 8) as u64,
                    (r.end - r.start) * self.row_len * 8,
                    false,
                )?;
                Ok(f(win.slice()))
            }
        }
    }

    /// Copy row `i` into `out` (cleared first). The O(m) staging path of
    /// the stored commit (`v`, `c_b`) and weights.
    pub fn read_row_into(
        &self,
        i: usize,
        out: &mut Vec<f64>,
    ) -> anyhow::Result<()> {
        self.read_rows(i..i + 1, |row| {
            out.clear();
            out.extend_from_slice(row);
        })
    }

    /// Run `f` over the contiguous rows `r` (read-write).
    pub fn write_rows<T>(
        &mut self,
        r: Range<usize>,
        f: impl FnOnce(&mut [f64]) -> T,
    ) -> anyhow::Result<T> {
        ensure!(
            r.start <= r.end && r.end <= self.rows,
            "row range {}..{} out of bounds (rows = {})",
            r.start,
            r.end,
            self.rows
        );
        match &mut self.inner {
            Inner::Ram(data) => {
                Ok(f(&mut data[r.start * self.row_len..r.end * self.row_len]))
            }
            #[cfg(target_os = "linux")]
            Inner::Mapped(map) => {
                ensure!(map.writable, "store is read-only");
                let mut win = Window::map(
                    &map.file,
                    (r.start * self.row_len * 8) as u64,
                    (r.end - r.start) * self.row_len * 8,
                    true,
                )?;
                Ok(f(win.slice_mut()))
            }
        }
    }

    /// Apply `f` to every row block in parallel: `f(first_row, block)`
    /// where `block` is a row-aligned mutable slab. Rows are sharded
    /// across `threads` workers exactly like
    /// [`crate::parallel::for_each_row_chunk`]; on the mmap backend each
    /// worker walks its shard in windows of at most
    /// [`MatrixStore::window_rows`] rows, so per-worker address space
    /// stays bounded. Workers touch disjoint rows, and each row receives
    /// the identical serial update — bit-identical at any thread count
    /// and any window size.
    pub fn par_update_row_blocks(
        &mut self,
        threads: usize,
        f: impl Fn(usize, &mut [f64]) + Sync,
    ) -> anyhow::Result<()> {
        let row_len = self.row_len;
        match &mut self.inner {
            Inner::Ram(data) => {
                crate::parallel::for_each_row_chunk(
                    threads, data, row_len, f,
                );
                Ok(())
            }
            #[cfg(target_os = "linux")]
            Inner::Mapped(map) => {
                ensure!(map.writable, "store is read-only");
                let rows = self.rows;
                let window = self.window_rows;
                let t = crate::parallel::resolve(threads).min(rows.max(1));
                let ranges = crate::parallel::split_ranges(rows, t);
                let file = &map.file;
                let results: Vec<anyhow::Result<()>> =
                    crate::parallel::map_ranges(&ranges, |r| {
                        let mut r0 = r.start;
                        while r0 < r.end {
                            let r1 = (r0 + window).min(r.end);
                            let mut win = Window::map(
                                file,
                                (r0 * row_len * 8) as u64,
                                (r1 - r0) * row_len * 8,
                                true,
                            )?;
                            f(r0, win.slice_mut());
                            r0 = r1;
                        }
                        Ok(())
                    });
                for res in results {
                    res?;
                }
                Ok(())
            }
        }
    }

    /// Materialize the store as an in-RAM [`Matrix`] (test- and
    /// small-data-sized; the whole store is copied).
    pub fn to_matrix(&self) -> anyhow::Result<Matrix> {
        let mut data = Vec::with_capacity(self.rows * self.row_len);
        let step = self.window_rows;
        let mut r0 = 0;
        while r0 < self.rows {
            let r1 = (r0 + step).min(self.rows);
            self.read_rows(r0..r1, |rows| data.extend_from_slice(rows))?;
            r0 = r1;
        }
        Ok(Matrix::from_vec(self.rows, self.row_len, data))
    }

    /// Consume the store into a [`Matrix`]. RAM stores convert for free;
    /// mmap stores become a whole-file read-only mapping ([`ReadMap`]),
    /// which lets every selector consume the data through the unchanged
    /// `Matrix` API (the mapping counts against address space — use the
    /// windowed store directly where an address-space cap applies).
    pub fn into_matrix(self) -> anyhow::Result<Matrix> {
        let (rows, row_len) = (self.rows, self.row_len);
        match self.inner {
            Inner::Ram(data) => Ok(Matrix::from_vec(rows, row_len, data)),
            #[cfg(target_os = "linux")]
            Inner::Mapped(map) => {
                let map =
                    ReadMap::from_parts(map.file, rows * row_len, map._scratch)?;
                Ok(Matrix::from_mapped(rows, row_len, map))
            }
        }
    }
}

impl std::fmt::Debug for MatrixStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MatrixStore")
            .field("rows", &self.rows)
            .field("row_len", &self.row_len)
            .field("backend", &self.backend())
            .field("window_rows", &self.window_rows)
            .finish()
    }
}

// ---------------------------------------------------------------------------
// ReadMap — a whole-file read-only mapping backing a Matrix
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
struct MapInner {
    base: *mut u8,
    map_len: usize,
    f64_len: usize,
    // Deletes the backing scratch file (if any) when the last clone drops.
    _scratch: Option<ScratchFile>,
}

// SAFETY: the mapping is read-only for its entire lifetime; concurrent
// reads from any thread are safe, and the pointer is never exposed
// mutably.
#[cfg(target_os = "linux")]
unsafe impl Send for MapInner {}
#[cfg(target_os = "linux")]
unsafe impl Sync for MapInner {}

#[cfg(target_os = "linux")]
impl Drop for MapInner {
    fn drop(&mut self) {
        if !self.base.is_null() {
            unsafe {
                sys::munmap(self.base as *mut std::ffi::c_void, self.map_len);
            }
        }
    }
}

/// A shared, read-only, whole-file f64 mapping — the buffer behind an
/// mmap-backed [`Matrix`]. Cloning shares the mapping (`Arc`); the
/// backing scratch file (if the map owns one) is deleted when the last
/// clone drops.
#[derive(Clone)]
pub struct ReadMap {
    #[cfg(target_os = "linux")]
    inner: std::sync::Arc<MapInner>,
    #[cfg(not(target_os = "linux"))]
    inner: std::sync::Arc<Vec<f64>>,
}

impl ReadMap {
    /// Map an existing dense f64 file read-only (Linux-only).
    pub fn open(path: &Path, f64_len: usize) -> anyhow::Result<ReadMap> {
        #[cfg(target_os = "linux")]
        {
            let file = File::open(path).with_context(|| {
                format!("opening dense store {}", path.display())
            })?;
            let want = (f64_len * 8) as u64;
            let got = file.metadata()?.len();
            ensure!(
                got == want,
                "dense store {} is {got} bytes, expected {want}",
                path.display()
            );
            ReadMap::from_parts(file, f64_len, None)
        }
        #[cfg(not(target_os = "linux"))]
        {
            let _ = (path, f64_len);
            bail!("memory-mapped datasets require linux (raw mmap bindings)")
        }
    }

    #[cfg(target_os = "linux")]
    fn from_parts(
        file: File,
        f64_len: usize,
        scratch: Option<ScratchFile>,
    ) -> anyhow::Result<ReadMap> {
        use std::os::unix::io::AsRawFd;
        let map_len = f64_len * 8;
        if map_len == 0 {
            return Ok(ReadMap {
                inner: std::sync::Arc::new(MapInner {
                    base: std::ptr::null_mut(),
                    map_len: 0,
                    f64_len: 0,
                    _scratch: scratch,
                }),
            });
        }
        let base = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                map_len,
                sys::PROT_READ,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        ensure!(
            !base.is_null() && base as isize != -1,
            "mmap of {map_len} bytes failed (address-space limit?)"
        );
        Ok(ReadMap {
            inner: std::sync::Arc::new(MapInner {
                base: base as *mut u8,
                map_len,
                f64_len,
                _scratch: scratch,
            }),
        })
    }

    /// The mapped values.
    pub fn as_slice(&self) -> &[f64] {
        #[cfg(target_os = "linux")]
        {
            if self.inner.f64_len == 0 {
                return &[];
            }
            // SAFETY: the mapping is valid for the Arc's lifetime and
            // page-aligned (offset 0), hence f64-aligned.
            unsafe {
                std::slice::from_raw_parts(
                    self.inner.base as *const f64,
                    self.inner.f64_len,
                )
            }
        }
        #[cfg(not(target_os = "linux"))]
        {
            &self.inner
        }
    }

    /// Number of mapped f64 values.
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    /// Whether the mapping is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl std::fmt::Debug for ReadMap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReadMap").field("len", &self.len()).finish()
    }
}

// ---------------------------------------------------------------------------
// ChunkedLines
// ---------------------------------------------------------------------------

/// Bounded-buffer line splitter over any [`Read`]: reads fixed-size
/// chunks and yields `&str` lines, reassembling lines that straddle a
/// chunk boundary. Memory use is bounded by the chunk size plus the
/// longest single line — never the file size (there is deliberately no
/// `read_to_end` anywhere in this module).
///
/// Line semantics match [`std::io::BufRead::lines`]: the trailing `\n`
/// is stripped, then one trailing `\r`; a final line without a newline
/// is still yielded; invalid UTF-8 is an error.
///
/// ```
/// use greedy_rls::data::storage::ChunkedLines;
///
/// // A 5-byte chunk forces the second line to straddle a boundary.
/// let mut lines = ChunkedLines::new("ab\nlong line\r\nc".as_bytes(), 5);
/// assert_eq!(lines.next_line()?, Some("ab"));
/// assert_eq!(lines.next_line()?, Some("long line"));
/// assert_eq!(lines.next_line()?, Some("c"));
/// assert_eq!(lines.next_line()?, None);
/// # anyhow::Ok(())
/// ```
pub struct ChunkedLines<R: Read> {
    src: R,
    chunk: usize,
    buf: Vec<u8>,
    start: usize,
    eof: bool,
}

impl<R: Read> ChunkedLines<R> {
    /// Wrap a reader; `chunk_bytes` is the read granularity (≥ 1).
    pub fn new(src: R, chunk_bytes: usize) -> ChunkedLines<R> {
        ChunkedLines {
            src,
            chunk: chunk_bytes.max(1),
            buf: Vec::new(),
            start: 0,
            eof: false,
        }
    }

    fn refill(&mut self) -> anyhow::Result<()> {
        // Compact the consumed prefix, then read one bounded chunk.
        self.buf.drain(..self.start);
        self.start = 0;
        let old = self.buf.len();
        self.buf.resize(old + self.chunk, 0);
        let got = self
            .src
            .read(&mut self.buf[old..])
            .context("reading input chunk")?;
        self.buf.truncate(old + got);
        if got == 0 {
            self.eof = true;
        }
        Ok(())
    }

    /// The next line, or `None` at end of input.
    pub fn next_line(&mut self) -> anyhow::Result<Option<&str>> {
        let range = loop {
            if let Some(p) =
                self.buf[self.start..].iter().position(|&b| b == b'\n')
            {
                let s = self.start;
                self.start = s + p + 1;
                break Some((s, s + p));
            }
            if self.eof {
                if self.start < self.buf.len() {
                    let s = self.start;
                    let e = self.buf.len();
                    self.start = e;
                    break Some((s, e));
                }
                break None;
            }
            self.refill()?;
        };
        match range {
            Some((s, mut e)) => {
                if e > s && self.buf[e - 1] == b'\r' {
                    e -= 1;
                }
                let line = std::str::from_utf8(&self.buf[s..e])
                    .context("input is not valid UTF-8")?;
                Ok(Some(line))
            }
            None => Ok(None),
        }
    }
}

// ---------------------------------------------------------------------------
// StoredDataset
// ---------------------------------------------------------------------------

/// A dataset whose design matrix lives in a [`MatrixStore`] — the
/// out-of-core counterpart of [`crate::data::Dataset`]. Labels stay in
/// RAM (O(m)); only the O(n·m) matrix is storage-backed.
///
/// ```
/// use greedy_rls::data::storage::StorageOptions;
/// use greedy_rls::data::synthetic::two_gaussians_stored;
///
/// let opts = StorageOptions::default();
/// let mut ds = two_gaussians_stored(30, 8, 3, 1.0, 7, &opts)?;
/// let stats = ds.standardize()?;
/// assert_eq!(stats.len(), ds.n_features());
/// assert_eq!(ds.n_examples(), 30);
/// # anyhow::Ok(())
/// ```
pub struct StoredDataset {
    /// Feature-major design matrix, `n_features × m_examples`.
    pub x: MatrixStore,
    /// Labels, length `m` (±1 for classification).
    pub y: Vec<f64>,
    /// Human-readable name (file stem / generator tag).
    pub name: String,
}

impl StoredDataset {
    /// Construct and validate shapes.
    pub fn new(
        name: impl Into<String>,
        x: MatrixStore,
        y: Vec<f64>,
    ) -> anyhow::Result<StoredDataset> {
        ensure!(
            x.row_len() == y.len(),
            "X columns ({}) must equal |y| ({})",
            x.row_len(),
            y.len()
        );
        Ok(StoredDataset { x, y, name: name.into() })
    }

    /// Number of features `n`.
    pub fn n_features(&self) -> usize {
        self.x.rows()
    }

    /// Number of examples `m`.
    pub fn n_examples(&self) -> usize {
        self.y.len()
    }

    /// Standardize every feature to zero mean / unit variance in place,
    /// streaming over row windows. Per-row arithmetic is exactly
    /// [`crate::data::Dataset::standardize`]'s, so the result is
    /// bit-identical to standardizing the same data in RAM.
    pub fn standardize(&mut self) -> anyhow::Result<Vec<(f64, f64)>> {
        let m = self.n_examples() as f64;
        let row_len = self.x.row_len();
        let n = self.x.rows();
        let mut stats = Vec::with_capacity(n);
        let step = self.x.window_rows();
        let mut r0 = 0;
        while r0 < n {
            let r1 = (r0 + step).min(n);
            self.x.write_rows(r0..r1, |rows| {
                for row in rows.chunks_exact_mut(row_len) {
                    let mean = row.iter().sum::<f64>() / m;
                    let var = row
                        .iter()
                        .map(|v| (v - mean).powi(2))
                        .sum::<f64>()
                        / m;
                    let std = if var > 0.0 { var.sqrt() } else { 1.0 };
                    for v in row.iter_mut() {
                        *v = (*v - mean) / std;
                    }
                    stats.push((mean, std));
                }
            })?;
            r0 = r1;
        }
        Ok(stats)
    }

    /// Streaming dataset fingerprint, equal to
    /// [`crate::data::fingerprint::fingerprint_xy`] on the same data —
    /// checkpoints are interchangeable between backends.
    pub fn fingerprint(&self) -> anyhow::Result<u64> {
        super::fingerprint::fingerprint_xy_stored(&self.x, &self.y)
    }

    /// Materialize as an in-RAM [`crate::data::Dataset`] (copies the
    /// whole matrix — test- and small-data-sized).
    pub fn to_dataset(&self) -> anyhow::Result<super::Dataset> {
        Ok(super::Dataset::new(
            self.name.clone(),
            self.x.to_matrix()?,
            self.y.clone(),
        ))
    }

    /// Consume into a [`crate::data::Dataset`] whose matrix is a
    /// whole-file [`ReadMap`] on the mmap backend (zero-copy) or the RAM
    /// vector on the RAM backend.
    pub fn into_dataset(self) -> anyhow::Result<super::Dataset> {
        Ok(super::Dataset::new(self.name, self.x.into_matrix()?, self.y))
    }
}

impl std::fmt::Debug for StoredDataset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StoredDataset")
            .field("name", &self.name)
            .field("x", &self.x)
            .field("m", &self.y.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn both_backends() -> Vec<StorageOptions> {
        let mut opts = vec![StorageOptions::default()];
        if cfg!(target_os = "linux") {
            // A tiny window forces many mappings per scan.
            opts.push(
                StorageOptions::default()
                    .backend(Backend::Mmap)
                    .window_bytes(1 << 20),
            );
        }
        opts
    }

    #[test]
    fn backend_parses_and_displays() {
        assert_eq!("ram".parse::<Backend>().unwrap(), Backend::Ram);
        assert_eq!("mmap".parse::<Backend>().unwrap(), Backend::Mmap);
        assert!("disk".parse::<Backend>().is_err());
        assert_eq!(Backend::Mmap.to_string(), "mmap");
        assert_eq!(Backend::default(), Backend::Ram);
    }

    #[test]
    fn store_roundtrip_both_backends() {
        for opts in both_backends() {
            let mut st = MatrixStore::zeros(5, 3, &opts).unwrap();
            st.write_rows(0..5, |rows| {
                for (i, v) in rows.iter_mut().enumerate() {
                    *v = i as f64;
                }
            })
            .unwrap();
            st.write_rows(2..3, |row| row.copy_from_slice(&[9.0, 9.0, 9.0]))
                .unwrap();
            let got = st.read_rows(0..5, |r| r.to_vec()).unwrap();
            let mut want: Vec<f64> = (0..15).map(|i| i as f64).collect();
            want[6..9].copy_from_slice(&[9.0, 9.0, 9.0]);
            assert_eq!(got, want, "{:?}", opts.backend);
            let mut row = Vec::new();
            st.read_row_into(2, &mut row).unwrap();
            assert_eq!(row, vec![9.0, 9.0, 9.0]);
            assert!(st.read_rows(4..6, |_| ()).is_err());
        }
    }

    #[test]
    fn from_matrix_and_to_matrix_are_inverse() {
        let x = Matrix::from_rows(&[
            &[1.0, 2.0, 3.0],
            &[4.0, 5.0, 6.0],
            &[7.0, 8.0, 9.0],
        ]);
        for opts in both_backends() {
            let st = MatrixStore::from_matrix(&x, &opts).unwrap();
            assert_eq!(st.to_matrix().unwrap(), x, "{:?}", opts.backend);
        }
    }

    #[test]
    fn par_update_matches_serial_any_thread_count() {
        let rows = 13;
        let m = 7;
        let base: Vec<f64> = (0..rows * m).map(|i| (i as f64).sin()).collect();
        let x = Matrix::from_vec(rows, m, base.clone());
        // reference: serial elementwise transform
        let want: Vec<f64> = base
            .iter()
            .enumerate()
            .map(|(i, v)| v * 2.0 + (i / m) as f64)
            .collect();
        for opts in both_backends() {
            for t in [1usize, 2, 4] {
                let mut st = MatrixStore::from_matrix(&x, &opts).unwrap();
                st.par_update_row_blocks(t, |first, block| {
                    for (r, row) in
                        block.chunks_exact_mut(m).enumerate()
                    {
                        for v in row.iter_mut() {
                            *v = *v * 2.0 + (first + r) as f64;
                        }
                    }
                })
                .unwrap();
                let got = st.read_rows(0..rows, |r| r.to_vec()).unwrap();
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{:?} t={t}",
                        opts.backend
                    );
                }
            }
        }
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn scratch_file_removed_on_drop() {
        let opts = StorageOptions::default().backend(Backend::Mmap);
        let dir = opts.scratch_dir();
        let before: usize = count_scratch(&dir);
        {
            let _st = MatrixStore::zeros(4, 4, &opts).unwrap();
            assert_eq!(count_scratch(&dir), before + 1);
        }
        assert_eq!(count_scratch(&dir), before);
    }

    #[cfg(target_os = "linux")]
    fn count_scratch(dir: &Path) -> usize {
        let pid = std::process::id().to_string();
        std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                let name = e.file_name().to_string_lossy().into_owned();
                name.starts_with("greedy-rls-scratch-")
                    && name.contains(&pid)
            })
            .count()
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn into_matrix_maps_whole_file() {
        let x = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let opts = StorageOptions::default().backend(Backend::Mmap);
        let st = MatrixStore::from_matrix(&x, &opts).unwrap();
        let mapped = st.into_matrix().unwrap();
        assert_eq!(mapped, x);
        assert_eq!(mapped.row(1), &[3.0, 4.0]);
        // Clones share the mapping.
        let c = mapped.clone();
        assert_eq!(c, x);
    }

    #[test]
    fn chunked_lines_all_chunk_sizes() {
        let text = "first\nsecond line\n\n# comment\r\nlast";
        let want = ["first", "second line", "", "# comment", "last"];
        for chunk in 1..=40 {
            let mut lines = ChunkedLines::new(Cursor::new(text), chunk);
            let mut got = Vec::new();
            while let Some(l) = lines.next_line().unwrap() {
                got.push(l.to_string());
            }
            assert_eq!(got, want, "chunk={chunk}");
        }
    }

    #[test]
    fn chunked_lines_line_longer_than_chunk() {
        let long = "x".repeat(100);
        let text = format!("{long}\nshort\n");
        let mut lines = ChunkedLines::new(Cursor::new(text), 8);
        assert_eq!(lines.next_line().unwrap(), Some(long.as_str()));
        assert_eq!(lines.next_line().unwrap(), Some("short"));
        assert_eq!(lines.next_line().unwrap(), None);
        // next_line past EOF stays None
        let mut empty = ChunkedLines::new(Cursor::new(""), 4);
        assert_eq!(empty.next_line().unwrap(), None);
        assert_eq!(empty.next_line().unwrap(), None);
    }

    #[test]
    fn chunked_lines_rejects_invalid_utf8() {
        let mut lines =
            ChunkedLines::new(Cursor::new(&[0x66u8, 0xff, 0xfe][..]), 2);
        assert!(lines.next_line().is_err());
    }

    #[test]
    fn stored_standardize_matches_ram_bitwise() {
        let ds = crate::data::synthetic::two_gaussians(23, 9, 3, 1.0, 5);
        for opts in both_backends() {
            let x = MatrixStore::from_matrix(&ds.x, &opts).unwrap();
            let mut sds =
                StoredDataset::new("t", x, ds.y.clone()).unwrap();
            let stats = sds.standardize().unwrap();
            let mut ram = ds.clone();
            let ram_stats = ram.standardize();
            assert_eq!(stats.len(), ram_stats.len());
            for (a, b) in stats.iter().zip(&ram_stats) {
                assert_eq!(a.0.to_bits(), b.0.to_bits());
                assert_eq!(a.1.to_bits(), b.1.to_bits());
            }
            let got = sds.to_dataset().unwrap();
            for (a, b) in
                got.x.as_slice().iter().zip(ram.x.as_slice())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "{:?}", opts.backend);
            }
        }
    }
}
