//! Cross-validation fold construction.
//!
//! The paper's quality experiments (§4.2) use *stratified* ten-fold
//! cross-validation: folds preserve the class balance. This module builds
//! plain and stratified k-fold index partitions plus simple train/test
//! splits, all driven by the crate's deterministic RNG.

use crate::rng::Pcg64;

/// A partition of `0..m` into `k` disjoint folds.
#[derive(Clone, Debug)]
pub struct Folds {
    folds: Vec<Vec<usize>>,
}

impl Folds {
    /// Plain k-fold over `m` shuffled indices.
    pub fn new(m: usize, k: usize, rng: &mut Pcg64) -> Folds {
        assert!(k >= 2 && k <= m, "need 2 <= k <= m (k={k}, m={m})");
        let mut idx: Vec<usize> = (0..m).collect();
        rng.shuffle(&mut idx);
        let mut folds = vec![Vec::new(); k];
        for (pos, i) in idx.into_iter().enumerate() {
            folds[pos % k].push(i);
        }
        Folds { folds }
    }

    /// Stratified k-fold: each fold receives a proportional share of every
    /// class (`labels[i] > 0` vs `<= 0`).
    pub fn stratified(labels: &[f64], k: usize, rng: &mut Pcg64) -> Folds {
        let m = labels.len();
        assert!(k >= 2 && k <= m, "need 2 <= k <= m (k={k}, m={m})");
        let mut pos: Vec<usize> =
            (0..m).filter(|&i| labels[i] > 0.0).collect();
        let mut neg: Vec<usize> =
            (0..m).filter(|&i| labels[i] <= 0.0).collect();
        rng.shuffle(&mut pos);
        rng.shuffle(&mut neg);
        let mut folds = vec![Vec::new(); k];
        for (p, i) in pos.into_iter().enumerate() {
            folds[p % k].push(i);
        }
        // offset the negative round-robin so fold sizes stay balanced
        for (p, i) in neg.into_iter().enumerate() {
            folds[(k - 1 - p % k) % k].push(i);
        }
        Folds { folds }
    }

    /// Number of folds.
    pub fn k(&self) -> usize {
        self.folds.len()
    }

    /// Test indices of fold `f`.
    pub fn test_indices(&self, f: usize) -> &[usize] {
        &self.folds[f]
    }

    /// Train indices of fold `f` (all other folds, ascending).
    pub fn train_indices(&self, f: usize) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .folds
            .iter()
            .enumerate()
            .filter(|&(g, _)| g != f)
            .flat_map(|(_, v)| v.iter().copied())
            .collect();
        out.sort_unstable();
        out
    }

    /// Iterate `(train, test)` index pairs.
    pub fn splits(&self) -> impl Iterator<Item = (Vec<usize>, Vec<usize>)> + '_ {
        (0..self.k()).map(|f| (self.train_indices(f), self.folds[f].clone()))
    }
}

/// Random train/test split: returns `(train, test)` indices with
/// `test_fraction` of examples held out.
pub fn train_test_split(
    m: usize,
    test_fraction: f64,
    rng: &mut Pcg64,
) -> (Vec<usize>, Vec<usize>) {
    assert!((0.0..1.0).contains(&test_fraction));
    let mut idx: Vec<usize> = (0..m).collect();
    rng.shuffle(&mut idx);
    let n_test = ((m as f64) * test_fraction).round() as usize;
    let test = idx.split_off(m - n_test);
    (idx, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folds_partition_everything() {
        let mut rng = Pcg64::seeded(1);
        let f = Folds::new(103, 10, &mut rng);
        let mut all: Vec<usize> =
            (0..10).flat_map(|i| f.test_indices(i).to_vec()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
    }

    #[test]
    fn folds_are_balanced() {
        let mut rng = Pcg64::seeded(2);
        let f = Folds::new(100, 10, &mut rng);
        for i in 0..10 {
            assert_eq!(f.test_indices(i).len(), 10);
        }
    }

    #[test]
    fn train_test_disjoint_and_complete() {
        let mut rng = Pcg64::seeded(3);
        let f = Folds::new(30, 5, &mut rng);
        for fold in 0..5 {
            let train = f.train_indices(fold);
            let test = f.test_indices(fold);
            assert_eq!(train.len() + test.len(), 30);
            for t in test {
                assert!(!train.contains(t));
            }
        }
    }

    #[test]
    fn stratified_preserves_balance() {
        let mut rng = Pcg64::seeded(4);
        // 30 positive, 70 negative
        let labels: Vec<f64> =
            (0..100).map(|i| if i < 30 { 1.0 } else { -1.0 }).collect();
        let f = Folds::stratified(&labels, 10, &mut rng);
        for i in 0..10 {
            let test = f.test_indices(i);
            let pos = test.iter().filter(|&&j| labels[j] > 0.0).count();
            assert_eq!(test.len(), 10, "fold {i}");
            assert_eq!(pos, 3, "fold {i} pos count");
        }
    }

    #[test]
    fn stratified_partitions_everything() {
        let mut rng = Pcg64::seeded(5);
        let labels: Vec<f64> =
            (0..47).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect();
        let f = Folds::stratified(&labels, 4, &mut rng);
        let mut all: Vec<usize> =
            (0..4).flat_map(|i| f.test_indices(i).to_vec()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..47).collect::<Vec<_>>());
    }

    #[test]
    fn splits_iterator_covers_all_folds() {
        let mut rng = Pcg64::seeded(6);
        let f = Folds::new(20, 4, &mut rng);
        assert_eq!(f.splits().count(), 4);
        for (train, test) in f.splits() {
            assert_eq!(train.len() + test.len(), 20);
        }
    }

    #[test]
    fn train_test_split_sizes() {
        let mut rng = Pcg64::seeded(7);
        let (train, test) = train_test_split(100, 0.25, &mut rng);
        assert_eq!(test.len(), 25);
        assert_eq!(train.len(), 75);
        let mut all = [train, test].concat();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "need 2 <= k <= m")]
    fn rejects_k_larger_than_m() {
        let mut rng = Pcg64::seeded(8);
        Folds::new(3, 5, &mut rng);
    }
}
