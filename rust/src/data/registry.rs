//! Benchmark dataset registry (paper Table 1).
//!
//! The paper evaluates on six LIBSVM datasets. This environment has no
//! network access, so each registry entry resolves in order:
//!
//! 1. a real file at `data/real/<name>.libsvm` (drop-in, parsed by
//!    [`super::libsvm`]);
//! 2. a synthetic stand-in from [`super::synthetic::planted_sparse`] with
//!    the **same (m, n) shape as Table 1** (or a documented scaled-down
//!    shape for the three large sets, to keep single-CPU runs tractable —
//!    pass `full_size = true` for the paper's exact sizes).
//!
//! The planted-sparse parameters are chosen per dataset to mimic the
//! qualitative regime: colon-cancer is tiny-m/huge-n (the paper's
//! overfitting showcase), adult/ijcnn1 are large-m/small-n, mnist5 is
//! large both ways with many weakly informative features.

use super::synthetic::planted_sparse;
use super::{libsvm, Dataset};

/// Static description of one benchmark dataset.
#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    /// Registry key (paper's name).
    pub name: &'static str,
    /// Paper's instance count (Table 1).
    pub paper_m: usize,
    /// Paper's feature count (Table 1).
    pub paper_n: usize,
    /// Scaled-down instance count used by default on this testbed.
    pub scaled_m: usize,
    /// Planted informative features in the synthetic stand-in.
    pub informative: usize,
    /// Class-conditional signal strength.
    pub signal: f64,
    /// Per-feature signal decay (weak tail features).
    pub decay: f64,
    /// Label-noise flip probability (irreducible error).
    pub flip_prob: f64,
}

/// Table 1 of the paper, plus the stand-in generation parameters.
pub const SPECS: &[DatasetSpec] = &[
    DatasetSpec {
        name: "adult",
        paper_m: 32561,
        paper_n: 123,
        scaled_m: 4000,
        informative: 25,
        signal: 0.55,
        decay: 0.92,
        flip_prob: 0.12,
    },
    DatasetSpec {
        name: "australian",
        paper_m: 683,
        paper_n: 14,
        scaled_m: 683,
        informative: 6,
        signal: 0.8,
        decay: 0.8,
        flip_prob: 0.08,
    },
    DatasetSpec {
        name: "colon-cancer",
        paper_m: 62,
        paper_n: 2000,
        scaled_m: 62,
        informative: 20,
        signal: 0.9,
        decay: 0.9,
        flip_prob: 0.02,
    },
    DatasetSpec {
        name: "german.numer",
        paper_m: 1000,
        paper_n: 24,
        scaled_m: 1000,
        informative: 8,
        signal: 0.45,
        decay: 0.85,
        flip_prob: 0.18,
    },
    DatasetSpec {
        name: "ijcnn1",
        paper_m: 141691,
        paper_n: 22,
        scaled_m: 6000,
        informative: 12,
        signal: 0.6,
        decay: 0.9,
        flip_prob: 0.06,
    },
    DatasetSpec {
        name: "mnist5",
        paper_m: 70000,
        paper_n: 780,
        scaled_m: 3000,
        informative: 60,
        signal: 0.5,
        decay: 0.97,
        flip_prob: 0.03,
    },
];

/// Look up a spec by name.
pub fn spec(name: &str) -> Option<&'static DatasetSpec> {
    SPECS.iter().find(|s| s.name == name)
}

/// All registry names in Table 1 order.
pub fn names() -> Vec<&'static str> {
    SPECS.iter().map(|s| s.name).collect()
}

/// Load a benchmark dataset: real file if present, synthetic stand-in
/// otherwise. `full_size` selects the paper's exact m (slow on 1 CPU).
pub fn load(name: &str, full_size: bool, seed: u64) -> anyhow::Result<Dataset> {
    let s = spec(name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?}"))?;
    let real = std::path::Path::new("data/real").join(format!("{name}.libsvm"));
    if real.exists() {
        let mut ds = libsvm::parse_file(&real, Some(s.paper_n))?;
        ds.name = name.to_string();
        return Ok(ds);
    }
    Ok(generate(s, full_size, seed))
}

/// Generate the synthetic stand-in for a spec (no filesystem probe).
pub fn generate(s: &DatasetSpec, full_size: bool, seed: u64) -> Dataset {
    let m = if full_size { s.paper_m } else { s.scaled_m };
    planted_sparse(
        s.name,
        m,
        s.paper_n,
        s.informative,
        s.signal,
        s.decay,
        s.flip_prob,
        seed ^ fxhash(s.name),
    )
}

/// Tiny stable string hash so each dataset gets an independent stream.
fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_table1() {
        // Table 1 of the paper, verbatim.
        let expected = [
            ("adult", 32561, 123),
            ("australian", 683, 14),
            ("colon-cancer", 62, 2000),
            ("german.numer", 1000, 24),
            ("ijcnn1", 141691, 22),
            ("mnist5", 70000, 780),
        ];
        assert_eq!(SPECS.len(), expected.len());
        for (spec, (name, m, n)) in SPECS.iter().zip(expected) {
            assert_eq!(spec.name, name);
            assert_eq!(spec.paper_m, m, "{name} m");
            assert_eq!(spec.paper_n, n, "{name} n");
        }
    }

    #[test]
    fn load_scaled_shapes() {
        let ds = load("australian", false, 1).unwrap();
        assert_eq!(ds.n_examples(), 683);
        assert_eq!(ds.n_features(), 14);
        let ds = load("colon-cancer", false, 1).unwrap();
        assert_eq!(ds.n_examples(), 62);
        assert_eq!(ds.n_features(), 2000);
    }

    #[test]
    fn unknown_dataset_errors() {
        assert!(load("nope", false, 1).is_err());
    }

    #[test]
    fn distinct_datasets_get_distinct_data() {
        let a = load("adult", false, 1).unwrap();
        let b = load("german.numer", false, 1).unwrap();
        assert_ne!(a.n_examples(), b.n_examples());
        // same seed but different name-hash streams
        assert_ne!(a.x[(0, 0)], b.x[(0, 0)]);
    }

    #[test]
    fn labels_are_plus_minus_one() {
        for name in names() {
            let ds = load(name, false, 2).unwrap();
            assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0), "{name}");
            let frac = ds.positive_fraction();
            assert!((0.3..0.7).contains(&frac), "{name} balance {frac}");
        }
    }

    #[test]
    fn seed_changes_data() {
        let a = load("australian", false, 1).unwrap();
        let b = load("australian", false, 2).unwrap();
        assert!(a.x.max_abs_diff(&b.x) > 0.0);
    }
}
