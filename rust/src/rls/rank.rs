//! RankRLS: pairwise regularized least-squares for learning to rank
//! (paper §5, refs [32, 33] — the authors' own RankRLS line of work:
//! "we also plan to design and implement similar feature selection
//! algorithms for RankRLS").
//!
//! Objective (all-pairs magnitude-preserving ranking loss):
//!
//! ```text
//! argmin_w  Σ_{i<j} ((y_i − y_j) − (f_i − f_j))²  +  λ wᵀw,   f = X_Sᵀ w
//! ```
//!
//! With the centering Laplacian `L = m·I − 1 1ᵀ` this is
//! `‖L(f − y)‖²`-like and has the closed form
//!
//! ```text
//! w = (X_S L X_Sᵀ + λI)⁻¹ X_S L y
//! ```
//!
//! The crucial structural fact used everywhere here: `L v = m·v − (Σv)·1`
//! costs **O(m)**, so all Laplacian products stay linear in m and the
//! primal matrix `M_S = X_S L X_Sᵀ + λI` is only k × k.

use crate::linalg::{dot, Cholesky, Matrix};

/// `L v = m·v − (Σ v)·1` — the all-pairs centering Laplacian applied in
/// O(m) (never materialize the m×m L).
pub fn laplacian_apply(v: &[f64]) -> Vec<f64> {
    let m = v.len() as f64;
    let s: f64 = v.iter().sum();
    v.iter().map(|&x| m * x - s).collect()
}

/// Pairwise squared ranking risk: Σ_{i<j} ((y_i−y_j) − (f_i−f_j))².
/// Computed in O(m) via the identity Σ_{i<j}(d_i−d_j)² = dᵀ L d with
/// d = y − f (the ½ from double counting cancels against L's factor 2).
pub fn pairwise_risk(y: &[f64], f: &[f64]) -> f64 {
    assert_eq!(y.len(), f.len());
    let d: Vec<f64> = y.iter().zip(f).map(|(&a, &b)| a - b).collect();
    let ld = laplacian_apply(&d);
    dot(&d, &ld)
}

/// Fraction of correctly ordered pairs (ties in y skipped; ties in f
/// count half) — the ranking analogue of accuracy.
pub fn pairwise_accuracy(y: &[f64], f: &[f64]) -> f64 {
    assert_eq!(y.len(), f.len());
    let m = y.len();
    let mut correct = 0.0;
    let mut total = 0.0;
    for i in 0..m {
        for j in i + 1..m {
            let dy = y[i] - y[j];
            if dy == 0.0 {
                continue;
            }
            total += 1.0;
            let df = f[i] - f[j];
            if df == 0.0 {
                correct += 0.5;
            } else if dy.signum() == df.signum() {
                correct += 1.0;
            }
        }
    }
    if total > 0.0 {
        correct / total
    } else {
        0.0
    }
}

/// Train RankRLS on the selected-feature matrix `xs` (k × m):
/// `w = (X L Xᵀ + λI)⁻¹ X L y`.
pub fn train_rank(xs: &Matrix, y: &[f64], lambda: f64) -> Vec<f64> {
    let k = xs.rows();
    let m = xs.cols();
    assert_eq!(m, y.len());
    assert!(lambda > 0.0);
    // X L Xᵀ: row i of X L is laplacian_apply(row i) — O(km) total
    let lx: Vec<Vec<f64>> =
        (0..k).map(|i| laplacian_apply(xs.row(i))).collect();
    let mut mmat = Matrix::zeros(k, k);
    for i in 0..k {
        for j in i..k {
            let v = dot(&lx[i], xs.row(j));
            mmat[(i, j)] = v;
            mmat[(j, i)] = v;
        }
    }
    mmat.add_diag(lambda);
    let ly = laplacian_apply(y);
    let rhs: Vec<f64> = (0..k).map(|i| dot(xs.row(i), &ly)).collect();
    Cholesky::factor(&mmat)
        .expect("X L Xᵀ + λI is SPD for λ > 0 (L is PSD)")
        .solve(&rhs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{assert_close, forall_seeds, Gen};

    #[test]
    fn laplacian_matches_dense_form() {
        forall_seeds(10, |seed| {
            let mut g = Gen::new(seed + 40);
            let m = g.size(2, 12);
            let v = g.targets(m);
            let got = laplacian_apply(&v);
            // dense L = m I − 1 1ᵀ
            let s: f64 = v.iter().sum();
            for (j, &gj) in got.iter().enumerate() {
                let want = m as f64 * v[j] - s;
                assert!((gj - want).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn pairwise_risk_matches_naive_double_sum() {
        forall_seeds(10, |seed| {
            let mut g = Gen::new(seed + 41);
            let m = g.size(2, 10);
            let y = g.targets(m);
            let f = g.targets(m);
            let fast = pairwise_risk(&y, &f);
            let mut naive = 0.0;
            for i in 0..m {
                for j in i + 1..m {
                    let d = (y[i] - y[j]) - (f[i] - f[j]);
                    naive += d * d;
                }
            }
            assert!(
                (fast - naive).abs() <= 1e-9 * naive.max(1.0),
                "{fast} vs {naive}"
            );
        });
    }

    #[test]
    fn pairwise_accuracy_bounds_and_perfect_order() {
        let y = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(pairwise_accuracy(&y, &y), 1.0);
        let rev = [4.0, 3.0, 2.0, 1.0];
        assert_eq!(pairwise_accuracy(&y, &rev), 0.0);
        let constant = [0.0; 4];
        assert_eq!(pairwise_accuracy(&y, &constant), 0.5);
    }

    #[test]
    fn train_rank_minimizes_the_objective() {
        // w* must beat random perturbations of itself on the regularized
        // pairwise objective
        let mut g = Gen::new(7);
        let xs = g.matrix(3, 25);
        let y = g.targets(25);
        let lam = 0.5;
        let w = train_rank(&xs, &y, lam);
        let objective = |wv: &[f64]| {
            let f: Vec<f64> = (0..25)
                .map(|j| {
                    let col = xs.col(j);
                    dot(wv, &col)
                })
                .collect();
            pairwise_risk(&y, &f) + lam * dot(wv, wv)
        };
        let base = objective(&w);
        for t in 0..20 {
            let mut g2 = Gen::new(100 + t);
            let wp: Vec<f64> = w
                .iter()
                .map(|&wi| wi + 0.1 * g2.rng.normal())
                .collect();
            assert!(objective(&wp) >= base - 1e-9, "perturbation won");
        }
    }

    #[test]
    fn shift_invariance_of_ranking_solution() {
        // adding a constant to y changes nothing: L annihilates constants
        let mut g = Gen::new(9);
        let xs = g.matrix(4, 15);
        let y = g.targets(15);
        let y_shift: Vec<f64> = y.iter().map(|&v| v + 100.0).collect();
        let w1 = train_rank(&xs, &y, 1.0);
        let w2 = train_rank(&xs, &y_shift, 1.0);
        assert_close(&w1, &w2, 1e-8, "shift invariance");
    }

    #[test]
    fn recovers_true_ranking_feature() {
        // y is a noisy monotone function of feature 0 only
        let mut g = Gen::new(11);
        let m = 60;
        let mut x = g.matrix(5, m);
        let mut y = vec![0.0; m];
        for j in 0..m {
            y[j] = 3.0 * x[(0, j)] + 0.01 * g.rng.normal();
        }
        let _ = &mut x;
        let w = train_rank(&x, &y, 0.1);
        assert!(w[0].abs() > 10.0 * w[1..].iter().fold(0.0f64, |a, &b| a.max(b.abs())));
    }
}
