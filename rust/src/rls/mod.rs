//! Regularized least-squares (RLS / ridge regression / LS-SVM) core.
//!
//! Implements the paper's §2 verbatim:
//!
//! * primal training, eq. (3): `w = (X_S X_Sᵀ + λI)⁻¹ X_S y` — O(|S|²m),
//!   preferred when |S| < m;
//! * dual training, eq. (4): `w = X_S (X_Sᵀ X_S + λI)⁻¹ y` — O(m²|S|),
//!   preferred when m < |S|;
//! * the O(1)-per-example LOO shortcuts, eq. (7) (primal) and eq. (8)
//!   (dual), plus a brute-force LOO used as the test oracle;
//! * a [`Predictor`] type for the sparse learned model (prediction is
//!   O(k) per example, matching the paper's deployment claim).

pub mod kernel;
pub mod rank;

use crate::linalg::{dot, spd_inverse, Cholesky, Matrix};

/// A sparse linear predictor over selected feature indices (paper eq. 1).
#[derive(Clone, Debug)]
pub struct Predictor {
    /// Selected feature indices S (in selection order).
    pub selected: Vec<usize>,
    /// Weights aligned with `selected`.
    pub weights: Vec<f64>,
}

impl Predictor {
    /// Score one example given its **full** feature vector (length n).
    pub fn predict_full(&self, x: &[f64]) -> f64 {
        self.selected
            .iter()
            .zip(&self.weights)
            .map(|(&i, &w)| w * x[i])
            .sum()
    }

    /// Score every column of a feature-major matrix (n × m).
    pub fn predict_matrix(&self, x: &Matrix) -> Vec<f64> {
        self.predict_range(x, 0, x.cols())
    }

    /// Score columns `start..end` of a feature-major matrix without
    /// materializing a sub-matrix — the serving hot loops batch over
    /// column ranges, and copying all n rows per batch to read the ≤ k
    /// selected ones would dominate the batch cost. Accumulation order
    /// per column is identical to [`Predictor::predict_matrix`], so a
    /// range-batched pass is bit-identical to a whole-matrix pass.
    pub fn predict_range(
        &self,
        x: &Matrix,
        start: usize,
        end: usize,
    ) -> Vec<f64> {
        let mut out = vec![0.0; end - start];
        for (&i, &w) in self.selected.iter().zip(&self.weights) {
            let row = &x.row(i)[start..end];
            for (o, &v) in out.iter_mut().zip(row) {
                *o += w * v;
            }
        }
        out
    }

    /// Dense n-length weight vector (zeros off the support).
    pub fn dense_weights(&self, n: usize) -> Vec<f64> {
        let mut w = vec![0.0; n];
        for (&i, &wi) in self.selected.iter().zip(&self.weights) {
            w[i] = wi;
        }
        w
    }
}

/// Primal RLS (eq. 3). `xs` is the selected-feature matrix (|S| × m).
/// Returns the |S|-length weight vector.
pub fn train_primal(xs: &Matrix, y: &[f64], lambda: f64) -> Vec<f64> {
    assert_eq!(xs.cols(), y.len());
    let mut a = xs.gram(); // X Xᵀ, |S| × |S|
    a.add_diag(lambda);
    let rhs = xs.matvec(y); // X y
    Cholesky::factor(&a)
        .expect("X Xᵀ + λI is SPD for λ > 0")
        .solve(&rhs)
}

/// Dual RLS (eq. 4): returns `(w, a)` with `a = (XᵀX + λI)⁻¹ y`, `w = X a`.
pub fn train_dual(xs: &Matrix, y: &[f64], lambda: f64) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(xs.cols(), y.len());
    let mut k = xs.gram_t(); // XᵀX, m × m
    k.add_diag(lambda);
    let a = Cholesky::factor(&k)
        .expect("XᵀX + λI is SPD for λ > 0")
        .solve(y);
    let w = xs.matvec(&a);
    (w, a)
}

/// Automatic form choice, as the paper prescribes: primal when |S| ≤ m.
pub fn train(xs: &Matrix, y: &[f64], lambda: f64) -> Vec<f64> {
    if xs.rows() <= xs.cols() {
        train_primal(xs, y, lambda)
    } else {
        train_dual(xs, y, lambda).0
    }
}

/// Summed LOO loss of the feature subset `s` (rows of feature-major `x`),
/// via the eq. 7/8 shortcut — primal when |s| ≤ m, dual otherwise. The
/// shared criterion of the wrapper-style selectors (floating, FoBa, the
/// random baseline's log).
pub fn loo_subset_criterion(
    x: &Matrix,
    s: &[usize],
    y: &[f64],
    lambda: f64,
    loss: crate::metrics::Loss,
) -> f64 {
    let xs = x.select_rows(s);
    let p = if xs.rows() <= xs.cols() {
        loo_primal(&xs, y, lambda)
    } else {
        loo_dual(&xs, y, lambda)
    };
    loss.total(y, &p)
}

/// LOO predictions via the primal shortcut (eq. 7):
/// `p_j = (1 − q_j)⁻¹ (f_j − q_j y_j)` with
/// `q_j = x_jᵀ (X Xᵀ + λI)⁻¹ x_j` and `f = Xᵀ w`.
pub fn loo_primal(xs: &Matrix, y: &[f64], lambda: f64) -> Vec<f64> {
    let s = xs.rows();
    let m = xs.cols();
    assert_eq!(m, y.len());
    let mut a = xs.gram();
    a.add_diag(lambda);
    let inv = spd_inverse(&a).expect("SPD");
    let w = {
        let rhs = xs.matvec(y);
        inv.matvec(&rhs)
    };
    let f: Vec<f64> = (0..m).map(|j| {
        let mut s_ = 0.0;
        for i in 0..s {
            s_ += w[i] * xs[(i, j)];
        }
        s_
    }).collect();
    (0..m)
        .map(|j| {
            // q_j = x_jᵀ inv x_j with x_j the j-th column of xs
            let xj = xs.col(j);
            let ix = inv.matvec(&xj);
            let q = dot(&xj, &ix);
            (f[j] - q * y[j]) / (1.0 - q)
        })
        .collect()
}

/// LOO predictions via the dual shortcut (eq. 8):
/// `p_j = y_j − a_j / G_jj` with `G = (XᵀX + λI)⁻¹`, `a = G y`.
pub fn loo_dual(xs: &Matrix, y: &[f64], lambda: f64) -> Vec<f64> {
    let m = xs.cols();
    assert_eq!(m, y.len());
    let mut k = xs.gram_t();
    k.add_diag(lambda);
    let g = spd_inverse(&k).expect("SPD");
    let a = g.matvec(y);
    (0..m).map(|j| y[j] - a[j] / g[(j, j)]).collect()
}

/// Brute-force LOO: retrain with example j held out, predict j. The
/// O(m·training) oracle the shortcuts are verified against.
pub fn loo_brute_force(xs: &Matrix, y: &[f64], lambda: f64) -> Vec<f64> {
    let m = xs.cols();
    assert_eq!(m, y.len());
    (0..m)
        .map(|j| {
            let keep: Vec<usize> = (0..m).filter(|&t| t != j).collect();
            let xl = xs.select_cols(&keep);
            let yl: Vec<f64> = keep.iter().map(|&t| y[t]).collect();
            let w = train(&xl, &yl, lambda);
            let xj = xs.col(j);
            dot(&w, &xj)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{assert_close, forall_seeds, Gen};

    #[test]
    fn primal_equals_dual() {
        forall_seeds(25, |seed| {
            let mut g = Gen::new(seed);
            let s = g.size(1, 8);
            let m = g.size(2, 12);
            let lam = g.lambda(-2, 2);
            let xs = g.matrix(s, m);
            let y = g.targets(m);
            let wp = train_primal(&xs, &y, lam);
            let (wd, _) = train_dual(&xs, &y, lam);
            assert_close(&wp, &wd, 1e-8, "primal vs dual");
        });
    }

    #[test]
    fn train_matches_normal_equations() {
        let mut g = Gen::new(7);
        let xs = g.matrix(3, 20);
        let y = g.targets(20);
        let lam = 0.9;
        let w = train(&xs, &y, lam);
        // residual of (X Xᵀ + λI) w − X y must vanish
        let mut a = xs.gram();
        a.add_diag(lam);
        let lhs = a.matvec(&w);
        let rhs = xs.matvec(&y);
        assert_close(&lhs, &rhs, 1e-9, "normal equations");
    }

    #[test]
    fn loo_shortcuts_agree_with_each_other() {
        forall_seeds(25, |seed| {
            let mut g = Gen::new(seed + 1000);
            let s = g.size(1, 6);
            let m = g.size(3, 14);
            let lam = g.lambda(-1, 2);
            let xs = g.matrix(s, m);
            let y = g.targets(m);
            let p7 = loo_primal(&xs, &y, lam);
            let p8 = loo_dual(&xs, &y, lam);
            assert_close(&p7, &p8, 1e-7, "eq7 vs eq8");
        });
    }

    #[test]
    fn loo_shortcuts_equal_brute_force() {
        forall_seeds(15, |seed| {
            let mut g = Gen::new(seed + 2000);
            let s = g.size(1, 5);
            let m = g.size(4, 10);
            let lam = g.lambda(-1, 1);
            let xs = g.matrix(s, m);
            let y = g.targets(m);
            let brute = loo_brute_force(&xs, &y, lam);
            let p7 = loo_primal(&xs, &y, lam);
            let p8 = loo_dual(&xs, &y, lam);
            assert_close(&p7, &brute, 1e-6, "eq7 vs brute");
            assert_close(&p8, &brute, 1e-6, "eq8 vs brute");
        });
    }

    #[test]
    fn heavy_regularization_shrinks_weights() {
        let mut g = Gen::new(3);
        let xs = g.matrix(4, 30);
        let y = g.targets(30);
        let w_small = train(&xs, &y, 1e-3);
        let w_large = train(&xs, &y, 1e6);
        let n_small = crate::linalg::norm2(&w_small);
        let n_large = crate::linalg::norm2(&w_large);
        assert!(n_large < n_small * 1e-2, "{n_large} vs {n_small}");
    }

    #[test]
    fn predictor_predicts_selected_only() {
        let p = Predictor { selected: vec![2, 0], weights: vec![1.5, -0.5] };
        let x = [10.0, 99.0, 2.0, 99.0];
        assert_eq!(p.predict_full(&x), 1.5 * 2.0 - 0.5 * 10.0);
    }

    #[test]
    fn predictor_matrix_matches_pointwise() {
        let mut g = Gen::new(4);
        let x = g.matrix(5, 7);
        let p = Predictor { selected: vec![1, 4], weights: vec![0.3, -2.0] };
        let batch = p.predict_matrix(&x);
        for j in 0..7 {
            let col = x.col(j);
            assert!((batch[j] - p.predict_full(&col)).abs() < 1e-12);
        }
    }

    #[test]
    fn dense_weights_scatter() {
        let p = Predictor { selected: vec![3, 1], weights: vec![2.0, -1.0] };
        assert_eq!(p.dense_weights(5), vec![0.0, -1.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn interpolation_limit() {
        // with tiny λ and more features than examples, training data is fit
        let mut g = Gen::new(5);
        let xs = g.matrix(12, 6);
        let y = g.targets(6);
        let w = train(&xs, &y, 1e-10);
        let f: Vec<f64> = (0..6)
            .map(|j| {
                let col = xs.col(j);
                dot(&w, &col)
            })
            .collect();
        assert_close(&f, &y, 1e-4, "interpolation");
    }
}
