//! Kernel functions and kernel RLS (substrate for reduced-set selection).
//!
//! The paper's §5 points at "reduced set selection used in context of
//! kernel-based learning algorithms" and center selection for RBF
//! networks as the natural next applications of the greedy machinery.
//! This module provides the kernel substrate: standard kernels, kernel
//! matrix assembly, and full (non-sparse) kernel RLS as the reference
//! the reduced-set selector ([`crate::select::centers`]) is compared to.

use crate::linalg::{dot, Cholesky, Matrix};

/// Kernel function over column-vector examples.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// ⟨x, z⟩
    Linear,
    /// exp(−γ‖x − z‖²)
    Rbf { gamma: f64 },
    /// (⟨x, z⟩ + coef)^degree
    Poly { degree: i32, coef: f64 },
}

impl Kernel {
    /// k(x, z) for two example vectors.
    pub fn eval(&self, x: &[f64], z: &[f64]) -> f64 {
        match *self {
            Kernel::Linear => dot(x, z),
            Kernel::Rbf { gamma } => {
                let d2: f64 = x
                    .iter()
                    .zip(z)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                (-gamma * d2).exp()
            }
            Kernel::Poly { degree, coef } => (dot(x, z) + coef).powi(degree),
        }
    }

    /// Kernel matrix between the columns of two feature-major matrices:
    /// `out[i][j] = k(a_i, b_j)` where `a_i` is column i of `a`.
    pub fn matrix(&self, a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.rows(), b.rows(), "feature dimension mismatch");
        let (ma, mb) = (a.cols(), b.cols());
        let mut out = Matrix::zeros(ma, mb);
        // columns are strided; copy once per outer index
        for i in 0..ma {
            let ai = a.col(i);
            for j in 0..mb {
                let bj = b.col(j);
                out[(i, j)] = self.eval(&ai, &bj);
            }
        }
        out
    }

    /// Symmetric kernel matrix of one dataset (exploits symmetry).
    pub fn gram(&self, a: &Matrix) -> Matrix {
        let m = a.cols();
        let mut out = Matrix::zeros(m, m);
        let cols: Vec<Vec<f64>> = (0..m).map(|i| a.col(i)).collect();
        for i in 0..m {
            for j in i..m {
                let v = self.eval(&cols[i], &cols[j]);
                out[(i, j)] = v;
                out[(j, i)] = v;
            }
        }
        out
    }
}

/// Full (dense) kernel RLS model: a = (K + λI)⁻¹ y.
#[derive(Clone, Debug)]
pub struct KernelRls {
    /// Kernel used at train time.
    pub kernel: Kernel,
    /// Dual coefficients, one per training example.
    pub alpha: Vec<f64>,
    /// Training examples (feature-major) retained for prediction.
    pub train_x: Matrix,
}

impl KernelRls {
    /// Fit on feature-major `x` (n × m) with labels `y`.
    pub fn fit(x: &Matrix, y: &[f64], kernel: Kernel, lambda: f64) -> Self {
        assert_eq!(x.cols(), y.len());
        assert!(lambda > 0.0);
        let mut k = kernel.gram(x);
        k.add_diag(lambda);
        let alpha = Cholesky::factor(&k)
            .expect("K + λI SPD for λ>0 and PSD kernels")
            .solve(y);
        KernelRls { kernel, alpha, train_x: x.clone() }
    }

    /// Predict every column of `x_test`.
    pub fn predict(&self, x_test: &Matrix) -> Vec<f64> {
        let kt = self.kernel.matrix(x_test, &self.train_x); // (mt × m)
        kt.matvec(&self.alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{assert_close, Gen};

    #[test]
    fn linear_kernel_is_dot() {
        assert_eq!(Kernel::Linear.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    fn rbf_kernel_properties() {
        let k = Kernel::Rbf { gamma: 0.5 };
        assert!((k.eval(&[1.0, 2.0], &[1.0, 2.0]) - 1.0).abs() < 1e-15);
        let far = k.eval(&[0.0, 0.0], &[10.0, 10.0]);
        assert!(far < 1e-10);
        // symmetry
        let a = [0.3, -0.7];
        let b = [1.1, 0.2];
        assert_eq!(k.eval(&a, &b), k.eval(&b, &a));
    }

    #[test]
    fn poly_kernel_known_value() {
        let k = Kernel::Poly { degree: 2, coef: 1.0 };
        // (1*2 + 1)^2 = 9
        assert_eq!(k.eval(&[1.0], &[2.0]), 9.0);
    }

    #[test]
    fn gram_matches_pairwise_matrix() {
        let mut g = Gen::new(1);
        let x = g.matrix(3, 6);
        for kernel in [
            Kernel::Linear,
            Kernel::Rbf { gamma: 0.3 },
            Kernel::Poly { degree: 3, coef: 0.5 },
        ] {
            let gram = kernel.gram(&x);
            let full = kernel.matrix(&x, &x);
            assert!(gram.max_abs_diff(&full) < 1e-12);
        }
    }

    #[test]
    fn linear_kernel_rls_equals_linear_rls() {
        // with the linear kernel, kernel RLS = dual linear RLS
        let mut g = Gen::new(2);
        let x = g.matrix(4, 9);
        let y = g.targets(9);
        let lam = 0.8;
        let model = KernelRls::fit(&x, &y, Kernel::Linear, lam);
        let preds = model.predict(&x);
        let (w, _) = crate::rls::train_dual(&x, &y, lam);
        let direct: Vec<f64> = (0..9)
            .map(|j| {
                let col = x.col(j);
                crate::linalg::dot(&w, &col)
            })
            .collect();
        assert_close(&preds, &direct, 1e-8, "linear-kernel RLS");
    }

    #[test]
    fn rbf_rls_interpolates_with_tiny_lambda() {
        let mut g = Gen::new(3);
        let x = g.matrix(2, 12);
        let y = g.targets(12);
        let model = KernelRls::fit(&x, &y, Kernel::Rbf { gamma: 1.0 }, 1e-10);
        let preds = model.predict(&x);
        assert_close(&preds, &y, 1e-4, "interpolation");
    }
}
