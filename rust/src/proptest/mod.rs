//! Minimal property-based testing support.
//!
//! The offline crate cache has no `proptest`/`quickcheck`, so this module
//! provides the small subset the test suite needs: a seeded case runner
//! with failure reporting including the failing seed, plus generators for
//! the problem shapes used throughout (random matrices, labels, λ grids).
//!
//! Usage (runs under `cargo test` like every doctest in this crate —
//! the default build is pure Rust and links nothing external; for
//! `--features pjrt` test runs the xla shared library must be on the
//! loader path, since rustdoc test binaries don't inherit the
//! workspace rpath):
//! ```
//! use greedy_rls::proptest::forall_seeds;
//! forall_seeds(64, |seed| {
//!     assert!(seed < 64); // property under test
//! });
//! ```

#[cfg(test)]
mod sketch_props;

use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// Run `prop` for `cases` deterministic seeds; panics with the failing
/// seed so the case can be replayed directly.
pub fn forall_seeds<F: Fn(u64) + std::panic::RefUnwindSafe>(cases: u64, prop: F) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(|| prop(seed));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Problem-shape generator shared by equivalence/property tests.
pub struct Gen {
    /// Underlying deterministic stream (exposed so tests can draw extra
    /// values — labels, permutations — from the same seed).
    pub rng: Pcg64,
}

impl Gen {
    /// Generator on a fixed stream derived from `seed`.
    pub fn new(seed: u64) -> Self {
        Gen { rng: Pcg64::new(seed, 101) }
    }

    /// Random size in [lo, hi].
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// Random λ on a log grid spanning [10^lo, 10^hi].
    pub fn lambda(&mut self, lo: i32, hi: i32) -> f64 {
        10f64.powf(self.rng.uniform_range(lo as f64, hi as f64))
    }

    /// Standard-normal matrix.
    pub fn matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| self.rng.normal()).collect(),
        )
    }

    /// ±1 labels.
    pub fn labels(&mut self, m: usize) -> Vec<f64> {
        (0..m).map(|_| self.rng.sign()).collect()
    }

    /// Real-valued targets.
    pub fn targets(&mut self, m: usize) -> Vec<f64> {
        (0..m).map(|_| self.rng.normal()).collect()
    }
}

/// Assert two slices are element-wise close.
#[track_caller]
pub fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0_f64.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}[{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_every_seed() {
        let hits = std::sync::atomic::AtomicU64::new(0);
        forall_seeds(10, |_| {
            hits.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(hits.load(std::sync::atomic::Ordering::SeqCst), 10);
    }

    #[test]
    #[should_panic(expected = "property failed at seed 3")]
    fn forall_reports_failing_seed() {
        forall_seeds(10, |seed| {
            assert!(seed != 3, "boom");
        });
    }

    #[test]
    fn gen_sizes_in_range() {
        let mut g = Gen::new(0);
        for _ in 0..100 {
            let s = g.size(3, 7);
            assert!((3..=7).contains(&s));
        }
    }

    #[test]
    fn gen_lambda_in_decade_range() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let l = g.lambda(-2, 2);
            assert!((0.01..=100.0).contains(&l));
        }
    }

    #[test]
    fn labels_are_signs() {
        let mut g = Gen::new(2);
        assert!(g.labels(50).iter().all(|&v| v.abs() == 1.0));
    }

    #[test]
    fn assert_close_passes_within_tol() {
        assert_close(&[1.0, 2.0], &[1.0 + 1e-12, 2.0], 1e-9, "ok");
    }

    #[test]
    #[should_panic]
    fn assert_close_fails_outside_tol() {
        assert_close(&[1.0], &[1.1], 1e-9, "bad");
    }
}
