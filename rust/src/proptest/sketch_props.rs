//! Property tests for the sketched-preselection leverage scores
//! (`crate::select::sketch`), run through the seeded in-house harness.
//!
//! The exact path (`sketch_dim == 0`) is the mathematical reference:
//! τ_i = x_iᵀ (XᵀX + λI)⁻¹ x_i. The properties below pin the facts the
//! filter relies on — nonnegativity, the effective-dimension sum
//! identity, permutation equivariance, and monotonicity under
//! duplicated features — with tolerances, since float summation order
//! over features legitimately differs between algebraically equal
//! computations.

use super::{assert_close, forall_seeds, Gen};
use crate::kernel::KernelKind;
use crate::linalg::{spd_inverse, Matrix};
use crate::select::sketch::{leverage_scores, top_p, PreselectConfig};

fn ps(p: usize, d: usize, seed: u64) -> PreselectConfig {
    PreselectConfig { p, sketch_dim: d, seed }
}

fn scores(x: &Matrix, lambda: f64, d: usize, seed: u64) -> Vec<f64> {
    leverage_scores(x, lambda, &ps(1, d, seed), 1, KernelKind::Scalar)
        .expect("leverage scores on a finite matrix")
}

/// λ·tr((XᵀX + λI)⁻¹), computed independently of the sketch module.
fn lambda_trace_kinv(x: &Matrix, lambda: f64) -> f64 {
    let m = x.cols();
    let mut k = Matrix::zeros(m, m);
    for i in 0..x.rows() {
        let xi = x.row(i);
        for r in 0..m {
            for q in 0..m {
                k.row_mut(r)[q] += xi[r] * xi[q];
            }
        }
    }
    k.add_diag(lambda);
    let kinv = spd_inverse(&k).expect("ridge Gram is SPD");
    lambda * (0..m).map(|r| kinv.row(r)[r]).sum::<f64>()
}

#[test]
fn scores_are_nonnegative_and_finite_on_both_paths() {
    forall_seeds(24, |seed| {
        let mut g = Gen::new(seed);
        let n = g.size(3, 14);
        let m = g.size(2, 9);
        let lambda = g.lambda(-2, 2);
        let x = g.matrix(n, m);
        for d in [0, 1, n / 2, n] {
            let t = scores(&x, lambda, d, seed);
            assert_eq!(t.len(), n);
            assert!(
                t.iter().all(|&v| v >= 0.0 && v.is_finite()),
                "d={d}: {t:?}"
            );
        }
    });
}

#[test]
fn exact_scores_sum_to_the_effective_dimension() {
    // Σ_i τ_i = tr(XᵀX (XᵀX + λI)⁻¹) = m − λ·tr((XᵀX + λI)⁻¹), and is
    // bounded by min(n, m) — the paper-side meaning of the filter: the
    // scores budget exactly d_eff "important feature" slots.
    forall_seeds(24, |seed| {
        let mut g = Gen::new(seed);
        let n = g.size(3, 14);
        let m = g.size(2, 9);
        let lambda = g.lambda(-2, 2);
        let x = g.matrix(n, m);
        let sum: f64 = scores(&x, lambda, 0, seed).iter().sum();
        let d_eff = m as f64 - lambda_trace_kinv(&x, lambda);
        assert_close(&[sum], &[d_eff], 1e-8, "sum vs d_eff");
        assert!(sum <= (n.min(m) as f64) + 1e-8, "sum {sum} > min(n,m)");
    });
}

#[test]
fn exact_scores_are_permutation_equivariant() {
    forall_seeds(24, |seed| {
        let mut g = Gen::new(seed);
        let n = g.size(4, 12);
        let m = g.size(2, 8);
        let lambda = g.lambda(-1, 1);
        let x = g.matrix(n, m);
        let mut perm: Vec<usize> = (0..n).collect();
        g.rng.shuffle(&mut perm);
        let rows: Vec<&[f64]> = perm.iter().map(|&i| x.row(i)).collect();
        let xp = Matrix::from_rows(&rows);

        let t = scores(&x, lambda, 0, seed);
        let tp = scores(&xp, lambda, 0, seed);
        let expected: Vec<f64> = perm.iter().map(|&i| t[i]).collect();
        assert_close(&tp, &expected, 1e-9, "permuted scores");

        // Equivariant top-p: when the selection boundary is not a
        // float-level tie, the survivor sets map through the
        // permutation. Degenerate draws (near-tied boundary) are
        // skipped — the tie rule is index-based and permuting indices
        // legitimately changes which of two equal scores survives.
        let p = 1 + g.size(0, n - 2);
        let mut sorted = t.clone();
        sorted.sort_by(|a, b| b.total_cmp(a));
        if sorted[p - 1] - sorted[p] > 1e-6 {
            let mut mapped: Vec<usize> =
                top_p(&tp, p).iter().map(|&j| perm[j]).collect();
            mapped.sort_unstable();
            assert_eq!(mapped, top_p(&t, p), "survivor sets diverged");
        }
    });
}

#[test]
fn exact_scores_weakly_decrease_under_duplicated_features() {
    // Appending a copy of any feature row grows XᵀX by a PSD term, so
    // (XᵀX + λI)⁻¹ shrinks in the Loewner order and every score can
    // only go down — duplicated information never inflates importance.
    forall_seeds(24, |seed| {
        let mut g = Gen::new(seed);
        let n = g.size(3, 10);
        let m = g.size(2, 8);
        let lambda = g.lambda(-1, 1);
        let x = g.matrix(n, m);
        let dup = g.size(0, n - 1);
        let mut rows: Vec<&[f64]> = (0..n).map(|i| x.row(i)).collect();
        rows.push(x.row(dup));
        let xd = Matrix::from_rows(&rows);

        let t = scores(&x, lambda, 0, seed);
        let td = scores(&xd, lambda, 0, seed);
        for i in 0..n {
            assert!(
                td[i] <= t[i] + 1e-9,
                "score {i} grew after duplication: {} -> {}",
                t[i],
                td[i]
            );
        }
        // and the two copies agree with each other exactly in math,
        // to float tolerance in practice
        assert_close(&[td[n]], &[td[dup]], 1e-9, "duplicate pair");
    });
}

#[test]
fn sketched_path_matches_exact_oracle_on_tiny_matrices() {
    // On a 2-feature problem a d >= n sketch takes the exact path, and
    // the hand-computable oracle from the sketch module's unit tests
    // pins both: rows (1, 0) and (0, 2) at λ = 1 give τ = (1/2, 4/5).
    let x = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
    for d in [0, 2, 5] {
        let t = scores(&x, 1.0, d, 9);
        assert_close(&t, &[0.5, 0.8], 1e-12, "oracle");
    }
}
