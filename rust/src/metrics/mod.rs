//! Losses and evaluation metrics.
//!
//! The paper evaluates selection with two losses (squared for regression,
//! zero-one for classification) and reports test-set classification
//! accuracy averaged over stratified ten-fold cross-validation.

/// Per-example loss used as the LOO selection criterion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loss {
    /// `(y - p)^2` — regression.
    Squared,
    /// `[y * p <= 0]` — binary classification with ±1 labels; a raw
    /// prediction of exactly 0 counts as an error (matches the kernels).
    ZeroOne,
}

impl Loss {
    /// Loss of one prediction.
    #[inline]
    pub fn eval(&self, y: f64, p: f64) -> f64 {
        match self {
            Loss::Squared => {
                let r = y - p;
                r * r
            }
            Loss::ZeroOne => {
                if y * p > 0.0 {
                    0.0
                } else {
                    1.0
                }
            }
        }
    }

    /// Summed loss over a batch.
    pub fn total(&self, y: &[f64], p: &[f64]) -> f64 {
        assert_eq!(y.len(), p.len());
        y.iter().zip(p).map(|(&yi, &pi)| self.eval(yi, pi)).sum()
    }
}

impl std::str::FromStr for Loss {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "squared" | "sq" | "regression" => Ok(Loss::Squared),
            "zeroone" | "01" | "classification" => Ok(Loss::ZeroOne),
            other => Err(format!("unknown loss {other:?}")),
        }
    }
}

/// Fraction of sign-correct predictions (±1 labels).
pub fn accuracy(y: &[f64], p: &[f64]) -> f64 {
    assert_eq!(y.len(), p.len());
    if y.is_empty() {
        return 0.0;
    }
    let correct = y
        .iter()
        .zip(p)
        .filter(|(&yi, &pi)| yi * pi > 0.0)
        .count();
    correct as f64 / y.len() as f64
}

/// Mean squared error.
pub fn mse(y: &[f64], p: &[f64]) -> f64 {
    assert_eq!(y.len(), p.len());
    if y.is_empty() {
        return 0.0;
    }
    y.iter().zip(p).map(|(&a, &b)| (a - b) * (a - b)).sum::<f64>()
        / y.len() as f64
}

/// Mean and sample standard deviation of a series (figure error bars).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
        / (xs.len() - 1) as f64;
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squared_loss() {
        assert_eq!(Loss::Squared.eval(1.0, 0.5), 0.25);
        assert_eq!(Loss::Squared.eval(-1.0, -1.0), 0.0);
    }

    #[test]
    fn zero_one_loss() {
        assert_eq!(Loss::ZeroOne.eval(1.0, 2.0), 0.0);
        assert_eq!(Loss::ZeroOne.eval(1.0, -0.1), 1.0);
        assert_eq!(Loss::ZeroOne.eval(-1.0, -3.0), 0.0);
        // exactly-zero prediction counts as an error (kernel convention)
        assert_eq!(Loss::ZeroOne.eval(1.0, 0.0), 1.0);
        assert_eq!(Loss::ZeroOne.eval(-1.0, 0.0), 1.0);
    }

    #[test]
    fn total_sums() {
        let y = [1.0, -1.0, 1.0];
        let p = [0.5, 0.5, -0.5];
        assert_eq!(Loss::ZeroOne.total(&y, &p), 2.0);
    }

    #[test]
    fn accuracy_counts_signs() {
        let y = [1.0, -1.0, 1.0, -1.0];
        let p = [2.0, -0.5, -1.0, 0.0];
        assert_eq!(accuracy(&y, &p), 0.5);
    }

    #[test]
    fn accuracy_empty_is_zero() {
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn mse_known() {
        assert!((mse(&[1.0, 2.0], &[2.0, 0.0]) - 2.5).abs() < 1e-15);
    }

    #[test]
    fn mean_std_known() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-15);
        assert!((s - 1.0).abs() < 1e-15);
    }

    #[test]
    fn loss_parses() {
        assert_eq!("squared".parse::<Loss>().unwrap(), Loss::Squared);
        assert_eq!("01".parse::<Loss>().unwrap(), Loss::ZeroOne);
        assert!("bogus".parse::<Loss>().is_err());
    }
}
