//! Scalar f64 kernels — the **bit-exact reference** implementation.
//!
//! Every function in this module is the literal inner loop the engines
//! ran before the kernel tier existed (moved here verbatim from
//! `select::greedy`, `select::backward`, `select::nfold`, and
//! `parallel`): the pairing, unroll factors, accumulator layout, and
//! summation order are frozen. The SIMD module ([`super::simd`]) must
//! reproduce these outputs bit-for-bit; the mixed-precision module
//! ([`super::f32c`]) is tolerance-gated against them. Do not "clean up"
//! the arithmetic here — the operation sequence *is* the contract.

use crate::metrics::Loss;

// ---------------------------------------------------------------------------
// Greedy forward scan (Algorithm 3 lines 8–17)
// ---------------------------------------------------------------------------

/// Score one candidate: the O(m) inner body of the greedy scan. Two
/// fused passes over (v, c): pass 1 accumulates v·c and v·a; pass 2
/// accumulates the LOO loss.
#[inline]
pub fn score_one(
    v: &[f64],
    c: &[f64],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
) -> f64 {
    // Fused pass 1: vc = v·c and va = v·a in one stream over v
    // (iterator zips elide the bounds checks; 2 accumulator pairs keep
    // the FMA ports busy).
    let m = y.len();
    let (mut vc0, mut vc1, mut va0, mut va1) = (0.0, 0.0, 0.0, 0.0);
    let mut it = v.chunks_exact(2).zip(c.chunks_exact(2)).zip(a.chunks_exact(2));
    for ((vv, cc), aa) in &mut it {
        vc0 += vv[0] * cc[0];
        vc1 += vv[1] * cc[1];
        va0 += vv[0] * aa[0];
        va1 += vv[1] * aa[1];
    }
    let (mut vc, mut va) = (vc0 + vc1, va0 + va1);
    if m % 2 == 1 {
        vc += v[m - 1] * c[m - 1];
        va += v[m - 1] * a[m - 1];
    }
    // One reciprocal for the whole candidate (divisions are the hot-path
    // bottleneck on this core — see EXPERIMENTS.md §Perf).
    let inv_denom = 1.0 / (1.0 + vc);
    let s = va * inv_denom; // u_j · va = c_j · s
    loss_pass(c, a, d, y, loss, inv_denom, s)
}

/// Pass 2 of [`score_one`]: accumulate the LOO loss given the
/// candidate's `inv_denom` and `s = va · inv_denom`. Split out so the
/// SIMD kernel can share the exact serial accumulation for the phases
/// it does not vectorize.
#[inline]
pub(super) fn loss_pass(
    c: &[f64],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
    inv_denom: f64,
    s: f64,
) -> f64 {
    match loss {
        Loss::Squared => {
            // residual y − p = ã/d̃ — a single division per example
            let mut e = 0.0;
            for ((&cj, &aj), &dj) in c.iter().zip(a).zip(d) {
                let at = aj - cj * s;
                let dt = dj - cj * cj * inv_denom;
                let r = at / dt;
                e += r * r;
            }
            e
        }
        Loss::ZeroOne => {
            // division-free: d̃ = diag of an SPD inverse is positive, so
            //   y·p ≤ 0  ⟺  1 − y·ã/d̃ ≤ 0  ⟺  y·ã ≥ d̃
            let mut e = 0.0;
            for (((&cj, &aj), &dj), &yj) in
                c.iter().zip(a).zip(d).zip(y)
            {
                let at = aj - cj * s;
                let dt = dj - cj * cj * inv_denom;
                if yj * at >= dt {
                    e += 1.0;
                }
            }
            e
        }
    }
}

/// Score four candidates in one fused pass: the shared `a`, `d`, `y`
/// streams are read once for the whole quad. Numerically identical to
/// four [`score_one`] calls (same operation order per candidate).
pub fn score_quad(
    v: [&[f64]; 4],
    c: [&[f64]; 4],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
) -> [f64; 4] {
    let m = y.len();
    // pass 1: vc_t = v_t·c_t, va_t = v_t·a
    let mut vc = [0.0f64; 4];
    let mut va = [0.0f64; 4];
    for j in 0..m {
        let aj = a[j];
        for t in 0..4 {
            vc[t] += v[t][j] * c[t][j];
            va[t] += v[t][j] * aj;
        }
    }
    let mut inv_denom = [0.0f64; 4];
    let mut s = [0.0f64; 4];
    for t in 0..4 {
        inv_denom[t] = 1.0 / (1.0 + vc[t]);
        s[t] = va[t] * inv_denom[t];
    }
    // pass 2: loss accumulation, a/d/y loaded once per j
    let mut e = [0.0f64; 4];
    match loss {
        Loss::Squared => {
            for j in 0..m {
                let (aj, dj) = (a[j], d[j]);
                for t in 0..4 {
                    let cj = c[t][j];
                    let at = aj - cj * s[t];
                    let dt = dj - cj * cj * inv_denom[t];
                    let r = at / dt;
                    e[t] += r * r;
                }
            }
        }
        Loss::ZeroOne => {
            for j in 0..m {
                let (aj, dj, yj) = (a[j], d[j], y[j]);
                for t in 0..4 {
                    let cj = c[t][j];
                    let at = aj - cj * s[t];
                    let dt = dj - cj * cj * inv_denom[t];
                    if yj * at >= dt {
                        e[t] += 1.0;
                    }
                }
            }
        }
    }
    e
}

/// Tiled variant of [`score_one`]: walks the example axis in `tile`
/// wide blocks while **carrying the untiled kernel's accumulators
/// across tiles**, so the floating-point operation sequence — pairing,
/// summation order, the post-combine odd tail — is literally the serial
/// one and the result is bit-identical for every `tile` (a multiple of
/// 8, which keeps each tile start even so the pair walk never straddles
/// a boundary).
pub fn score_one_tiled(
    v: &[f64],
    c: &[f64],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
    tile: usize,
) -> f64 {
    debug_assert!(tile >= 8 && tile % 8 == 0, "tile must be a multiple of 8");
    let m = y.len();
    // pass 1: same 2-pair accumulators as score_one, carried across
    // tiles; tiles have even length except possibly the last, so the
    // pair grouping matches the untiled chunks_exact(2) walk.
    let (mut vc0, mut vc1, mut va0, mut va1) = (0.0, 0.0, 0.0, 0.0);
    let mut j0 = 0;
    while j0 < m {
        let j1 = (j0 + tile).min(m);
        let mut it = v[j0..j1]
            .chunks_exact(2)
            .zip(c[j0..j1].chunks_exact(2))
            .zip(a[j0..j1].chunks_exact(2));
        for ((vv, cc), aa) in &mut it {
            vc0 += vv[0] * cc[0];
            vc1 += vv[1] * cc[1];
            va0 += vv[0] * aa[0];
            va1 += vv[1] * aa[1];
        }
        j0 = j1;
    }
    let (mut vc, mut va) = (vc0 + vc1, va0 + va1);
    if m % 2 == 1 {
        vc += v[m - 1] * c[m - 1];
        va += v[m - 1] * a[m - 1];
    }
    let inv_denom = 1.0 / (1.0 + vc);
    let s = va * inv_denom;
    loss_pass_tiled(c, a, d, y, loss, inv_denom, s, tile)
}

/// Pass 2 of [`score_one_tiled`] (shared with the SIMD kernel): the
/// per-example bodies are identical to [`loss_pass`], visited in the
/// same `j` order — tiling only changes slice boundaries.
#[allow(clippy::too_many_arguments)]
#[inline]
pub(super) fn loss_pass_tiled(
    c: &[f64],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
    inv_denom: f64,
    s: f64,
    tile: usize,
) -> f64 {
    let m = y.len();
    match loss {
        Loss::Squared => {
            let mut e = 0.0;
            let mut j0 = 0;
            while j0 < m {
                let j1 = (j0 + tile).min(m);
                for ((&cj, &aj), &dj) in
                    c[j0..j1].iter().zip(&a[j0..j1]).zip(&d[j0..j1])
                {
                    let at = aj - cj * s;
                    let dt = dj - cj * cj * inv_denom;
                    let r = at / dt;
                    e += r * r;
                }
                j0 = j1;
            }
            e
        }
        Loss::ZeroOne => {
            let mut e = 0.0;
            let mut j0 = 0;
            while j0 < m {
                let j1 = (j0 + tile).min(m);
                for (((&cj, &aj), &dj), &yj) in c[j0..j1]
                    .iter()
                    .zip(&a[j0..j1])
                    .zip(&d[j0..j1])
                    .zip(&y[j0..j1])
                {
                    let at = aj - cj * s;
                    let dt = dj - cj * cj * inv_denom;
                    if yj * at >= dt {
                        e += 1.0;
                    }
                }
                j0 = j1;
            }
            e
        }
    }
}

/// Tiled variant of [`score_quad`]: the per-`j` bodies and the
/// `vc`/`va`/`e` accumulators are the untiled quad kernel's, visited in
/// the same order with the accumulators carried across tiles — bit-
/// identical to it (and hence to four [`score_one`] calls) for every
/// tile width.
pub fn score_quad_tiled(
    v: [&[f64]; 4],
    c: [&[f64]; 4],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
    tile: usize,
) -> [f64; 4] {
    debug_assert!(tile >= 8 && tile % 8 == 0, "tile must be a multiple of 8");
    let m = y.len();
    let mut vc = [0.0f64; 4];
    let mut va = [0.0f64; 4];
    let mut j0 = 0;
    while j0 < m {
        let j1 = (j0 + tile).min(m);
        for j in j0..j1 {
            let aj = a[j];
            for t in 0..4 {
                vc[t] += v[t][j] * c[t][j];
                va[t] += v[t][j] * aj;
            }
        }
        j0 = j1;
    }
    let mut inv_denom = [0.0f64; 4];
    let mut s = [0.0f64; 4];
    for t in 0..4 {
        inv_denom[t] = 1.0 / (1.0 + vc[t]);
        s[t] = va[t] * inv_denom[t];
    }
    let mut e = [0.0f64; 4];
    match loss {
        Loss::Squared => {
            let mut j0 = 0;
            while j0 < m {
                let j1 = (j0 + tile).min(m);
                for j in j0..j1 {
                    let (aj, dj) = (a[j], d[j]);
                    for t in 0..4 {
                        let cj = c[t][j];
                        let at = aj - cj * s[t];
                        let dt = dj - cj * cj * inv_denom[t];
                        let r = at / dt;
                        e[t] += r * r;
                    }
                }
                j0 = j1;
            }
        }
        Loss::ZeroOne => {
            let mut j0 = 0;
            while j0 < m {
                let j1 = (j0 + tile).min(m);
                for j in j0..j1 {
                    let (aj, dj, yj) = (a[j], d[j], y[j]);
                    for t in 0..4 {
                        let cj = c[t][j];
                        let at = aj - cj * s[t];
                        let dt = dj - cj * cj * inv_denom[t];
                        if yj * at >= dt {
                            e[t] += 1.0;
                        }
                    }
                }
                j0 = j1;
            }
        }
    }
    e
}

// ---------------------------------------------------------------------------
// Rank-1 cache downdate (Algorithm 3 lines 23–30, and the backward /
// n-fold mirror images)
// ---------------------------------------------------------------------------

/// The fused serial a/d downdate of a commit/removal:
/// `a[j] += sign·u[j]·va; d[j] += sign·u[j]·cb[j]` for every example j.
/// `sign` is `-1.0` for the forward commit and `+1.0` for backward
/// elimination's sign-flipped removal; the negation is exact in IEEE
/// 754, so both directions match their historical fused loops bit-for-
/// bit.
#[inline]
pub fn update_ad(
    a: &mut [f64],
    d: &mut [f64],
    u: &[f64],
    cb: &[f64],
    va: f64,
    sign: f64,
) {
    let sva = sign * va;
    for j in 0..a.len() {
        a[j] += u[j] * sva;
        d[j] += sign * (u[j] * cb[j]);
    }
}

/// The a-only variant of [`update_ad`] (the n-fold engine maintains
/// fold blocks instead of `d`).
#[inline]
pub fn update_a(a: &mut [f64], u: &[f64], va: f64, sign: f64) {
    let sva = sign * va;
    for (aj, &uj) in a.iter_mut().zip(u) {
        *aj += uj * sva;
    }
}

/// Per-row body of the SMW rank-1 cache update:
/// `w = v·row; if w ≠ 0 { row ← row + sign·w·u }`. The dot runs the
/// 4-way-unrolled [`crate::linalg::dot`]; the update is elementwise.
#[inline]
pub fn rank1_update_row(row: &mut [f64], v: &[f64], u: &[f64], sign: f64) {
    let w = crate::linalg::dot(v, row);
    if w != 0.0 {
        let sw = sign * w;
        for (r, &uj) in row.iter_mut().zip(u) {
            *r += sw * uj;
        }
    }
}

/// [`rank1_update_row`] evaluated in column tiles of `tile` elements (a
/// positive multiple of 4): the dot pass carries its four partial sums
/// across tiles ([`crate::linalg::dot_tiled`]) and the update pass
/// walks the same tiles elementwise. Both phases perform literally the
/// serial operation sequence, so results are bit-identical to the
/// untiled update for every tile width.
#[inline]
pub fn rank1_update_row_tiled(
    row: &mut [f64],
    v: &[f64],
    u: &[f64],
    sign: f64,
    tile: usize,
) {
    debug_assert!(tile > 0 && tile % 4 == 0, "tile must be a multiple of 4");
    let row_len = row.len();
    let w = crate::linalg::dot_tiled(v, row, tile);
    if w != 0.0 {
        let sw = sign * w;
        let mut j0 = 0;
        while j0 < row_len {
            let j1 = (j0 + tile).min(row_len);
            for (r, &uj) in row[j0..j1].iter_mut().zip(&u[j0..j1]) {
                *r += sw * uj;
            }
            j0 = j1;
        }
    }
}

// ---------------------------------------------------------------------------
// Backward elimination (sign-flipped SMW, paper §5)
// ---------------------------------------------------------------------------

/// Pass 2 of backward elimination's removal score: given `va = v·a` and
/// the removal denominator `denom = 1 − v·c`, accumulate the LOO loss
/// of S \ {i} over every example. Moved verbatim from
/// `BackState::removal_score`.
#[inline]
pub fn removal_loss(
    c: &[f64],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
    va: f64,
    denom: f64,
) -> f64 {
    let mut e = 0.0;
    for j in 0..y.len() {
        let u = c[j] / denom;
        let at = a[j] + u * va;
        let dt = d[j] + u * c[j];
        let p = y[j] - at / dt;
        e += loss.eval(y[j], p);
    }
    e
}

// ---------------------------------------------------------------------------
// n-fold CV criterion (paper §5)
// ---------------------------------------------------------------------------

/// One fold's tentative SMW downdate for the n-fold scan: for fold
/// members `h`, compute `ã_H = a_H − u_H·va` into `at` and
/// `B̃ = B − u_H c_Hᵀ` into `bt` (row-major |H|×|H|), with
/// `u_r = c[h[r]] / denom`. Moved verbatim from
/// `NFoldState::score_one`'s inner loop.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn fold_tilde(
    c: &[f64],
    a: &[f64],
    h: &[usize],
    block: &[f64],
    denom: f64,
    va: f64,
    at: &mut [f64],
    bt: &mut [f64],
) {
    let s = h.len();
    for (r, &jr) in h.iter().enumerate() {
        let u_r = c[jr] / denom;
        at[r] = a[jr] - u_r * va;
        for (t, &jt) in h.iter().enumerate() {
            bt[r * s + t] = block[r * s + t] - u_r * c[jt];
        }
    }
}

/// Commit-time fold-block downdate of the n-fold engine:
/// `B_h[r,t] −= u[h[r]]·cb[h[t]]` for one fold's block. Moved verbatim
/// from `NFoldState::commit`.
#[inline]
pub fn fold_block_downdate(
    block: &mut [f64],
    h: &[usize],
    u: &[f64],
    cb: &[f64],
) {
    let s = h.len();
    for (r, &jr) in h.iter().enumerate() {
        for (t, &jt) in h.iter().enumerate() {
            block[r * s + t] -= u[jr] * cb[jt];
        }
    }
}
