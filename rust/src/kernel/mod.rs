//! The **compute-kernel tier**: every O(mn) inner loop of the selection
//! engines lives here, behind one dispatch surface.
//!
//! Before this tier existed the scan/commit/downdate arithmetic was
//! hand-copied three times (the in-RAM greedy engine, the LLC-tiled
//! stored engine, and the `scan_candidates` selectors). Now there is
//! exactly one implementation per *(kernel, precision)* pair:
//!
//! | module | selects | contract |
//! |---|---|---|
//! | [`scalar`] | default | **bit-exact reference** — frozen operation order |
//! | [`simd`] | `--features simd` + [`KernelKind::Simd`] | bit-identical to [`scalar`] (lane layout mirrors the scalar accumulators) |
//! | [`f32c`] | `SelectionConfig::precision = F32c` | f32 cache, f64 Neumaier accumulation; tolerance-gated vs f64 |
//!
//! **Determinism contract.** Dispatch is chosen once per session
//! ([`KernelKind::active`] at state construction) and never varies
//! mid-run. Shard boundaries and serial reduction order are owned by
//! [`crate::parallel`] and are identical for every kernel, so results
//! are bit-identical across thread counts, tile widths, and backends
//! *per (kernel, precision) pair* — and the `(Simd, F64)` pair is
//! additionally bit-identical to `(Scalar, F64)` by construction. See
//! ARCHITECTURE.md §Compute kernels for the full table.

pub mod f32c;
pub mod scalar;
#[cfg(feature = "simd")]
pub mod simd;

use crate::metrics::Loss;

/// Which instruction-level implementation of the f64 kernels a session
/// runs. Chosen once at state construction and fixed for the life of
/// the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// The hand-unrolled scalar reference (always available).
    Scalar,
    /// Portable `std::simd` lanes (`--features simd`, nightly). In a
    /// build without the feature this variant still exists so callers
    /// never need `cfg` — dispatch falls back to [`KernelKind::Scalar`]
    /// arithmetic (which it equals bitwise anyway).
    Simd,
}

impl KernelKind {
    /// The kind this build activates by default: [`KernelKind::Simd`]
    /// when compiled with `--features simd`, else
    /// [`KernelKind::Scalar`].
    pub fn active() -> KernelKind {
        #[cfg(feature = "simd")]
        {
            KernelKind::Simd
        }
        #[cfg(not(feature = "simd"))]
        {
            KernelKind::Scalar
        }
    }

    /// Stable lowercase name (microbench JSON rows, logs).
    pub fn as_str(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Simd => "simd",
        }
    }
}

/// Numeric representation of the candidate cache Cᵀ — the
/// `SelectionConfig::precision` knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Full f64 cache: the reference representation, bit-exact across
    /// kernels/threads/tiles/backends.
    #[default]
    F64,
    /// f32 cache with f64 compensated (Neumaier) accumulation: halves
    /// cache bytes per round on the bandwidth-bound scan. Deterministic
    /// per run (bit-identical across threads and tile widths), but a
    /// *different* trajectory from [`Precision::F64`] — tolerance-gated
    /// against it, never mixed: checkpoints carry the precision in
    /// their config fingerprint. Greedy/native only.
    F32c,
}

impl Precision {
    /// Stable lowercase name (CLI value, microbench JSON, fingerprints).
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32c => "f32c",
        }
    }
}

impl std::str::FromStr for Precision {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Precision> {
        match s {
            "f64" => Ok(Precision::F64),
            "f32c" => Ok(Precision::F32c),
            other => Err(anyhow::anyhow!(
                "unknown precision '{other}' (expected f64 or f32c)"
            )),
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Score one candidate (LOO criterion of S ∪ {i}, Algorithm 3 lines
/// 8–17) with the selected kernel. See [`scalar::score_one`] for the
/// reference semantics.
#[inline]
pub fn score_one(
    kind: KernelKind,
    v: &[f64],
    c: &[f64],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
) -> f64 {
    match kind {
        KernelKind::Scalar => scalar::score_one(v, c, a, d, y, loss),
        #[cfg(feature = "simd")]
        KernelKind::Simd => simd::score_one(v, c, a, d, y, loss),
        #[cfg(not(feature = "simd"))]
        KernelKind::Simd => scalar::score_one(v, c, a, d, y, loss),
    }
}

/// Score a quad of candidates in one fused pass with the selected
/// kernel. See [`scalar::score_quad`].
#[inline]
pub fn score_quad(
    kind: KernelKind,
    v: [&[f64]; 4],
    c: [&[f64]; 4],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
) -> [f64; 4] {
    match kind {
        KernelKind::Scalar => scalar::score_quad(v, c, a, d, y, loss),
        #[cfg(feature = "simd")]
        KernelKind::Simd => simd::score_quad(v, c, a, d, y, loss),
        #[cfg(not(feature = "simd"))]
        KernelKind::Simd => scalar::score_quad(v, c, a, d, y, loss),
    }
}

/// Column-tiled [`score_one`]; bit-identical to it for every tile width
/// (accumulators carried across tiles). See [`scalar::score_one_tiled`].
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn score_one_tiled(
    kind: KernelKind,
    v: &[f64],
    c: &[f64],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
    tile: usize,
) -> f64 {
    match kind {
        KernelKind::Scalar => scalar::score_one_tiled(v, c, a, d, y, loss, tile),
        #[cfg(feature = "simd")]
        KernelKind::Simd => simd::score_one_tiled(v, c, a, d, y, loss, tile),
        #[cfg(not(feature = "simd"))]
        KernelKind::Simd => scalar::score_one_tiled(v, c, a, d, y, loss, tile),
    }
}

/// Column-tiled [`score_quad`]; bit-identical to it for every tile
/// width. See [`scalar::score_quad_tiled`].
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn score_quad_tiled(
    kind: KernelKind,
    v: [&[f64]; 4],
    c: [&[f64]; 4],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
    tile: usize,
) -> [f64; 4] {
    match kind {
        KernelKind::Scalar => {
            scalar::score_quad_tiled(v, c, a, d, y, loss, tile)
        }
        #[cfg(feature = "simd")]
        KernelKind::Simd => simd::score_quad_tiled(v, c, a, d, y, loss, tile),
        #[cfg(not(feature = "simd"))]
        KernelKind::Simd => {
            scalar::score_quad_tiled(v, c, a, d, y, loss, tile)
        }
    }
}

/// Score a run of candidates (rows already staged as slices) with the
/// tiled kernels: quads first, then the scalar remainder — the same
/// blocks-of-4 grouping as the untiled shard loop, so appending to
/// `out` yields scores bit-identical to the untiled scan. Callers must
/// only pass a non-multiple-of-4 run for the *final* run of the final
/// shard (where the untiled scan also falls back to single candidates).
#[allow(clippy::too_many_arguments)]
pub fn score_rows_tiled(
    kind: KernelKind,
    vrows: &[&[f64]],
    crows: &[&[f64]],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
    tile: usize,
    out: &mut Vec<f64>,
) {
    debug_assert_eq!(vrows.len(), crows.len());
    let mut vq = vrows.chunks_exact(4);
    let mut cq = crows.chunks_exact(4);
    for (v4, c4) in (&mut vq).zip(&mut cq) {
        let e = score_quad_tiled(
            kind,
            [v4[0], v4[1], v4[2], v4[3]],
            [c4[0], c4[1], c4[2], c4[3]],
            a,
            d,
            y,
            loss,
            tile,
        );
        out.extend_from_slice(&e);
    }
    for (v, c) in vq.remainder().iter().zip(cq.remainder()) {
        out.push(score_one_tiled(kind, v, c, a, d, y, loss, tile));
    }
}

/// Per-row body of the SMW rank-1 cache update with the selected
/// kernel. See [`scalar::rank1_update_row`].
#[inline]
pub fn rank1_update_row(
    kind: KernelKind,
    row: &mut [f64],
    v: &[f64],
    u: &[f64],
    sign: f64,
) {
    match kind {
        KernelKind::Scalar => scalar::rank1_update_row(row, v, u, sign),
        #[cfg(feature = "simd")]
        KernelKind::Simd => simd::rank1_update_row(row, v, u, sign),
        #[cfg(not(feature = "simd"))]
        KernelKind::Simd => scalar::rank1_update_row(row, v, u, sign),
    }
}

/// Column-tiled [`rank1_update_row`]; bit-identical to it for every
/// tile width. See [`scalar::rank1_update_row_tiled`].
#[inline]
pub fn rank1_update_row_tiled(
    kind: KernelKind,
    row: &mut [f64],
    v: &[f64],
    u: &[f64],
    sign: f64,
    tile: usize,
) {
    match kind {
        KernelKind::Scalar => {
            scalar::rank1_update_row_tiled(row, v, u, sign, tile)
        }
        #[cfg(feature = "simd")]
        KernelKind::Simd => simd::rank1_update_row_tiled(row, v, u, sign, tile),
        #[cfg(not(feature = "simd"))]
        KernelKind::Simd => {
            scalar::rank1_update_row_tiled(row, v, u, sign, tile)
        }
    }
}

/// Inner product with the selected kernel — the staging dot of the
/// backward scan and the commit paths. Bit-identical to
/// [`crate::linalg::dot`] for every kind (the SIMD lanes mirror the
/// scalar kernel's four partial sums).
#[inline]
pub fn dot(kind: KernelKind, x: &[f64], y: &[f64]) -> f64 {
    match kind {
        KernelKind::Scalar => crate::linalg::dot(x, y),
        #[cfg(feature = "simd")]
        KernelKind::Simd => simd::dot(x, y),
        #[cfg(not(feature = "simd"))]
        KernelKind::Simd => crate::linalg::dot(x, y),
    }
}

/// `y += alpha * x` with the selected kernel. A serial O(m) epilogue
/// like [`update_a`]: the sketch accumulation passes it serves (Gram
/// and projection builds in [`crate::select::sketch`]) are outside the
/// per-round hot loop, so it dispatches to [`crate::linalg::axpy`] for
/// every kind and the determinism argument stays trivial.
#[inline]
pub fn axpy(kind: KernelKind, alpha: f64, x: &[f64], y: &mut [f64]) {
    match kind {
        KernelKind::Scalar | KernelKind::Simd => {
            crate::linalg::axpy(alpha, x, y)
        }
    }
}

// O(m)-per-round epilogues and fold-block helpers: serial by design
// (they are not worth lanes and keeping them single-sourced keeps the
// determinism argument trivial), so they dispatch to scalar for every
// kernel kind.
pub use scalar::{
    fold_block_downdate, fold_tilde, removal_loss, update_a, update_ad,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_round_trips_through_strings() {
        for p in [Precision::F64, Precision::F32c] {
            let parsed: Precision = p.as_str().parse().unwrap();
            assert_eq!(parsed, p);
            assert_eq!(format!("{p}"), p.as_str());
        }
        assert!("f32".parse::<Precision>().is_err());
        assert!("F64".parse::<Precision>().is_err());
    }

    #[test]
    fn default_precision_is_f64() {
        assert_eq!(Precision::default(), Precision::F64);
    }

    #[test]
    fn active_kind_matches_feature() {
        #[cfg(feature = "simd")]
        assert_eq!(KernelKind::active(), KernelKind::Simd);
        #[cfg(not(feature = "simd"))]
        assert_eq!(KernelKind::active(), KernelKind::Scalar);
    }

    /// Without the `simd` feature, Simd dispatch must be the scalar
    /// kernel verbatim (with the feature, the dedicated equivalence
    /// suite pins lane-vs-scalar bit-identity on real engines).
    #[test]
    fn simd_kind_always_resolves() {
        let v = [0.5, -1.25, 2.0, 0.125, -0.75];
        let c = [1.0, 0.5, -0.25, 2.0, 1.5];
        let a = [0.1, -0.2, 0.3, -0.4, 0.5];
        let d = [1.0, 1.1, 0.9, 1.2, 0.8];
        let y = [1.0, -1.0, 1.0, -1.0, 1.0];
        for loss in [Loss::Squared, Loss::ZeroOne] {
            let s = score_one(KernelKind::Scalar, &v, &c, &a, &d, &y, loss);
            let q = score_one(KernelKind::Simd, &v, &c, &a, &d, &y, loss);
            assert_eq!(s.to_bits(), q.to_bits());
        }
        assert_eq!(
            dot(KernelKind::Simd, &v, &c).to_bits(),
            dot(KernelKind::Scalar, &v, &c).to_bits()
        );
    }
}
