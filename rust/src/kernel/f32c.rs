//! Mixed-precision kernels: **f32 cache, f64 compensated accumulation**
//! (`SelectionConfig::precision = F32c`).
//!
//! The per-round scan is bandwidth-bound and the cache matrix Cᵀ is the
//! dominant stream (n×m, re-read every round), so storing it in f32
//! halves the bytes per round. Everything else — `X`, the duals `a`,
//! `d`, `y`, and all intermediate arithmetic — stays f64: cache
//! elements are promoted on load and every contraction over them runs a
//! Neumaier compensated f64 sum, so the only precision loss is the f32
//! *storage rounding* of the cache itself (≈1 ulp per element per
//! commit), not accumulation error.
//!
//! **Determinism contract.** These kernels walk each candidate's
//! examples strictly sequentially (one compensated accumulator, no
//! quad/pair blocking), so a candidate's score depends only on the
//! cache bytes — not on tile width or its position in the active list.
//! That makes thread-count, tile-width, and `score_of`-vs-`score_all`
//! bit-identity *trivial* for this precision. The trajectory is NOT
//! bit-comparable to [`super::scalar`] — it is tolerance-gated (see
//! EXPERIMENTS.md §Mixed precision) and the precision participates in
//! the checkpoint config fingerprint so runs cannot silently resume
//! across representations. SIMD never applies here: f32c is
//! scalar-only by contract, whatever the build features.

use crate::metrics::Loss;

/// Neumaier (improved Kahan) compensated f64 accumulator: tracks a
/// running compensation for the low-order bits lost by each add. One
/// extra add + comparison per term; immune to the `sum ≫ term` *and*
/// `term ≫ sum` cancellation cases.
#[derive(Clone, Copy, Debug, Default)]
pub struct Neumaier {
    s: f64,
    comp: f64,
}

impl Neumaier {
    /// Fresh accumulator at 0.
    #[inline]
    pub fn new() -> Neumaier {
        Neumaier { s: 0.0, comp: 0.0 }
    }

    /// Add one term.
    #[inline]
    pub fn add(&mut self, term: f64) {
        let t = self.s + term;
        if self.s.abs() >= term.abs() {
            self.comp += (self.s - t) + term;
        } else {
            self.comp += (term - t) + self.s;
        }
        self.s = t;
    }

    /// The compensated total.
    #[inline]
    pub fn finish(self) -> f64 {
        self.s + self.comp
    }
}

/// Demote an f64 slice to the f32 cache representation (round to
/// nearest — the storage rounding the tolerance gate accounts for).
pub fn demote(src: &[f64]) -> Vec<f32> {
    src.iter().map(|&v| v as f32).collect()
}

/// Promote one f32 cache row into a reusable f64 staging buffer
/// (commit-time `c_b` staging).
pub fn promote_into(src: &[f32], dst: &mut Vec<f64>) {
    dst.clear();
    dst.extend(src.iter().map(|&v| v as f64));
}

/// Compensated inner product of two f64 slices — the commit staging
/// dots (`v·c_b`, `v·a`) of an f32c session.
#[inline]
pub fn neumaier_dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = Neumaier::new();
    for (&x, &y) in a.iter().zip(b) {
        acc.add(x * y);
    }
    acc.finish()
}

/// Compensated inner product of an f64 slice with an f32 cache row
/// (elements promoted on load).
#[inline]
pub fn dot_promote(v: &[f64], c32: &[f32]) -> f64 {
    debug_assert_eq!(v.len(), c32.len());
    let mut acc = Neumaier::new();
    for (&vj, &cj) in v.iter().zip(c32) {
        acc.add(vj * (cj as f64));
    }
    acc.finish()
}

/// Score one candidate against an f32 cache row: the mixed-precision
/// twin of [`super::scalar::score_one`]. Pass 1 accumulates v·c and
/// v·a with compensated f64 sums; pass 2 accumulates the LOO loss the
/// same way (the 0-1 count is exact integer arithmetic in f64 and needs
/// no compensation).
pub fn score_one(
    v: &[f64],
    c32: &[f32],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
) -> f64 {
    let mut vc = Neumaier::new();
    let mut va = Neumaier::new();
    for ((&vj, &cj), &aj) in v.iter().zip(c32).zip(a) {
        let cj = cj as f64;
        vc.add(vj * cj);
        va.add(vj * aj);
    }
    let inv_denom = 1.0 / (1.0 + vc.finish());
    let s = va.finish() * inv_denom;
    match loss {
        Loss::Squared => {
            let mut e = Neumaier::new();
            for ((&cj, &aj), &dj) in c32.iter().zip(a).zip(d) {
                let cj = cj as f64;
                let at = aj - cj * s;
                let dt = dj - cj * cj * inv_denom;
                let r = at / dt;
                e.add(r * r);
            }
            e.finish()
        }
        Loss::ZeroOne => {
            let mut e = 0.0;
            for (((&cj, &aj), &dj), &yj) in
                c32.iter().zip(a).zip(d).zip(y)
            {
                let cj = cj as f64;
                let at = aj - cj * s;
                let dt = dj - cj * cj * inv_denom;
                if yj * at >= dt {
                    e += 1.0;
                }
            }
            e
        }
    }
}

/// Score a run of staged candidate rows, appending to `out`: one
/// independent [`score_one`] per row — no quad blocking, so a score
/// never depends on neighbors in the active list (see the module
/// determinism contract).
pub fn score_rows(
    vrows: &[&[f64]],
    crows: &[&[f32]],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
    out: &mut Vec<f64>,
) {
    debug_assert_eq!(vrows.len(), crows.len());
    for (v, c32) in vrows.iter().zip(crows) {
        out.push(score_one(v, c32, a, d, y, loss));
    }
}

/// Per-row body of the SMW rank-1 downdate on the f32 cache:
/// `w = v·row` (compensated, promoted), then each element is updated in
/// f64 and rounded back to f32 — one storage rounding per commit, the
/// same order every run.
#[inline]
pub fn rank1_update_row(row32: &mut [f32], v: &[f64], u: &[f64], sign: f64) {
    let w = dot_promote(v, row32);
    if w != 0.0 {
        let sw = sign * w;
        for (r, &uj) in row32.iter_mut().zip(u) {
            *r = ((*r as f64) + sw * uj) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn neumaier_recovers_cancelled_terms() {
        // naive summation of [1e16, 1, -1e16] loses the 1.0 entirely
        let mut naive = 0.0;
        let mut comp = Neumaier::new();
        for t in [1e16, 1.0, -1e16] {
            naive += t;
            comp.add(t);
        }
        assert_eq!(naive, 0.0);
        assert_eq!(comp.finish(), 1.0);
    }

    #[test]
    fn f32c_score_tracks_the_f64_reference() {
        let mut rng = Pcg64::new(0xF32C, 1);
        let m = 96;
        let v: Vec<f64> =
            (0..m).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let c: Vec<f64> =
            (0..m).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let a: Vec<f64> =
            (0..m).map(|_| rng.uniform_range(-0.5, 0.5)).collect();
        let d: Vec<f64> =
            (0..m).map(|_| rng.uniform_range(0.5, 1.5)).collect();
        let y: Vec<f64> = (0..m)
            .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let c32 = demote(&c);
        for loss in [Loss::Squared, Loss::ZeroOne] {
            let exact = super::super::scalar::score_one(
                &v, &c, &a, &d, &y, loss,
            );
            let mixed = score_one(&v, &c32, &a, &d, &y, loss);
            let tol = match loss {
                // storage rounding only: ~1e-7 relative per element
                Loss::Squared => 1e-4 * exact.abs().max(1.0),
                // a misclassification count flips only at a boundary
                Loss::ZeroOne => 1.0 + 1e-12,
            };
            assert!(
                (exact - mixed).abs() <= tol,
                "{loss:?}: exact={exact} mixed={mixed}"
            );
        }
    }

    #[test]
    fn f32c_rank1_update_is_deterministic_and_close() {
        let mut rng = Pcg64::new(0xAB, 7);
        let m = 64;
        let base: Vec<f64> =
            (0..m).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let v: Vec<f64> =
            (0..m).map(|_| rng.uniform_range(-1.0, 1.0)).collect();
        let u: Vec<f64> =
            (0..m).map(|_| rng.uniform_range(-0.25, 0.25)).collect();
        let mut row_a = demote(&base);
        let mut row_b = row_a.clone();
        rank1_update_row(&mut row_a, &v, &u, -1.0);
        rank1_update_row(&mut row_b, &v, &u, -1.0);
        assert_eq!(row_a, row_b, "same inputs must give identical bytes");
        // f64 reference of the same update
        let w = crate::linalg::dot(&v, &base);
        for j in 0..m {
            let reference = base[j] - w * u[j];
            assert!(
                (row_a[j] as f64 - reference).abs()
                    <= 1e-5 * reference.abs().max(1.0),
                "j={j}"
            );
        }
    }

    #[test]
    fn promote_demote_round_trip() {
        let src = vec![0.5, -1.25, 3.0, 0.0];
        let c32 = demote(&src);
        let mut back = Vec::new();
        promote_into(&c32, &mut back);
        assert_eq!(src, back, "exactly representable values round-trip");
    }
}
