//! Portable `std::simd` kernels (`--features simd`, nightly toolchain).
//!
//! **Bit-identical to [`super::scalar`] by construction.** The scalar
//! kernels were written with explicit independent partial sums — the
//! (vc0, vc1) even/odd pairs of the single-candidate scan, the four
//! per-candidate lanes of the quad scan, the four partial sums of
//! [`crate::linalg::dot`] — precisely so that each partial sum could
//! become one SIMD lane. Every function here maps those accumulators
//! onto `f64x2`/`f64x4` lanes, performs the same IEEE 754 operations
//! per lane in the same order, and combines lanes with the scalar
//! kernel's exact summation tree. IEEE 754 arithmetic is deterministic
//! per operation, so lane-wise evaluation of independent accumulators
//! is the *same computation*, not an approximation — the
//! `kernel_equivalence` suite pins `to_bits()` equality across whole
//! selection trajectories.
//!
//! Phases with a single serial accumulator (the loss pass of the
//! one-candidate kernel) stay on the shared scalar helpers: vectorizing
//! them would change the summation order and break bit-identity.

use std::simd::cmp::SimdPartialOrd;
use std::simd::{f64x2, f64x4};

use super::scalar;
use crate::metrics::Loss;

/// SIMD twin of [`scalar::score_one`]: pass 1 runs the (vc0, vc1) /
/// (va0, va1) accumulator pairs as `f64x2` lanes; pass 2 is the shared
/// serial loss pass (single accumulator — kept scalar by contract).
#[inline]
pub fn score_one(
    v: &[f64],
    c: &[f64],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
) -> f64 {
    let m = y.len();
    let pairs = m / 2;
    let mut vc_v = f64x2::splat(0.0);
    let mut va_v = f64x2::splat(0.0);
    for p in 0..pairs {
        let j = p * 2;
        let vv = f64x2::from_slice(&v[j..]);
        let cc = f64x2::from_slice(&c[j..]);
        let aa = f64x2::from_slice(&a[j..]);
        vc_v += vv * cc;
        va_v += vv * aa;
    }
    let vc_l = vc_v.to_array();
    let va_l = va_v.to_array();
    // lane 0 ≡ vc0/va0, lane 1 ≡ vc1/va1 — combine in the scalar order
    let (mut vc, mut va) = (vc_l[0] + vc_l[1], va_l[0] + va_l[1]);
    if m % 2 == 1 {
        vc += v[m - 1] * c[m - 1];
        va += v[m - 1] * a[m - 1];
    }
    let inv_denom = 1.0 / (1.0 + vc);
    let s = va * inv_denom;
    scalar::loss_pass(c, a, d, y, loss, inv_denom, s)
}

/// SIMD twin of [`scalar::score_one_tiled`]: the `f64x2` pass-1 lanes
/// are carried across tiles (tile starts stay even — tiles are
/// multiples of 8), the loss pass is the shared scalar tiled helper.
pub fn score_one_tiled(
    v: &[f64],
    c: &[f64],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
    tile: usize,
) -> f64 {
    debug_assert!(tile >= 8 && tile % 8 == 0, "tile must be a multiple of 8");
    let m = y.len();
    let mut vc_v = f64x2::splat(0.0);
    let mut va_v = f64x2::splat(0.0);
    let mut j0 = 0;
    while j0 < m {
        let j1 = (j0 + tile).min(m);
        let pairs = (j1 - j0) / 2;
        for p in 0..pairs {
            let j = j0 + p * 2;
            let vv = f64x2::from_slice(&v[j..]);
            let cc = f64x2::from_slice(&c[j..]);
            let aa = f64x2::from_slice(&a[j..]);
            vc_v += vv * cc;
            va_v += vv * aa;
        }
        j0 = j1;
    }
    let vc_l = vc_v.to_array();
    let va_l = va_v.to_array();
    let (mut vc, mut va) = (vc_l[0] + vc_l[1], va_l[0] + va_l[1]);
    if m % 2 == 1 {
        vc += v[m - 1] * c[m - 1];
        va += v[m - 1] * a[m - 1];
    }
    let inv_denom = 1.0 / (1.0 + vc);
    let s = va * inv_denom;
    scalar::loss_pass_tiled(c, a, d, y, loss, inv_denom, s, tile)
}

/// SIMD twin of [`scalar::score_quad`]: one candidate per `f64x4` lane
/// in **both** passes. The scalar quad kernel's `vc[4]`/`va[4]`/`e[4]`
/// arrays are fully independent per candidate, so lane-wise evaluation
/// is the identical operation sequence — including the per-lane
/// divisions.
pub fn score_quad(
    v: [&[f64]; 4],
    c: [&[f64]; 4],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
) -> [f64; 4] {
    let m = y.len();
    let mut vc_v = f64x4::splat(0.0);
    let mut va_v = f64x4::splat(0.0);
    for j in 0..m {
        let vj =
            f64x4::from_array([v[0][j], v[1][j], v[2][j], v[3][j]]);
        let cj =
            f64x4::from_array([c[0][j], c[1][j], c[2][j], c[3][j]]);
        vc_v += vj * cj;
        va_v += vj * f64x4::splat(a[j]);
    }
    let inv_denom_v = f64x4::splat(1.0) / (f64x4::splat(1.0) + vc_v);
    let s_v = va_v * inv_denom_v;
    quad_loss_pass(c, a, d, y, loss, inv_denom_v, s_v, 0, m, f64x4::splat(0.0))
        .to_array()
}

/// SIMD twin of [`scalar::score_quad_tiled`]: pass-1 and loss lanes are
/// carried across tiles exactly like the scalar accumulators.
pub fn score_quad_tiled(
    v: [&[f64]; 4],
    c: [&[f64]; 4],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
    tile: usize,
) -> [f64; 4] {
    debug_assert!(tile >= 8 && tile % 8 == 0, "tile must be a multiple of 8");
    let m = y.len();
    let mut vc_v = f64x4::splat(0.0);
    let mut va_v = f64x4::splat(0.0);
    let mut j0 = 0;
    while j0 < m {
        let j1 = (j0 + tile).min(m);
        for j in j0..j1 {
            let vj =
                f64x4::from_array([v[0][j], v[1][j], v[2][j], v[3][j]]);
            let cj =
                f64x4::from_array([c[0][j], c[1][j], c[2][j], c[3][j]]);
            vc_v += vj * cj;
            va_v += vj * f64x4::splat(a[j]);
        }
        j0 = j1;
    }
    let inv_denom_v = f64x4::splat(1.0) / (f64x4::splat(1.0) + vc_v);
    let s_v = va_v * inv_denom_v;
    let mut e_v = f64x4::splat(0.0);
    let mut j0 = 0;
    while j0 < m {
        let j1 = (j0 + tile).min(m);
        e_v = quad_loss_pass(c, a, d, y, loss, inv_denom_v, s_v, j0, j1, e_v);
        j0 = j1;
    }
    e_v.to_array()
}

/// Loss pass of the quad kernels over examples `[j0, j1)`, lanes
/// accumulating into (and returning) `e_v`. The 0-1 arm adds a
/// mask-selected 0.0/1.0 per lane: adding +0.0 to a non-negative count
/// is exact, so lanes match the scalar kernel's conditional `e += 1.0`.
#[allow(clippy::too_many_arguments)]
#[inline]
fn quad_loss_pass(
    c: [&[f64]; 4],
    a: &[f64],
    d: &[f64],
    y: &[f64],
    loss: Loss,
    inv_denom_v: f64x4,
    s_v: f64x4,
    j0: usize,
    j1: usize,
    mut e_v: f64x4,
) -> f64x4 {
    match loss {
        Loss::Squared => {
            for j in j0..j1 {
                let cj = f64x4::from_array([
                    c[0][j], c[1][j], c[2][j], c[3][j],
                ]);
                let at = f64x4::splat(a[j]) - cj * s_v;
                let dt = f64x4::splat(d[j]) - cj * cj * inv_denom_v;
                let r = at / dt;
                e_v += r * r;
            }
        }
        Loss::ZeroOne => {
            let one = f64x4::splat(1.0);
            let zero = f64x4::splat(0.0);
            for j in j0..j1 {
                let cj = f64x4::from_array([
                    c[0][j], c[1][j], c[2][j], c[3][j],
                ]);
                let at = f64x4::splat(a[j]) - cj * s_v;
                let dt = f64x4::splat(d[j]) - cj * cj * inv_denom_v;
                let hit = (f64x4::splat(y[j]) * at).simd_ge(dt);
                e_v += hit.select(one, zero);
            }
        }
    }
    e_v
}

/// SIMD twin of [`crate::linalg::dot`]: the four partial sums s0..s3
/// become one `f64x4`, combined in the scalar kernel's left-to-right
/// order, scalar tail unchanged.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let mut s_v = f64x4::splat(0.0);
    for ch in 0..chunks {
        let i = ch * 4;
        s_v += f64x4::from_slice(&a[i..]) * f64x4::from_slice(&b[i..]);
    }
    let l = s_v.to_array();
    let mut s = l[0] + l[1] + l[2] + l[3];
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// SIMD twin of [`crate::linalg::dot_tiled`]: the `f64x4` partial sums
/// are carried across tiles, combine + tail as in [`dot`].
#[inline]
pub fn dot_tiled(a: &[f64], b: &[f64], tile: usize) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(tile > 0 && tile % 4 == 0, "tile must be a multiple of 4");
    let n = a.len();
    let quads = n / 4;
    let tile_q = tile / 4;
    let mut s_v = f64x4::splat(0.0);
    let mut q0 = 0;
    while q0 < quads {
        let q1 = (q0 + tile_q).min(quads);
        for ch in q0..q1 {
            let i = ch * 4;
            s_v += f64x4::from_slice(&a[i..]) * f64x4::from_slice(&b[i..]);
        }
        q0 = q1;
    }
    let l = s_v.to_array();
    let mut s = l[0] + l[1] + l[2] + l[3];
    for i in quads * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// SIMD twin of [`scalar::rank1_update_row`]: `w` via [`dot`] (bit-
/// identical), then the elementwise update in `f64x4` quads + scalar
/// tail — each element's `row[j] + sign·w·u[j]` is an independent
/// operation, so vector width cannot change any result bit.
#[inline]
pub fn rank1_update_row(row: &mut [f64], v: &[f64], u: &[f64], sign: f64) {
    let w = dot(v, row);
    if w != 0.0 {
        let sw = sign * w;
        axpy_quads(row, u, sw, 0, row.len());
    }
}

/// SIMD twin of [`scalar::rank1_update_row_tiled`]: dot lanes carried
/// across tiles, elementwise update per tile.
#[inline]
pub fn rank1_update_row_tiled(
    row: &mut [f64],
    v: &[f64],
    u: &[f64],
    sign: f64,
    tile: usize,
) {
    debug_assert!(tile > 0 && tile % 4 == 0, "tile must be a multiple of 4");
    let row_len = row.len();
    let w = dot_tiled(v, row, tile);
    if w != 0.0 {
        let sw = sign * w;
        let mut j0 = 0;
        while j0 < row_len {
            let j1 = (j0 + tile).min(row_len);
            axpy_quads(row, u, sw, j0, j1);
            j0 = j1;
        }
    }
}

/// `row[j] += sw·u[j]` for `j` in `[j0, j1)`, vectorized in quads with
/// a scalar tail. Elementwise — bit-identical to the serial loop.
#[inline]
fn axpy_quads(row: &mut [f64], u: &[f64], sw: f64, j0: usize, j1: usize) {
    let sw_v = f64x4::splat(sw);
    let quads = (j1 - j0) / 4;
    for q in 0..quads {
        let i = j0 + q * 4;
        let r = f64x4::from_slice(&row[i..]);
        let uu = f64x4::from_slice(&u[i..]);
        (r + sw_v * uu).copy_to_slice(&mut row[i..i + 4]);
    }
    for i in (j0 + quads * 4)..j1 {
        row[i] += sw * u[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn gen_vec(rng: &mut Pcg64, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.uniform_range(-1.0, 1.0)).collect()
    }

    /// Lane kernels vs scalar reference, every odd/even length, both
    /// losses, tiled and untiled — `to_bits` equality, no tolerance.
    #[test]
    fn simd_kernels_match_scalar_bitwise() {
        let mut rng = Pcg64::new(0x51AD, 1);
        for m in [1, 2, 3, 7, 8, 15, 16, 33, 64, 129] {
            let v: Vec<Vec<f64>> =
                (0..4).map(|_| gen_vec(&mut rng, m)).collect();
            let c: Vec<Vec<f64>> =
                (0..4).map(|_| gen_vec(&mut rng, m)).collect();
            let a = gen_vec(&mut rng, m);
            let d: Vec<f64> =
                (0..m).map(|_| rng.uniform_range(0.5, 1.5)).collect();
            let y: Vec<f64> = (0..m)
                .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
                .collect();
            for loss in [Loss::Squared, Loss::ZeroOne] {
                let s_ref =
                    scalar::score_one(&v[0], &c[0], &a, &d, &y, loss);
                let s_simd = score_one(&v[0], &c[0], &a, &d, &y, loss);
                assert_eq!(s_ref.to_bits(), s_simd.to_bits(), "m={m}");

                let vq = [&v[0][..], &v[1][..], &v[2][..], &v[3][..]];
                let cq = [&c[0][..], &c[1][..], &c[2][..], &c[3][..]];
                let q_ref = scalar::score_quad(vq, cq, &a, &d, &y, loss);
                let q_simd = score_quad(vq, cq, &a, &d, &y, loss);
                for t in 0..4 {
                    assert_eq!(
                        q_ref[t].to_bits(),
                        q_simd[t].to_bits(),
                        "m={m} t={t}"
                    );
                }
                if m > 8 {
                    let t_ref = scalar::score_one_tiled(
                        &v[0], &c[0], &a, &d, &y, loss, 8,
                    );
                    let t_simd =
                        score_one_tiled(&v[0], &c[0], &a, &d, &y, loss, 8);
                    assert_eq!(t_ref.to_bits(), t_simd.to_bits(), "m={m}");
                    let tq_ref = scalar::score_quad_tiled(
                        vq, cq, &a, &d, &y, loss, 8,
                    );
                    let tq_simd =
                        score_quad_tiled(vq, cq, &a, &d, &y, loss, 8);
                    for t in 0..4 {
                        assert_eq!(tq_ref[t].to_bits(), tq_simd[t].to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn simd_dot_and_rank1_match_reference_bitwise() {
        let mut rng = Pcg64::new(0xD07, 1);
        for n in [1, 3, 4, 5, 8, 17, 64, 130] {
            let a = gen_vec(&mut rng, n);
            let b = gen_vec(&mut rng, n);
            assert_eq!(
                dot(&a, &b).to_bits(),
                crate::linalg::dot(&a, &b).to_bits(),
                "n={n}"
            );
            if n > 4 {
                assert_eq!(
                    dot_tiled(&a, &b, 4).to_bits(),
                    crate::linalg::dot_tiled(&a, &b, 4).to_bits(),
                    "n={n}"
                );
            }
            let u = gen_vec(&mut rng, n);
            let v = gen_vec(&mut rng, n);
            let mut row_ref = a.clone();
            let mut row_simd = a.clone();
            scalar::rank1_update_row(&mut row_ref, &v, &u, -1.0);
            rank1_update_row(&mut row_simd, &v, &u, -1.0);
            assert_eq!(row_ref, row_simd, "n={n}");
            if n > 4 {
                let mut t_ref = b.clone();
                let mut t_simd = b.clone();
                scalar::rank1_update_row_tiled(&mut t_ref, &v, &u, 1.0, 4);
                rank1_update_row_tiled(&mut t_simd, &v, &u, 1.0, 4);
                assert_eq!(t_ref, t_simd, "n={n}");
            }
        }
    }
}
