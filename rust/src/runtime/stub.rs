//! API-compatible stand-in for the `xla` crate, compiled when the `pjrt`
//! feature is off (the default — the real bindings need the XLA C++
//! extension from the offline cache).
//!
//! Only [`PjRtClient::cpu`] is reachable at runtime: it fails with a
//! clear "built without the pjrt feature" error, so `Runtime::open`
//! (and therefore every PJRT engine/serving path — all five artifact
//! selector engines in `runtime/engine.rs` construct through it) reports
//! the missing feature instead of failing to link. The remaining items
//! exist solely so the non-gated code in `runtime/` and
//! `runtime/engine.rs` typechecks; none of them can be constructed. The
//! stub-path contract is pinned by
//! `rust/tests/pjrt_integration.rs::stub_runtime_reports_missing_feature_clearly`.

use std::fmt;
use std::path::Path;

/// Error returned by every stub entry point.
#[derive(Clone, Copy, Debug)]
pub struct Unavailable;

impl fmt::Display for Unavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(
            "built without the pjrt feature — rebuild with \
             `--features pjrt` (requires the offline xla crate cache)",
        )
    }
}

/// Stub PJRT client; [`PjRtClient::cpu`] always errors.
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails: the stub has no PJRT backend.
    pub fn cpu() -> Result<PjRtClient, Unavailable> {
        Err(Unavailable)
    }

    /// Reports the stub platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Always 0 devices.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Always fails: nothing to compile against.
    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Unavailable> {
        Err(Unavailable)
    }
}

/// Stub compiled executable (never constructed).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Always fails: no executable can exist.
    pub fn execute<T>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Unavailable> {
        Err(Unavailable)
    }
}

/// Stub device buffer (never constructed).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Always fails: no buffer can exist.
    pub fn to_literal_sync(&self) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }
}

/// Stub host literal. Constructible (the `lit` helpers build literals
/// before executing), but empty — no executable exists to consume it.
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    /// Empty literal (real marshalling needs the xla crate).
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    /// Empty literal (real marshalling needs the xla crate).
    pub fn scalar<T>(_v: T) -> Literal {
        Literal
    }

    /// Always fails on the stub literal.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }

    /// Always fails on the stub literal.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Unavailable> {
        Err(Unavailable)
    }

    /// Always fails on the stub literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Unavailable> {
        Err(Unavailable)
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Always fails: HLO parsing needs the xla crate.
    pub fn from_text_file<P: AsRef<Path>>(
        _path: P,
    ) -> Result<HloModuleProto, Unavailable> {
        Err(Unavailable)
    }
}

/// Stub XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    /// Trivial conversion so call sites typecheck.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
