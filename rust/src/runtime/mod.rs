//! PJRT runtime: load AOT artifacts, compile once, execute from Rust.
//!
//! `python/compile/aot.py` lowers the Layer-2 entry points to HLO **text**
//! (xla_extension 0.5.1 rejects jax≥0.5 serialized protos; the text parser
//! reassigns instruction ids) at a set of static shape buckets, and writes
//! `artifacts/manifest.tsv`. This module:
//!
//! * parses the manifest,
//! * compiles each needed artifact exactly once on [`xla::PjRtClient::cpu`]
//!   (cached thereafter — compilation happens at coordinator startup, never
//!   on the request path),
//! * exposes typed `execute` wrappers that marshal between the crate's
//!   `f64` buffers and [`xla::Literal`]s,
//! * implements bucket selection + exact zero-padding (DESIGN.md §5).
//!
//! Python never runs at runtime: the Rust binary is self-contained once
//! `make artifacts` has produced the HLO text.

pub mod engine;

/// Real `xla` bindings behind the `pjrt` feature; an API-compatible
/// stub otherwise (see [`stub`]) so the crate builds without the
/// offline XLA cache — the PJRT paths then error at runtime.
#[cfg(not(feature = "pjrt"))]
pub(crate) mod stub;
#[cfg(not(feature = "pjrt"))]
pub(crate) use self::stub as xla;
#[cfg(feature = "pjrt")]
pub(crate) use ::xla;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context};

/// One manifest row.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    /// Entry-point name (`score_step`, `commit_step`, ...).
    pub entry: String,
    /// Artifact file name relative to the artifacts dir.
    pub file: String,
    /// First dimension, e.g. `("m", 256)`.
    pub dim1: (String, usize),
    /// Second dimension, e.g. `("n", 256)`.
    pub dim2: (String, usize),
    /// Extra static dimensions beyond the (m, n) bucket — the `nfold_*`
    /// entries record their fold capacity here (`f=16`, `s=32`), written
    /// by `python -m compile.aot` so the runtime never mirrors the
    /// sizing formula.
    pub extra: Vec<(String, usize)>,
}

impl ManifestEntry {
    /// Look up an extra dimension by name (e.g. `"f"`, `"s"`).
    pub fn extra_dim(&self, name: &str) -> Option<usize> {
        self.extra.iter().find(|(k, _)| k == name).map(|&(_, v)| v)
    }
}

/// Artifact store + compilation cache on a PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Vec<ManifestEntry>,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open an artifacts directory (must contain `manifest.tsv`).
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// The PJRT client (platform introspection, serving buffers).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// All manifest entries.
    pub fn manifest(&self) -> &[ManifestEntry] {
        &self.manifest
    }

    /// Selection-loop buckets (m, n), ascending by m·n: every bucket that
    /// has all three of init_state/score_step/commit_step.
    pub fn selection_buckets(&self) -> Vec<(usize, usize)> {
        let mut buckets: Vec<(usize, usize)> = self
            .manifest
            .iter()
            .filter(|e| e.entry == "score_step")
            .map(|e| (e.dim1.1, e.dim2.1))
            .filter(|&(m, n)| {
                ["init_state", "commit_step"].iter().all(|want| {
                    self.manifest.iter().any(|e| {
                        e.entry == *want && e.dim1.1 == m && e.dim2.1 == n
                    })
                })
            })
            .collect();
        buckets.sort_by_key(|&(m, n)| (m * n, m));
        buckets
    }

    /// Smallest bucket with m_b ≥ m and n_b ≥ n.
    pub fn pick_bucket(&self, m: usize, n: usize) -> Option<(usize, usize)> {
        self.selection_buckets()
            .into_iter()
            .find(|&(mb, nb)| mb >= m && nb >= n)
    }

    /// The manifest row for `entry` at bucket dims (d1, d2), if lowered.
    pub fn entry_at(
        &self,
        entry: &str,
        d1: usize,
        d2: usize,
    ) -> Option<&ManifestEntry> {
        self.manifest
            .iter()
            .find(|e| e.entry == entry && e.dim1.1 == d1 && e.dim2.1 == d2)
    }

    /// Compile (or fetch from cache) the artifact for `entry` at bucket
    /// dims (d1, d2).
    pub fn executable(
        &self,
        entry: &str,
        d1: usize,
        d2: usize,
    ) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        let row = self.entry_at(entry, d1, d2).ok_or_else(|| {
            anyhow!(
                "no artifact for {entry} at ({d1}, {d2}) — artifacts may \
                 predate this binary; rerun `make artifacts`"
            )
        })?;
        let key = row.file.clone();
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(&row.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far (test/diagnostic hook).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Run an executable whose output is a tuple, returning the parts.
    pub fn run_tuple(
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let out = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("tuple: {e}"))
    }
}

fn parse_manifest(text: &str) -> anyhow::Result<Vec<ManifestEntry>> {
    let mut rows = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() < 4 {
            bail!("manifest line {}: expected 4 columns", lineno + 1);
        }
        let parse_dim = |s: &str| -> anyhow::Result<(String, usize)> {
            let (k, v) = s
                .split_once('=')
                .ok_or_else(|| anyhow!("bad dim {s:?}"))?;
            Ok((k.to_string(), v.parse()?))
        };
        rows.push(ManifestEntry {
            entry: cols[0].to_string(),
            file: cols[1].to_string(),
            dim1: parse_dim(cols[2])?,
            dim2: parse_dim(cols[3])?,
            extra: cols[4..]
                .iter()
                .map(|c| parse_dim(c))
                .collect::<anyhow::Result<_>>()?,
        });
    }
    if rows.is_empty() {
        bail!("empty manifest");
    }
    Ok(rows)
}

/// Literal helpers shared by the engine and serving paths.
pub mod lit {
    use anyhow::anyhow;

    use super::xla;

    /// 1-D f64 literal.
    pub fn vec_f64(data: &[f64]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    /// Row-major (rows × cols) f64 literal.
    pub fn mat_f64(
        data: &[f64],
        rows: usize,
        cols: usize,
    ) -> anyhow::Result<xla::Literal> {
        assert_eq!(data.len(), rows * cols);
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| anyhow!("reshape: {e}"))
    }

    /// i32 scalar literal.
    pub fn scalar_i32(v: i32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    /// Row-major (rows × cols) i32 literal (fold-index tensors).
    pub fn mat_i32(
        data: &[i32],
        rows: usize,
        cols: usize,
    ) -> anyhow::Result<xla::Literal> {
        assert_eq!(data.len(), rows * cols);
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| anyhow!("reshape: {e}"))
    }

    /// Row-major (d0 × d1 × d2) f64 literal (fold-block tensors).
    pub fn tensor3_f64(
        data: &[f64],
        d0: usize,
        d1: usize,
        d2: usize,
    ) -> anyhow::Result<xla::Literal> {
        assert_eq!(data.len(), d0 * d1 * d2);
        xla::Literal::vec1(data)
            .reshape(&[d0 as i64, d1 as i64, d2 as i64])
            .map_err(|e| anyhow!("reshape: {e}"))
    }

    /// Copy a literal's f64 payload out.
    pub fn to_vec_f64(l: &xla::Literal) -> anyhow::Result<Vec<f64>> {
        l.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_well_formed_rows() {
        let text = "# comment\nscore_step\tscore_step_m4_n8.hlo.txt\tm=4\tn=8\n";
        let rows = parse_manifest(text).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].entry, "score_step");
        assert_eq!(rows[0].dim1, ("m".to_string(), 4));
        assert_eq!(rows[0].dim2, ("n".to_string(), 8));
    }

    #[test]
    fn manifest_parses_extra_fold_dims() {
        let text = "nfold_score_step\tnfold_score_step_m64_n128.hlo.txt\t\
                    m=64\tn=128\tf=16\ts=16\n";
        let rows = parse_manifest(text).unwrap();
        assert_eq!(rows[0].extra_dim("f"), Some(16));
        assert_eq!(rows[0].extra_dim("s"), Some(16));
        assert_eq!(rows[0].extra_dim("q"), None);
        // plain rows carry no extras
        let plain =
            parse_manifest("score_step\ta.hlo.txt\tm=4\tn=8\n").unwrap();
        assert!(plain[0].extra.is_empty());
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(parse_manifest("just one col\n").is_err());
        assert!(parse_manifest("a\tb\tm=x\tn=2\n").is_err());
        assert!(parse_manifest("a\tb\tm=1\tn=2\tbad-extra\n").is_err());
        assert!(parse_manifest("").is_err());
    }

    // Tests that need real artifacts + a PJRT client live in
    // rust/tests/pjrt_integration.rs (they require `make artifacts`).
}
