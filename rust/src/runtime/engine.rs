//! PJRT-backed selection engines.
//!
//! Runs the paper's O(mn) scan/update rounds through the AOT-compiled
//! Layer-1/2 artifacts (Pallas score kernels + rank-1 update), while Rust
//! owns the control flow: bucket choice, padding, the argmin, the
//! selected-set mask, and the final weight extraction.
//!
//! The shared plumbing lives in [`EngineCore`] (padding/bucketing, the
//! problem literals, the membership mask) and [`CadState`] (the
//! `[C, a, d]` device state plus its four launches: masked *addition*
//! scoring, masked *removal* scoring, rank-1 commit, rank-1 downdate).
//! Every selector whose inner loop is one of those masked score launches
//! rides on top:
//!
//! * [`PjrtGreedy`] — Algorithm 3 (forward greedy RLS);
//! * [`PjrtBackward`] — backward elimination (full-set init via the
//!   `full_init_state` artifact, then removal scoring + downdates);
//! * [`PjrtFoba`] — adaptive forward–backward greedy (adds via the score
//!   launch, ν-thresholded deletions via the removal launch);
//! * [`PjrtFloating`] — SFFS (forward launches + conditional backward
//!   launches);
//! * [`PjrtNFold`] — n-fold-CV greedy, on its own `[C, a, B]` state
//!   ([`NfState`]) with fold-masked scoring against the on-device
//!   fold-diagonal blocks.
//!
//! The `wrapper` selector needs no engine of its own: its trajectory is
//! equivalence-tested equal to greedy RLS (Algorithms 1–3 agree), so
//! [`PjrtGreedy`] serves it. RankRLS, the reduced-set selector, low-rank
//! and random stay native — their inner loops are not this masked scan
//! (pairwise ranking criterion / kernel-space caches / no scan at all).
//!
//! Padding into a bucket is **exact** (DESIGN.md §5): zero feature rows
//! and zero labels for padded examples contribute nothing to any cache or
//! loss; padded candidates are masked to BIG by the kernels; padded fold
//! slots decouple behind identity rows. Every engine here is
//! equivalence-tested against its native twin in
//! `rust/tests/pjrt_integration.rs` (bit-equal selected sets, tolerance
//! on criteria — the n-fold engine solves its fold blocks with CG where
//! the native engine uses Cholesky).

use std::rc::Rc;

use anyhow::{anyhow, ensure};

use super::{lit, xla, Runtime};
use crate::linalg::{dot, Matrix};
use crate::metrics::Loss;
use crate::select::session::{
    CoreStep, PolicySession, Session, SessionCore, SessionSelector,
};
use crate::select::{
    argmin, Round, SelectionConfig, SelectionResult, Selector, BIG,
};

type Exe = Rc<xla::PjRtLoadedExecutable>;

// ---------------------------------------------------------------------------
// EngineCore: padding, bucketing, masks — shared by every artifact engine
// ---------------------------------------------------------------------------

/// The bucket-padded problem: owned literals for X/y/the example mask,
/// the real and bucket dimensions, and the feature membership vector that
/// every masked launch derives its candidate mask from. Executables are
/// cloned `Rc`s and all literals are owned, so sessions borrow only the
/// problem data, never the [`Runtime`].
pub(crate) struct EngineCore<'a> {
    x: &'a Matrix,
    y: &'a [f64],
    loss: Loss,
    /// Real dims.
    m: usize,
    n: usize,
    /// Bucket dims.
    mb: usize,
    nb: usize,
    x_lit: xla::Literal,
    y_lit: xla::Literal,
    ex_lit: xla::Literal,
    /// Membership of each real feature in the current set S.
    in_s: Vec<bool>,
}

impl<'a> EngineCore<'a> {
    /// Validate the problem, pick the smallest enclosing bucket, build
    /// the padded literals.
    fn open(
        rt: &Runtime,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<EngineCore<'a>> {
        let n = x.rows();
        let m = x.cols();
        let (mb, nb) = rt.pick_bucket(m, n).ok_or_else(|| {
            anyhow!(
                "no artifact bucket fits (m={m}, n={n}); rebuild artifacts \
                 with larger buckets (python -m compile.aot --buckets ...)"
            )
        })?;
        EngineCore::at_bucket(x, y, cfg, mb, nb)
    }

    /// [`EngineCore::open`] at a caller-chosen bucket (the n-fold engine
    /// also constrains fold capacity when picking).
    fn at_bucket(
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
        mb: usize,
        nb: usize,
    ) -> anyhow::Result<EngineCore<'a>> {
        let n = x.rows();
        let m = x.cols();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(m == y.len(), "shape mismatch");
        // the compiled artifacts are f64-only; mixed precision is the
        // native in-RAM greedy engine's feature
        ensure!(
            cfg.precision == crate::kernel::Precision::F64,
            "--precision {} is not supported by the pjrt engine",
            cfg.precision,
        );
        // candidate masking lives in the compiled artifacts' BIG
        // sentinel path; a pre-round survivor filter has no lowering
        // yet, so the engine rejects it instead of silently scanning
        // every candidate under a config that claims otherwise
        ensure!(
            cfg.preselect.is_none(),
            "--preselect is not supported by the pjrt engine (sketched \
             preselection runs on the native greedy-rls engine)",
        );
        // Pad feature-major x (n × m) into the (nb rows × mb cols) bucket.
        let mut x_pad = vec![0.0; nb * mb];
        for i in 0..n {
            x_pad[i * mb..i * mb + m].copy_from_slice(x.row(i));
        }
        let x_lit = lit::mat_f64(&x_pad, nb, mb)?;
        let mut y_pad = vec![0.0; mb];
        y_pad[..m].copy_from_slice(y);
        let y_lit = lit::vec_f64(&y_pad);
        let mut ex_mask = vec![0.0; mb];
        ex_mask[..m].fill(1.0);
        let ex_lit = lit::vec_f64(&ex_mask);
        Ok(EngineCore {
            x,
            y,
            loss: cfg.loss,
            m,
            n,
            mb,
            nb,
            x_lit,
            y_lit,
            ex_lit,
            in_s: vec![false; n],
        })
    }

    /// Bucket-length mask literal: 1.0 where `member(i)` for real
    /// features, 0.0 elsewhere (padded candidates stay masked).
    fn mask_lit(&self, member: impl Fn(usize) -> bool) -> xla::Literal {
        let mut mask = vec![0.0; self.nb];
        for (i, slot) in mask.iter_mut().take(self.n).enumerate() {
            if member(i) {
                *slot = 1.0;
            }
        }
        lit::vec_f64(&mask)
    }

    /// Pick this round's feature: the caller-forced candidate (validated
    /// against `want_member` — removal rounds force members, addition
    /// rounds force non-members) or the strict argmin over `scores`.
    fn pick(
        &self,
        forced: Option<usize>,
        scores: &[f64],
        want_member: bool,
        exhausted_msg: &str,
    ) -> anyhow::Result<(usize, f64)> {
        match forced {
            Some(b) => {
                ensure!(b < self.n, "feature {b} out of range (n={})", self.n);
                if want_member {
                    ensure!(self.in_s[b], "feature {b} already removed");
                    ensure!(
                        scores[b] < BIG,
                        "feature {b} is not numerically removable this round"
                    );
                } else {
                    ensure!(!self.in_s[b], "feature {b} already selected");
                }
                Ok((b, scores[b]))
            }
            None => {
                let b = argmin(scores)
                    .ok_or_else(|| anyhow!("{exhausted_msg}"))?;
                Ok((b, scores[b]))
            }
        }
    }

    /// Unpack a two-output score launch, select the configured loss row,
    /// and truncate to the real candidate count.
    fn scores_from(
        &self,
        outs: Vec<xla::Literal>,
    ) -> anyhow::Result<Vec<f64>> {
        ensure!(outs.len() == 2, "score launch returned {}", outs.len());
        let [e_sq, e_01] = &outs[..] else { unreachable!() };
        let picked = match self.loss {
            Loss::Squared => e_sq,
            Loss::ZeroOne => e_01,
        };
        let mut v = lit::to_vec_f64(picked)?;
        v.truncate(self.n);
        Ok(v)
    }

    /// w = X_S a over the unpadded coordinates, in `selected` order.
    fn weights_for(
        &self,
        a_lit: &xla::Literal,
        selected: &[usize],
    ) -> anyhow::Result<Vec<f64>> {
        let a_full = lit::to_vec_f64(a_lit)?;
        let a = &a_full[..self.m];
        Ok(selected.iter().map(|&i| dot(self.x.row(i), a)).collect())
    }

    /// Features currently in S, ascending.
    fn members(&self) -> Vec<usize> {
        (0..self.n).filter(|&i| self.in_s[i]).collect()
    }
}

// ---------------------------------------------------------------------------
// CadState: the [C, a, d] device state and its four launches
// ---------------------------------------------------------------------------

/// Which artifacts a [`CadState`] engine needs compiled.
struct CadExes {
    score: Exe,
    commit: Exe,
    /// Removal-direction launches; present when the selector takes
    /// backward steps (backward elimination, FoBa, floating).
    score_removal: Option<Exe>,
    downdate: Option<Exe>,
}

/// `[C, a, d]` state on device plus the launches over it. The state
/// tuple is exactly the native greedy/backward cache triple; addition
/// and removal use the sign-flipped SMW pair of kernels.
pub(crate) struct CadState<'a> {
    core: EngineCore<'a>,
    exes: CadExes,
    /// `[C, a, d]` literals.
    state: Vec<xla::Literal>,
}

impl<'a> CadState<'a> {
    /// Open the engine: pick a bucket, compile the needed entry points,
    /// and initialize the device state — `init_state` (empty S) or
    /// `full_init_state` (S = all features, backward elimination's
    /// starting point; one launch, n in-device rank-1 commits).
    fn open(
        rt: &Runtime,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
        with_removal: bool,
        full_init: bool,
    ) -> anyhow::Result<CadState<'a>> {
        let mut core = EngineCore::open(rt, x, y, cfg)?;
        let (mb, nb) = (core.mb, core.nb);
        let init_entry =
            if full_init { "full_init_state" } else { "init_state" };
        let init = rt.executable(init_entry, mb, nb)?;
        let exes = CadExes {
            score: rt.executable("score_step", mb, nb)?,
            commit: rt.executable("commit_step", mb, nb)?,
            score_removal: with_removal
                .then(|| rt.executable("score_removal_step", mb, nb))
                .transpose()?,
            downdate: with_removal
                .then(|| rt.executable("downdate_step", mb, nb))
                .transpose()?,
        };
        let lam_lit = lit::vec_f64(&[cfg.lambda]);
        let state = Runtime::run_tuple(
            &init,
            &[core.x_lit.clone(), core.y_lit.clone(), lam_lit],
        )?;
        ensure!(state.len() == 3, "{init_entry} returned {}", state.len());
        if full_init {
            core.in_s.fill(true);
        }
        Ok(CadState { core, exes, state })
    }

    /// Masked score launch in one SMW direction: additions score the
    /// non-members, removals score the members.
    fn scores(&self, removal: bool) -> anyhow::Result<Vec<f64>> {
        let (exe, mask) = if removal {
            let exe = self
                .exes
                .score_removal
                .as_ref()
                .expect("engine opened without removal launches");
            (exe, self.core.mask_lit(|i| self.core.in_s[i]))
        } else {
            (&self.exes.score, self.core.mask_lit(|i| !self.core.in_s[i]))
        };
        let outs = Runtime::run_tuple(
            exe,
            &[
                self.core.x_lit.clone(),
                self.state[0].clone(),
                self.state[1].clone(),
                self.state[2].clone(),
                self.core.y_lit.clone(),
                mask,
                self.core.ex_lit.clone(),
            ],
        )?;
        self.core.scores_from(outs)
    }

    /// Rank-1 state update in one SMW direction: commit (add `b` to S)
    /// or downdate (remove `b` from S).
    fn update(&mut self, b: usize, removal: bool) -> anyhow::Result<()> {
        let exe = if removal {
            self.exes
                .downdate
                .as_ref()
                .expect("engine opened without removal launches")
        } else {
            &self.exes.commit
        };
        let entry = if removal { "downdate_step" } else { "commit_step" };
        let b_lit = lit::scalar_i32(b as i32);
        self.state = Runtime::run_tuple(
            exe,
            &[
                self.core.x_lit.clone(),
                self.state[0].clone(),
                self.state[1].clone(),
                self.state[2].clone(),
                b_lit,
            ],
        )?;
        ensure!(
            self.state.len() == 3,
            "{entry} returned {}",
            self.state.len()
        );
        self.core.in_s[b] = !removal;
        Ok(())
    }

    fn weights_for(&self, selected: &[usize]) -> anyhow::Result<Vec<f64>> {
        self.core.weights_for(&self.state[1], selected)
    }
}

// ---------------------------------------------------------------------------
// Greedy RLS (Algorithm 3)
// ---------------------------------------------------------------------------

/// Greedy RLS driven through the PJRT artifacts.
pub struct PjrtGreedy<'rt> {
    rt: &'rt Runtime,
}

impl<'rt> PjrtGreedy<'rt> {
    /// Bind the engine to a runtime (artifacts must be built).
    pub fn new(rt: &'rt Runtime) -> Self {
        PjrtGreedy { rt }
    }
}

/// Round-by-round greedy engine. Forced rounds (warm-start replay) run
/// the same full `score_step` launch as greedy rounds — the kernel has no
/// single-candidate entry point — so a PJRT replay costs one score + one
/// commit launch per round.
struct PjrtGreedyCore<'a> {
    st: CadState<'a>,
    k: usize,
    selected: Vec<usize>,
    rounds: Vec<Round>,
}

impl SessionCore for PjrtGreedyCore<'_> {
    fn target_reached(&self) -> bool {
        self.selected.len() >= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let scores = self.st.scores(false)?;
        let (b, criterion) =
            self.st.core.pick(forced, &scores, false, "no candidate left")?;
        self.st.update(b, false)?;
        self.selected.push(b);
        let round = Round { feature: b, criterion };
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.selected.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        self.st.weights_for(&self.selected)
    }
}

impl SessionSelector for PjrtGreedy<'_> {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let st = CadState::open(self.rt, x, y, cfg, false, false)?;
        let core = PjrtGreedyCore {
            st,
            k: cfg.k,
            selected: Vec::with_capacity(cfg.k),
            rounds: Vec::with_capacity(cfg.k),
        };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for PjrtGreedy<'_> {
    fn name(&self) -> &'static str {
        "greedy-rls-pjrt"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        crate::select::run_to_completion(self.begin(x, y, cfg)?)
    }
}

// ---------------------------------------------------------------------------
// Backward elimination
// ---------------------------------------------------------------------------

/// Backward elimination driven through the PJRT artifacts: one
/// `full_init_state` launch trains on the full feature set, then every
/// elimination round is one masked removal-score launch + one downdate
/// launch (the sign-flipped SMW pair).
pub struct PjrtBackward<'rt> {
    rt: &'rt Runtime,
}

impl<'rt> PjrtBackward<'rt> {
    /// Bind the engine to a runtime (artifacts must be built).
    pub fn new(rt: &'rt Runtime) -> Self {
        PjrtBackward { rt }
    }
}

/// Each round is one *elimination*: the round log records the removed
/// feature, `selected()` is the set still standing in ascending order —
/// the native [`crate::select::backward`] conventions exactly.
struct PjrtBackwardCore<'a> {
    st: CadState<'a>,
    k: usize,
    rounds: Vec<Round>,
}

impl SessionCore for PjrtBackwardCore<'_> {
    fn target_reached(&self) -> bool {
        self.st.core.n - self.rounds.len() <= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let scores = self.st.scores(true)?;
        let (b, criterion) =
            self.st.core.pick(forced, &scores, true, "no removable feature")?;
        self.st.update(b, true)?;
        let round = Round { feature: b, criterion };
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.st.core.members()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        self.st.weights_for(&self.selected())
    }
}

impl SessionSelector for PjrtBackward<'_> {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let st = CadState::open(self.rt, x, y, cfg, true, true)?;
        let core = PjrtBackwardCore { st, k: cfg.k, rounds: Vec::new() };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for PjrtBackward<'_> {
    fn name(&self) -> &'static str {
        "backward-elimination-pjrt"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        crate::select::run_to_completion(self.begin(x, y, cfg)?)
    }
}

// ---------------------------------------------------------------------------
// FoBa (adaptive forward–backward greedy)
// ---------------------------------------------------------------------------

/// FoBa driven through the PJRT artifacts: forward additions via the
/// score launch, ν-thresholded corrective deletions and the swap phase
/// via the removal launch. Control flow mirrors the native
/// [`crate::select::foba`] engine; criteria come from the `[C, a, d]`
/// cache scans instead of per-subset retraining (the same LOO values up
/// to f64 rounding, so the equivalence tests are tolerance-based).
///
/// **Degenerate-data divergence:** the removal kernel scores a member
/// `BIG` when its SMW denominator collapses (|1 − v·c| < 1e-12); the
/// native engine retrains the subset instead and always gets a finite
/// score. On such data this engine simply never deletes that member
/// (an all-`BIG` scan keeps the set / ends the swap phase) where the
/// native run might — the parity tests use well-conditioned problems.
pub struct PjrtFoba<'rt> {
    rt: &'rt Runtime,
    /// Native-parameter twin (ν, swap phase, step budget).
    pub params: crate::select::foba::Foba,
}

impl<'rt> PjrtFoba<'rt> {
    /// Bind the engine to a runtime with default FoBa parameters.
    pub fn new(rt: &'rt Runtime) -> Self {
        PjrtFoba { rt, params: Default::default() }
    }

    /// Override the FoBa parameters (must match the native selector's
    /// for equivalence).
    pub fn with_params(rt: &'rt Runtime, params: crate::select::foba::Foba) -> Self {
        PjrtFoba { rt, params }
    }
}

struct PjrtFobaCore<'a> {
    st: CadState<'a>,
    k: usize,
    nu: f64,
    swap: bool,
    max_steps: usize,
    /// Selection order (native FoBa's `s`).
    s: Vec<usize>,
    rounds: Vec<Round>,
    steps: usize,
    cur: f64,
    stable: bool,
}

impl PjrtFobaCore<'_> {
    /// Deletion scores by *position* in `s`, preserving the native
    /// engine's lowest-position tie-break.
    fn deletion_scores(&self) -> anyhow::Result<Vec<f64>> {
        let by_feature = self.st.scores(true)?;
        Ok(self.s.iter().map(|&f| by_feature[f]).collect())
    }

    fn grow_round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        self.steps += 1;
        let scores = self.st.scores(false)?;
        let (b, score_b) = match forced {
            Some(_) => {
                self.st.core.pick(forced, &scores, false, "no candidate left")?
            }
            None => match argmin(&scores) {
                Some(b) => (b, scores[b]),
                None => return Ok(CoreStep::Exhausted),
            },
        };
        let fwd_gain = self.cur - score_b;
        self.st.update(b, false)?;
        self.s.push(b);
        self.cur = score_b;
        let round = Round { feature: b, criterion: self.cur };
        self.rounds.push(round.clone());
        if fwd_gain > 0.0 {
            // delete while cheap relative to the forward gain; members
            // the removal kernel marks numerically unremovable (BIG)
            // are simply never deleted — see the divergence note on
            // [`PjrtFoba`]
            while self.s.len() > 1 && self.steps < self.max_steps {
                self.steps += 1;
                let del = self.deletion_scores()?;
                let Some(pos) = argmin(&del) else { break };
                if del[pos] - self.cur < self.nu * fwd_gain {
                    let f = self.s[pos];
                    self.st.update(f, true)?;
                    self.s.remove(pos);
                    self.cur = del[pos];
                } else {
                    break;
                }
            }
        }
        Ok(CoreStep::Committed(round))
    }

    fn swap_round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        self.steps += 1;
        // the overshoot feature's own score is never recorded — only the
        // argmin needs the scan, so a forced swap (warm-start replay)
        // skips the launch entirely, like the native engine
        let b = match forced {
            Some(b) => {
                let n = self.st.core.n;
                ensure!(b < n, "feature {b} out of range (n={n})");
                ensure!(
                    !self.st.core.in_s[b],
                    "feature {b} already selected"
                );
                b
            }
            None => {
                let scores = self.st.scores(false)?;
                match argmin(&scores) {
                    Some(b) => b,
                    None => {
                        self.stable = true;
                        return Ok(CoreStep::Exhausted);
                    }
                }
            }
        };
        self.st.update(b, false)?;
        self.s.push(b);
        let del = self.deletion_scores()?;
        // every deletion numerically unremovable ⇒ no improving swap
        let Some(pos) = argmin(&del) else {
            self.st.update(b, true)?;
            self.s.pop();
            self.stable = true;
            return Ok(CoreStep::Exhausted);
        };
        if self.s[pos] == b || del[pos] >= self.cur {
            self.st.update(b, true)?; // undo the overshoot — stable
            self.s.pop();
            self.stable = true;
            return Ok(CoreStep::Exhausted);
        }
        let f = self.s[pos];
        self.st.update(f, true)?;
        self.s.remove(pos);
        self.cur = del[pos];
        let round = Round { feature: b, criterion: self.cur };
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }
}

impl SessionCore for PjrtFobaCore<'_> {
    fn target_reached(&self) -> bool {
        self.s.len() >= self.k
            && (!self.swap || self.k >= self.st.core.n || self.stable)
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        if self.s.len() < self.k {
            if self.steps >= self.max_steps {
                return Ok(CoreStep::Exhausted);
            }
            self.grow_round(forced)
        } else if self.swap && self.k < self.st.core.n && !self.stable {
            if self.steps >= self.max_steps {
                return Ok(CoreStep::Exhausted);
            }
            self.swap_round(forced)
        } else {
            Ok(CoreStep::Exhausted)
        }
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.s.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        self.st.weights_for(&self.s)
    }
}

impl SessionSelector for PjrtFoba<'_> {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        ensure!(self.params.nu > 0.0, "ν must be positive");
        let st = CadState::open(self.rt, x, y, cfg, true, false)?;
        // empty-model LOO: predict 0 for everything (host-side; no scan)
        let cur = st
            .core
            .y
            .iter()
            .map(|&yv| cfg.loss.eval(yv, 0.0))
            .sum();
        let core = PjrtFobaCore {
            st,
            k: cfg.k,
            nu: self.params.nu,
            swap: self.params.swap,
            max_steps: self.params.max_steps,
            s: Vec::new(),
            rounds: Vec::new(),
            steps: 0,
            cur,
            stable: false,
        };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for PjrtFoba<'_> {
    fn name(&self) -> &'static str {
        "foba-pjrt"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        crate::select::run_to_completion(self.begin(x, y, cfg)?)
    }
}

// ---------------------------------------------------------------------------
// Floating forward selection (SFFS)
// ---------------------------------------------------------------------------

/// SFFS driven through the PJRT artifacts: one session round is a
/// forward score+commit launch plus its conditional backward
/// (removal-score + downdate) launches, mirroring the native
/// [`crate::select::floating`] control flow. Shares [`PjrtFoba`]'s
/// degenerate-data divergence note: numerically unremovable members
/// (`BIG` removal scores) are never floated out.
pub struct PjrtFloating<'rt> {
    rt: &'rt Runtime,
    /// Native-parameter twin (step budget).
    pub params: crate::select::floating::FloatingForward,
}

impl<'rt> PjrtFloating<'rt> {
    /// Bind the engine to a runtime with the default step budget.
    pub fn new(rt: &'rt Runtime) -> Self {
        PjrtFloating { rt, params: Default::default() }
    }
}

struct PjrtFloatingCore<'a> {
    st: CadState<'a>,
    k: usize,
    max_steps: usize,
    s: Vec<usize>,
    /// Best criterion seen for each subset size (index = |S|).
    best_at: Vec<f64>,
    steps: usize,
    rounds: Vec<Round>,
}

impl SessionCore for PjrtFloatingCore<'_> {
    fn target_reached(&self) -> bool {
        self.s.len() >= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        if self.steps >= self.max_steps {
            return Ok(CoreStep::Exhausted);
        }
        self.steps += 1;
        let scores = self.st.scores(false)?;
        let (b, cur) = match forced {
            Some(_) => {
                self.st.core.pick(forced, &scores, false, "no candidate left")?
            }
            None => {
                let b = argmin(&scores)
                    .ok_or_else(|| anyhow!("no candidate left"))?;
                (b, scores[b])
            }
        };
        self.st.update(b, false)?;
        self.s.push(b);
        self.best_at[self.s.len()] = self.best_at[self.s.len()].min(cur);
        let round = Round { feature: b, criterion: cur };
        self.rounds.push(round.clone());

        // conditional backward steps (never undo the just-added one
        // immediately into an empty improvement loop)
        while self.s.len() > 2 && self.steps < self.max_steps {
            self.steps += 1;
            let by_feature = self.st.scores(true)?;
            let rem_scores: Vec<f64> =
                self.s.iter().map(|&f| by_feature[f]).collect();
            // all members numerically unremovable (BIG) ⇒ keep the set
            let Some(worst_pos) = argmin(&rem_scores) else { break };
            let smaller = self.s.len() - 1;
            if rem_scores[worst_pos] + 1e-12 < self.best_at[smaller] {
                self.best_at[smaller] = rem_scores[worst_pos];
                let f = self.s[worst_pos];
                self.st.update(f, true)?;
                self.s.remove(worst_pos);
            } else {
                break;
            }
        }
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.s.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        self.st.weights_for(&self.s)
    }
}

impl SessionSelector for PjrtFloating<'_> {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let st = CadState::open(self.rt, x, y, cfg, true, false)?;
        let core = PjrtFloatingCore {
            st,
            k: cfg.k,
            max_steps: self.params.max_steps,
            s: Vec::new(),
            best_at: vec![f64::INFINITY; cfg.k + 1],
            steps: 0,
            rounds: Vec::new(),
        };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for PjrtFloating<'_> {
    fn name(&self) -> &'static str {
        "floating-forward-pjrt"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        crate::select::run_to_completion(self.begin(x, y, cfg)?)
    }
}

// ---------------------------------------------------------------------------
// n-fold-CV greedy (fold-masked scoring)
// ---------------------------------------------------------------------------

/// n-fold greedy driven through the PJRT artifacts. The device state is
/// `[C, a, B]` where `B` holds the fold-diagonal blocks of G at a static
/// (f, s) capacity baked into the `nfold_*` artifacts (read back from
/// the manifest's extra columns); scoring is one fold-masked launch over
/// all candidates, solving every (fold × candidate) block with batched
/// CG — which is why the equivalence tests for this engine are
/// tolerance-based (the native engine factors with Cholesky).
pub struct PjrtNFold<'rt> {
    rt: &'rt Runtime,
    /// Fold-count/seed twin of the native selector; the fold assignment
    /// is drawn by the shared [`crate::select::nfold::NFoldGreedy`] code
    /// path, so both engines score identical partitions.
    pub params: crate::select::nfold::NFoldGreedy,
}

impl<'rt> PjrtNFold<'rt> {
    /// Bind the engine to a runtime with the native default folds/seed.
    pub fn new(rt: &'rt Runtime) -> Self {
        PjrtNFold { rt, params: Default::default() }
    }

    /// Override fold count and assignment seed (must match the native
    /// selector's for equivalence).
    pub fn with_params(
        rt: &'rt Runtime,
        params: crate::select::nfold::NFoldGreedy,
    ) -> Self {
        PjrtNFold { rt, params }
    }

    /// Smallest bucket fitting (m, n) whose `nfold_*` artifacts also fit
    /// the fold layout: fold count ≤ f capacity, max fold size ≤ s
    /// capacity.
    fn pick_nfold_bucket(
        &self,
        m: usize,
        n: usize,
        folds: &[Vec<usize>],
    ) -> anyhow::Result<(usize, usize, usize, usize)> {
        let max_fold = folds.iter().map(Vec::len).max().unwrap_or(0);
        for (mb, nb) in self.rt.selection_buckets() {
            if mb < m || nb < n {
                continue;
            }
            let (Some(score), Some(commit)) = (
                self.rt.entry_at("nfold_score_step", mb, nb),
                self.rt.entry_at("nfold_commit_step", mb, nb),
            ) else {
                continue;
            };
            let (Some(fc), Some(sc)) =
                (score.extra_dim("f"), score.extra_dim("s"))
            else {
                continue;
            };
            ensure!(
                commit.extra_dim("f") == Some(fc)
                    && commit.extra_dim("s") == Some(sc),
                "nfold artifacts at ({mb}, {nb}) disagree on fold capacity"
            );
            if folds.len() <= fc && max_fold <= sc {
                return Ok((mb, nb, fc, sc));
            }
        }
        Err(anyhow!(
            "no nfold artifact bucket fits m={m}, n={n} with {} folds of \
             max size {max_fold}; use more/smaller folds, rebuild artifacts \
             with larger buckets, or run the native engine",
            folds.len()
        ))
    }
}

/// `[C, a, B]` engine state + fold tensors.
struct NfState<'a> {
    core: EngineCore<'a>,
    score: Exe,
    commit: Exe,
    /// `[C, a, B]` literals.
    state: Vec<xla::Literal>,
    fidx_lit: xla::Literal,
    fmask_lit: xla::Literal,
}

struct PjrtNFoldCore<'a> {
    st: NfState<'a>,
    k: usize,
    selected: Vec<usize>,
    rounds: Vec<Round>,
}

impl PjrtNFoldCore<'_> {
    fn scores(&self) -> anyhow::Result<Vec<f64>> {
        let st = &self.st;
        let mask = st.core.mask_lit(|i| !st.core.in_s[i]);
        let outs = Runtime::run_tuple(
            &st.score,
            &[
                st.core.x_lit.clone(),
                st.state[0].clone(),
                st.state[1].clone(),
                st.core.y_lit.clone(),
                st.state[2].clone(),
                st.fidx_lit.clone(),
                st.fmask_lit.clone(),
                mask,
            ],
        )?;
        st.core.scores_from(outs)
    }

    fn commit(&mut self, b: usize) -> anyhow::Result<()> {
        let st = &mut self.st;
        let b_lit = lit::scalar_i32(b as i32);
        st.state = Runtime::run_tuple(
            &st.commit,
            &[
                st.core.x_lit.clone(),
                st.state[0].clone(),
                st.state[1].clone(),
                st.state[2].clone(),
                st.fidx_lit.clone(),
                st.fmask_lit.clone(),
                b_lit,
            ],
        )?;
        ensure!(
            st.state.len() == 3,
            "nfold_commit_step returned {}",
            st.state.len()
        );
        st.core.in_s[b] = true;
        Ok(())
    }
}

impl SessionCore for PjrtNFoldCore<'_> {
    fn target_reached(&self) -> bool {
        self.selected.len() >= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let scores = self.scores()?;
        let (b, criterion) =
            self.st.core.pick(forced, &scores, false, "no candidate left")?;
        if forced.is_some() {
            // mirror the native forced-round guard: a fold block that
            // fails to factor makes the candidate unevaluable
            ensure!(
                criterion < BIG,
                "feature {b} is not evaluable this round"
            );
        }
        self.commit(b)?;
        self.selected.push(b);
        let round = Round { feature: b, criterion };
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.selected.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        self.st.core.weights_for(&self.st.state[1], &self.selected)
    }
}

impl SessionSelector for PjrtNFold<'_> {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let m = x.cols();
        ensure!(
            self.params.folds >= 2 && self.params.folds <= m,
            "bad fold count"
        );
        // identical fold assignment to the native engine
        let folds = self.params.fold_assignment(m);
        let (mb, nb, fc, sc) =
            self.pick_nfold_bucket(m, x.rows(), &folds)?;
        let core = EngineCore::at_bucket(x, y, cfg, mb, nb)?;

        let init = self.rt.executable("init_state", mb, nb)?;
        let score = self.rt.executable("nfold_score_step", mb, nb)?;
        let commit = self.rt.executable("nfold_commit_step", mb, nb)?;

        // fold tensors: member indices + slot mask, padded slots at 0
        let mut fidx = vec![0i32; fc * sc];
        let mut fmask = vec![0.0f64; fc * sc];
        for (h, members) in folds.iter().enumerate() {
            for (t, &j) in members.iter().enumerate() {
                fidx[h * sc + t] = j as i32;
                fmask[h * sc + t] = 1.0;
            }
        }
        let fidx_lit = lit::mat_i32(&fidx, fc, sc)?;
        let fmask_lit = lit::mat_f64(&fmask, fc, sc)?;

        // G = λ⁻¹ I for the empty set ⇒ every fold block starts as λ⁻¹ I
        let inv = 1.0 / cfg.lambda;
        let mut blocks = vec![0.0f64; fc * sc * sc];
        for h in 0..fc {
            for t in 0..sc {
                blocks[h * sc * sc + t * sc + t] = inv;
            }
        }
        let b_lit = lit::tensor3_f64(&blocks, fc, sc, sc)?;

        let lam_lit = lit::vec_f64(&[cfg.lambda]);
        let init_state = Runtime::run_tuple(
            &init,
            &[core.x_lit.clone(), core.y_lit.clone(), lam_lit],
        )?;
        ensure!(
            init_state.len() == 3,
            "init_state returned {}",
            init_state.len()
        );
        let [c_lit, a_lit, _d_unused] =
            <[xla::Literal; 3]>::try_from(init_state)
                .map_err(|_| anyhow!("init_state tuple"))?;

        let st = NfState {
            core,
            score,
            commit,
            state: vec![c_lit, a_lit, b_lit],
            fidx_lit,
            fmask_lit,
        };
        let core = PjrtNFoldCore {
            st,
            k: cfg.k,
            selected: Vec::with_capacity(cfg.k),
            rounds: Vec::with_capacity(cfg.k),
        };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for PjrtNFold<'_> {
    fn name(&self) -> &'static str {
        "nfold-greedy-pjrt"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        crate::select::run_to_completion(self.begin(x, y, cfg)?)
    }
}

// Literal cloning: xla::Literal is a C++ heap object behind a pointer; the
// crate exposes Clone via copy construction, which we rely on for feeding
// state tuples back. (Cheap relative to kernel execution.)

#[cfg(test)]
mod tests {
    // PJRT integration tests require compiled artifacts; they live in
    // rust/tests/pjrt_integration.rs so `cargo test --lib` stays hermetic.
}
