//! PJRT-backed greedy RLS engine.
//!
//! Runs the paper's Algorithm 3 with every O(mn) step executed by the
//! AOT-compiled Layer-1/2 artifacts (Pallas score kernel + rank-1 update),
//! while Rust owns the control flow: bucket choice, padding, the argmin,
//! the selected-set mask, and the final weight extraction.
//!
//! Padding into a bucket is **exact** (DESIGN.md §5): zero feature rows
//! and zero labels for padded examples contribute nothing to any cache or
//! loss; padded candidates are masked to BIG by the kernel. The engine is
//! equivalence-tested against the native [`crate::select::greedy`] engine.

use std::rc::Rc;

use anyhow::{anyhow, ensure};

use super::{lit, xla, Runtime};
use crate::linalg::{dot, Matrix};
use crate::metrics::Loss;
use crate::select::session::{
    CoreStep, PolicySession, Session, SessionCore, SessionSelector,
};
use crate::select::{argmin, Round, SelectionConfig, SelectionResult, Selector};

/// Greedy RLS driven through the PJRT artifacts.
pub struct PjrtGreedy<'rt> {
    rt: &'rt Runtime,
}

impl<'rt> PjrtGreedy<'rt> {
    /// Bind the engine to a runtime (artifacts must be built).
    pub fn new(rt: &'rt Runtime) -> Self {
        PjrtGreedy { rt }
    }

    /// Pad feature-major `x` (n × m) into bucket (nb rows × mb cols).
    fn pad_x(x: &Matrix, mb: usize, nb: usize) -> Vec<f64> {
        let (n, m) = (x.rows(), x.cols());
        let mut out = vec![0.0; nb * mb];
        for i in 0..n {
            out[i * mb..i * mb + m].copy_from_slice(x.row(i));
        }
        out
    }
}

/// Round-by-round engine over the artifacts. The executables are cloned
/// `Rc`s and all literals are owned, so the session borrows only the
/// problem data, not the [`Runtime`]. Forced rounds (warm-start replay)
/// run the same full `score_step` launch as greedy rounds — the kernel
/// has no single-candidate entry point — so a PJRT replay costs one
/// score + one commit launch per round.
struct PjrtCore<'a> {
    x: &'a Matrix,
    loss: Loss,
    k: usize,
    n: usize,
    m: usize,
    score: Rc<xla::PjRtLoadedExecutable>,
    commit: Rc<xla::PjRtLoadedExecutable>,
    x_lit: xla::Literal,
    y_lit: xla::Literal,
    ex_lit: xla::Literal,
    /// [C, a, d] device state.
    state: Vec<xla::Literal>,
    cand_mask: Vec<f64>,
    selected: Vec<usize>,
    rounds: Vec<Round>,
}

impl SessionCore for PjrtCore<'_> {
    fn target_reached(&self) -> bool {
        self.selected.len() >= self.k
    }

    fn round(&mut self, forced: Option<usize>) -> anyhow::Result<CoreStep> {
        let n = self.n;
        let cm_lit = lit::vec_f64(&self.cand_mask);
        let outs = Runtime::run_tuple(
            &self.score,
            &[
                self.x_lit.clone(),
                self.state[0].clone(),
                self.state[1].clone(),
                self.state[2].clone(),
                self.y_lit.clone(),
                cm_lit,
                self.ex_lit.clone(),
            ],
        )?;
        ensure!(outs.len() == 2, "score_step returned {}", outs.len());
        let e_sq = lit::to_vec_f64(&outs[0])?;
        let e_01 = lit::to_vec_f64(&outs[1])?;
        let scores = match self.loss {
            Loss::Squared => &e_sq,
            Loss::ZeroOne => &e_01,
        };
        let b = match forced {
            Some(b) => {
                ensure!(b < n, "feature {b} out of range (n={n})");
                ensure!(
                    self.cand_mask[b] != 0.0,
                    "feature {b} already selected"
                );
                b
            }
            None => argmin(&scores[..n])
                .ok_or_else(|| anyhow!("no candidate left"))?,
        };
        let round = Round { feature: b, criterion: scores[b] };

        let b_lit = lit::scalar_i32(b as i32);
        self.state = Runtime::run_tuple(
            &self.commit,
            &[
                self.x_lit.clone(),
                self.state[0].clone(),
                self.state[1].clone(),
                self.state[2].clone(),
                b_lit,
            ],
        )?;
        ensure!(
            self.state.len() == 3,
            "commit_step returned {}",
            self.state.len()
        );
        self.cand_mask[b] = 0.0;
        self.selected.push(b);
        self.rounds.push(round.clone());
        Ok(CoreStep::Committed(round))
    }

    fn rounds(&self) -> &[Round] {
        &self.rounds
    }

    fn selected(&self) -> Vec<usize> {
        self.selected.clone()
    }

    fn weights(&self) -> anyhow::Result<Vec<f64>> {
        // w = X_S a (unpadded coordinates only).
        let a_full = lit::to_vec_f64(&self.state[1])?;
        let a = &a_full[..self.m];
        Ok(self
            .selected
            .iter()
            .map(|&i| dot(self.x.row(i), a))
            .collect())
    }
}

impl SessionSelector for PjrtGreedy<'_> {
    fn begin<'a>(
        &self,
        x: &'a Matrix,
        y: &'a [f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<Box<dyn Session + 'a>> {
        let n = x.rows();
        let m = x.cols();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(m == y.len(), "shape mismatch");
        let (mb, nb) = self.rt.pick_bucket(m, n).ok_or_else(|| {
            anyhow!(
                "no artifact bucket fits (m={m}, n={n}); rebuild artifacts \
                 with larger buckets (python -m compile.aot --buckets ...)"
            )
        })?;

        let init = self.rt.executable("init_state", mb, nb)?;
        let score = self.rt.executable("score_step", mb, nb)?;
        let commit = self.rt.executable("commit_step", mb, nb)?;

        // Padded constants.
        let x_pad = PjrtGreedy::pad_x(x, mb, nb);
        let x_lit = lit::mat_f64(&x_pad, nb, mb)?;
        let mut y_pad = vec![0.0; mb];
        y_pad[..m].copy_from_slice(y);
        let y_lit = lit::vec_f64(&y_pad);
        let mut ex_mask = vec![0.0; mb];
        ex_mask[..m].fill(1.0);
        let ex_lit = lit::vec_f64(&ex_mask);

        // init_state(X, y, λ) -> (C, a, d)
        let lam_lit = lit::vec_f64(&[cfg.lambda]);
        let state =
            Runtime::run_tuple(&init, &[x_lit.clone(), y_lit.clone(), lam_lit])?;
        ensure!(state.len() == 3, "init_state returned {}", state.len());

        let mut cand_mask = vec![0.0; nb];
        cand_mask[..n].fill(1.0);
        let core = PjrtCore {
            x,
            loss: cfg.loss,
            k: cfg.k,
            n,
            m,
            score,
            commit,
            x_lit,
            y_lit,
            ex_lit,
            state,
            cand_mask,
            selected: Vec::with_capacity(cfg.k),
            rounds: Vec::with_capacity(cfg.k),
        };
        Ok(Box::new(PolicySession::new(core, cfg)?))
    }
}

impl Selector for PjrtGreedy<'_> {
    fn name(&self) -> &'static str {
        "greedy-rls-pjrt"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        crate::select::run_to_completion(self.begin(x, y, cfg)?)
    }
}

// Literal cloning: xla::Literal is a C++ heap object behind a pointer; the
// crate exposes Clone via copy construction, which we rely on for feeding
// state tuples back. (Cheap relative to kernel execution.)

#[cfg(test)]
mod tests {
    // PJRT integration tests require compiled artifacts; they live in
    // rust/tests/pjrt_integration.rs so `cargo test --lib` stays hermetic.
}
