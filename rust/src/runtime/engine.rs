//! PJRT-backed greedy RLS engine.
//!
//! Runs the paper's Algorithm 3 with every O(mn) step executed by the
//! AOT-compiled Layer-1/2 artifacts (Pallas score kernel + rank-1 update),
//! while Rust owns the control flow: bucket choice, padding, the argmin,
//! the selected-set mask, and the final weight extraction.
//!
//! Padding into a bucket is **exact** (DESIGN.md §5): zero feature rows
//! and zero labels for padded examples contribute nothing to any cache or
//! loss; padded candidates are masked to BIG by the kernel. The engine is
//! equivalence-tested against the native [`crate::select::greedy`] engine.

use anyhow::{anyhow, ensure};

use super::{lit, Runtime};
use crate::linalg::{dot, Matrix};
use crate::metrics::Loss;
use crate::select::{
    argmin, Round, SelectionConfig, SelectionResult, Selector,
};

/// Greedy RLS driven through the PJRT artifacts.
pub struct PjrtGreedy<'rt> {
    rt: &'rt Runtime,
}

impl<'rt> PjrtGreedy<'rt> {
    /// Bind the engine to a runtime (artifacts must be built).
    pub fn new(rt: &'rt Runtime) -> Self {
        PjrtGreedy { rt }
    }

    /// Pad feature-major `x` (n × m) into bucket (nb rows × mb cols).
    fn pad_x(x: &Matrix, mb: usize, nb: usize) -> Vec<f64> {
        let (n, m) = (x.rows(), x.cols());
        let mut out = vec![0.0; nb * mb];
        for i in 0..n {
            out[i * mb..i * mb + m].copy_from_slice(x.row(i));
        }
        out
    }
}

impl Selector for PjrtGreedy<'_> {
    fn name(&self) -> &'static str {
        "greedy-rls-pjrt"
    }

    fn select(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &SelectionConfig,
    ) -> anyhow::Result<SelectionResult> {
        let n = x.rows();
        let m = x.cols();
        ensure!(cfg.k <= n, "k={} > n={}", cfg.k, n);
        ensure!(cfg.lambda > 0.0, "λ must be positive");
        ensure!(m == y.len(), "shape mismatch");
        let (mb, nb) = self.rt.pick_bucket(m, n).ok_or_else(|| {
            anyhow!(
                "no artifact bucket fits (m={m}, n={n}); rebuild artifacts \
                 with larger buckets (python -m compile.aot --buckets ...)"
            )
        })?;

        let init = self.rt.executable("init_state", mb, nb)?;
        let score = self.rt.executable("score_step", mb, nb)?;
        let commit = self.rt.executable("commit_step", mb, nb)?;

        // Padded constants.
        let x_pad = Self::pad_x(x, mb, nb);
        let x_lit = lit::mat_f64(&x_pad, nb, mb)?;
        let mut y_pad = vec![0.0; mb];
        y_pad[..m].copy_from_slice(y);
        let y_lit = lit::vec_f64(&y_pad);
        let mut ex_mask = vec![0.0; mb];
        ex_mask[..m].fill(1.0);
        let ex_lit = lit::vec_f64(&ex_mask);

        // init_state(X, y, λ) -> (C, a, d)
        let lam_lit = lit::vec_f64(&[cfg.lambda]);
        let mut state =
            Runtime::run_tuple(&init, &[x_lit.clone(), y_lit.clone(), lam_lit])?;
        ensure!(state.len() == 3, "init_state returned {}", state.len());
        // state = [C, a, d]

        let mut cand_mask = vec![0.0; nb];
        cand_mask[..n].fill(1.0);
        let mut selected = Vec::with_capacity(cfg.k);
        let mut rounds = Vec::with_capacity(cfg.k);

        for _ in 0..cfg.k {
            let cm_lit = lit::vec_f64(&cand_mask);
            let d_lit = &state[2];
            let a_lit = &state[1];
            let c_lit = &state[0];
            let outs = Runtime::run_tuple(
                &score,
                &[
                    x_lit.clone(),
                    c_lit.clone(),
                    a_lit.clone(),
                    d_lit.clone(),
                    y_lit.clone(),
                    cm_lit,
                    ex_lit.clone(),
                ],
            )?;
            ensure!(outs.len() == 2, "score_step returned {}", outs.len());
            let e_sq = lit::to_vec_f64(&outs[0])?;
            let e_01 = lit::to_vec_f64(&outs[1])?;
            let scores = match cfg.loss {
                Loss::Squared => &e_sq,
                Loss::ZeroOne => &e_01,
            };
            let b = argmin(&scores[..n])
                .ok_or_else(|| anyhow!("no candidate left"))?;
            rounds.push(Round { feature: b, criterion: scores[b] });

            let b_lit = lit::scalar_i32(b as i32);
            state = Runtime::run_tuple(
                &commit,
                &[
                    x_lit.clone(),
                    state[0].clone(),
                    state[1].clone(),
                    state[2].clone(),
                    b_lit,
                ],
            )?;
            ensure!(state.len() == 3, "commit_step returned {}", state.len());
            cand_mask[b] = 0.0;
            selected.push(b);
        }

        // w = X_S a (unpadded coordinates only).
        let a_full = lit::to_vec_f64(&state[1])?;
        let a = &a_full[..m];
        let weights: Vec<f64> =
            selected.iter().map(|&i| dot(x.row(i), a)).collect();
        Ok(SelectionResult { selected, rounds, weights })
    }
}

// Literal cloning: xla::Literal is a C++ heap object behind a pointer; the
// crate exposes Clone via copy construction, which we rely on for feeding
// state tuples back. (Cheap relative to kernel execution.)

#[cfg(test)]
mod tests {
    // PJRT integration tests require compiled artifacts; they live in
    // rust/tests/pjrt_integration.rs so `cargo test --lib` stays hermetic.
}
