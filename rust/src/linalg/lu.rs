//! LU factorization with partial pivoting.
//!
//! General (non-SPD) solves: used by tests as an independent oracle against
//! Cholesky, and by the general inverse needed when checking the paper's
//! SMW identities against explicit re-inversion.

use super::Matrix;

/// Compact LU factorization `P A = L U` with partial pivoting.
pub struct Lu {
    lu: Matrix,
    perm: Vec<usize>,
    sign: f64,
}

impl Lu {
    /// Factor a square matrix. Returns `None` on exact singularity.
    pub fn factor(a: &Matrix) -> Option<Lu> {
        let n = a.rows();
        assert_eq!(n, a.cols(), "LU needs a square matrix");
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for col in 0..n {
            // pivot: largest |entry| at or below the diagonal
            let mut piv = col;
            let mut max = lu[(col, col)].abs();
            for r in col + 1..n {
                let v = lu[(r, col)].abs();
                if v > max {
                    max = v;
                    piv = r;
                }
            }
            if max == 0.0 {
                return None;
            }
            if piv != col {
                for j in 0..n {
                    let tmp = lu[(col, j)];
                    lu[(col, j)] = lu[(piv, j)];
                    lu[(piv, j)] = tmp;
                }
                perm.swap(col, piv);
                sign = -sign;
            }
            let d = lu[(col, col)];
            for r in col + 1..n {
                let f = lu[(r, col)] / d;
                lu[(r, col)] = f;
                for j in col + 1..n {
                    let v = lu[(col, j)];
                    lu[(r, j)] -= f * v;
                }
            }
        }
        Some(Lu { lu, perm, sign })
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        // apply permutation, forward substitution on unit-lower part
        let mut x: Vec<f64> = self.perm.iter().map(|&p| b[p]).collect();
        for i in 1..n {
            let mut s = x[i];
            for k in 0..i {
                s -= self.lu[(i, k)] * x[k];
            }
            x[i] = s;
        }
        // back substitution on upper part
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in i + 1..n {
                s -= self.lu[(i, k)] * x[k];
            }
            x[i] = s / self.lu[(i, i)];
        }
        x
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        let mut d = self.sign;
        for i in 0..self.lu.rows() {
            d *= self.lu[(i, i)];
        }
        d
    }

    /// Full inverse (column-by-column solve).
    pub fn inverse(&self) -> Matrix {
        let n = self.lu.rows();
        let mut inv = Matrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.solve(&e);
            for i in 0..n {
                inv[(i, j)] = col[i];
            }
            e[j] = 0.0;
        }
        inv
    }
}

/// General inverse helper; `None` if singular.
pub fn inverse(a: &Matrix) -> Option<Matrix> {
    Lu::factor(a).map(|lu| lu.inverse())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;
    use crate::rng::Pcg64;

    fn random_square(rng: &mut Pcg64, n: usize) -> Matrix {
        Matrix::from_vec(n, n, (0..n * n).map(|_| rng.normal()).collect())
    }

    #[test]
    fn solve_matches_known() {
        // [[2,1],[1,3]] x = [3,5]  =>  x = [0.8, 1.4]
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = Lu::factor(&a).unwrap().solve(&[3.0, 5.0]);
        assert!((x[0] - 0.8).abs() < 1e-14);
        assert!((x[1] - 1.4).abs() < 1e-14);
    }

    #[test]
    fn inverse_roundtrip() {
        let mut rng = Pcg64::seeded(31);
        let a = random_square(&mut rng, 8);
        let inv = inverse(&a).unwrap();
        assert!(a.matmul(&inv).max_abs_diff(&Matrix::identity(8)) < 1e-9);
    }

    #[test]
    fn det_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert!((Lu::factor(&a).unwrap().det() + 2.0).abs() < 1e-14);
    }

    #[test]
    fn det_permutation_sign() {
        // row-swapped identity has det -1
        let a = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        assert!((Lu::factor(&a).unwrap().det() + 1.0).abs() < 1e-14);
    }

    #[test]
    fn singular_detected() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(Lu::factor(&a).is_none());
    }

    #[test]
    fn agrees_with_cholesky_on_spd() {
        let mut rng = Pcg64::seeded(32);
        let b = random_square(&mut rng, 6);
        let mut a = b.gram();
        a.add_diag(1.0);
        let rhs: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let x_lu = Lu::factor(&a).unwrap().solve(&rhs);
        let x_ch = Cholesky::factor(&a).unwrap().solve(&rhs);
        for (l, c) in x_lu.iter().zip(&x_ch) {
            assert!((l - c).abs() < 1e-9);
        }
    }

    #[test]
    fn needs_pivoting_case() {
        // zero top-left pivot forces a swap
        let a = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 1.0]]);
        let x = Lu::factor(&a).unwrap().solve(&[2.0, 3.0]);
        assert!((x[0] - 1.0).abs() < 1e-14);
        assert!((x[1] - 2.0).abs() < 1e-14);
    }
}
