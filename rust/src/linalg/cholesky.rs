//! Cholesky factorization for symmetric positive-definite systems.
//!
//! RLS training solves `(X_S X_Sᵀ + λI) w = X_S y` (primal, eq. 3) or
//! `(X_Sᵀ X_S + λI) a = y` (dual, eq. 4); both system matrices are SPD for
//! λ > 0, so Cholesky is the right factorization: half the flops of LU and
//! unconditionally stable here.

use super::Matrix;

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`.
pub struct Cholesky {
    l: Matrix,
}

impl Cholesky {
    /// Factor an SPD matrix. Returns `None` if a non-positive pivot is hit
    /// (matrix not positive definite to working precision).
    pub fn factor(a: &Matrix) -> Option<Cholesky> {
        let n = a.rows();
        assert_eq!(n, a.cols(), "Cholesky needs a square matrix");
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l })
    }

    /// The lower-triangular factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }

    /// Solve `A x = b` via forward + back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        // L z = b
        let mut z = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            let row = self.l.row(i);
            for k in 0..i {
                s -= row[k] * z[k];
            }
            z[i] = s / row[i];
        }
        // Lᵀ x = z
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = z[i];
            for k in i + 1..n {
                s -= self.l[(k, i)] * x[k];
            }
            x[i] = s / self.l[(i, i)];
        }
        x
    }

    /// log(det(A)) = 2 Σ log L_ii — used for model-evidence diagnostics.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;
    use crate::rng::Pcg64;

    fn random_spd(rng: &mut Pcg64, n: usize, ridge: f64) -> Matrix {
        let a = Matrix::from_vec(
            n,
            n + 3,
            (0..n * (n + 3)).map(|_| rng.normal()).collect(),
        );
        let mut g = a.gram();
        g.add_diag(ridge);
        g
    }

    #[test]
    fn reconstructs_a() {
        let mut rng = Pcg64::seeded(21);
        let a = random_spd(&mut rng, 7, 0.3);
        let ch = Cholesky::factor(&a).unwrap();
        let rec = ch.l().matmul(&ch.l().transpose());
        assert!(rec.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn solve_residual_small() {
        let mut rng = Pcg64::seeded(22);
        let a = random_spd(&mut rng, 9, 0.5);
        let b: Vec<f64> = (0..9).map(|_| rng.normal()).collect();
        let x = Cholesky::factor(&a).unwrap().solve(&b);
        let r = a.matvec(&x);
        for i in 0..9 {
            assert!((r[i] - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eig −1
        assert!(Cholesky::factor(&a).is_none());
    }

    #[test]
    fn known_2x2() {
        let a = Matrix::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]);
        let ch = Cholesky::factor(&a).unwrap();
        assert!((ch.l()[(0, 0)] - 2.0).abs() < 1e-15);
        assert!((ch.l()[(1, 0)] - 1.0).abs() < 1e-15);
        assert!((ch.l()[(1, 1)] - 2.0_f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn log_det_matches_known() {
        // det([[4,2],[2,3]]) = 8
        let a = Matrix::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]);
        let ch = Cholesky::factor(&a).unwrap();
        assert!((ch.log_det() - 8.0_f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn solve_identity_recovers_rhs() {
        let eye = Matrix::identity(5);
        let ch = Cholesky::factor(&eye).unwrap();
        let b = vec![1.0, -2.0, 3.0, 0.0, 5.0];
        let x = ch.solve(&b);
        for (xi, bi) in x.iter().zip(&b) {
            assert!((xi - bi).abs() < 1e-15);
        }
    }

    #[test]
    fn orthogonality_check_via_dot() {
        // sanity for the test-helper dot import
        assert_eq!(dot(&[1.0, 0.0], &[0.0, 1.0]), 0.0);
    }
}
